//! Operator plane end-to-end: the HTTP surface must report exactly what
//! the typed [`FabricSnapshot`] holds, the control verbs must be
//! bit-identical to calling the underlying [`FabricServer`] methods
//! directly, and the plane — disabled or scraping at 10 Hz — must never
//! change a session's scores.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::fabric::operator::OperatorServer;
use fsead::fabric::server::{FabricServer, SessionSpec};

fn tiny(name: &'static str, n: usize, d: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

fn cpu_cfg(chunk: usize, kinds: &[DetectorKind]) -> FseadConfig {
    let mut cfg = FseadConfig { use_fpga: false, chunk, ..FseadConfig::default() };
    for (i, k) in kinds.iter().enumerate() {
        cfg.pblocks.push(PblockCfg {
            id: i + 1,
            rm: RmKind::Detector(*k),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

/// Minimal HTTP/1.1 client: one request, one response, connection closed.
fn http(addr: SocketAddr, method: &str, path: &str, body: &str, token: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect operator");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let auth = match token {
        Some(t) => format!("Authorization: Bearer {t}\r\n"),
        None => String::new(),
    };
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: operator\r\n{auth}Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

/// Pull one sample's value out of a Prometheus text exposition.
fn metric(text: &str, key: &str) -> f64 {
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        if let Some((name, value)) = line.rsplit_once(' ') {
            if name == key {
                return value.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
            }
        }
    }
    panic!("metric {key:?} not found");
}

fn serve_dataset(server: &FabricServer, ds: &Dataset, pblock: usize, window: usize) -> Vec<f32> {
    let mut session =
        server.open(SessionSpec::for_dataset(ds, window).on_pblock(pblock)).unwrap();
    session.push(&ds.data).unwrap();
    session.close().unwrap().scores
}

#[test]
fn metrics_equal_snapshot_and_state_serves_json() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda, DetectorKind::RsHash]);
    let window = cfg.hyper.window;
    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let op = OperatorServer::start("127.0.0.1:0", None, Arc::clone(&server)).unwrap();
    let ds = tiny("operator", 120, 3, 7);
    serve_dataset(&server, &ds, 1, window);
    serve_dataset(&server, &ds, 2, window);

    // The scrape must render exactly the values the typed snapshot holds
    // (the fabric is idle, so back-to-back reads see the same counters).
    let snap = server.snapshot();
    let (status, text) = http(op.addr(), "GET", "/metrics", "", None);
    assert_eq!(status, 200, "{text}");
    assert_eq!(
        metric(&text, "fsead_server_sessions_served_total"),
        snap.server.sessions_served as f64
    );
    assert_eq!(metric(&text, "fsead_server_sessions_active"), 0.0);
    assert_eq!(metric(&text, "fsead_server_sessions_parked"), 0.0);
    for p in &snap.partitions {
        let key = |name: &str| format!("{name}{{partition=\"{}\"}}", p.id);
        assert_eq!(metric(&text, &key("fsead_partition_flits_seen")), p.flits_seen as f64);
        assert_eq!(metric(&text, &key("fsead_swap_executed_total")), p.swaps_executed as f64);
        assert_eq!(metric(&text, &key("fsead_partition_session_capacity")), p.capacity as f64);
        assert_eq!(metric(&text, &key("fsead_faults_events_total")), p.fault_events as f64);
        assert_eq!(
            metric(&text, &key("fsead_controller_threshold")),
            p.controller_threshold
        );
    }
    // Prometheus text discipline: every non-comment line is `name value`
    // with a parseable float, every family has HELP + TYPE.
    for line in text.lines() {
        if line.starts_with('#') {
            assert!(
                line.starts_with("# HELP fsead_") || line.starts_with("# TYPE fsead_"),
                "stray comment: {line:?}"
            );
            continue;
        }
        let (name, value) = line.rsplit_once(' ').expect("name value");
        assert!(name.starts_with("fsead_"), "{line:?}");
        assert!(value.parse::<f64>().is_ok(), "{line:?}");
    }

    // /state mirrors the same snapshot as JSON.
    let (status, json) = http(op.addr(), "GET", "/state", "", None);
    assert_eq!(status, 200);
    assert!(json.contains(&format!("\"sessions_served\":{}", snap.server.sessions_served)));
    assert!(json.contains("\"partitions\":[{\"id\":1,\"rm\":\"loda\""));
    assert!(json.contains("\"id\":2,\"rm\":\"rshash\""));

    op.stop();
    Arc::try_unwrap(server).ok().expect("sole owner").shutdown().unwrap();
}

#[test]
fn post_swap_is_bit_identical_to_schedule_swap() {
    // Server A stages a mid-stream swap through the public method, server
    // B through POST /swap with the same parameters: both sessions must
    // score bit-identically, and the POST must report the same dark-window
    // model numbers the method returned.
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let ds = tiny("swap", 150, 3, 11);

    let a = FabricServer::start(cfg.clone()).unwrap();
    let (model_ms, dark_flits) =
        a.schedule_swap(1, 4, RmKind::Detector(DetectorKind::RsHash), 2, None).unwrap();
    let scores_a = serve_dataset(&a, &ds, 1, window);
    a.shutdown().unwrap();

    let b = Arc::new(FabricServer::start(cfg).unwrap());
    let op = OperatorServer::start("127.0.0.1:0", None, Arc::clone(&b)).unwrap();
    let (status, body) = http(
        op.addr(),
        "POST",
        "/swap",
        r#"{"pblock": 1, "at_flit": 4, "rm": "rshash", "r": 2}"#,
        None,
    );
    assert_eq!(status, 200, "{body}");
    assert_eq!(body, format!("{{\"model_ms\":{model_ms},\"dark_flits\":{dark_flits}}}"));
    let scores_b = serve_dataset(&b, &ds, 1, window);
    assert_eq!(scores_a, scores_b, "POST /swap diverged from schedule_swap");

    // The executed swap shows up on the scrape.
    let (_, text) = http(op.addr(), "GET", "/metrics", "", None);
    assert_eq!(metric(&text, "fsead_swap_executed_total{partition=\"1\"}"), 1.0);

    op.stop();
    Arc::try_unwrap(b).ok().expect("sole owner").shutdown().unwrap();
}

#[test]
fn drain_parks_sessions_and_resume_round_trips() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let ds = tiny("drain", 120, 3, 23);

    // Uninterrupted reference.
    let reference = {
        let server = FabricServer::start(cfg.clone()).unwrap();
        let scores = serve_dataset(&server, &ds, 1, window);
        server.shutdown().unwrap();
        scores
    };

    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let op = OperatorServer::start("127.0.0.1:0", None, Arc::clone(&server)).unwrap();
    let mut session =
        server.open(SessionSpec::for_dataset(&ds, window).on_pblock(1)).unwrap();
    let id = session.id();
    let cut = 64 * ds.d;
    session.push(&ds.data[..cut]).unwrap();

    let (status, body) = http(op.addr(), "POST", "/drain", "{\"pblock\": 1}", None);
    assert_eq!(status, 200, "{body}");
    assert_eq!(body, format!("{{\"draining\":[{id}]}}"));

    // The drained session parks; the client collects the ticket and
    // resumes — the stream must pick up exactly where it left off.
    let (ticket, mut scores) = session.suspend().unwrap();
    assert_eq!(ticket.id, id);
    let mut resumed = server.resume(ticket).unwrap();
    resumed.push(&ds.data[cut..]).unwrap();
    scores.extend(resumed.close().unwrap().scores);
    assert_eq!(scores, reference, "drain + resume changed the scores");

    // Draining an unknown partition is a 404, not a refusal.
    let (status, _) = http(op.addr(), "POST", "/drain", "{\"pblock\": 9}", None);
    assert_eq!(status, 404);

    op.stop();
    Arc::try_unwrap(server).ok().expect("sole owner").shutdown().unwrap();
}

#[test]
fn scraping_at_10hz_leaves_scores_bit_identical() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let ds = tiny("scrape", 200, 3, 31);

    // Plane disabled: the baseline.
    let baseline = {
        let server = FabricServer::start(cfg.clone()).unwrap();
        let scores = serve_dataset(&server, &ds, 1, window);
        server.shutdown().unwrap();
        scores
    };

    // Plane enabled with a concurrent scraper hammering /metrics and
    // /state while the session streams.
    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let op = OperatorServer::start("127.0.0.1:0", None, Arc::clone(&server)).unwrap();
    let addr = op.addr();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let scraper = std::thread::spawn(move || {
        let mut scrapes = 0u64;
        while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
            let (status, _) = http(addr, "GET", "/metrics", "", None);
            assert_eq!(status, 200);
            let (status, _) = http(addr, "GET", "/state", "", None);
            assert_eq!(status, 200);
            scrapes += 1;
            std::thread::sleep(Duration::from_millis(100));
        }
        scrapes
    });
    let scores = serve_dataset(&server, &ds, 1, window);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let scrapes = scraper.join().unwrap();
    assert!(scrapes > 0, "scraper never ran");
    assert_eq!(scores, baseline, "a live scrape changed session scores");

    op.stop();
    Arc::try_unwrap(server).ok().expect("sole owner").shutdown().unwrap();
}

#[test]
fn connection_flood_sheds_with_503_and_recovers() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let op =
        OperatorServer::start_with_limit("127.0.0.1:0", None, Arc::clone(&server), 2).unwrap();

    // Two connections camp on both handler slots by sending an incomplete
    // request head and holding the socket open — each parks its handler
    // thread in the (timed) read loop.
    let hold = |addr: SocketAddr| -> TcpStream {
        let mut s = TcpStream::connect(addr).expect("connect holder");
        s.write_all(b"GET /metrics HTTP/1.1\r\n").unwrap();
        s
    };
    let h1 = hold(op.addr());
    let h2 = hold(op.addr());

    // Everything past the cap is shed on the accept thread with a named
    // 503 — no handler thread is spawned for it, and the listener keeps
    // answering instead of silently queueing work. (The holders were
    // accepted first, so the gauge is at the cap by the time these probes
    // reach the accept loop.)
    for _ in 0..4 {
        let (status, body) = http(op.addr(), "GET", "/metrics", "", None);
        assert_eq!(status, 503, "{body}");
        assert!(body.contains("too many concurrent operator connections"), "{body}");
    }

    // Releasing the campers frees their slots; the server serves normally
    // again (poll briefly — the handlers notice the hang-up on their own
    // schedule).
    drop(h1);
    drop(h2);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let (status, text) = http(op.addr(), "GET", "/metrics", "", None);
        if status == 200 {
            assert!(text.contains("fsead_server_sessions_served_total"), "{text}");
            break;
        }
        assert_eq!(status, 503, "unexpected status during recovery");
        assert!(
            std::time::Instant::now() < deadline,
            "server never recovered after the flood"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    op.stop();
    Arc::try_unwrap(server).ok().expect("sole owner").shutdown().unwrap();
}

#[test]
fn auth_and_error_mapping() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let op =
        OperatorServer::start("127.0.0.1:0", Some("s3cret".into()), Arc::clone(&server)).unwrap();

    // Bearer auth gates every endpoint.
    let (status, _) = http(op.addr(), "GET", "/metrics", "", None);
    assert_eq!(status, 401);
    let (status, _) = http(op.addr(), "GET", "/metrics", "", Some("wrong"));
    assert_eq!(status, 401);
    let (status, _) = http(op.addr(), "GET", "/metrics", "", Some("s3cret"));
    assert_eq!(status, 200);

    let t = Some("s3cret");
    // Unknown path → 404; known path, wrong method → 405.
    let (status, _) = http(op.addr(), "GET", "/nope", "", t);
    assert_eq!(status, 404);
    let (status, _) = http(op.addr(), "POST", "/metrics", "", t);
    assert_eq!(status, 405);
    let (status, _) = http(op.addr(), "GET", "/swap", "", t);
    assert_eq!(status, 405);
    // Malformed / incomplete bodies → 400 with a named error.
    let (status, body) = http(op.addr(), "POST", "/swap", "{\"pblock\": 1}", t);
    assert_eq!(status, 400);
    assert!(body.contains("at_flit"), "{body}");
    let (status, _) = http(op.addr(), "POST", "/swap", "not json", t);
    assert_eq!(status, 400);
    let (status, body) = http(
        op.addr(),
        "POST",
        "/swap",
        r#"{"pblock": 1, "at_flit": 0, "rm": "warp", "r": 2}"#,
        t,
    );
    assert_eq!(status, 400);
    assert!(body.contains("warp"), "{body}");
    // Unknown partition → 404.
    let (status, _) = http(
        op.addr(),
        "POST",
        "/swap",
        r#"{"pblock": 6, "at_flit": 0, "rm": "loda", "r": 2}"#,
        t,
    );
    assert_eq!(status, 404);
    // Controller tuning: nothing to set → 409; bad threshold → 409;
    // a live adjustment → 200 and visible on the next scrape.
    let (status, _) = http(op.addr(), "POST", "/controller", "{\"pblock\": 1}", t);
    assert_eq!(status, 409);
    let (status, _) =
        http(op.addr(), "POST", "/controller", "{\"threshold\": -1}", t);
    assert_eq!(status, 409);
    let (status, body) = http(
        op.addr(),
        "POST",
        "/controller",
        r#"{"pblock": 1, "threshold": 2.5, "cooldown_flits": 64}"#,
        t,
    );
    assert_eq!(status, 200, "{body}");
    let (_, text) = http(op.addr(), "GET", "/metrics", "", t);
    assert_eq!(metric(&text, "fsead_controller_threshold{partition=\"1\"}"), 2.5);
    assert_eq!(metric(&text, "fsead_controller_cooldown_flits{partition=\"1\"}"), 64.0);

    op.stop();
    Arc::try_unwrap(server).ok().expect("sole owner").shutdown().unwrap();
}
