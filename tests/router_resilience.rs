//! Router resilience end-to-end: sessions sharded across worker processes
//! must score bit-identically to a direct, uninterrupted `fsead net`
//! session — through router-driven checkpoints, graceful drain + re-shard
//! onto a joining worker, and abrupt worker death mid-stream (survivors
//! absorb the orphans from the router-held ticket). Loss is only ever the
//! typed, bounded kind; silent divergence is the one unforgivable failure.

use std::io::{BufRead, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fsead::config::{FseadConfig, PblockCfg, RmKind, RouterCfg};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::fabric::net::{NetServer, STATUS_REROUTED};
use fsead::fabric::net_client::NetClient;
use fsead::fabric::router::Router;
use fsead::fabric::server::{FabricServer, SessionSpec};
use fsead::fabric::worker_pool::splitmix64;

fn tiny(name: &'static str, n: usize, d: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

fn cpu_cfg(exec: ExecMode) -> FseadConfig {
    let mut cfg = FseadConfig { use_fpga: false, chunk: 16, ..FseadConfig::default() };
    cfg.exec = exec;
    // Plenty of session slots: re-shards concentrate every session on the
    // survivors, and admission must never become the thing under test.
    cfg.server.sessions_per_partition = 64;
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 2,
        stream: 0,
        lanes: 0,
    });
    cfg
}

/// An in-process worker: the same fabric + net listener `fsead net` runs,
/// with a distinct session-id base so ids stay unique across the fleet.
fn start_worker(exec: ExecMode, base: u64) -> (Arc<FabricServer>, NetServer) {
    let mut cfg = cpu_cfg(exec);
    cfg.server.session_id_base = base;
    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let net = NetServer::start("127.0.0.1:0", Arc::clone(&server)).unwrap();
    (server, net)
}

/// Stop the listener, wait for connection handlers to drop their server
/// clones, then shut the fabric down. Handlers release once the router's
/// upstream connections die, so the router must be stopped first.
fn stop_worker(net: NetServer, server: Arc<FabricServer>) {
    net.stop();
    let mut server = server;
    for _ in 0..2000 {
        match Arc::try_unwrap(server) {
            Ok(s) => {
                s.shutdown().unwrap();
                return;
            }
            Err(s) => {
                server = s;
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    panic!("a worker connection handler never released the fabric");
}

/// In-process reference: the session API end to end, one pblock, never
/// interrupted. The parity bar every routed stream is held to.
fn reference_scores(exec: ExecMode, ds: &Dataset) -> Vec<f32> {
    let cfg = cpu_cfg(exec);
    let window = cfg.hyper.window;
    let server = FabricServer::start(cfg).unwrap();
    let mut session = server.open(SessionSpec::for_dataset(ds, window).on_pblock(1)).unwrap();
    session.push(&ds.data).unwrap();
    let scores = session.close().unwrap().scores;
    server.shutdown().unwrap();
    scores
}

/// Router tuned for tests: fast heartbeat, two strikes, checkpoints every
/// few pushes so the replay window is actually exercised.
fn test_router(workers: Vec<String>) -> Router {
    let cfg = RouterCfg {
        enabled: true,
        addr: "127.0.0.1:0".into(),
        workers,
        heartbeat_ms: 50,
        max_failures: 2,
        checkpoint_pushes: 4,
        connect_timeout_ms: 500,
        io_timeout_ms: 0,
        retry_deadline_ms: 5_000,
        backoff_base_ms: 5,
        ..RouterCfg::default()
    };
    Router::start(&cfg).unwrap()
}

// ---------------------------------------------------------------------------
// A killable TCP proxy: the router dials the proxy, the proxy pipes bytes
// to the real worker. `kill()` severs every live connection and refuses
// new ones — from the router's side, indistinguishable from `kill -9` of
// the worker process, while the test keeps a clean handle on the fabric.
// ---------------------------------------------------------------------------

struct Proxy {
    addr: String,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Proxy {
    fn start(upstream: String) -> Proxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&conns);
        let accept = std::thread::spawn(move || {
            for inbound in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(down) = inbound else { continue };
                let Ok(up) = TcpStream::connect(&upstream) else { continue };
                let down2 = down.try_clone().unwrap();
                let up2 = up.try_clone().unwrap();
                {
                    let mut held = conns2.lock().unwrap();
                    held.push(down.try_clone().unwrap());
                    held.push(up.try_clone().unwrap());
                }
                std::thread::spawn(move || pump(down, up2));
                std::thread::spawn(move || pump(up, down2));
            }
        });
        Proxy { addr, stop, conns, accept: Some(accept) }
    }

    fn kill(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept; the loop sees the flag and drops the
        // listener, so later connects are refused outright.
        let _ = TcpStream::connect(&self.addr);
        for s in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Proxy {
    fn drop(&mut self) {
        self.kill();
    }
}

fn pump(mut from: TcpStream, mut to: TcpStream) {
    let mut buf = [0u8; 4096];
    loop {
        match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = to.shutdown(Shutdown::Both);
}

/// Which worker address the ring assigns this session to right now.
fn owner_addr(router: &Router, id: u64) -> String {
    let pool = router.pool();
    let idx = pool.owner(splitmix64(id)).expect("at least one routable worker");
    pool.addr_of(idx)
}

// ---------------------------------------------------------------------------
// Transparency: with one worker and nothing failing, the router must be
// invisible — bit-identical scores, no notices — in both exec modes.
// ---------------------------------------------------------------------------

#[test]
fn single_worker_router_is_bit_transparent_in_both_exec_modes() {
    for exec in ExecMode::ALL {
        let ds = tiny("transparent", 400, 3, 71);
        let reference = reference_scores(exec, &ds);
        let window = cpu_cfg(exec).hyper.window;

        let (server, net) = start_worker(exec, 1 << 32);
        let router = test_router(vec![net.addr().to_string()]);

        let mut client = NetClient::connect(&router.addr().to_string()).unwrap();
        client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
        // 7-row blocks: neither flit-aligned nor checkpoint-aligned, so
        // router checkpoints land on staged partial flits.
        let mut scores = Vec::new();
        for block in ds.data.chunks(7 * ds.d) {
            scores.extend(client.push(block).unwrap());
        }
        let closed = client.close().unwrap();
        scores.extend(closed.scores);
        assert_eq!(closed.samples, ds.n() as u64, "{exec:?}");
        assert_eq!(
            scores, reference,
            "{exec:?}: routed scores diverged from a direct session"
        );
        assert!(
            client.take_notices().is_empty(),
            "{exec:?}: a healthy single-worker route must emit no notices"
        );
        assert_eq!(router.stats().lost, 0, "{exec:?}");

        drop(client);
        router.stop();
        stop_worker(net, server);
    }
}

// ---------------------------------------------------------------------------
// Suspend → ticket over the wire → resume, with the router in the middle
// on both legs. The ticket a routed client holds is portable.
// ---------------------------------------------------------------------------

#[test]
fn suspend_and_resume_through_the_router_round_trips_bit_identically() {
    let exec = ExecMode::Batched;
    let ds = tiny("ticket-hop", 400, 3, 73);
    let reference = reference_scores(exec, &ds);
    let window = cpu_cfg(exec).hyper.window;

    let (server_a, net_a) = start_worker(exec, 1 << 32);
    let (server_b, net_b) = start_worker(exec, 2 << 32);
    let router = test_router(vec![net_a.addr().to_string(), net_b.addr().to_string()]);
    let addr = router.addr().to_string();

    let cut = 150 * ds.d;
    let mut client = NetClient::connect(&addr).unwrap();
    client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
    let mut scores = Vec::new();
    for block in ds.data[..cut].chunks(11 * ds.d) {
        scores.extend(client.push(block).unwrap());
    }
    let (ticket, flushed) = client.suspend().unwrap();
    scores.extend(flushed);
    drop(client);

    let mut resumed = NetClient::connect(&addr).unwrap();
    resumed.resume(&ticket).unwrap();
    for block in ds.data[cut..].chunks(11 * ds.d) {
        scores.extend(resumed.push(block).unwrap());
    }
    let closed = resumed.close().unwrap();
    scores.extend(closed.scores);
    assert_eq!(scores, reference, "suspend/resume through the router diverged");
    assert_eq!(router.stats().lost, 0);

    drop(resumed);
    router.stop();
    stop_worker(net_a, server_a);
    stop_worker(net_b, server_b);
}

// ---------------------------------------------------------------------------
// Crash recovery: kill a worker mid-stream under multi-session load. The
// survivors must absorb its sessions from the router-held tickets, the
// score stream must stay bit-identical, and affected clients must see the
// `rerouted` notice — never a hang, never silent loss.
// ---------------------------------------------------------------------------

#[test]
fn killing_a_worker_mid_stream_reshards_onto_survivors_bit_identically() {
    for exec in ExecMode::ALL {
        let window = cpu_cfg(exec).hyper.window;
        let (server_a, net_a) = start_worker(exec, 1 << 32);
        let (server_b, net_b) = start_worker(exec, 2 << 32);
        let mut proxy = Proxy::start(net_a.addr().to_string());
        let proxied = proxy.addr.clone();
        let router = test_router(vec![proxied.clone(), net_b.addr().to_string()]);
        let addr = router.addr().to_string();

        // Open sessions until both workers own at least one — ownership is
        // a deterministic function of the ring, so peek instead of hoping.
        let mut sessions = Vec::new();
        let mut on_proxy = 0usize;
        let mut on_direct = 0usize;
        for i in 0..24 {
            let ds = tiny("kill", 320, 3, 100 + i as u64);
            let mut client = NetClient::connect(&addr).unwrap();
            let id = client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
            if owner_addr(&router, id) == proxied {
                on_proxy += 1;
            } else {
                on_direct += 1;
            }
            sessions.push((client, ds, Vec::<f32>::new()));
            if sessions.len() >= 6 && on_proxy >= 1 && on_direct >= 1 {
                break;
            }
        }
        assert!(
            on_proxy >= 1 && on_direct >= 1,
            "24 sessions never covered both workers — the ring is broken"
        );

        // First half streams with everything healthy (and checkpoints
        // firing every 4 pushes).
        let cut = 160 * 3;
        for (client, ds, scores) in &mut sessions {
            for block in ds.data[..cut].chunks(25 * ds.d) {
                scores.extend(client.push(block).unwrap());
            }
        }

        // kill -9, as seen from the router: every byte in flight is gone,
        // new connects are refused.
        proxy.kill();

        // Second half: sessions that lived on the dead worker re-shard
        // onto the survivor from their last router-held checkpoint.
        for (client, ds, scores) in &mut sessions {
            for block in ds.data[cut..].chunks(25 * ds.d) {
                scores.extend(client.push(block).unwrap());
            }
            let closed = client.close().unwrap();
            scores.extend(closed.scores);
        }

        let mut rerouted_clients = 0usize;
        for (client, ds, scores) in &mut sessions {
            let reference = reference_scores(exec, ds);
            assert_eq!(
                scores, &reference,
                "{exec:?}: a re-sharded session diverged from its uninterrupted twin"
            );
            let notices = client.take_notices();
            if notices.iter().any(|n| n.code == STATUS_REROUTED) {
                rerouted_clients += 1;
            }
        }
        assert!(
            rerouted_clients >= on_proxy.min(1),
            "{exec:?}: no client saw the rerouted notice"
        );

        let stats = router.stats();
        assert!(stats.rerouted >= 1, "{exec:?}: {stats:?}");
        assert_eq!(stats.lost, 0, "{exec:?}: sessions were lost, not re-sharded");
        assert_eq!(stats.gap_samples, 0, "{exec:?}: replay should cover every sample");

        // The heartbeat prober must also notice the corpse and eject it
        // within a few probe periods.
        let deadline = Instant::now() + Duration::from_secs(5);
        while router.stats().ejections == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(router.stats().ejections >= 1, "{exec:?}: the dead worker was never ejected");

        drop(sessions);
        router.stop();
        stop_worker(net_a, server_a);
        stop_worker(net_b, server_b);
    }
}

// ---------------------------------------------------------------------------
// Graceful re-shard: join a worker, drain the old one. Every session moves
// via suspend → carry ticket → resume with zero divergence.
// ---------------------------------------------------------------------------

#[test]
fn joining_a_worker_and_draining_the_old_one_migrates_without_divergence() {
    let exec = ExecMode::Batched;
    let window = cpu_cfg(exec).hyper.window;
    let (server_a, net_a) = start_worker(exec, 1 << 32);
    let (server_b, net_b) = start_worker(exec, 2 << 32);
    let router = test_router(vec![net_a.addr().to_string()]);
    let addr = router.addr().to_string();

    let mut sessions = Vec::new();
    for i in 0..4 {
        let ds = tiny("drain", 320, 3, 200 + i as u64);
        let mut client = NetClient::connect(&addr).unwrap();
        client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
        sessions.push((client, ds, Vec::<f32>::new()));
    }

    let cut = 160 * 3;
    for (client, ds, scores) in &mut sessions {
        for block in ds.data[..cut].chunks(25 * ds.d) {
            scores.extend(client.push(block).unwrap());
        }
    }

    // B joins the ring; A drains. Every session's owner is now B, and the
    // next push per session triggers the clean suspend-carry-resume hop.
    router.add_worker(&net_b.addr().to_string());
    assert!(router.drain_worker(&net_a.addr().to_string()));

    for (client, ds, scores) in &mut sessions {
        for block in ds.data[cut..].chunks(25 * ds.d) {
            scores.extend(client.push(block).unwrap());
        }
        let closed = client.close().unwrap();
        scores.extend(closed.scores);
    }

    for (client, ds, scores) in &mut sessions {
        let reference = reference_scores(exec, ds);
        assert_eq!(scores, &reference, "a drained session diverged while migrating");
        let notices = client.take_notices();
        assert!(
            notices.iter().any(|n| n.code == STATUS_REROUTED),
            "every session must report its migration off the draining worker"
        );
    }

    let stats = router.stats();
    assert!(stats.rerouted >= sessions.len() as u64, "{stats:?}");
    assert_eq!(stats.lost, 0, "{stats:?}");

    drop(sessions);
    router.stop();
    stop_worker(net_a, server_a);
    stop_worker(net_b, server_b);
}

// ---------------------------------------------------------------------------
// The real thing: kill -9 an actual `fsead net` worker process and let the
// survivors absorb its sessions. Gated on the binary being built (cargo
// sets CARGO_BIN_EXE_fsead for integration tests when the bin target
// exists); skipped silently otherwise so library-only builds stay green.
// ---------------------------------------------------------------------------

#[test]
fn kill_minus_nine_of_a_worker_process_reshards_onto_survivors() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_fsead") else {
        eprintln!("skipping: no fsead binary in this build");
        return;
    };
    let exec = ExecMode::Batched;

    // The workers must run the exact config the in-process reference uses.
    let cfg_path = std::env::temp_dir().join(format!(
        "fsead-router-resilience-{}.toml",
        std::process::id()
    ));
    std::fs::write(
        &cfg_path,
        "[fabric]\nuse_fpga = false\nchunk = 16\nexec = \"batched\"\n\n\
         [fabric.server]\nsessions_per_partition = 64\n\n\
         [pblock.1]\nrm = \"loda\"\nr = 2\nstream = 0\n",
    )
    .unwrap();

    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..3u64 {
        let child = std::process::Command::new(bin)
            .arg("net")
            .arg("127.0.0.1:0")
            .arg(&cfg_path)
            .arg("--session-base")
            .arg(((i + 1) << 32).to_string())
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn fsead net worker");
        children.push(child);
    }
    for child in &mut children {
        let stdout = child.stdout.take().expect("worker stdout piped");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let addr = loop {
            let line = lines
                .next()
                .expect("worker exited before announcing its address")
                .expect("worker stdout read");
            if let Some(rest) = line.strip_prefix("net plane on ") {
                break rest.split_whitespace().next().unwrap().to_string();
            }
        };
        addrs.push(addr);
    }

    let router = test_router(addrs.clone());
    let addr = router.addr().to_string();
    let window = cpu_cfg(exec).hyper.window;

    let mut sessions = Vec::new();
    for i in 0..6 {
        let ds = tiny("process-kill", 320, 3, 300 + i as u64);
        let mut client = NetClient::connect(&addr).unwrap();
        let id = client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
        let owner = owner_addr(&router, id);
        sessions.push((client, ds, Vec::<f32>::new(), owner));
    }

    let cut = 160 * 3;
    for (client, ds, scores, _) in &mut sessions {
        for block in ds.data[..cut].chunks(25 * ds.d) {
            scores.extend(client.push(block).unwrap());
        }
    }

    // Kill the worker that owns session 0 — for real, no cleanup handlers.
    let victim_addr = sessions[0].3.clone();
    let victim = addrs.iter().position(|a| *a == victim_addr).unwrap();
    children[victim].kill().unwrap();
    children[victim].wait().unwrap();

    for (client, ds, scores, _) in &mut sessions {
        for block in ds.data[cut..].chunks(25 * ds.d) {
            scores.extend(client.push(block).unwrap());
        }
        let closed = client.close().unwrap();
        scores.extend(closed.scores);
    }

    for (client, ds, scores, owner) in &mut sessions {
        let reference = reference_scores(exec, ds);
        assert_eq!(
            scores, &reference,
            "a session (owner {owner}) diverged after the worker was killed"
        );
        if *owner == victim_addr {
            assert!(
                client.take_notices().iter().any(|n| n.code == STATUS_REROUTED),
                "the killed worker's client never saw the rerouted notice"
            );
        }
    }

    let stats = router.stats();
    assert!(stats.rerouted >= 1, "{stats:?}");
    assert_eq!(stats.lost, 0, "{stats:?}");

    let deadline = Instant::now() + Duration::from_secs(5);
    while router.stats().ejections == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(router.stats().ejections >= 1, "the killed process was never ejected");

    drop(sessions);
    router.stop();
    for mut child in children {
        if let Some(mut stdin) = child.stdin.take() {
            let _ = stdin.write_all(b"quit\n");
        }
        let _ = child.kill();
        let _ = child.wait();
    }
    let _ = std::fs::remove_file(&cfg_path);
}
