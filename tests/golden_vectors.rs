//! Golden-vector regression tests: the three detectors' scores on a fixed
//! seeded stream are pinned against checked-in fixtures, so any arithmetic
//! drift — a reordered accumulation, a changed clamp, a hoisted expression
//! that alters rounding — is caught at the 1e-6 level, independently of the
//! batched-vs-sequential parity proptests (which would both drift together
//! if the shared arithmetic changed).
//!
//! The fixtures live in `tests/fixtures/golden_<kind>.txt` and were
//! produced by `python/tools/gen_golden_vectors.py`, a bit-level port of
//! the rust detectors validated against the Jenkins golden vectors and an
//! independent f64 reference implementation. To regenerate after an
//! *intentional* arithmetic change:
//!
//! ```sh
//! FSEAD_BLESS_GOLDEN=1 cargo test --test golden_vectors
//! # or: python3 python/tools/gen_golden_vectors.py tests/fixtures
//! ```

use fsead::detectors::prng::Prng;
use fsead::detectors::{DetectorKind, DetectorSpec};

/// Must mirror python/tools/gen_golden_vectors.py exactly.
const STREAM_SEED: u64 = 20240601;
const N: usize = 64;
const D: usize = 3;
const WARMUP_SAMPLES: usize = 16;
const WINDOW: usize = 16;
const BINS: usize = 8;
const W: usize = 2;
const MODULUS: usize = 32;
const K: usize = 4;
const R: usize = 4;
const DET_SEED: u64 = 7;

fn fixture_stream() -> Vec<f32> {
    let mut p = Prng::new(STREAM_SEED);
    (0..N * D).map(|_| p.gaussian() as f32).collect()
}

fn spec_for(kind: DetectorKind) -> DetectorSpec {
    let mut spec = DetectorSpec::new(kind, D, R, DET_SEED);
    spec.window = WINDOW;
    spec.bins = BINS;
    spec.w = W;
    spec.modulus = MODULUS;
    spec.k = K;
    spec
}

fn fixture_path(kind: DetectorKind) -> String {
    format!("tests/fixtures/golden_{}.txt", kind.as_str())
}

fn load_fixture(kind: DetectorKind) -> Vec<f32> {
    let path = fixture_path(kind);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {path}: {e} (run the bless command in the header)"));
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(|l| l.parse::<f32>().unwrap_or_else(|e| panic!("{path}: bad score {l:?}: {e}")))
        .collect()
}

fn bless(kind: DetectorKind, scores: &[f32]) {
    let path = fixture_path(kind);
    let mut out = format!(
        "# golden scores: {} r={R} d={D} seed={DET_SEED} window={WINDOW}\n\
         # stream: {N} samples, Prng({STREAM_SEED}) unit gaussians, warmup={WARMUP_SAMPLES}\n",
        kind.as_str()
    );
    for s in scores {
        out.push_str(&format!("{s}\n"));
    }
    std::fs::write(&path, out).unwrap_or_else(|e| panic!("writing {path}: {e}"));
    eprintln!("blessed {path}");
}

/// |got − want| ≤ 1e-6 · max(1, |want|): catches drift at the 1e-6 level
/// while absorbing sub-ulp libm differences across platforms.
fn assert_close(kind: DetectorKind, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{kind:?}: fixture length");
    let mut worst = 0f64;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = 1e-6 * f64::from(w.abs()).max(1.0);
        let diff = (f64::from(g) - f64::from(w)).abs();
        worst = worst.max(diff);
        assert!(
            diff <= tol,
            "{kind:?}: sample {i} drifted: got {g}, fixture {w} (|diff| = {diff:.3e})"
        );
    }
    eprintln!("{kind:?}: max |score − fixture| = {worst:.3e}");
}

fn run_golden(kind: DetectorKind) {
    let data = fixture_stream();
    let warmup = &data[..WARMUP_SAMPLES * D];
    let mut det = spec_for(kind).build(warmup);
    let scores = det.run_stream(&data);
    assert_eq!(scores.len(), N);
    assert_eq!(scores[0], 0.0, "{kind:?}: first sample must score 0 (denom=1, count clamp)");
    if std::env::var("FSEAD_BLESS_GOLDEN").is_ok() {
        bless(kind, &scores);
        return;
    }
    let want = load_fixture(kind);
    assert_close(kind, &scores, &want);
    // The batch fast path must hit the same fixtures bit-for-bit with the
    // per-sample loop (it is asserted bit-identical to `update` in the
    // detector unit tests; here it is pinned to the absolute values too).
    let mut det = spec_for(kind).build(warmup);
    let mut batched = vec![0f32; N];
    det.update_batch(&data, &mut batched);
    assert_eq!(scores, batched, "{kind:?}: update_batch diverged from run_stream");
    assert_close(kind, &batched, &want);
}

#[test]
fn golden_loda() {
    run_golden(DetectorKind::Loda);
}

#[test]
fn golden_rshash() {
    run_golden(DetectorKind::RsHash);
}

#[test]
fn golden_xstream() {
    run_golden(DetectorKind::XStream);
}

#[test]
fn fixtures_are_committed_for_all_kinds() {
    for kind in DetectorKind::ALL {
        let fix = load_fixture(kind);
        assert_eq!(fix.len(), N, "{kind:?}: fixture must hold one score per sample");
        assert!(fix.iter().all(|s| s.is_finite()), "{kind:?}");
    }
}
