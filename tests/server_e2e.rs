//! Session server end-to-end: the persistent `FabricServer` must reproduce
//! the one-shot `Fabric::run` data plane bit-for-bit — same detector
//! parameters (shared per-pblock seed), same chunking (DMA-identical flit
//! cutting), same service loops — in both execution modes, with and
//! without mid-session live DFX; and it must survive multi-client session
//! churn without leaking scores across sessions or deadlocking at
//! shutdown.

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::ExecMode;
use fsead::fabric::server::{FabricServer, SessionSpec};
use fsead::fabric::{pblock_seed, Fabric};

fn tiny(name: &'static str, n: usize, d: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

fn cpu_cfg(exec: ExecMode, chunk: usize) -> FseadConfig {
    FseadConfig { use_fpga: false, chunk, exec, ..FseadConfig::default() }
}

/// Standalone reference: the detector a fabric pblock builds (same seed,
/// same hyper-parameters, same warm-up recipe) run over the whole stream.
fn standalone_scores(
    cfg: &FseadConfig,
    kind: DetectorKind,
    r: usize,
    pblock: usize,
    ds: &Dataset,
) -> Vec<f32> {
    let mut spec = DetectorSpec::new(kind, ds.d, r, pblock_seed(cfg.seed, pblock));
    spec.window = cfg.hyper.window;
    spec.bins = cfg.hyper.bins;
    spec.w = cfg.hyper.w;
    spec.modulus = cfg.hyper.modulus;
    spec.k = cfg.hyper.k;
    let mut det = spec.build(ds.warmup(cfg.hyper.window));
    det.run_stream(&ds.data)
}

#[test]
fn session_scores_are_bit_identical_to_fabric_run() {
    // Three heterogeneous partitions; the same 150-sample stream pushed
    // through server sessions in irregular client-sized chunks must score
    // bit-identically to one Fabric::run pass — in both execution modes.
    let kinds = [DetectorKind::Loda, DetectorKind::RsHash, DetectorKind::XStream];
    let ds = tiny("parity", 150, 3, 41);
    for exec in ExecMode::ALL {
        let mut cfg = cpu_cfg(exec, 16);
        for (i, k) in kinds.iter().enumerate() {
            cfg.pblocks.push(PblockCfg {
                id: i + 1,
                rm: RmKind::Detector(*k),
                r: 2,
                stream: 0,
                lanes: 0,
            });
        }
        let mut fabric = Fabric::new(cfg.clone(), vec![ds.clone()]).unwrap();
        let fabric_out = fabric.run().unwrap();

        let server = FabricServer::start(cfg.clone()).unwrap();
        for id in 1..=3usize {
            let mut session = server
                .open(SessionSpec::for_dataset(&ds, cfg.hyper.window).on_pblock(id))
                .unwrap();
            // Client-sized pushes deliberately misaligned with the flit
            // chunk: 7 samples, 40, 80, remainder.
            let cuts = [0usize, 7, 47, 127, 150];
            for w in cuts.windows(2) {
                session.push(&ds.data[w[0] * ds.d..w[1] * ds.d]).unwrap();
            }
            let closed = session.close().unwrap();
            // 150 = 9×16 + 6: the close cuts mid-chunk and reports it.
            assert!(closed.padded_tail, "{exec:?}");
            assert_eq!(closed.tail_valid, 6, "{exec:?}");
            assert_eq!(
                closed.scores, fabric_out.pblock_scores[&id],
                "{exec:?}: pblock {id} session scores drifted from Fabric::run"
            );
            assert_eq!(closed.report.samples, 150, "{exec:?}");
        }
        server.shutdown().unwrap();
    }
}

#[test]
fn mid_session_swap_is_bit_identical_to_fabric_swap() {
    // Live DFX during a session: pblock 1 hot-swaps Loda → xStream at flit
    // 4 with a 2-flit dark window while pblock 2 keeps streaming. Both the
    // swapped partition (prefix, dark zeros, fresh-detector suffix) and the
    // untouched one must match the equivalent Fabric::run with the same
    // scheduled swap — bit-for-bit, in both execution modes.
    let ds = tiny("hotswap", 150, 3, 33);
    for exec in ExecMode::ALL {
        let mut cfg = cpu_cfg(exec, 16);
        for id in 1..=2usize {
            cfg.pblocks.push(PblockCfg {
                id,
                rm: RmKind::Detector(DetectorKind::Loda),
                r: 2,
                stream: 0,
                lanes: 0,
            });
        }
        let mut fabric = Fabric::new(cfg.clone(), vec![ds.clone()]).unwrap();
        fabric.schedule_swap(1, 4, RmKind::Detector(DetectorKind::XStream), 2, Some(2)).unwrap();
        let fabric_out = fabric.run().unwrap();
        assert_eq!(fabric_out.swap_events.len(), 1);

        let server = FabricServer::start(cfg.clone()).unwrap();
        let mut s1 =
            server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window).on_pblock(1)).unwrap();
        let mut s2 =
            server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window).on_pblock(2)).unwrap();
        // Arm the swap before any data flows so it fires at the same flit
        // index as the fabric's scripted run.
        let (model_ms, dark) = server
            .schedule_swap(1, 4, RmKind::Detector(DetectorKind::XStream), 2, Some(2))
            .unwrap();
        assert_eq!(dark, 2);
        assert!(model_ms > 570.0 && model_ms < 640.0, "{model_ms}");
        s1.push(&ds.data).unwrap();
        s2.push(&ds.data).unwrap();
        let c1 = s1.close().unwrap();
        let c2 = s2.close().unwrap();

        assert_eq!(
            c2.scores, fabric_out.pblock_scores[&2],
            "{exec:?}: untouched partition must not see the swap"
        );
        assert!(c2.swap_events.is_empty(), "{exec:?}");
        let got = &c1.scores;
        let want = &fabric_out.pblock_scores[&1];
        assert_eq!(got.len(), 150, "{exec:?}: bypass policy keeps the framing");
        assert_eq!(got, want, "{exec:?}: swapped partition drifted from Fabric::run");
        // Dark window sanity: samples 64..96 are zero-score placeholders.
        assert!(got[64..96].iter().all(|&v| v == 0.0), "{exec:?}");
        assert_eq!(c1.swap_events.len(), 1, "{exec:?}");
        let ev = &c1.swap_events[0];
        assert_eq!((ev.pblock, ev.at_flit, ev.dark_flits, ev.bypassed), (1, 4, 2, 2));
        assert!(ev.dark_complete);
        assert!(ev.from.contains("loda") && ev.to.contains("xstream"), "{} {}", ev.from, ev.to);
        server.shutdown().unwrap();
    }
}

#[test]
fn scripted_config_swap_fires_on_first_session_only() {
    // A [fabric.dfx.swap.N] schedule arms the partition's first session —
    // mirroring Fabric::new arming the first run — and is consumed: the
    // second session on the same partition rebuilds the *configured* RM and
    // streams clean (sessions are independent episodes; swap effects never
    // leak forward).
    let text = r#"
[fabric]
use_fpga = false
chunk = 16

[pblock.1]
rm = "loda"
r = 2
stream = 0

[fabric.dfx.swap.1]
pblock = 1
at_flit = 3
rm = "rshash"
r = 2
dark_flits = 1
"#;
    let cfg = FseadConfig::from_str(text).unwrap();
    let ds = tiny("scripted", 120, 3, 17);
    let fabric_out = {
        let mut fabric = Fabric::new(cfg.clone(), vec![ds.clone()]).unwrap();
        fabric.run().unwrap()
    };
    let server = FabricServer::start(cfg.clone()).unwrap();
    // First session: the scripted swap executes mid-stream.
    let mut s = server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window)).unwrap();
    s.push(&ds.data).unwrap();
    let first = s.close().unwrap();
    assert_eq!(first.swap_events.len(), 1);
    assert!(first.swap_events[0].to.contains("rshash"));
    assert_eq!(first.scores, fabric_out.pblock_scores[&1], "scripted swap parity");
    // Second session: clean stream through the configured Loda RM.
    let mut s = server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window)).unwrap();
    s.push(&ds.data).unwrap();
    let second = s.close().unwrap();
    assert!(second.swap_events.is_empty(), "schedule must be consumed");
    let expect = standalone_scores(&cfg, DetectorKind::Loda, 2, 1, &ds);
    assert_eq!(second.scores, expect, "swap effects must not leak into later sessions");
    server.shutdown().unwrap();
}

#[test]
fn interleaved_session_churn_has_no_leakage_and_shutdown_is_clean() {
    // Four partitions, six client threads churning open/push/close while a
    // long-lived session on partition 4 outlives all of them. Every session
    // must score exactly as the standalone detector seeded for whichever
    // partition served it — any cross-session state leak (stale window
    // contents, another stream's scores) breaks bit-equality. Finally the
    // server shuts down with two sessions still open, without deadlock.
    let mut cfg = cpu_cfg(ExecMode::Batched, 16);
    for id in 1..=4usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    let server = FabricServer::start(cfg.clone()).unwrap();

    // Long-lived session pinned to partition 4, first half pushed now.
    let long_ds = tiny("long", 128, 3, 900);
    let mut long_session = server
        .open(SessionSpec::for_dataset(&long_ds, cfg.hyper.window).on_pblock(4))
        .unwrap();
    long_session.push(&long_ds.data[..64 * 3]).unwrap();

    let cfg_ref = &cfg;
    let server_ref = &server;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for client in 0..6usize {
            handles.push(scope.spawn(move || {
                for round in 0..3usize {
                    let ds = tiny("churn", 64 + 16 * round, 3, (client * 31 + round) as u64);
                    let mut session = server_ref
                        .open(SessionSpec::for_dataset(&ds, cfg_ref.hyper.window))
                        .unwrap();
                    assert_ne!(session.pblock(), 4, "partition 4 is held by the long session");
                    let pblock = session.pblock();
                    // Push in two uneven blocks with a poll in between.
                    let cut = ds.n() / 3 * ds.d;
                    session.push(&ds.data[..cut]).unwrap();
                    let mut scores = session.poll_scores();
                    session.push(&ds.data[cut..]).unwrap();
                    let closed = session.close().unwrap();
                    scores.extend(closed.scores);
                    let expect =
                        standalone_scores(cfg_ref, DetectorKind::Loda, 2, pblock, &ds);
                    assert_eq!(scores, expect, "client {client} round {round} (RP-{pblock})");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    // The long session survived the churn: finish and verify end to end.
    long_session.push(&long_ds.data[64 * 3..]).unwrap();
    let closed = long_session.close().unwrap();
    assert_eq!(closed.samples, 128);
    let expect = standalone_scores(&cfg, DetectorKind::Loda, 2, 4, &long_ds);
    assert_eq!(closed.scores, expect, "long-lived session drifted");

    // Shutdown with sessions still open on two partitions: no deadlock,
    // the forced episodes complete, later pushes fail fast.
    let open_ds = tiny("open", 64, 3, 901);
    let mut open_a = server.open(SessionSpec::for_dataset(&open_ds, cfg.hyper.window)).unwrap();
    let mut open_b = server.open(SessionSpec::for_dataset(&open_ds, cfg.hyper.window)).unwrap();
    open_a.push(&open_ds.data[..32 * 3]).unwrap();
    open_b.push(&open_ds.data[..16 * 3]).unwrap();
    let report = server.shutdown().unwrap();
    // 6 clients × 3 rounds + the long session + two force-closed ones.
    assert_eq!(report.sessions_served, 21);
    assert!(open_a.push(&open_ds.data[..16 * 3]).is_err(), "push after shutdown must fail");
}

#[test]
fn dropped_session_closes_its_inbox_and_releases_the_partition() {
    // Dropping a session without close() must (a) force-close its inbox so
    // the worker retires the episode without draining the backlog, and
    // (b) free the partition for the next client with zero state leakage.
    // A small inbox plus a large undelivered backlog makes (a) observable:
    // if the Drop impl merely hung up, the worker would still score the
    // queue before freeing — here the immediate re-open succeeds quickly
    // and its scores match the standalone detector bit-for-bit.
    let mut cfg = cpu_cfg(ExecMode::Batched, 16);
    cfg.server.inbox_flits = 2;
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 2,
        stream: 0,
        lanes: 0,
    });
    let server = FabricServer::start(cfg.clone()).unwrap();
    let junk = tiny("junk", 64, 3, 77);
    {
        let mut s = server.open(SessionSpec::for_dataset(&junk, cfg.hyper.window)).unwrap();
        s.push(&junk.data).unwrap();
        // Never closed, never drained — dropped with scores in flight.
    }
    let ds = tiny("fresh", 96, 3, 78);
    let mut s = server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window)).unwrap();
    s.push(&ds.data).unwrap();
    let closed = s.close().unwrap();
    let expect = standalone_scores(&cfg, DetectorKind::Loda, 2, 1, &ds);
    assert_eq!(closed.scores, expect, "state leaked across the abandoned session");
    let report = server.shutdown().unwrap();
    assert_eq!(report.sessions_served, 2, "abandoned episode still retires");
}
