//! Property-based tests over coordinator invariants (routing, chunking,
//! windowing, scoring, configuration) using the in-repo mini-framework
//! (`fsead::testutil` — proptest is unavailable offline, DESIGN.md §6).

use fsead::combine::{LabelCombiner, ScoreCombiner};
use fsead::config::{ComboCfg, FseadConfig, PblockCfg, RmKind};
use fsead::data::stream::ChunkStream;
use fsead::detectors::window::SlidingCounts;
use fsead::detectors::{quantize::q16, DetectorKind, DetectorSpec};
use fsead::ensemble::{run_batched_chunked, run_sequential};
use fsead::fabric::AxiSwitch;
use fsead::metrics::{auc_roc, normalize_scores};
use fsead::prop_assert;
use fsead::testutil::forall;

#[test]
fn switch_arbitration_invariants() {
    forall("switch-arbitration", 200, |g| {
        let n_s = g.usize_in(1, 16);
        let n_m = g.usize_in(1, 16);
        let mut sw = AxiSwitch::new("p", n_s, n_m).unwrap();
        let programs = g.usize_in(0, 24);
        for _ in 0..programs {
            let m = g.usize_in(0, n_m - 1);
            if g.bool() {
                sw.set_route(m, g.usize_in(0, n_s - 1)).unwrap();
            } else {
                sw.disable(m).unwrap();
            }
        }
        let eff = sw.resolve();
        // 1. No slave is connected to two masters.
        let mut used = vec![false; n_s];
        for (m, s) in eff.iter().enumerate() {
            if let Some(s) = *s {
                prop_assert!(!used[s], "slave {s} double-assigned");
                used[s] = true;
                // 2. Every effective route was actually requested.
                prop_assert!(sw.route_of(m) == Some(s), "M{m} got unrequested S{s}");
                // 3. The winner is the lowest-numbered requester.
                for lower in 0..m {
                    prop_assert!(
                        sw.route_of(lower) != Some(s),
                        "M{lower} < M{m} requested S{s} but lost arbitration"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn chunk_stream_reassembles_exactly() {
    forall("chunk-reassembly", 100, |g| {
        let n = g.usize_in(0, 400);
        let d = g.usize_in(1, 8);
        let chunk = g.usize_in(1, 64);
        let data = g.f32_vec(n * d, -10.0, 10.0);
        let mut rebuilt = Vec::new();
        let mut valid = 0usize;
        let mut last_seen = false;
        for f in ChunkStream::new(&data, d, chunk) {
            prop_assert!(!last_seen, "flit after TLAST");
            prop_assert!(f.data.len() == chunk * d, "padded size wrong");
            prop_assert!(f.mask.len() == chunk, "mask size wrong");
            let mask_count = f.mask.iter().filter(|&&m| m > 0.5).count();
            prop_assert!(mask_count == f.n_valid, "mask disagrees with n_valid");
            rebuilt.extend_from_slice(&f.data[..f.n_valid * d]);
            valid += f.n_valid;
            last_seen = f.last;
        }
        prop_assert!(last_seen, "no TLAST emitted");
        prop_assert!(valid == n, "valid {valid} != n {n}");
        prop_assert!(rebuilt == data, "payload corrupted");
        Ok(())
    });
}

#[test]
fn sliding_counts_conservation() {
    forall("window-conservation", 150, |g| {
        let rows = g.usize_in(1, 6);
        let width = g.usize_in(2, 64);
        let window = g.usize_in(1, 32);
        let mut sc = SlidingCounts::new(rows, width, window);
        let inserts = g.usize_in(0, 200);
        for _ in 0..inserts {
            let idxs: Vec<i32> =
                (0..rows).map(|_| g.usize_in(0, width - 1) as i32).collect();
            sc.insert(&idxs);
        }
        for row in 0..rows {
            let total = sc.row_total(row);
            let expect = (inserts as i64).min(window as i64);
            prop_assert!(total == expect, "row {row}: total {total} != {expect}");
        }
        prop_assert!(sc.counts().iter().all(|&c| c >= 0), "negative count");
        prop_assert!(
            sc.counts().iter().all(|&c| c <= window as i32),
            "count exceeds window"
        );
        Ok(())
    });
}

#[test]
fn auc_monotone_invariance_and_symmetry() {
    forall("auc-invariance", 100, |g| {
        let n = g.usize_in(4, 200);
        let scores = g.f32_vec(n, -5.0, 5.0);
        let truth: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        if truth.iter().all(|&t| t) || truth.iter().all(|&t| !t) {
            return Ok(()); // degenerate: AUC fixed at 0.5 by definition
        }
        let a = auc_roc(&scores, &truth);
        // Monotone transform invariance.
        let transformed: Vec<f32> = scores.iter().map(|&s| s.exp()).collect();
        let b = auc_roc(&transformed, &truth);
        prop_assert!((a - b).abs() < 1e-9, "monotone transform changed AUC: {a} vs {b}");
        // Normalisation invariance.
        let c = auc_roc(&normalize_scores(&scores), &truth);
        prop_assert!((a - c).abs() < 1e-6, "normalisation changed AUC: {a} vs {c}");
        // Negation symmetry.
        let neg: Vec<f32> = scores.iter().map(|s| -s).collect();
        let d = auc_roc(&neg, &truth);
        prop_assert!((a + d - 1.0).abs() < 1e-9, "negation asymmetry: {a} + {d} != 1");
        Ok(())
    });
}

#[test]
fn combiner_order_relations() {
    forall("combiner-relations", 100, |g| {
        let n = g.usize_in(1, 50);
        let k = g.usize_in(1, 4);
        let streams: Vec<Vec<f32>> = (0..k).map(|_| g.f32_vec(n, -3.0, 3.0)).collect();
        let views: Vec<&[f32]> = streams.iter().map(|v| v.as_slice()).collect();
        let avg = ScoreCombiner::Averaging.combine(&views);
        let max = ScoreCombiner::Maximization.combine(&views);
        for i in 0..n {
            prop_assert!(avg[i] <= max[i] + 1e-5, "avg > max at {i}");
        }
        // OR dominates voting: vote(i) ⇒ or(i).
        let labels: Vec<Vec<bool>> =
            (0..k).map(|_| (0..n).map(|_| g.bool()).collect()).collect();
        let lviews: Vec<&[bool]> = labels.iter().map(|v| v.as_slice()).collect();
        let or = LabelCombiner::Or.combine(&lviews);
        let vote = LabelCombiner::Voting.combine(&lviews);
        for i in 0..n {
            prop_assert!(!vote[i] || or[i], "vote set but OR clear at {i}");
        }
        Ok(())
    });
}

#[test]
fn q16_quantisation_error_bound() {
    forall("q16-bound", 200, |g| {
        let v = g.f32_in(-1000.0, 1000.0);
        let q = q16(v);
        prop_assert!((q - v).abs() <= 0.5 / 65536.0 + 1e-6, "error too large for {v}");
        prop_assert!(q16(q) == q, "not idempotent at {v}");
        Ok(())
    });
}

#[test]
fn detectors_deterministic_and_finite() {
    forall("detector-sanity", 30, |g| {
        let kind = *g.pick(&DetectorKind::ALL);
        let d = g.usize_in(1, 8);
        let r = g.usize_in(1, 6);
        let n = g.usize_in(2, 120);
        let seed = g.usize_in(0, 1_000_000) as u64;
        let data = g.gaussian_vec(n * d);
        let mut spec = DetectorSpec::new(kind, d, r, seed);
        spec.window = g.usize_in(1, 64);
        let mut det_a = spec.build(&data);
        let mut det_b = spec.build(&data);
        let a = det_a.run_stream(&data);
        let b = det_b.run_stream(&data);
        prop_assert!(a == b, "{kind:?} nondeterministic");
        prop_assert!(a.iter().all(|s| s.is_finite()), "{kind:?} non-finite score");
        prop_assert!(a.len() == n, "{kind:?} wrong score count");
        Ok(())
    });
}

#[test]
fn batched_engine_matches_sequential() {
    // The lock-free batched engine must agree with the sequential reference
    // within 1e-4 for every detector kind, uneven R/thread splits, and
    // chunk sizes {1, W-1, W, 3W+1} straddling the sliding window.
    forall("batched-parity", 16, |g| {
        let kind = *g.pick(&DetectorKind::ALL);
        let d = g.usize_in(1, 6);
        let r = g.usize_in(1, 9);
        let n = g.usize_in(2, 160);
        let seed = g.usize_in(0, 1_000_000) as u64;
        let threads = g.usize_in(1, 5); // r % threads != 0 ⇒ uneven splits
        let mut spec = DetectorSpec::new(kind, d, r, seed);
        spec.window = g.usize_in(1, 48);
        let w = spec.window;
        let ds = fsead::data::Dataset {
            name: "prop".into(),
            d,
            data: g.gaussian_vec(n * d),
            labels: vec![false; n],
        };
        let seq = run_sequential(&spec, &ds);
        for chunk in [1, w.saturating_sub(1).max(1), w, 3 * w + 1] {
            let fast = run_batched_chunked(&spec, &ds, threads, chunk);
            prop_assert!(fast.len() == n, "{kind:?}: {} scores != {n}", fast.len());
            for (i, (a, b)) in seq.iter().zip(&fast).enumerate() {
                prop_assert!(
                    (a - b).abs() < 1e-4,
                    "{kind:?} r={r} t={threads} chunk={chunk} sample {i}: {a} vs {b}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn fabric_conserves_samples_cpu() {
    forall("fabric-conservation", 12, |g| {
        let n = g.usize_in(20, 300);
        let d = g.usize_in(2, 6);
        let n_pblocks = g.usize_in(1, 4);
        let use_combo = g.bool() && n_pblocks >= 2;
        let mut cfg = FseadConfig::default();
        cfg.use_fpga = false;
        cfg.chunk = g.usize_in(8, 64);
        for id in 1..=n_pblocks {
            let kind = *g.pick(&DetectorKind::ALL);
            cfg.pblocks.push(PblockCfg {
                id,
                rm: RmKind::Detector(kind),
                r: g.usize_in(1, 4),
                stream: 0,
                lanes: 0,
            });
        }
        if use_combo {
            cfg.combos.push(ComboCfg {
                id: 1,
                method: "avg".into(),
                inputs: (1..=n_pblocks).collect(),
                weights: vec![],
            });
        }
        let data = g.gaussian_vec(n * d);
        let ds = fsead::data::Dataset {
            name: "prop".into(),
            d,
            data,
            labels: vec![false; n],
        };
        let mut fabric = match fsead::fabric::Fabric::new(cfg, vec![ds]) {
            Ok(f) => f,
            Err(e) => return Err(format!("fabric build failed: {e}")),
        };
        let out = fabric.run().map_err(|e| format!("run failed: {e}"))?;
        let total: usize = out
            .pblock_scores
            .values()
            .chain(out.combo_scores.values())
            .map(|v| v.len())
            .sum();
        let expected = if use_combo { n } else { n * n_pblocks };
        prop_assert!(total == expected, "sample conservation: {total} != {expected}");
        Ok(())
    });
}

#[test]
fn config_combo_codes_total_seven() {
    forall("combo-codes", 60, |g| {
        // Random valid 3-way splits of 7 pblocks always build and validate.
        let a = g.usize_in(0, 7);
        let b = g.usize_in(0, 7 - a);
        let c = 7 - a - b;
        let mut code = String::new();
        if a > 0 {
            code.push_str(&format!("A{a}"));
        }
        if b > 0 {
            code.push_str(&format!("B{b}"));
        }
        if c > 0 {
            code.push_str(&format!("C{c}"));
        }
        let cfg = FseadConfig::from_combo_code(&code)
            .map_err(|e| format!("{code}: {e}"))?;
        prop_assert!(cfg.pblocks.len() == 7, "{code}: {} pblocks", cfg.pblocks.len());
        cfg.validate().map_err(|e| format!("{code}: {e}"))?;
        Ok(())
    });
}
