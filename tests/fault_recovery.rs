//! Fault-tolerance end-to-end: seeded fault injection through the fabric's
//! supervisor ladder. Every scheduled fault must end in a recorded recovery
//! (rung 0 worker containment, rung 1 checkpoint-restored RM reload) or
//! quarantine (rung 2, with combo renormalization), the surviving data
//! plane must stay bit-identical to its fault-free references, and the
//! session server must reproduce the same recoveries per episode.

use fsead::combine::ScoreCombiner;
use fsead::config::{ComboCfg, FseadConfig, InjectSpec, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::ExecMode;
use fsead::fabric::server::{FabricServer, SessionSpec};
use fsead::fabric::{pblock_seed, Fabric};

const CHUNK: usize = 16;
const D: usize = 3;

fn tiny(name: &'static str, n: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d: D, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

/// Small-hyper CPU fabric with the fault campaign armed: checkpoints every
/// 4 flits, a 1-flit reload dark window, and a generous staging wait so
/// recovery lands deterministically at the next flit even on slow CI.
fn faulty_cfg() -> FseadConfig {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = false;
    cfg.chunk = CHUNK;
    cfg.hyper.window = 16;
    cfg.hyper.bins = 8;
    cfg.hyper.modulus = 32;
    cfg.hyper.k = 4;
    cfg.faults.enabled = true;
    cfg.faults.checkpoint_every_flits = 4;
    cfg.faults.dark_flits = Some(1);
    cfg.faults.reload_wait_ms = 2_000;
    cfg
}

fn pblock(id: usize, kind: DetectorKind, r: usize, lanes: usize) -> PblockCfg {
    PblockCfg { id, rm: RmKind::Detector(kind), r, stream: 0, lanes }
}

fn inject(id: &str, pb: usize, at_flit: u64, kind: &str) -> InjectSpec {
    InjectSpec { id: id.into(), pblock: pb, at_flit, kind: kind.into(), lane: 0, ms: 0 }
}

/// Fault-free reference: the detector a fabric pblock builds (same seed,
/// hyper-parameters and warm-up) streamed standalone — the server parity
/// suite holds the fabric bit-identical to this.
fn standalone(cfg: &FseadConfig, kind: DetectorKind, r: usize, pb: usize, ds: &Dataset) -> Vec<f32> {
    let mut spec = DetectorSpec::new(kind, D, r, pblock_seed(cfg.seed, pb));
    spec.window = cfg.hyper.window;
    spec.bins = cfg.hyper.bins;
    spec.w = cfg.hyper.w;
    spec.modulus = cfg.hyper.modulus;
    spec.k = cfg.hyper.k;
    let mut det = spec.build(ds.warmup(cfg.hyper.window));
    det.run_stream(&ds.data)
}

#[test]
fn state_corruption_reloads_from_checkpoint_bit_identically() {
    // One Loda partition, 240 samples = 15 flits. Checkpoints land after
    // flits 4 and 8; a state_corrupt injection poisons the window at input
    // flit 9, so flit 9's scores go non-finite and are zeroed, the
    // supervisor stages a reload at flit 10 (1 dark flit, bypass policy),
    // and flits 11.. are scored by the replacement restored from the flit-8
    // checkpoint — bit-identical to a fresh detector fed samples [0, 128)
    // and then the post-dark suffix.
    let ds = tiny("reload", 240, 41);
    let mut cfg = faulty_cfg();
    cfg.pblocks.push(pblock(1, DetectorKind::Loda, 2, 0));
    cfg.faults.injections.push(inject("corrupt", 1, 9, "state_corrupt"));

    // Faults disabled: the campaign config must be bit-transparent.
    let mut clean_cfg = cfg.clone();
    clean_cfg.faults.enabled = false;
    let baseline = standalone(&cfg, DetectorKind::Loda, 2, 1, &ds);
    let clean = Fabric::new(clean_cfg, vec![ds.clone()]).unwrap().run().unwrap();
    assert_eq!(clean.pblock_scores[&1], baseline, "disabled campaign must be transparent");
    assert!(clean.fault_events.is_empty());

    let mut fabric = Fabric::new(cfg.clone(), vec![ds.clone()]).unwrap();
    let out = fabric.run().unwrap();
    let got = &out.pblock_scores[&1];
    assert_eq!(got.len(), 240, "bypass policy keeps the framing");

    // Healthy prefix (flits 0..9) is untouched by the armed hooks.
    assert_eq!(&got[..144], &baseline[..144], "prefix must match the fault-free detector");
    // Flit 9 (screened corruption) and flit 10 (reload dark window) zeroed.
    assert!(got[144..176].iter().all(|&v| v == 0.0), "screened + dark flits must be zeros");
    // Suffix: the restored RM resumes from the flit-8 checkpoint (128
    // samples) — bit-identical to a fresh detector warmed the same way.
    let mut spec = DetectorSpec::new(DetectorKind::Loda, D, 2, pblock_seed(cfg.seed, 1));
    spec.window = cfg.hyper.window;
    spec.bins = cfg.hyper.bins;
    spec.w = cfg.hyper.w;
    spec.modulus = cfg.hyper.modulus;
    spec.k = cfg.hyper.k;
    let mut det = spec.build(ds.warmup(cfg.hyper.window));
    det.run_stream(&ds.data[..128 * D]);
    let tail = det.run_stream(&ds.data[176 * D..]);
    assert_eq!(&got[176..], &tail[..], "restored RM must resume bit-identically");

    // Event trail: injection -> detection -> rung-1 reload, in flit order.
    let actions: Vec<&str> = out.fault_events.iter().map(|e| e.action.as_str()).collect();
    assert_eq!(actions, ["injected", "nonfinite_detected", "reloaded"], "{:?}", out.fault_events);
    assert_eq!(out.fault_events[0].id, "corrupt");
    assert_eq!(out.fault_events[0].at_flit, 9);
    assert_eq!(out.fault_events[1].fault, "state_corrupt");
    assert_eq!(out.fault_events[2].rung, 1);
    assert_eq!(out.fault_events[2].checkpoint_flit, Some(8), "{}", out.fault_events[2]);
    // The reload rides the DFX stage path and is accounted like any swap.
    assert_eq!(out.swap_events.len(), 1);
    assert_eq!((out.swap_events[0].at_flit, out.swap_events[0].dark_flits), (10, 1));
}

#[test]
fn exhausted_reloads_quarantine_and_the_combo_renormalizes() {
    // Two Loda partitions averaged through a combo; max_reloads = 0 sends
    // partition 1 straight to rung-2 quarantine when its window is
    // poisoned at flit 5. The combo must average both inputs up to the
    // screened flit, then renormalize over the survivor — bit-identical to
    // the combiner applied by hand to the standalone references.
    let ds = tiny("quarantine", 160, 17);
    let mut cfg = faulty_cfg();
    cfg.faults.max_reloads = 0;
    cfg.pblocks.push(pblock(1, DetectorKind::Loda, 2, 0));
    cfg.pblocks.push(pblock(2, DetectorKind::Loda, 2, 0));
    cfg.combos.push(ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2], weights: vec![] });
    cfg.faults.injections.push(inject("q", 1, 5, "state_corrupt"));

    let s1 = standalone(&cfg, DetectorKind::Loda, 2, 1, &ds);
    let s2 = standalone(&cfg, DetectorKind::Loda, 2, 2, &ds);
    let mut fabric = Fabric::new(cfg, vec![ds]).unwrap();
    let out = fabric.run().unwrap();
    let got = &out.combo_scores[&1];
    assert_eq!(got.len(), 160);

    let avg = ScoreCombiner::Averaging;
    // Flits 0..5: both partitions healthy.
    assert_eq!(&got[..80], &avg.combine(&[&s1[..80], &s2[..80]])[..]);
    // Flit 5: partition 1's screened flit contributes zeros.
    let zeros = [0f32; 16];
    assert_eq!(&got[80..96], &avg.combine(&[&zeros[..], &s2[80..96]])[..]);
    // Flits 6..: partition 1 is quarantined (stream dropped at its
    // decoupler); the combo renormalizes over the survivor.
    assert_eq!(&got[96..], &avg.combine(&[&s2[96..160]])[..], "survivor must be untouched");

    let p1: Vec<&str> = out
        .fault_events
        .iter()
        .filter(|e| e.pblock == 1)
        .map(|e| e.action.as_str())
        .collect();
    assert_eq!(p1, ["injected", "nonfinite_detected", "quarantined"], "{:?}", out.fault_events);
    let q = out.fault_events.iter().find(|e| e.action == "quarantined").unwrap();
    assert_eq!((q.rung, q.id.as_str()), (2, "-"), "{q}");
    assert!(
        out.fault_events.iter().all(|e| e.pblock == 1),
        "the healthy partition must record nothing: {:?}",
        out.fault_events
    );
}

#[test]
fn lane_panic_is_contained_on_the_worker_bit_exactly() {
    // A two-lane partition takes an injected lane panic at flit 3: the
    // armed worker rolls the lane's window back to its pre-job state and
    // rescores in place (rung 0) — the whole run stays bit-identical to
    // the same multi-lane fabric with the campaign disabled.
    let ds = tiny("lanes", 160, 23);
    let mk = |enabled: bool| {
        let mut cfg = faulty_cfg();
        cfg.exec = ExecMode::LockStep;
        cfg.faults.enabled = enabled;
        cfg.pblocks.push(pblock(1, DetectorKind::Loda, 4, 2));
        let mut spec = inject("lp", 1, 3, "lane_panic");
        spec.lane = 1;
        cfg.faults.injections.push(spec);
        cfg
    };
    let clean = Fabric::new(mk(false), vec![ds.clone()]).unwrap().run().unwrap();
    let out = Fabric::new(mk(true), vec![ds]).unwrap().run().unwrap();
    assert_eq!(
        out.pblock_scores[&1], clean.pblock_scores[&1],
        "rollback + rescore must be bit-exact"
    );
    let fired = out
        .fault_events
        .iter()
        .find(|e| e.action == "injected")
        .unwrap_or_else(|| panic!("{:?}", out.fault_events));
    assert_eq!((fired.id.as_str(), fired.fault.as_str(), fired.at_flit), ("lp", "lane_panic", 3));
    let retried = out
        .fault_events
        .iter()
        .find(|e| e.action == "lane_panic_retried")
        .unwrap_or_else(|| panic!("{:?}", out.fault_events));
    assert_eq!((retried.rung, retried.fault.as_str()), (0, "lane_panic"), "{retried}");
}

#[test]
fn watchdog_flags_processing_stalls_but_not_inbox_starvation() {
    // A mid-processing wedge at flit 3 must trip the heartbeat watchdog; an
    // equally long starvation *outside* processing at flit 6 must not — a
    // partition blocked on its inbox is healthy. Neither perturbs a single
    // score.
    let ds = tiny("stall", 160, 29);
    let mk = |enabled: bool| {
        let mut cfg = faulty_cfg();
        cfg.exec = ExecMode::LockStep;
        cfg.faults.enabled = enabled;
        cfg.faults.stall_timeout_ms = 8;
        cfg.pblocks.push(pblock(1, DetectorKind::Loda, 2, 0));
        let mut wedge = inject("wedge", 1, 3, "stall");
        wedge.ms = 60;
        let mut starve = inject("starve", 1, 6, "inbox_stall");
        starve.ms = 40;
        cfg.faults.injections.extend([wedge, starve]);
        cfg
    };
    let clean = Fabric::new(mk(false), vec![ds.clone()]).unwrap().run().unwrap();
    let out = Fabric::new(mk(true), vec![ds]).unwrap().run().unwrap();
    assert_eq!(out.pblock_scores[&1], clean.pblock_scores[&1], "stalls must not change scores");

    let stalls: Vec<u64> = out
        .fault_events
        .iter()
        .filter(|e| e.action == "stall_detected")
        .map(|e| e.at_flit)
        .collect();
    assert!(stalls.contains(&3), "the processing wedge must be flagged: {:?}", out.fault_events);
    assert!(!stalls.contains(&6), "inbox starvation is healthy: {:?}", out.fault_events);
    let injected: Vec<&str> = out
        .fault_events
        .iter()
        .filter(|e| e.action == "injected")
        .map(|e| e.id.as_str())
        .collect();
    assert_eq!(injected, ["wedge", "starve"]);
}

#[test]
fn server_sessions_recover_and_repeat_deterministically() {
    // The same corruption → checkpoint-reload scenario through the session
    // server: session scores must match the one-shot Fabric::run campaign
    // bit-for-bit, the recovery trail must surface on SessionClose, and a
    // second session on the freshly rebuilt partition must reproduce the
    // identical recovery (episodes re-arm the same deterministic plan).
    let ds = tiny("serve", 240, 41);
    let mut cfg = faulty_cfg();
    cfg.pblocks.push(pblock(1, DetectorKind::Loda, 2, 0));
    cfg.faults.injections.push(inject("corrupt", 1, 9, "state_corrupt"));

    let fabric_out = Fabric::new(cfg.clone(), vec![ds.clone()]).unwrap().run().unwrap();
    let server = FabricServer::start(cfg.clone()).unwrap();

    let mut first_scores = Vec::new();
    for round in 0..2 {
        let mut s = server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window)).unwrap();
        s.push(&ds.data).unwrap();
        let closed = s.close().unwrap();
        assert_eq!(
            closed.scores, fabric_out.pblock_scores[&1],
            "round {round}: session recovery drifted from Fabric::run"
        );
        let actions: Vec<&str> = closed.fault_events.iter().map(|e| e.action.as_str()).collect();
        assert_eq!(
            actions,
            ["injected", "nonfinite_detected", "reloaded"],
            "round {round}: {:?}",
            closed.fault_events
        );
        let reloaded = closed.fault_events.last().unwrap();
        assert_eq!(reloaded.checkpoint_flit, Some(8), "round {round}: {reloaded}");
        if round == 0 {
            first_scores = closed.scores;
        } else {
            assert_eq!(closed.scores, first_scores, "episodes must recover identically");
        }
    }
    server.shutdown().unwrap();
}
