//! Session lifecycle resilience end-to-end: a suspended session's ticket
//! must survive a server teardown and resume bit-identically on a fresh
//! server built from the same config; the durable score sink must replay
//! its intact prefix after arbitrary tail corruption; and a quarantined
//! partition must hand its session over to a healthy sibling from the
//! last checkpoint instead of dropping the rest of the stream.

use fsead::config::{FseadConfig, InjectSpec, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::fabric::server::{FabricServer, SessionSpec};
use fsead::fabric::{pblock_seed, score_sink, SessionTicket};
use std::fs;
use std::path::PathBuf;

const CHUNK: usize = 16;
const D: usize = 3;

fn tiny(name: &'static str, n: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d: D, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

/// Small-hyper CPU config shared by the lifecycle suite.
fn lifecycle_cfg() -> FseadConfig {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = false;
    cfg.chunk = CHUNK;
    cfg.hyper.window = 16;
    cfg.hyper.bins = 8;
    cfg.hyper.modulus = 32;
    cfg.hyper.k = 4;
    cfg
}

fn pblock(id: usize, kind: DetectorKind, r: usize) -> PblockCfg {
    PblockCfg { id, rm: RmKind::Detector(kind), r, stream: 0, lanes: 0 }
}

/// Uninterrupted reference: the detector a fabric pblock builds (same
/// seed, hyper-parameters and warm-up) streamed standalone.
fn standalone(cfg: &FseadConfig, kind: DetectorKind, r: usize, pb: usize, ds: &Dataset) -> Vec<f32> {
    let mut det = reference_det(cfg, kind, r, pb, ds);
    det.run_stream(&ds.data)
}

fn reference_det(
    cfg: &FseadConfig,
    kind: DetectorKind,
    r: usize,
    pb: usize,
    ds: &Dataset,
) -> Box<dyn fsead::detectors::Detector> {
    let mut spec = DetectorSpec::new(kind, D, r, pblock_seed(cfg.seed, pb));
    spec.window = cfg.hyper.window;
    spec.bins = cfg.hyper.bins;
    spec.w = cfg.hyper.w;
    spec.modulus = cfg.hyper.modulus;
    spec.k = cfg.hyper.k;
    spec.build(ds.warmup(cfg.hyper.window))
}

/// Fresh scratch directory under the system temp dir, unique per test so
/// the suite can run in parallel.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fsead-lifecycle-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn suspended_ticket_resumes_on_a_fresh_server_bit_identically() {
    // The "process boundary" round trip: server A suspends mid-stream and
    // spills the ticket to disk, is torn down entirely, and a fresh server
    // B built from the same config resumes from the spill file. The two
    // half-streams stitched together must be bit-identical to one
    // uninterrupted session — including a suspend point deliberately
    // misaligned with the flit chunk so the staged tail rides the ticket.
    let dir = scratch("resume");
    let ds = tiny("resume", 160, 29);
    let mut cfg = lifecycle_cfg();
    cfg.pblocks.push(pblock(1, DetectorKind::Loda, 2));
    cfg.server.spill_dir = Some(dir.to_string_lossy().into_owned());
    let reference = standalone(&cfg, DetectorKind::Loda, 2, 1, &ds);

    let server_a = FabricServer::start(cfg.clone()).unwrap();
    let mut session =
        server_a.open(SessionSpec::for_dataset(&ds, cfg.hyper.window).on_pblock(1)).unwrap();
    // 84 samples = 5 full flits + a 4-sample staged tail.
    session.push(&ds.data[..84 * D]).unwrap();
    let (ticket, scores_a) = session.suspend().unwrap();
    assert_eq!(ticket.seq, 5, "five whole flits were cut before the suspend");
    assert_eq!(ticket.pushed, 84);
    assert_eq!(ticket.staged.len(), 4 * D, "the sub-flit tail must ride the ticket");
    assert_eq!(scores_a.len(), 80, "every queued flit is scored before the park");
    assert_eq!(&scores_a[..], &reference[..80], "pre-suspend scores must match the reference");
    let spill = SessionTicket::spill_path(&dir, ticket.id);
    assert!(spill.exists(), "suspend must spill the ticket when spill_dir is set");
    server_a.shutdown().unwrap();

    // Fresh server, same config: resume from disk alone (the in-memory
    // ticket is deliberately ignored), finish the stream.
    let server_b = FabricServer::start(cfg.clone()).unwrap();
    let mut resumed = server_b.resume_spilled(ticket.id).unwrap();
    assert!(!spill.exists(), "the spill file is consumed by a successful resume");
    resumed.push(&ds.data[84 * D..]).unwrap();
    let closed = resumed.close().unwrap();
    assert!(!closed.padded_tail, "160 samples = 10 whole flits");
    assert_eq!(closed.samples, 160, "the resumed cursor keeps counting from the ticket");
    assert_eq!(closed.report.samples, 160);

    let mut stitched = scores_a;
    stitched.extend_from_slice(&closed.scores);
    assert_eq!(stitched, reference, "suspend/teardown/resume must be bit-transparent");
    server_b.shutdown().unwrap();
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn score_sink_replays_after_garbage_and_torn_tail() {
    // A sink-backed session's scores must be recoverable from the file
    // alone: a clean scan replays them bit-identically, appended garbage is
    // ignored and truncated away by recovery, and tearing the last frame
    // (a mid-write crash) costs exactly that frame — never the prefix.
    let dir = scratch("sink");
    let sink = dir.join("scores.fsnk");
    let ds = tiny("sink", 96, 59);
    let mut cfg = lifecycle_cfg();
    cfg.pblocks.push(pblock(1, DetectorKind::Loda, 2));
    cfg.server.sink_path = Some(sink.to_string_lossy().into_owned());
    cfg.server.sink_fsync_records = 2;

    let server = FabricServer::start(cfg.clone()).unwrap();
    let mut session =
        server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window).on_pblock(1)).unwrap();
    session.push(&ds.data).unwrap();
    let closed = session.close().unwrap();
    assert_eq!(closed.scores.len(), 96);
    server.shutdown().unwrap();

    // Clean file: every frame parses, the scan consumes the whole file and
    // the replayed stream is bit-identical to what the client saw.
    let (records, clean_len) = score_sink::scan(&sink).unwrap();
    assert_eq!(clean_len, fs::metadata(&sink).unwrap().len());
    assert!(records.len() >= 6, "at least one frame per data flit");
    assert!(records.windows(2).all(|w| w[0].seq < w[1].seq), "frames land in flit order");
    let session_id = records[0].session;
    assert!(records.iter().all(|r| r.session == session_id));
    let replay: Vec<f32> = records.iter().flat_map(|r| r.scores.iter().copied()).collect();
    assert_eq!(replay, closed.scores, "sink replay must be bit-identical to the live stream");

    // Garbage appended after the last frame (a crashed writer's junk): the
    // scan stops at the torn length word, recovery truncates it away.
    let mut bytes = fs::read(&sink).unwrap();
    bytes.extend_from_slice(&[0xEE; 11]);
    fs::write(&sink, &bytes).unwrap();
    let recovered = score_sink::recover(&sink).unwrap();
    assert_eq!(recovered, records, "garbage tail must not cost any intact frame");
    assert_eq!(fs::metadata(&sink).unwrap().len(), clean_len, "recovery truncates the junk");

    // Torn final frame (crash mid-write): recovery drops exactly that
    // frame and the surviving prefix still replays bit-identically.
    let file = fs::OpenOptions::new().write(true).open(&sink).unwrap();
    file.set_len(clean_len - 5).unwrap();
    drop(file);
    let recovered = score_sink::recover(&sink).unwrap();
    assert_eq!(recovered, records[..records.len() - 1], "only the torn frame is lost");
    let (rescan, len) = score_sink::scan(&sink).unwrap();
    assert_eq!(rescan, recovered, "the recovered file scans clean");
    assert_eq!(len, fs::metadata(&sink).unwrap().len());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn quarantined_session_migrates_to_a_sibling_from_its_checkpoint() {
    // Two Loda partitions; max_reloads = 0 sends partition 1 straight to
    // rung-2 quarantine when its window is poisoned at flit 5. With
    // `evict_quarantined` on, the session is parked from the last periodic
    // checkpoint (after flit 4: 64 samples) and re-dispatched to the
    // healthy sibling instead of losing the rest of the stream:
    //   flits 0..=4  scored live on partition 1 (healthy prefix),
    //   flit 5       screened to zeros (the poisoned window),
    //   flit 6       lost — it is the admission probe that trips the
    //                eviction, and a quarantined decoupler drops what it
    //                has already dequeued (the pre-eviction fabric dropped
    //                this flit *and everything after it*),
    //   flits 7..    scored on partition 2 by the checkpoint-restored RM.
    let ds = tiny("evict", 160, 17);
    let mut cfg = lifecycle_cfg();
    cfg.pblocks.push(pblock(1, DetectorKind::Loda, 2));
    cfg.pblocks.push(pblock(2, DetectorKind::Loda, 2));
    cfg.faults.enabled = true;
    cfg.faults.checkpoint_every_flits = 4;
    cfg.faults.dark_flits = Some(1);
    cfg.faults.reload_wait_ms = 2_000;
    cfg.faults.max_reloads = 0;
    cfg.faults.injections.push(InjectSpec {
        id: "q".into(),
        pblock: 1,
        at_flit: 5,
        kind: "state_corrupt".into(),
        lane: 0,
        ms: 0,
    });
    cfg.server.evict_quarantined = true;

    let server = FabricServer::start(cfg.clone()).unwrap();
    let mut session =
        server.open(SessionSpec::for_dataset(&ds, cfg.hyper.window).on_pblock(1)).unwrap();
    session.push(&ds.data).unwrap();
    let closed = session.close().unwrap();
    server.shutdown().unwrap();

    // 160 samples minus the screened-then-lost quarantine window: flit 5
    // scores as zeros, flit 6 emits nothing.
    assert_eq!(closed.scores.len(), 144, "exactly one flit is lost to the eviction");
    let full = standalone(&cfg, DetectorKind::Loda, 2, 1, &ds);
    assert_eq!(&closed.scores[..80], &full[..80], "healthy prefix must match the reference");
    assert!(closed.scores[80..96].iter().all(|&v| v == 0.0), "the poisoned flit is screened");
    // The sibling resumes from the flit-4 checkpoint (64 samples): its
    // suffix must be bit-identical to a fresh detector fed samples [0, 64)
    // and then the post-quarantine stream — partition 1's own seed rides
    // the parked session, so the sibling's layout is all that matters.
    let mut det = reference_det(&cfg, DetectorKind::Loda, 2, 1, &ds);
    det.run_stream(&ds.data[..64 * D]);
    let tail = det.run_stream(&ds.data[112 * D..]);
    assert_eq!(tail.len(), 48);
    assert_eq!(&closed.scores[96..], &tail[..], "sibling must resume from the checkpoint");
}
