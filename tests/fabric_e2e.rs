//! Fabric end-to-end: the composable topology (switches, DMAs, pblocks,
//! combos) over real streams, in both CPU-native and PJRT modes, covering
//! the paper's Fig 7 composition patterns and run-time reconfiguration.

use fsead::config::{ComboCfg, FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::detectors::DetectorSpec;
use fsead::ensemble::{run_sequential, ExecMode};
use fsead::fabric::Fabric;

fn tiny(name: &'static str, n: usize, d: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

fn cpu_cfg() -> FseadConfig {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = false;
    cfg.chunk = 64;
    cfg
}

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn fig7a_direct_routes_cpu() {
    // Seven pblocks, seven independent streams, no combos.
    let mut cfg = cpu_cfg();
    for id in 1..=7usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 3,
            stream: id - 1,
            lanes: 0,
        });
    }
    let streams: Vec<Dataset> = (0..7).map(|i| tiny("s", 150, 3, i as u64)).collect();
    let mut fabric = Fabric::new(cfg, streams.clone()).unwrap();
    let out = fabric.run().unwrap();
    assert_eq!(out.pblock_scores.len(), 7);
    assert!(out.combo_scores.is_empty());
    for (id, scores) in &out.pblock_scores {
        assert_eq!(scores.len(), 150, "pblock {id}");
        assert!(scores.iter().all(|s| s.is_finite()));
    }
    // Each pblock's scores must match a standalone sequential run with the
    // fabric's per-pblock seed.
    let cfg2 = fabric.config().clone();
    for p in &cfg2.pblocks {
        let seed = cfg2.seed.wrapping_add(p.id as u64 * 1009);
        let mut spec = DetectorSpec::new(DetectorKind::Loda, 3, 3, seed);
        spec.window = cfg2.hyper.window;
        spec.bins = cfg2.hyper.bins;
        let expect = run_sequential(&spec, &streams[p.stream]);
        let got = &out.pblock_scores[&p.id];
        for (a, b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5, "pblock {}: {a} vs {b}", p.id);
        }
    }
}

#[test]
fn fig7c_homogeneous_combo_cpu() {
    // All pblocks on one stream, averaged through combos.
    let mut cfg = cpu_cfg();
    for id in 1..=4usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::RsHash),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg.combos.push(ComboCfg {
        id: 1,
        method: "avg".into(),
        inputs: vec![1, 2, 3, 4],
        weights: vec![],
    });
    let ds = tiny("one", 200, 3, 5);
    let mut fabric = Fabric::new(cfg, vec![ds.clone()]).unwrap();
    let out = fabric.run().unwrap();
    assert!(out.pblock_scores.is_empty(), "all pblocks consumed by the combo");
    let combined = &out.combo_scores[&1];
    assert_eq!(combined.len(), 200);
    // The combo average must equal the mean of standalone pblock runs.
    let cfg2 = fabric.config().clone();
    let mut acc = vec![0f32; 200];
    for p in &cfg2.pblocks {
        let seed = cfg2.seed.wrapping_add(p.id as u64 * 1009);
        let mut spec = DetectorSpec::new(DetectorKind::RsHash, 3, 2, seed);
        spec.window = cfg2.hyper.window;
        spec.w = cfg2.hyper.w;
        spec.modulus = cfg2.hyper.modulus;
        for (a, b) in acc.iter_mut().zip(run_sequential(&spec, &ds)) {
            *a += b / 4.0;
        }
    }
    for (i, (a, b)) in combined.iter().zip(&acc).enumerate() {
        assert!((a - b).abs() < 1e-4, "sample {i}: {a} vs {b}");
    }
}

#[test]
fn fig7d_heterogeneous_mixture_cpu() {
    let mut cfg = cpu_cfg();
    let kinds = [
        DetectorKind::Loda,
        DetectorKind::Loda,
        DetectorKind::RsHash,
        DetectorKind::XStream,
    ];
    for (i, k) in kinds.iter().enumerate() {
        cfg.pblocks.push(PblockCfg {
            id: i + 1,
            rm: RmKind::Detector(*k),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg.combos.push(ComboCfg {
        id: 1,
        method: "max".into(),
        inputs: vec![1, 2, 3, 4],
        weights: vec![],
    });
    let ds = tiny("mix", 120, 3, 7);
    let mut fabric = Fabric::new(cfg, vec![ds]).unwrap();
    let out = fabric.run().unwrap();
    let scores = &out.combo_scores[&1];
    assert_eq!(scores.len(), 120);
    assert!(scores.iter().all(|s| s.is_finite()));
    assert!(out.switch_flits > 0);
}

#[test]
fn runtime_reconfiguration_swaps_detectors() {
    // Run Loda, reconfigure the pblock to xStream at run time, run again.
    let mut cfg = cpu_cfg();
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 2,
        stream: 0,
        lanes: 0,
    });
    let ds = tiny("reconf", 100, 3, 9);
    let mut fabric = Fabric::new(cfg, vec![ds]).unwrap();
    let first = fabric.run().unwrap();
    assert_eq!(first.pblock_scores[&1].len(), 100);

    let report = fabric
        .reconfigure(1, RmKind::Detector(DetectorKind::XStream), 2, 0)
        .unwrap();
    assert!(report.from.contains("loda"), "{}", report.from);
    assert!(report.to.contains("xstream"), "{}", report.to);
    assert!(report.model_ms > 570.0 && report.model_ms < 640.0);

    let second = fabric.run().unwrap();
    assert_eq!(second.pblock_scores[&1].len(), 100);
    // Different algorithm ⇒ different scores.
    let diff = first.pblock_scores[&1]
        .iter()
        .zip(&second.pblock_scores[&1])
        .filter(|(a, b)| (*a - *b).abs() > 1e-6)
        .count();
    assert!(diff > 50, "only {diff} samples changed after reconfig");
}

#[test]
fn streaming_state_persists_across_runs() {
    // Two consecutive runs without reset: the second starts with a warm
    // window (and a saturated score denominator), so early samples score
    // differently — the state genuinely persisted.
    let mut cfg = cpu_cfg();
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::RsHash),
        r: 2,
        stream: 0,
        lanes: 0,
    });
    let ds = tiny("warm", 80, 3, 11);
    let mut fabric = Fabric::new(cfg, vec![ds]).unwrap();
    let cold = fabric.run().unwrap().pblock_scores[&1].clone();
    let warm = fabric.run().unwrap().pblock_scores[&1].clone();
    assert_ne!(cold, warm, "window state did not persist across runs");
    // After reset, the cold scores reproduce exactly.
    fabric.reset_all().unwrap();
    let cold2 = fabric.run().unwrap().pblock_scores[&1].clone();
    assert_eq!(cold, cold2);
}

#[test]
fn fabric_on_pjrt_matches_cpu_fabric() {
    if !have_artifacts() {
        eprintln!("artifacts not built — skipping PJRT fabric test");
        return;
    }
    let ds = tiny("pjrt", 520, 3, 13);
    let mk_cfg = |fpga: bool| {
        let mut cfg = FseadConfig::default();
        cfg.use_fpga = fpga;
        cfg.chunk = 256; // artifact chunk
        for id in 1..=2usize {
            cfg.pblocks.push(PblockCfg {
                id,
                rm: RmKind::Detector(DetectorKind::Loda),
                r: 4, // test artifact size
                stream: 0,
                lanes: 0,
            });
        }
        cfg.combos.push(ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2], weights: vec![] });
        cfg
    };
    let mut cpu = Fabric::new(mk_cfg(false), vec![ds.clone()]).unwrap();
    let mut cpu_cfg_q = cpu.config().clone();
    drop(cpu);
    // The artifacts are quantized; make the CPU fabric quantize too by
    // running the FPGA-quantized artifacts against CPU RMs built with
    // quantize=false and comparing with a loose tolerance instead.
    cpu_cfg_q.use_fpga = false;
    let mut cpu = Fabric::new(cpu_cfg_q, vec![ds.clone()]).unwrap();
    let cpu_out = cpu.run().unwrap();

    let mut fpga = Fabric::new(mk_cfg(true), vec![ds.clone()]).unwrap();
    let fpga_out = fpga.run().unwrap();

    let a = &cpu_out.combo_scores[&1];
    let b = &fpga_out.combo_scores[&1];
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < 3e-3, "sample {i}: cpu={x} fpga={y}");
    }
    assert!(fpga_out.modeled_fpga_secs > 0.0);
}

#[test]
fn burst_fabric_matches_per_flit_fabric_exactly() {
    // The whole data plane — DMAs, switches, burst-drained pblocks, a wavg
    // combo and direct outputs — must produce *bit-identical* scores under
    // ExecMode::Batched (burst servicing) and ExecMode::LockStep (the
    // per-flit seed path) on CPU RMs. chunk=16 over 150 samples forces a
    // padded tail flit through the burst splitter.
    let kinds = [
        DetectorKind::Loda,
        DetectorKind::RsHash,
        DetectorKind::XStream,
        DetectorKind::Loda,
    ];
    let run = |exec: ExecMode| {
        let mut cfg = cpu_cfg();
        cfg.exec = exec;
        cfg.chunk = 16;
        for (i, k) in kinds.iter().enumerate() {
            cfg.pblocks.push(PblockCfg {
                id: i + 1,
                rm: RmKind::Detector(*k),
                r: 2,
                stream: 0,
                lanes: 0,
            });
        }
        cfg.combos.push(ComboCfg {
            id: 1,
            method: "wavg".into(),
            inputs: vec![1, 2],
            weights: vec![0.25, 0.75],
        });
        let ds = tiny("parity", 150, 3, 21);
        let mut fabric = Fabric::new(cfg, vec![ds]).unwrap();
        fabric.run().unwrap()
    };
    let per_flit = run(ExecMode::LockStep);
    let burst = run(ExecMode::Batched);
    assert_eq!(per_flit.combo_scores[&1].len(), 150);
    assert_eq!(per_flit.combo_scores[&1], burst.combo_scores[&1]);
    assert_eq!(per_flit.pblock_scores.len(), 2); // pblocks 3 and 4
    for (id, scores) in &per_flit.pblock_scores {
        assert_eq!(scores, &burst.pblock_scores[id], "pblock {id}");
    }
    // Same samples serviced, whatever the drain granularity.
    for id in 1..=4usize {
        assert_eq!(per_flit.pblock_reports[&id].samples, 150);
        assert_eq!(burst.pblock_reports[&id].samples, 150);
        assert_eq!(per_flit.pblock_reports[&id].flits_out, 10);
    }
}

#[test]
fn mid_run_hot_swap_isolates_to_target_pblock() {
    // Live DFX: three Loda pblocks on one stream; pblock 1 is hot-swapped
    // to xStream at flit 4 with a 2-flit dark window (samples 64..96 at
    // chunk 16) while the fabric streams. Outside the dark window the
    // swapped pblock must match its references bit-for-bit, and the other
    // pblocks must be bit-identical to a never-swapped run everywhere — in
    // both execution modes.
    let ds = tiny("hotswap", 150, 3, 33);
    let mk_cfg = |exec: ExecMode| {
        let mut cfg = cpu_cfg();
        cfg.exec = exec;
        cfg.chunk = 16;
        for id in 1..=3usize {
            cfg.pblocks.push(PblockCfg {
                id,
                rm: RmKind::Detector(DetectorKind::Loda),
                r: 2,
                stream: 0,
                lanes: 0,
            });
        }
        cfg
    };
    for exec in ExecMode::ALL {
        let mut reference = Fabric::new(mk_cfg(exec), vec![ds.clone()]).unwrap();
        let ref_out = reference.run().unwrap();
        assert!(ref_out.swap_events.is_empty());

        let mut live = Fabric::new(mk_cfg(exec), vec![ds.clone()]).unwrap();
        live.schedule_swap(1, 4, RmKind::Detector(DetectorKind::XStream), 2, Some(2)).unwrap();
        let out = live.run().unwrap();

        // Only the target pblock is touched: the others are bit-identical.
        for id in [2usize, 3] {
            assert_eq!(
                out.pblock_scores[&id], ref_out.pblock_scores[&id],
                "pblock {id} must be untouched ({exec:?})"
            );
        }
        // The swapped pblock: same sample count (bypass policy keeps the
        // framing), identical prefix, zeros inside the dark window.
        let got = &out.pblock_scores[&1];
        let want = &ref_out.pblock_scores[&1];
        assert_eq!(got.len(), 150, "{exec:?}");
        assert_eq!(&got[..64], &want[..64], "prefix must match ({exec:?})");
        assert!(got[64..96].iter().all(|&s| s == 0.0), "dark window must be zeros ({exec:?})");
        // After the dark window the freshly-loaded xStream RM takes over:
        // bit-identical to a standalone xStream (fabric seed + warmup) fed
        // the post-dark suffix.
        let cfg2 = live.config().clone();
        let seed = cfg2.seed.wrapping_add(1009);
        let mut spec = DetectorSpec::new(DetectorKind::XStream, 3, 2, seed);
        spec.window = cfg2.hyper.window;
        spec.bins = cfg2.hyper.bins;
        spec.w = cfg2.hyper.w;
        spec.modulus = cfg2.hyper.modulus;
        spec.k = cfg2.hyper.k;
        let mut det = spec.build(ds.warmup(cfg2.hyper.window));
        let expect_tail = det.run_stream(&ds.data[96 * 3..]);
        assert_eq!(&got[96..], &expect_tail[..], "suffix must match fresh xStream ({exec:?})");

        // Event accounting + config tracking.
        assert_eq!(out.swap_events.len(), 1, "{exec:?}");
        let ev = &out.swap_events[0];
        assert_eq!(ev.pblock, 1);
        assert_eq!(ev.at_flit, 4);
        assert_eq!(ev.dark_flits, 2);
        assert_eq!(ev.bypassed, 2);
        assert_eq!(ev.dropped, 0);
        assert!(ev.dark_complete);
        assert!(ev.from.contains("loda"), "{}", ev.from);
        assert!(ev.to.contains("xstream"), "{}", ev.to);
        assert!(ev.model_ms > 570.0 && ev.model_ms < 640.0, "{}", ev.model_ms);
        assert_eq!(cfg2.pblocks[0].rm, RmKind::Detector(DetectorKind::XStream));
    }
}

#[test]
fn scripted_swap_from_config_with_drop_policy() {
    // The TOML-declared schedule ([fabric.dfx.swap.N]) arms at fabric
    // construction; Drop policy shortens only the target pblock's stream.
    let text = r#"
[fabric]
use_fpga = false
chunk = 16

[fabric.dfx]
policy = "drop"

[pblock.1]
rm = "loda"
r = 2
stream = 0

[pblock.2]
rm = "loda"
r = 2
stream = 0

[fabric.dfx.swap.1]
pblock = 1
at_flit = 3
rm = "rshash"
r = 2
dark_flits = 2
"#;
    let cfg = FseadConfig::from_str(text).unwrap();
    let ds = tiny("scripted", 120, 3, 17);
    let mut fabric = Fabric::new(cfg, vec![ds.clone()]).unwrap();
    let out = fabric.run().unwrap();
    // Dark flits 3 and 4 (samples 48..80) vanish at the decoupler.
    assert_eq!(out.pblock_scores[&1].len(), 120 - 32);
    assert_eq!(out.pblock_scores[&2].len(), 120);
    assert_eq!(out.swap_events.len(), 1);
    let ev = &out.swap_events[0];
    assert_eq!(ev.dropped, 2);
    assert_eq!(ev.bypassed, 0);
    assert!(ev.to.contains("rshash"), "{}", ev.to);
    assert_eq!(fabric.config().pblocks[0].rm, RmKind::Detector(DetectorKind::RsHash));
    // The schedule is consumed: a second pass streams clean through the
    // new assignment.
    let out2 = fabric.run().unwrap();
    assert!(out2.swap_events.is_empty());
    assert_eq!(out2.pblock_scores[&1].len(), 120);
}

#[test]
fn hot_swap_refused_without_decoupler() {
    let mut cfg = cpu_cfg();
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 2,
        stream: 0,
        lanes: 0,
    });
    let ds = tiny("nodec", 60, 3, 5);
    let fabric = Fabric::new(cfg, vec![ds]).unwrap();
    fabric.pblock(1).unwrap().decoupler.set_enabled(false);
    let err = fabric
        .schedule_swap(1, 2, RmKind::Detector(DetectorKind::XStream), 2, None)
        .unwrap_err();
    assert!(err.to_string().contains("decoupler is disabled"), "{err}");
}

#[test]
fn empty_fabric_errors() {
    let cfg = cpu_cfg();
    let err = Fabric::new(cfg, vec![]).and_then(|mut f| f.run());
    assert!(err.is_err());
}

#[test]
fn combo_across_streams_rejected() {
    let mut cfg = cpu_cfg();
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 2,
        stream: 0,
        lanes: 0,
    });
    cfg.pblocks.push(PblockCfg {
        id: 2,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 2,
        stream: 1,
        lanes: 0,
    });
    cfg.combos.push(ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2], weights: vec![] });
    let streams = vec![tiny("a", 50, 3, 1), tiny("b", 50, 3, 2)];
    assert!(Fabric::new(cfg, streams).is_err());
}
