//! CPU-baseline ↔ PJRT-artifact parity (the paper's Tables 8–10 AUC
//! columns): the same parameters drive both paths; scores must agree to
//! float tolerance and AUC must be essentially identical.
//!
//! Requires `make artifacts`; tests are skipped (not failed) if the
//! artifact directory is missing so `cargo test` works pre-AOT.

use fsead::config::DetectorHyper;
use fsead::data::stream::ChunkStream;
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::metrics::auc_roc;
use fsead::runtime::{generate_params, Runtime};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("artifacts not built — skipping PJRT parity test");
        return None;
    }
    Some(Runtime::start("artifacts").expect("runtime starts"))
}

fn tiny_dataset(n: usize) -> fsead::data::Dataset {
    let p = DatasetProfile { name: "parity", n, d: 3, outliers: n / 20, clusters: 3 };
    generate_profile(&p, 77)
}

/// Run one detector through the FPGA path and the CPU path with identical
/// parameters; return (fpga_scores, cpu_scores).
fn run_both(kind: DetectorKind, quantize: bool, n: usize) -> Option<(Vec<f32>, Vec<f32>, Vec<bool>)> {
    let rt = runtime()?;
    let handle = rt.handle();
    let reg = rt.registry();
    let ds = tiny_dataset(n);
    let hyper = DetectorHyper::default();
    let (r, d) = (4usize, 3usize);
    let meta = reg.find_detector(kind, d, r, quantize).expect("test artifact exists");
    assert_eq!(meta.window, hyper.window);
    let warmup = ds.warmup(hyper.window);
    let seed = 4242;
    let params = generate_params(kind, seed, r, d, &hyper, warmup);
    let inst = handle.load_detector(meta, params).expect("load detector");

    let mut fpga_scores = Vec::with_capacity(ds.n());
    for chunk in ChunkStream::new(&ds.data, d, meta.chunk) {
        let scores = handle.run_chunk(inst, chunk.data, chunk.mask).expect("run chunk");
        fpga_scores.extend_from_slice(&scores[..chunk.n_valid]);
    }

    let mut spec = DetectorSpec::new(kind, d, r, seed);
    spec.quantize = quantize;
    let mut det = spec.build(warmup);
    let cpu_scores = det.run_stream(&ds.data);
    Some((fpga_scores, cpu_scores, ds.labels))
}

fn assert_close(kind: DetectorKind, fpga: &[f32], cpu: &[f32], labels: &[bool], tol: f32) {
    assert_eq!(fpga.len(), cpu.len());
    let mut worst = 0f32;
    for (i, (a, b)) in fpga.iter().zip(cpu).enumerate() {
        let diff = (a - b).abs();
        if diff > worst {
            worst = diff;
        }
        assert!(
            diff < tol || diff / b.abs().max(1.0) < tol,
            "{kind:?} sample {i}: fpga={a} cpu={b}"
        );
    }
    let auc_f = auc_roc(fpga, labels);
    let auc_c = auc_roc(cpu, labels);
    // Paper Tables 8–10: CPU and FPGA AUC agree to ~1e-3.
    assert!(
        (auc_f - auc_c).abs() < 5e-3,
        "{kind:?}: AUC fpga={auc_f:.4} cpu={auc_c:.4} (worst |Δscore|={worst})"
    );
    eprintln!("{kind:?}: AUC fpga={auc_f:.4} cpu={auc_c:.4} worst |Δ|={worst:.2e}");
}

#[test]
fn loda_fpga_matches_cpu_unquantized() {
    if let Some((f, c, l)) = run_both(DetectorKind::Loda, false, 600) {
        assert_close(DetectorKind::Loda, &f, &c, &l, 2e-3);
    }
}

#[test]
fn rshash_fpga_matches_cpu_unquantized() {
    if let Some((f, c, l)) = run_both(DetectorKind::RsHash, false, 600) {
        assert_close(DetectorKind::RsHash, &f, &c, &l, 2e-3);
    }
}

#[test]
fn xstream_fpga_matches_cpu_unquantized() {
    if let Some((f, c, l)) = run_both(DetectorKind::XStream, false, 600) {
        assert_close(DetectorKind::XStream, &f, &c, &l, 2e-3);
    }
}

#[test]
fn quantized_artifacts_agree_with_quantized_cpu() {
    for kind in DetectorKind::ALL {
        if let Some((f, c, l)) = run_both(kind, true, 400) {
            // Q16.16 grid: differences are at most a few ulps of 2^-16
            // plus occasional bin-boundary flips.
            assert_close(kind, &f, &c, &l, 3e-3);
        }
    }
}

#[test]
fn state_threading_is_exact_across_chunks() {
    // Same stream through chunked invocations twice: identical scores
    // (the device instance carries no hidden nondeterminism).
    let Some(rt) = runtime() else { return };
    let handle = rt.handle();
    let ds = tiny_dataset(300);
    let hyper = DetectorHyper::default();
    let meta = rt
        .registry()
        .find_detector(DetectorKind::Loda, 3, 4, false)
        .unwrap();
    let params = generate_params(DetectorKind::Loda, 9, 4, 3, &hyper, ds.warmup(hyper.window));
    let inst = handle.load_detector(meta, params).unwrap();

    let mut pass = || -> Vec<f32> {
        handle.reset_state(inst).unwrap();
        let mut out = Vec::new();
        for chunk in ChunkStream::new(&ds.data, 3, meta.chunk) {
            let s = handle.run_chunk(inst, chunk.data, chunk.mask).unwrap();
            out.extend_from_slice(&s[..chunk.n_valid]);
        }
        out
    };
    let a = pass();
    let b = pass();
    assert_eq!(a, b);
}

#[test]
fn bypass_artifact_is_identity() {
    let Some(rt) = runtime() else { return };
    let handle = rt.handle();
    let meta = rt.registry().find_bypass(3).unwrap();
    let data: Vec<f32> = (0..meta.chunk * 3).map(|i| i as f32 * 0.25).collect();
    let out = handle.run_bypass(3, data.clone()).unwrap();
    assert_eq!(out, data);
}

#[test]
fn combo_artifacts_match_native_combiners() {
    let Some(rt) = runtime() else { return };
    let handle = rt.handle();
    let chunk = rt.registry().find_combo("avg").unwrap().chunk;
    let mut scores = vec![0f32; chunk * 4];
    for i in 0..chunk {
        for k in 0..4 {
            scores[i * 4 + k] = (i as f32 * 0.1) + k as f32;
        }
    }
    let active = vec![1.0, 1.0, 1.0, 0.0];
    let avg = handle.run_combo("avg", scores.clone(), active.clone(), vec![]).unwrap();
    let max = handle.run_combo("max", scores.clone(), active.clone(), vec![]).unwrap();
    let wavg = handle
        .run_combo("wavg", scores.clone(), active.clone(), vec![0.5, 0.25, 0.25, 0.0])
        .unwrap();
    for i in 0..chunk {
        let row: Vec<f32> = (0..3).map(|k| scores[i * 4 + k]).collect();
        let want_avg = row.iter().sum::<f32>() / 3.0;
        assert!((avg[i] - want_avg).abs() < 1e-5);
        assert!((max[i] - row.iter().cloned().fold(f32::MIN, f32::max)).abs() < 1e-5);
        let want_wavg = (row[0] * 0.5 + row[1] * 0.25 + row[2] * 0.25) / 1.0;
        assert!((wavg[i] - want_wavg).abs() < 1e-5);
    }
    // Label combos.
    let mut labels = vec![0f32; chunk * 4];
    labels[0] = 1.0; // sample 0: one vote
    labels[4] = 1.0;
    labels[5] = 1.0; // sample 1: two votes
    let or = handle.run_combo("or", labels.clone(), active.clone(), vec![]).unwrap();
    let vote = handle.run_combo("vote", labels.clone(), active.clone(), vec![]).unwrap();
    assert_eq!(or[0], 1.0);
    assert_eq!(or[1], 1.0);
    assert_eq!(or[2], 0.0);
    // quorum = 3 active: 1 vote is not a majority (2·1 < 3); 2 votes are.
    assert_eq!(vote[0], 0.0);
    assert_eq!(vote[1], 1.0);
}
