//! Network plane end-to-end: scores over the wire must be bit-identical
//! to the in-process session API — including across a mid-stream
//! suspend → ticket-over-the-wire → resume hop onto a second server built
//! from the same config — and garbage on the socket must always produce a
//! typed status, never a panic or a wedged partition.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use fsead::config::{FseadConfig, OverloadPolicy, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::fabric::net::{
    read_frame, write_frame, NetServer, TAG_CLOSE, TAG_OPEN, TAG_OPENED, TAG_PUSH,
    TAG_RESUME, TAG_STATUS, STATUS_BAD_FRAME, STATUS_BAD_TICKET, STATUS_CONFIG_MISMATCH,
    STATUS_FRAME_TOO_LARGE, STATUS_NO_SESSION, STATUS_SATURATED, STATUS_SERVER_BUSY,
    STATUS_SESSION_OPEN, STATUS_TICKET_VERSION, STATUS_UNKNOWN_TAG,
};
use fsead::fabric::net_client::{NetClient, NetStatus};
use fsead::fabric::server::{FabricServer, SessionSpec};

fn tiny(name: &'static str, n: usize, d: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

fn cpu_cfg(chunk: usize, kinds: &[DetectorKind]) -> FseadConfig {
    let mut cfg = FseadConfig { use_fpga: false, chunk, ..FseadConfig::default() };
    for (i, k) in kinds.iter().enumerate() {
        cfg.pblocks.push(PblockCfg {
            id: i + 1,
            rm: RmKind::Detector(*k),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

fn start_net(cfg: FseadConfig) -> (Arc<FabricServer>, NetServer) {
    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let net = NetServer::start("127.0.0.1:0", Arc::clone(&server)).unwrap();
    (server, net)
}

/// Stop the listener, wait for connection handlers to drop their server
/// clones, then shut the fabric down.
fn stop_net(net: NetServer, server: Arc<FabricServer>) {
    net.stop();
    let mut server = server;
    for _ in 0..1000 {
        match Arc::try_unwrap(server) {
            Ok(s) => {
                s.shutdown().unwrap();
                return;
            }
            Err(s) => {
                server = s;
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    panic!("a connection handler never released the fabric after stop()");
}

/// In-process reference: the session API end to end, one pblock.
fn reference_scores(cfg: &FseadConfig, ds: &Dataset, pblock: usize) -> Vec<f32> {
    let window = cfg.hyper.window;
    let server = FabricServer::start(cfg.clone()).unwrap();
    let mut session =
        server.open(SessionSpec::for_dataset(ds, window).on_pblock(pblock)).unwrap();
    session.push(&ds.data).unwrap();
    let scores = session.close().unwrap().scores;
    server.shutdown().unwrap();
    scores
}

fn status_code(err: &anyhow::Error) -> u16 {
    err.downcast_ref::<NetStatus>()
        .unwrap_or_else(|| panic!("expected a typed NetStatus, got {err:#}"))
        .code
}

#[test]
fn wire_scores_bit_identical_to_in_process_session() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda, DetectorKind::RsHash]);
    let window = cfg.hyper.window;
    let ds = tiny("net", 400, 3, 17);
    let (server, net) = start_net(cfg.clone());
    let addr = net.addr().to_string();

    for pblock in [1usize, 2] {
        let reference = reference_scores(&cfg, &ds, pblock);
        let mut client = NetClient::connect(&addr).unwrap();
        client.open(ds.d, Some(pblock), ds.warmup(window)).unwrap();
        // Deliberately rough block size: 7 rows is neither a flit (16 rows)
        // nor a divisor of one, so the server's byte-level staging path
        // (partial flits carried across pushes) is on the hook too.
        let mut scores = Vec::new();
        for block in ds.data.chunks(7 * ds.d) {
            scores.extend(client.push(block).unwrap());
        }
        let closed = client.close().unwrap();
        scores.extend(closed.scores);
        assert_eq!(closed.samples, ds.n() as u64);
        assert_eq!(closed.flits, ds.n().div_ceil(16) as u64);
        assert_eq!(
            scores, reference,
            "pblock {pblock}: networked scores diverged from the in-process session"
        );
    }

    stop_net(net, server);
}

#[test]
fn suspend_over_wire_resumes_on_second_server_bit_identically() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let ds = tiny("hop", 400, 3, 29);
    let reference = reference_scores(&cfg, &ds, 1);

    // Server A: stream 150 rows (9 whole flits + 6 rows staged mid-flit),
    // then suspend into ticket bytes. A is then torn down completely — the
    // ticket must carry everything the hop needs.
    let cut = 150 * ds.d;
    let (ticket, mut scores) = {
        let (server_a, net_a) = start_net(cfg.clone());
        let mut client = NetClient::connect(&net_a.addr().to_string()).unwrap();
        client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
        let mut scores = Vec::new();
        for block in ds.data[..cut].chunks(50 * ds.d) {
            scores.extend(client.push(block).unwrap());
        }
        let (ticket, tail) = client.suspend().unwrap();
        scores.extend(tail);
        stop_net(net_a, server_a);
        (ticket, scores)
    };

    // Server B, same config, fresh process state: resume from the raw
    // ticket bytes and stream the rest.
    let (server_b, net_b) = start_net(cfg.clone());
    let mut client = NetClient::connect(&net_b.addr().to_string()).unwrap();
    let id = client.resume(&ticket).unwrap();
    assert_eq!(Some(id), client.session());
    for block in ds.data[cut..].chunks(50 * ds.d) {
        scores.extend(client.push(block).unwrap());
    }
    let closed = client.close().unwrap();
    scores.extend(closed.scores);
    assert_eq!(closed.samples, ds.n() as u64, "the resumed cursor keeps counting");
    assert_eq!(
        scores, reference,
        "suspend → wire → resume onto a second server must be bit-transparent"
    );
    stop_net(net_b, server_b);
}

/// One raw exchange against the listener: write `bytes`, half-close, and
/// collect every reply frame until the server hangs up.
fn raw_exchange(addr: &str, bytes: &[u8]) -> Vec<(u8, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(bytes).unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut frames = Vec::new();
    while let Ok(Some(f)) = read_frame(&mut stream) {
        frames.push(f);
    }
    frames
}

/// The status code of the single reply frame an exchange produced.
fn sole_status(frames: &[(u8, Vec<u8>)]) -> u16 {
    assert_eq!(frames.len(), 1, "expected exactly one reply frame, got {frames:?}");
    let (tag, payload) = &frames[0];
    assert_eq!(*tag, TAG_STATUS, "expected a status frame");
    fsead::fabric::net::decode_status(payload).unwrap().0
}

#[test]
fn garbage_frames_yield_typed_statuses_and_never_wedge_the_server() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let (server, net) = start_net(cfg.clone());
    let addr = net.addr().to_string();

    // A full valid Open frame to tear apart: d=3, any pblock, no warm-up.
    let mut open = Vec::new();
    open.extend_from_slice(&3u32.to_le_bytes());
    open.extend_from_slice(&0u32.to_le_bytes());
    open.extend_from_slice(&0u32.to_le_bytes());
    let mut whole = Vec::new();
    write_frame(&mut whole, TAG_OPEN, &open).unwrap();

    // Truncation / mid-frame disconnect at every cut point inside the
    // frame: each must come back as one bad_frame status, never a hang.
    for cut in 1..whole.len() {
        let frames = raw_exchange(&addr, &whole[..cut]);
        assert_eq!(sole_status(&frames), STATUS_BAD_FRAME, "cut at byte {cut}");
    }

    // Oversized declared length: refused by code before any allocation.
    let mut huge = vec![TAG_PUSH];
    huge.extend_from_slice(&u32::MAX.to_le_bytes());
    assert_eq!(sole_status(&raw_exchange(&addr, &huge)), STATUS_FRAME_TOO_LARGE);

    // Unknown tag: typed refusal, connection closed (stream desync).
    let mut unknown = Vec::new();
    write_frame(&mut unknown, 0x55, b"?").unwrap();
    assert_eq!(sole_status(&raw_exchange(&addr, &unknown)), STATUS_UNKNOWN_TAG);

    // Push with no session open: typed, and *not* fatal — the same
    // connection then opens a session and is answered normally.
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut push = Vec::new();
        push.extend_from_slice(&1u64.to_le_bytes());
        write_frame(&mut stream, TAG_PUSH, &push).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap().unwrap();
        assert_eq!(tag, TAG_STATUS);
        assert_eq!(fsead::fabric::net::decode_status(&payload).unwrap().0, STATUS_NO_SESSION);
        write_frame(&mut stream, TAG_OPEN, &open).unwrap();
        let (tag, _) = read_frame(&mut stream).unwrap().unwrap();
        assert_eq!(tag, TAG_OPENED, "connection must survive a no-session push");
        // A second Open on the same connection is its own typed refusal.
        write_frame(&mut stream, TAG_OPEN, &open).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap().unwrap();
        assert_eq!(tag, TAG_STATUS);
        assert_eq!(fsead::fabric::net::decode_status(&payload).unwrap().0, STATUS_SESSION_OPEN);
    }

    // Resume with bytes that are not a ticket.
    let mut resume = Vec::new();
    write_frame(&mut resume, TAG_RESUME, b"not a ticket").unwrap();
    assert_eq!(sole_status(&raw_exchange(&addr, &resume)), STATUS_BAD_TICKET);

    // Close naming a session that is not this connection's.
    let mut close = Vec::new();
    write_frame(&mut close, TAG_CLOSE, &99u64.to_le_bytes()).unwrap();
    assert_eq!(sole_status(&raw_exchange(&addr, &close)), STATUS_NO_SESSION);

    // After the whole sweep the server still serves, bit-identically.
    let ds = tiny("after", 120, 3, 41);
    let reference = reference_scores(&cfg, &ds, 1);
    let mut client = NetClient::connect(&addr).unwrap();
    client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
    let mut scores = client.push(&ds.data).unwrap();
    scores.extend(client.close().unwrap().scores);
    assert_eq!(scores, reference, "the garbage sweep degraded the server");

    stop_net(net, server);
}

#[test]
fn admission_refusals_arrive_as_typed_status_codes() {
    // One partition, one slot, shed-on-overload: the second concurrent
    // open must surface AdmitError::Saturated as wire code 1.
    let mut cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    cfg.server.overload = OverloadPolicy::Shed;
    let (server, net) = start_net(cfg);
    let addr = net.addr().to_string();

    let mut holder = NetClient::connect(&addr).unwrap();
    holder.open(3, None, &[]).unwrap();

    let mut second = NetClient::connect(&addr).unwrap();
    let err = second.open(3, None, &[]).unwrap_err();
    assert_eq!(status_code(&err), STATUS_SATURATED, "{err:#}");

    // The refused client's connection is still good: close the holder and
    // the same client opens on the freed slot (poll briefly — the worker
    // frees the slot at its episode boundary).
    holder.close().unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        match second.open(3, None, &[]) {
            Ok(_) => break,
            Err(err) => {
                assert_eq!(status_code(&err), STATUS_SATURATED, "{err:#}");
                assert!(
                    std::time::Instant::now() < deadline,
                    "the partition slot was never released after close"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    second.close().unwrap();

    stop_net(net, server);
}

#[test]
fn ping_answers_with_pong_before_during_and_after_a_session() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let (server, net) = start_net(cfg);
    let addr = net.addr().to_string();

    let mut client = NetClient::connect(&addr).unwrap();
    client.ping().unwrap();
    client.open(3, None, &[]).unwrap();
    client.ping().unwrap();
    client.close().unwrap();
    client.ping().unwrap();

    stop_net(net, server);
}

#[test]
fn io_timeout_turns_a_wedged_server_into_an_error_not_a_hang() {
    // A listener that never accepts: the TCP handshake completes out of the
    // kernel backlog, the Open frame lands in the socket buffer, and no
    // reply ever comes. Without a timeout the client would block forever.
    let wedged = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = wedged.local_addr().unwrap().to_string();

    let t0 = std::time::Instant::now();
    let mut client = NetClient::connect_timeout(&addr, Duration::from_secs(2)).unwrap();
    client.set_io_timeout(Some(Duration::from_millis(200))).unwrap();
    let err = client.open(3, None, &[]).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "the wedged open must fail by timeout, not hang: {err:#}"
    );
    drop(wedged);
}

#[test]
fn reconnect_with_backoff_gives_up_at_the_deadline_and_succeeds_when_alive() {
    // A freshly freed port: connects are refused immediately, so the
    // back-off loop itself is what spends the deadline.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let t0 = std::time::Instant::now();
    let err = NetClient::reconnect_with_backoff(
        &dead,
        None,
        Duration::from_millis(10),
        Duration::from_millis(300),
    )
    .unwrap_err();
    let spent = t0.elapsed();
    assert!(
        spent < Duration::from_secs(5),
        "gave up too slowly ({spent:?}): {err:#}"
    );

    // Against a live server the same call connects and serves.
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let (server, net) = start_net(cfg);
    let mut client = NetClient::reconnect_with_backoff(
        &net.addr().to_string(),
        Some(Duration::from_secs(10)),
        Duration::from_millis(10),
        Duration::from_secs(5),
    )
    .unwrap();
    client.ping().unwrap();
    stop_net(net, server);
}

#[test]
fn ticket_version_skew_is_refused_with_its_own_wire_code() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let ds = tiny("skew", 160, 3, 53);
    let (server, net) = start_net(cfg);
    let addr = net.addr().to_string();

    let mut client = NetClient::connect(&addr).unwrap();
    client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
    client.push(&ds.data[..32 * ds.d]).unwrap();
    let (mut ticket, _) = client.suspend().unwrap();

    // The version byte sits at offset 4, outside the CRC frame — exactly
    // what a ticket written by a different build would present.
    ticket[4] = 99;
    let mut resumer = NetClient::connect(&addr).unwrap();
    let err = resumer.resume(&ticket).unwrap_err();
    assert_eq!(status_code(&err), STATUS_TICKET_VERSION, "{err:#}");

    // Total garbage stays bad_ticket — the codes are distinct.
    let mut garbler = NetClient::connect(&addr).unwrap();
    let err = garbler.resume(b"not a ticket at all").unwrap_err();
    assert_eq!(status_code(&err), STATUS_BAD_TICKET, "{err:#}");

    stop_net(net, server);
}

#[test]
fn resume_on_a_mis_provisioned_server_is_refused_as_config_mismatch() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let ds = tiny("mismatch", 160, 3, 59);
    let (server_a, net_a) = start_net(cfg.clone());
    let mut client = NetClient::connect(&net_a.addr().to_string()).unwrap();
    client.open(ds.d, Some(1), ds.warmup(window)).unwrap();
    client.push(&ds.data[..32 * ds.d]).unwrap();
    let (ticket, _) = client.suspend().unwrap();
    drop(client);
    stop_net(net_a, server_a);

    // Server B serves r = 4 partitions: the r = 2 ticket fits no layout
    // there, and that mis-provisioning must be distinct from bad_ticket.
    let mut cfg_b = FseadConfig { use_fpga: false, chunk: 16, ..FseadConfig::default() };
    cfg_b.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 4,
        stream: 0,
        lanes: 0,
    });
    let (server_b, net_b) = start_net(cfg_b);
    let mut resumer = NetClient::connect(&net_b.addr().to_string()).unwrap();
    let err = resumer.resume(&ticket).unwrap_err();
    assert_eq!(status_code(&err), STATUS_CONFIG_MISMATCH, "{err:#}");
    stop_net(net_b, server_b);
}

#[test]
fn accept_loop_survives_a_connect_and_drop_burst() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let window = cfg.hyper.window;
    let (server, net) = start_net(cfg.clone());
    let addr = net.addr().to_string();

    // A burst of connections torn down at every stage — immediately, after
    // a half-written frame, after a whole frame — is the userspace shape
    // of the aborted-handshake / fd-churn storms the accept loop's retry
    // classifier exists for. None of it may kill the listener.
    for i in 0..60 {
        let mut stream = TcpStream::connect(&addr).unwrap();
        match i % 3 {
            0 => {}
            1 => {
                let _ = stream.write_all(&[TAG_PUSH]);
            }
            _ => {
                let mut open = Vec::new();
                open.extend_from_slice(&3u32.to_le_bytes());
                open.extend_from_slice(&0u32.to_le_bytes());
                open.extend_from_slice(&0u32.to_le_bytes());
                let _ = write_frame(&mut stream, TAG_OPEN, &open);
            }
        }
        let _ = stream.shutdown(Shutdown::Both);
    }

    // The listener still serves a full bit-identical round trip.
    let ds = tiny("burst", 120, 3, 61);
    let reference = reference_scores(&cfg, &ds, 1);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut client = loop {
        // Burst leftovers may briefly hold connection slots; retry.
        let mut c = NetClient::connect(&addr).unwrap();
        if c.open(ds.d, Some(1), ds.warmup(window)).is_ok() {
            break c;
        }
        assert!(std::time::Instant::now() < deadline, "listener never recovered");
        std::thread::sleep(Duration::from_millis(20));
    };
    let mut scores = client.push(&ds.data).unwrap();
    scores.extend(client.close().unwrap().scores);
    assert_eq!(scores, reference, "the burst degraded the server");

    stop_net(net, server);
}

#[test]
fn connection_cap_sheds_with_server_busy_frame() {
    let cfg = cpu_cfg(16, &[DetectorKind::Loda]);
    let server = Arc::new(FabricServer::start(cfg).unwrap());
    let net = NetServer::start_with_limit("127.0.0.1:0", Arc::clone(&server), 1).unwrap();
    let addr = net.addr().to_string();

    // One connected client occupies the only slot...
    let mut holder = NetClient::connect(&addr).unwrap();
    holder.open(3, None, &[]).unwrap();

    // ...so the next connection is shed with one server_busy status frame
    // before any handler exists. (The holder was accepted first; the gauge
    // is at the cap by the time this connect reaches the accept loop.)
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let (tag, payload) = read_frame(&mut stream).unwrap().expect("busy status frame");
    assert_eq!(tag, TAG_STATUS);
    let (code, msg) = fsead::fabric::net::decode_status(&payload).unwrap();
    assert_eq!(code, STATUS_SERVER_BUSY, "{msg}");
    assert!(matches!(read_frame(&mut stream), Ok(None)), "shed connection must be closed");

    holder.close().unwrap();
    drop(holder);
    // The freed slot serves again. The handler releases it asynchronously,
    // and a still-shed attempt can die anywhere in its request (the server
    // hangs up right after the busy frame) — so just retry until a full
    // open/close round-trip succeeds.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let mut client = NetClient::connect(&addr).unwrap();
        if client.open(3, None, &[]).is_ok() {
            client.close().unwrap();
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "the connection slot was never released"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    stop_net(net, server);
}
