//! Multi-lane pblock parity: intra-partition instance parallelism must not
//! change what the data plane computes.
//!
//! Contract under test (see `fabric::pblock` module docs):
//!
//! - `lanes = 1` is **bit-identical** to the pre-lane service path — the
//!   single-detector RM and the exact service loops the golden-vector and
//!   server bit-identity suites pin down.
//! - `lanes > 1` changes only the f32 summation order of the ensemble mean
//!   (the established 1e-5 partition tolerance vs `lanes = 1`), including
//!   across mid-stream DFX swaps and server session re-opens, and covering
//!   uneven `r % lanes != 0` partitions.
//! - Lane workers are **resident**: spawned once per partition when the
//!   server (or fabric) comes up, and never again — not per session, not
//!   per burst.
//!
//! Tests serialize on one mutex so the process-wide lane-worker spawn
//! counter gives deterministic deltas.

use std::sync::{Mutex, MutexGuard, OnceLock};

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::lanes::total_workers_spawned;
use fsead::ensemble::ExecMode;
use fsead::fabric::server::{FabricServer, SessionSpec};
use fsead::fabric::{pblock_seed, Fabric};

fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny(name: &'static str, n: usize, d: usize, seed: u64) -> Dataset {
    let p = DatasetProfile { name, n, d, outliers: n / 20, clusters: 2 };
    generate_profile(&p, seed)
}

/// Single-pblock CPU fabric with an explicit per-pblock lane count.
fn lane_cfg(exec: ExecMode, kind: DetectorKind, r: usize, lanes: usize) -> FseadConfig {
    let mut cfg = FseadConfig { use_fpga: false, chunk: 16, exec, ..FseadConfig::default() };
    cfg.hyper.window = 16;
    cfg.hyper.bins = 8;
    cfg.hyper.modulus = 32;
    cfg.hyper.k = 4;
    cfg.pblocks.push(PblockCfg { id: 1, rm: RmKind::Detector(kind), r, stream: 0, lanes });
    cfg
}

fn run_scores(cfg: &FseadConfig, ds: &Dataset) -> Vec<f32> {
    let mut fabric = Fabric::new(cfg.clone(), vec![ds.clone()]).unwrap();
    let out = fabric.run().unwrap();
    out.pblock_scores[&1].clone()
}

/// The established partition tolerance: lane counts only reorder the f32
/// ensemble-mean summation.
fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = 1e-5 * x.abs().max(y.abs()).max(1.0);
        assert!((x - y).abs() <= tol, "{what}: sample {i}: {x} vs {y}");
    }
}

#[test]
fn single_lane_is_bit_identical_to_standalone_detector() {
    // lanes = 1 (explicit or inherited) must be the pre-lane data plane:
    // exact f32 equality with the standalone detector, in both exec modes.
    let _guard = serial();
    let ds = tiny("lane1", 150, 3, 41);
    for exec in ExecMode::ALL {
        let cfg = lane_cfg(exec, DetectorKind::Loda, 4, 1);
        let got = run_scores(&cfg, &ds);
        let mut spec = DetectorSpec::new(DetectorKind::Loda, 3, 4, pblock_seed(cfg.seed, 1));
        spec.window = cfg.hyper.window;
        spec.bins = cfg.hyper.bins;
        spec.w = cfg.hyper.w;
        spec.modulus = cfg.hyper.modulus;
        spec.k = cfg.hyper.k;
        let mut det = spec.build(ds.warmup(cfg.hyper.window));
        assert_eq!(got, det.run_stream(&ds.data), "{exec:?}");
        // Inheriting the [fabric] default is the same single-lane path.
        let mut inherit = lane_cfg(exec, DetectorKind::Loda, 4, 0);
        inherit.lanes = 1;
        assert_eq!(run_scores(&inherit, &ds), got, "{exec:?} inherited");
    }
}

#[test]
fn multi_lane_matches_single_lane_within_partition_tolerance() {
    // lanes ∈ {2, 4} vs lanes = 1 for every detector and both exec modes;
    // r = 6 gives an uneven 2+2+1+1 split at 4 lanes.
    let _guard = serial();
    let ds = tiny("lanes24", 150, 3, 42);
    for kind in DetectorKind::ALL {
        for exec in ExecMode::ALL {
            let base = run_scores(&lane_cfg(exec, kind, 6, 1), &ds);
            assert_eq!(base.len(), 150);
            for lanes in [2usize, 4] {
                let got = run_scores(&lane_cfg(exec, kind, 6, lanes), &ds);
                assert_close(&got, &base, &format!("{kind:?} {exec:?} lanes={lanes}"));
            }
        }
    }
}

#[test]
fn uneven_lane_partition_is_covered() {
    // r % lanes != 0 both ways: r = 5 over 2 and 3 lanes.
    let _guard = serial();
    let ds = tiny("uneven", 120, 3, 43);
    for lanes in [2usize, 3] {
        let base = run_scores(&lane_cfg(ExecMode::Batched, DetectorKind::RsHash, 5, 1), &ds);
        let got = run_scores(&lane_cfg(ExecMode::Batched, DetectorKind::RsHash, 5, lanes), &ds);
        assert_close(&got, &base, &format!("uneven lanes={lanes}"));
    }
}

#[test]
fn lane_scores_are_bit_identical_across_exec_modes() {
    // For a fixed lane count the two drain strategies must agree exactly:
    // chunk boundaries never change update_batch arithmetic, and the lane
    // merge is per sample.
    let _guard = serial();
    let ds = tiny("lanemodes", 140, 3, 44);
    for kind in DetectorKind::ALL {
        let lockstep = run_scores(&lane_cfg(ExecMode::LockStep, kind, 4, 2), &ds);
        let batched = run_scores(&lane_cfg(ExecMode::Batched, kind, 4, 2), &ds);
        assert_eq!(lockstep, batched, "{kind:?}");
    }
}

#[test]
fn mid_stream_swap_keeps_lane_parity() {
    // A live DFX swap on a 2-lane partition stages a whole 2-lane
    // replacement array; outside-the-dark-window scores stay within the
    // partition tolerance of the single-lane run, and the dark window is
    // zero in both.
    let _guard = serial();
    let ds = tiny("laneswap", 150, 3, 45);
    for exec in ExecMode::ALL {
        let mut outputs = Vec::new();
        for lanes in [1usize, 2] {
            let cfg = lane_cfg(exec, DetectorKind::Loda, 4, lanes);
            let mut fabric = Fabric::new(cfg, vec![ds.clone()]).unwrap();
            fabric
                .schedule_swap(1, 3, RmKind::Detector(DetectorKind::RsHash), 4, Some(2))
                .unwrap();
            let out = fabric.run().unwrap();
            assert_eq!(out.swap_events.len(), 1, "{exec:?} lanes={lanes}");
            let ev = &out.swap_events[0];
            assert_eq!((ev.at_flit, ev.dark_flits, ev.bypassed), (3, 2, 2));
            if lanes > 1 {
                assert!(ev.from.contains("lanes=2"), "{}", ev.from);
                assert!(ev.to.contains("lanes=2"), "swap must stage a lane array: {}", ev.to);
            }
            outputs.push(out.pblock_scores[&1].clone());
        }
        let (base, laned) = (&outputs[0], &outputs[1]);
        // Dark window (flits 3-4 → samples 48..80) is bypassed to zeros.
        assert!(laned[48..80].iter().all(|&v| v == 0.0), "{exec:?}");
        assert_close(laned, base, &format!("{exec:?} swap"));
    }
}

#[test]
fn server_sessions_reuse_resident_lane_workers() {
    // The multi-session stress case with lanes > 1: session scores stay
    // bit-identical to `Fabric::run` with the same lane count across
    // session re-opens and client churn, and the spawn counter proves the
    // lane workers came up once per partition — at server start — and
    // never again (not per session, not per burst).
    let _guard = serial();
    let ds = tiny("laneserve", 160, 3, 46);
    let mut cfg = FseadConfig { use_fpga: false, chunk: 16, ..FseadConfig::default() };
    cfg.hyper.window = 16;
    cfg.hyper.bins = 8;
    cfg.hyper.modulus = 32;
    cfg.hyper.k = 4;
    cfg.lanes = 2; // [fabric] default, inherited by both partitions
    for id in 1..=2usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 5, // uneven 3+2 lane split
            stream: 0,
            lanes: 0,
        });
    }
    // Reference pass (its fabric pools are torn down with the fabric).
    let reference: Vec<Vec<f32>> = {
        let mut fabric = Fabric::new(cfg.clone(), vec![ds.clone()]).unwrap();
        let out = fabric.run().unwrap();
        (1..=2).map(|id| out.pblock_scores[&id].clone()).collect()
    };

    let before = total_workers_spawned();
    let server = FabricServer::start(cfg.clone()).unwrap();
    let after_start = total_workers_spawned();
    assert_eq!(after_start - before, 4, "2 partitions × 2 resident lane workers");

    // Sequential re-opens on a pinned partition: every episode rebuilds
    // the lane array, reuses the pool, and reproduces the fabric pass.
    for round in 0..3 {
        let mut s = server
            .open(SessionSpec::for_dataset(&ds, cfg.hyper.window).on_pblock(1))
            .unwrap();
        s.push(&ds.data).unwrap();
        let closed = s.close().unwrap();
        assert_eq!(closed.scores, reference[0], "round {round}");
    }

    // Concurrent churn across both partitions.
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for client in 0..4usize {
            let ds = &ds;
            let cfg = &cfg;
            let server = &server;
            let reference = &reference;
            handles.push(scope.spawn(move || {
                for _ in 0..2 {
                    let mut s =
                        server.open(SessionSpec::for_dataset(ds, cfg.hyper.window)).unwrap();
                    let pblock = s.pblock();
                    let cut = 70 * ds.d;
                    s.push(&ds.data[..cut]).unwrap();
                    s.push(&ds.data[cut..]).unwrap();
                    let closed = s.close().unwrap();
                    assert_eq!(
                        closed.scores,
                        reference[pblock - 1],
                        "client {client} on RP-{pblock}"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    assert_eq!(
        total_workers_spawned(),
        after_start,
        "sessions and bursts must never respawn lane workers"
    );
    let report = server.shutdown().unwrap();
    assert_eq!(report.sessions_served, 11);
}
