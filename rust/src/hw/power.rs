//! Power model (paper Figures 18–19, DESIGN.md §6 substitution 1).
//!
//! Chip dynamic power is modelled as resource-proportional switching power,
//! `P_dyn = f_clk · Σ_blocks (c_lut·LUT + c_dsp·DSP + c_bram·BRAM + c_ff·FF)`,
//! with coefficient ratios typical for UltraScale+ (BRAM ≫ DSP ≫ LUT ≫ FF
//! per unit) and the overall scale calibrated so the paper's measured
//! full-fabric xStream configuration dissipates 5.232 W dynamic.
//! System power adds the measured 30 W board idle (Fig 19).

use super::resources::{Resources, TABLE6_BLOCKS};
use crate::defaults::FPGA_CLOCK_HZ;
use crate::detectors::DetectorKind;

/// Paper-reported reference points.
pub const PAPER_FPGA_DYNAMIC_W: f64 = 5.232;
pub const PAPER_FPGA_SYSTEM_IDLE_W: f64 = 30.0;
pub const PAPER_FPGA_SYSTEM_WORKING_W: f64 = 35.0;
pub const PAPER_CPU_IDLE_W: f64 = 7.90;
pub const PAPER_CPU_WORKING_W: f64 = 51.23;
pub const PAPER_CPU_DYNAMIC_W: f64 = 43.33;
/// ZCU111 chip static power estimate (UltraScale+ RFSoC, Vivado-typical).
pub const CHIP_STATIC_W: f64 = 2.8;

/// Relative switching energy per resource-unit per cycle (unnormalised).
const C_LUT: f64 = 1.0;
const C_DSP: f64 = 8.0;
const C_BRAM: f64 = 12.0;
const C_FF: f64 = 0.4;

/// Weighted toggle capacitance of a resource vector (arbitrary units).
fn toggle_weight(r: &Resources) -> f64 {
    C_LUT * r.lut + C_DSP * r.dsp + C_BRAM * r.bram + C_FF * r.ff
}

/// Power model calibrated against the paper's measured operating point.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Watts per (toggle-weight × Hz).
    scale: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Calibration point: the full fabric running xStream on all seven
        // pblocks (the Fig 18/19 measurement) dissipates 5.232 W dynamic.
        let total: f64 = TABLE6_BLOCKS.iter().map(|b| toggle_weight(&b.absolute())).sum();
        PowerModel { scale: PAPER_FPGA_DYNAMIC_W / (total * FPGA_CLOCK_HZ) }
    }
}

impl PowerModel {
    /// Dynamic power of a set of active resources at `clock_hz`.
    pub fn dynamic_w(&self, active: &[Resources], clock_hz: f64) -> f64 {
        let w: f64 = active.iter().map(toggle_weight).sum();
        self.scale * w * clock_hz
    }

    /// Chip power = static + dynamic (Fig 18).
    pub fn chip_w(&self, active: &[Resources], clock_hz: f64) -> f64 {
        CHIP_STATIC_W + self.dynamic_w(active, clock_hz)
    }

    /// Board/system power (Fig 19): measured idle + chip dynamic.
    pub fn system_w(&self, active: &[Resources], clock_hz: f64) -> f64 {
        PAPER_FPGA_SYSTEM_IDLE_W + self.dynamic_w(active, clock_hz)
    }

    /// Dynamic power of the full fabric running a homogeneous detector
    /// (all seven AD pblocks + switches + combos + static).
    pub fn full_fabric_dynamic_w(&self, _kind: DetectorKind) -> f64 {
        let all: Vec<Resources> = TABLE6_BLOCKS.iter().map(|b| b.absolute()).collect();
        self.dynamic_w(&all, FPGA_CLOCK_HZ)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_point_reproduced() {
        let m = PowerModel::default();
        let p = m.full_fabric_dynamic_w(DetectorKind::XStream);
        assert!((p - PAPER_FPGA_DYNAMIC_W).abs() < 1e-9, "p={p}");
    }

    #[test]
    fn system_working_power_matches_paper() {
        let m = PowerModel::default();
        let all: Vec<Resources> = TABLE6_BLOCKS.iter().map(|b| b.absolute()).collect();
        let sys = m.system_w(&all, FPGA_CLOCK_HZ);
        assert!((sys - PAPER_FPGA_SYSTEM_WORKING_W).abs() < 0.5, "sys={sys}");
    }

    #[test]
    fn power_scales_with_active_blocks() {
        let m = PowerModel::default();
        let one = [TABLE6_BLOCKS[0].absolute()];
        let two = [TABLE6_BLOCKS[0].absolute(), TABLE6_BLOCKS[1].absolute()];
        assert!(m.dynamic_w(&two, FPGA_CLOCK_HZ) > m.dynamic_w(&one, FPGA_CLOCK_HZ));
    }

    #[test]
    fn power_scales_with_clock() {
        let m = PowerModel::default();
        let blocks = [TABLE6_BLOCKS[0].absolute()];
        let full = m.dynamic_w(&blocks, FPGA_CLOCK_HZ);
        let half = m.dynamic_w(&blocks, FPGA_CLOCK_HZ / 2.0);
        assert!((full / half - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fpga_dynamic_is_8x_below_cpu_dynamic() {
        // Paper §4.4: CPU dynamic (43.33 W) > 8× FPGA dynamic (5.232 W).
        assert!(PAPER_CPU_DYNAMIC_W / PAPER_FPGA_DYNAMIC_W > 8.0);
    }
}
