//! Floorplan model (paper Figures 8–9): pblock placement on an abstract
//! ZCU111 grid, with the two switches in the centre, combo pblocks beside
//! them, and the seven AD pblocks surrounding the infrastructure. Used by
//! the `fsead resources` CLI to render the layout and by tests that check
//! the floorplanning invariants the paper calls out.

/// A rectangular region on the abstract device grid (cols × rows).
#[derive(Clone, Copy, Debug)]
pub struct Region {
    pub name: &'static str,
    pub x: usize,
    pub y: usize,
    pub w: usize,
    pub h: usize,
}

impl Region {
    pub fn centre(&self) -> (f64, f64) {
        (self.x as f64 + self.w as f64 / 2.0, self.y as f64 + self.h as f64 / 2.0)
    }

    pub fn area(&self) -> usize {
        self.w * self.h
    }

    pub fn intersects(&self, o: &Region) -> bool {
        self.x < o.x + o.w && o.x < self.x + self.w && self.y < o.y + o.h && o.y < self.y + self.h
    }
}

/// Grid dimensions of the abstract device.
pub const GRID_W: usize = 60;
pub const GRID_H: usize = 30;

/// The fSEAD floorplan (mirrors Fig 8's arrangement qualitatively):
/// switches central, combos adjacent, AD pblocks around the edge.
pub const FLOORPLAN: [Region; 12] = [
    Region { name: "RP-1", x: 0, y: 0, w: 14, h: 10 },
    Region { name: "RP-2", x: 0, y: 10, w: 14, h: 12 },
    Region { name: "RP-3", x: 0, y: 22, w: 14, h: 8 },
    Region { name: "RP-4", x: 46, y: 0, w: 14, h: 10 },
    Region { name: "RP-5", x: 46, y: 10, w: 14, h: 12 },
    Region { name: "RP-6", x: 46, y: 22, w: 14, h: 8 },
    Region { name: "RP-7", x: 18, y: 0, w: 24, h: 8 },
    Region { name: "SW1", x: 24, y: 12, w: 12, h: 8 },
    Region { name: "SW2", x: 24, y: 20, w: 8, h: 5 },
    Region { name: "CMB1", x: 18, y: 25, w: 8, h: 5 },
    Region { name: "CMB2", x: 27, y: 25, w: 8, h: 5 },
    Region { name: "CMB3", x: 36, y: 25, w: 8, h: 5 },
];

/// Render the floorplan as ASCII art (for `fsead resources --floorplan`).
pub fn render() -> String {
    let mut grid = vec![vec![b'.'; GRID_W]; GRID_H];
    for (i, r) in FLOORPLAN.iter().enumerate() {
        let ch = match r.name {
            n if n.starts_with("RP") => b'1' + (i as u8),
            "SW1" => b'S',
            "SW2" => b's',
            _ => b'C',
        };
        for y in r.y..(r.y + r.h).min(GRID_H) {
            for x in r.x..(r.x + r.w).min(GRID_W) {
                grid[y][x] = ch;
            }
        }
    }
    let mut out = String::new();
    for row in grid {
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out
}

fn find(name: &str) -> &'static Region {
    FLOORPLAN.iter().find(|r| r.name == name).unwrap()
}

/// Manhattan distance between region centres — the routing-delay proxy that
/// drives the paper's AXI register-slice pipelining decisions.
pub fn centre_distance(a: &str, b: &str) -> f64 {
    let (ax, ay) = find(a).centre();
    let (bx, by) = find(b).centre();
    (ax - bx).abs() + (ay - by).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_regions_overlap() {
        for (i, a) in FLOORPLAN.iter().enumerate() {
            for b in &FLOORPLAN[i + 1..] {
                assert!(!a.intersects(b), "{} overlaps {}", a.name, b.name);
            }
        }
    }

    #[test]
    fn switch1_is_central() {
        // Paper: "We place Switch-1 in the centre of the FPGA".
        let (x, y) = find("SW1").centre();
        assert!((x - GRID_W as f64 / 2.0).abs() < 6.0);
        assert!((y - GRID_H as f64 / 2.0).abs() < 6.0);
    }

    #[test]
    fn switch2_adjacent_to_switch1() {
        assert!(centre_distance("SW1", "SW2") < 10.0);
    }

    #[test]
    fn switch1_larger_than_switch2() {
        // Paper: Switch-1 gets a larger area (it serves seven pblocks).
        assert!(find("SW1").area() > find("SW2").area());
    }

    #[test]
    fn combos_connect_to_switch2_nearer_than_to_pblocks() {
        for c in ["CMB1", "CMB2", "CMB3"] {
            assert!(centre_distance(c, "SW2") < centre_distance(c, "RP-1"));
        }
    }

    #[test]
    fn every_pblock_within_grid() {
        for r in &FLOORPLAN {
            assert!(r.x + r.w <= GRID_W && r.y + r.h <= GRID_H, "{}", r.name);
        }
    }

    #[test]
    fn render_has_expected_shape() {
        let art = render();
        assert_eq!(art.lines().count(), GRID_H);
        assert!(art.contains('S') && art.contains('C'));
    }
}
