//! Roofline models (paper Figures 15–16).
//!
//! CPU (Fig 15): i7-10700F — peak f32 throughput from 8 cores × AVX2 FMA,
//! DRAM bandwidth from the paper's Intel Advisor run. FPGA (Fig 16): the
//! paper derives a 218.3 GOP/s compute bound for the whole ZCU111 and a
//! 110.4 GOP/s bound for the fSEAD partial-block region, with 13.4 GB/s
//! off-chip memory bandwidth.

/// A machine roofline: performance = min(peak, AI × bandwidth).
#[derive(Clone, Copy, Debug)]
pub struct Roofline {
    pub name: &'static str,
    /// Peak compute (GOP/s).
    pub peak_gops: f64,
    /// Memory bandwidth (GB/s).
    pub mem_bw_gbs: f64,
}

/// Attainable performance at arithmetic intensity `ai` (ops/byte).
impl Roofline {
    pub fn attainable(&self, ai: f64) -> f64 {
        (ai * self.mem_bw_gbs).min(self.peak_gops)
    }

    /// The ridge point: AI above which the machine is compute-bound.
    pub fn ridge(&self) -> f64 {
        self.peak_gops / self.mem_bw_gbs
    }
}

/// Intel i7-10700F (paper Fig 15): 8 cores × 2.9 GHz × 16 f32 FLOP/cycle.
pub const CPU_ROOFLINE: Roofline =
    Roofline { name: "i7-10700F", peak_gops: 371.2, mem_bw_gbs: 45.8 };

/// Whole-ZCU111 compute bound (paper: 218.3 GOP/s, 13.4 GB/s PL DDR).
pub const FPGA_ROOFLINE: Roofline =
    Roofline { name: "ZCU111", peak_gops: 218.3, mem_bw_gbs: 13.4 };

/// fSEAD partial-block region bound (paper: 110.4 GOP/s).
pub const FSEAD_ROOFLINE: Roofline =
    Roofline { name: "fSEAD pblocks", peak_gops: 110.4, mem_bw_gbs: 13.4 };

/// One measured application point on a roofline chart.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    pub label: String,
    pub ai: f64,
    pub gops: f64,
}

impl RooflinePoint {
    /// Fraction of the attainable roof achieved at this AI.
    pub fn efficiency(&self, roof: &Roofline) -> f64 {
        self.gops / roof.attainable(self.ai)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_bound_below_ridge() {
        let r = FPGA_ROOFLINE;
        let ai = r.ridge() / 2.0;
        assert!((r.attainable(ai) - ai * r.mem_bw_gbs).abs() < 1e-9);
    }

    #[test]
    fn compute_bound_above_ridge() {
        let r = FPGA_ROOFLINE;
        assert_eq!(r.attainable(r.ridge() * 10.0), r.peak_gops);
    }

    #[test]
    fn fsead_region_is_subset_of_device() {
        assert!(FSEAD_ROOFLINE.peak_gops < FPGA_ROOFLINE.peak_gops);
        // Paper: 110.4 ≈ 218.3 × (fSEAD share of resources ≈ 61.57% × 82%).
        let ratio = FSEAD_ROOFLINE.peak_gops / FPGA_ROOFLINE.peak_gops;
        assert!((0.4..0.6).contains(&ratio));
    }

    #[test]
    fn paper_best_point_is_under_the_roof() {
        // xStream/Shuttle: 67.959 GOPS — below the 110.4 fSEAD bound.
        let p = RooflinePoint { label: "xstream/shuttle".into(), ai: 20.0, gops: 67.959 };
        assert!(p.efficiency(&FSEAD_ROOFLINE) <= 1.0);
        assert!(p.efficiency(&FSEAD_ROOFLINE) > 0.5, "paper's own point is >50% of roof");
    }

    #[test]
    fn cpu_peak_from_microarchitecture() {
        // 8 cores × 2.9 GHz × (2 FMA ports × 8 f32) = 371.2 GOP/s.
        assert!((CPU_ROOFLINE.peak_gops - 8.0 * 2.9 * 16.0).abs() < 1e-9);
    }
}
