//! FPGA hardware models (DESIGN.md §6 substitution 1).
//!
//! The ZCU111 board is not available, so resource use, timing, power and
//! reconfiguration latency are analytic models calibrated against the
//! paper's own measurements (Tables 6–13, Figures 15–19). The *computation*
//! itself still really executes — through the PJRT artifacts — so scores
//! and AUC are measured, not modelled.

pub mod floorplan;
pub mod opcount;
pub mod power;
pub mod resources;
pub mod roofline;
pub mod timing;

pub use opcount::op_count;
pub use resources::{BlockResources, ResourceModel, ZCU111};
pub use timing::FpgaTimingModel;
