//! FPGA execution-time model (paper Tables 8–10, Figures 12–14).
//!
//! The paper measures end-to-end time "from the start of the input DMA
//! transfer to when all data is obtained from the output DMA" on a PYNQ
//! host. Back-fitting their numbers shows two regimes:
//!
//! - Loda / RS-Hash at every dataset are **transfer-bound**: effective PYNQ
//!   DMA bandwidth ≈ 30–50 MB/s (Linux host overhead, not AXI limits),
//!   e.g. HTTP-3: 6.8 MB / 227 ms ≈ 30 MB/s for both detectors.
//! - xStream at small d is **compute-bound**: HTTP-3 costs
//!   0.52 µs/sample ≈ 98 cycles @188 MHz — the K=20 projection/Jenkins
//!   drain — vs 75-cycle transfer time.
//!
//! The model is `t = t_cfg + max(t_dma, t_compute)` with
//! `t_dma = N·d·4 B / BW_eff` and `t_compute = N·(d + c_det)/f_clk`.
//! Sub-detector parallelism means R does not appear — that is the paper's
//! headline claim (latency flat in R on FPGA, linear on CPU).

use crate::defaults::FPGA_CLOCK_HZ;
use crate::detectors::DetectorKind;

/// Calibrated timing model.
#[derive(Clone, Copy, Debug)]
pub struct FpgaTimingModel {
    /// Fixed PYNQ/driver overhead per run (paper Fig 20: 0.80 ms).
    pub overhead_s: f64,
    /// Effective host↔fabric DMA bandwidth (bytes/s).
    pub dma_bw: f64,
    /// FPGA clock.
    pub clock_hz: f64,
}

impl Default for FpgaTimingModel {
    fn default() -> Self {
        FpgaTimingModel { overhead_s: 0.8e-3, dma_bw: 33.0e6, clock_hz: FPGA_CLOCK_HZ }
    }
}

impl FpgaTimingModel {
    /// Extra pipeline cycles per sample beyond the d-cycle windower
    /// (per-detector drain; xStream's K-wide projection + Jenkins dominates).
    pub fn extra_cycles(kind: DetectorKind) -> f64 {
        match kind {
            DetectorKind::Loda => 0.0,
            DetectorKind::RsHash => 4.0,
            DetectorKind::XStream => 95.0,
        }
    }

    /// Modelled end-to-end execution time for a stream of `n` samples of
    /// dimension `d`. Independent of ensemble size while the ensemble fits
    /// the fabric (spatial parallelism).
    pub fn exec_time_s(&self, kind: DetectorKind, n: usize, d: usize) -> f64 {
        let t_dma = (n as f64) * (d as f64) * 4.0 / self.dma_bw;
        let cycles = d as f64 + Self::extra_cycles(kind);
        let t_compute = (n as f64) * cycles / self.clock_hz;
        self.overhead_s + t_dma.max(t_compute)
    }

    /// Paper-reported FPGA execution times (ms) for side-by-side reporting.
    pub fn paper_exec_ms(kind: DetectorKind, dataset: &str) -> Option<f64> {
        let v = match (kind, dataset) {
            (DetectorKind::Loda, "cardio") => 4.63,
            (DetectorKind::Loda, "shuttle") => 34.23,
            (DetectorKind::Loda, "smtp3") => 39.31,
            (DetectorKind::Loda, "http3") => 228.25,
            (DetectorKind::RsHash, "cardio") => 4.87,
            (DetectorKind::RsHash, "shuttle") => 35.80,
            (DetectorKind::RsHash, "smtp3") => 39.63,
            (DetectorKind::RsHash, "http3") => 228.29,
            (DetectorKind::XStream, "cardio") => 4.82,
            (DetectorKind::XStream, "shuttle") => 40.62,
            (DetectorKind::XStream, "smtp3") => 50.99,
            (DetectorKind::XStream, "http3") => 297.85,
            _ => return None,
        };
        Some(v)
    }

    /// Paper-reported CPU execution times (ms) — the GCC 4-thread baseline.
    pub fn paper_cpu_ms(kind: DetectorKind, dataset: &str) -> Option<f64> {
        let v = match (kind, dataset) {
            (DetectorKind::Loda, "cardio") => 13.0,
            (DetectorKind::Loda, "shuttle") => 147.0,
            (DetectorKind::Loda, "smtp3") => 222.0,
            (DetectorKind::Loda, "http3") => 1396.0,
            (DetectorKind::RsHash, "cardio") => 15.0,
            (DetectorKind::RsHash, "shuttle") => 168.0,
            (DetectorKind::RsHash, "smtp3") => 260.0,
            (DetectorKind::RsHash, "http3") => 1490.0,
            (DetectorKind::XStream, "cardio") => 18.0,
            (DetectorKind::XStream, "shuttle") => 250.0,
            (DetectorKind::XStream, "smtp3") => 366.0,
            (DetectorKind::XStream, "http3") => 2460.0,
            _ => return None,
        };
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PROFILES;

    #[test]
    fn model_tracks_paper_fpga_times_within_2x() {
        let m = FpgaTimingModel::default();
        for kind in DetectorKind::ALL {
            for p in &PROFILES {
                let model_ms = m.exec_time_s(kind, p.n, p.d) * 1e3;
                let paper_ms = FpgaTimingModel::paper_exec_ms(kind, p.name).unwrap();
                let ratio = model_ms / paper_ms;
                assert!(
                    (0.5..=2.0).contains(&ratio),
                    "{kind:?}/{}: model {model_ms:.2} ms vs paper {paper_ms:.2} ms",
                    p.name
                );
            }
        }
    }

    #[test]
    fn xstream_slower_than_loda_at_small_d() {
        let m = FpgaTimingModel::default();
        let tx = m.exec_time_s(DetectorKind::XStream, 100_000, 3);
        let tl = m.exec_time_s(DetectorKind::Loda, 100_000, 3);
        assert!(tx > tl);
    }

    #[test]
    fn time_independent_of_ensemble_size_by_construction() {
        // The model has no R argument — spatial parallelism; this test
        // documents that invariant.
        let m = FpgaTimingModel::default();
        let t = m.exec_time_s(DetectorKind::Loda, 1000, 5);
        assert!(t > m.overhead_s);
    }

    #[test]
    fn paper_speedups_reproduced_by_model_and_paper_cpu() {
        // Paper speed-up range: 2.81×–8.26×, growing with dataset size.
        for kind in DetectorKind::ALL {
            let small = FpgaTimingModel::paper_cpu_ms(kind, "cardio").unwrap()
                / FpgaTimingModel::paper_exec_ms(kind, "cardio").unwrap();
            let large = FpgaTimingModel::paper_cpu_ms(kind, "http3").unwrap()
                / FpgaTimingModel::paper_exec_ms(kind, "http3").unwrap();
            assert!(large > small, "{kind:?}: speed-up should grow with N");
            assert!((2.5..=9.0).contains(&small) || (2.5..=9.0).contains(&large));
        }
    }

    #[test]
    fn overhead_dominates_tiny_streams() {
        let m = FpgaTimingModel::default();
        let t = m.exec_time_s(DetectorKind::Loda, 10, 3);
        assert!(t < 1.0e-3 + m.overhead_s);
    }
}
