//! FPGA resource model: device totals, the paper's floorplanned block
//! partition (Table 6) and the per-detector-instance costs (Table 7).

use crate::detectors::DetectorKind;

/// Absolute resource vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Resources {
    pub lut: f64,
    pub dsp: f64,
    pub bram: f64,
    pub ff: f64,
}

impl Resources {
    pub const fn new(lut: f64, dsp: f64, bram: f64, ff: f64) -> Self {
        Resources { lut, dsp, bram, ff }
    }

    pub fn scale(&self, k: f64) -> Resources {
        Resources::new(self.lut * k, self.dsp * k, self.bram * k, self.ff * k)
    }

    pub fn add(&self, o: &Resources) -> Resources {
        Resources::new(self.lut + o.lut, self.dsp + o.dsp, self.bram + o.bram, self.ff + o.ff)
    }

    /// Does `self` fit within `cap`?
    pub fn fits(&self, cap: &Resources) -> bool {
        self.lut <= cap.lut && self.dsp <= cap.dsp && self.bram <= cap.bram && self.ff <= cap.ff
    }

    /// Utilisation of the binding resource against `cap` (0..1+).
    pub fn max_utilisation(&self, cap: &Resources) -> f64 {
        [
            self.lut / cap.lut,
            self.dsp / cap.dsp,
            self.bram / cap.bram,
            self.ff / cap.ff,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// Zynq UltraScale+ XCZU28DR (ZCU111) device totals.
pub const ZCU111: Resources = Resources::new(425_280.0, 4_272.0, 1_080.0, 850_560.0);

/// One floorplanned block: name + % of device resources (paper Table 6).
#[derive(Clone, Copy, Debug)]
pub struct BlockResources {
    pub name: &'static str,
    /// Percent of device LUT/DSP/BRAM/FF.
    pub lut_pct: f64,
    pub dsp_pct: f64,
    pub bram_pct: f64,
    pub ff_pct: f64,
}

impl BlockResources {
    pub fn absolute(&self) -> Resources {
        Resources::new(
            ZCU111.lut * self.lut_pct / 100.0,
            ZCU111.dsp * self.dsp_pct / 100.0,
            ZCU111.bram * self.bram_pct / 100.0,
            ZCU111.ff * self.ff_pct / 100.0,
        )
    }
}

/// Paper Table 6: resource partition of the fSEAD floorplan.
pub const TABLE6_BLOCKS: [BlockResources; 16] = [
    BlockResources { name: "RP-1", lut_pct: 6.73, dsp_pct: 4.49, bram_pct: 6.67, ff_pct: 6.73 },
    BlockResources { name: "RP-2", lut_pct: 8.57, dsp_pct: 7.54, bram_pct: 8.52, ff_pct: 8.57 },
    BlockResources { name: "RP-3", lut_pct: 6.24, dsp_pct: 6.46, bram_pct: 6.39, ff_pct: 6.24 },
    BlockResources { name: "RP-4", lut_pct: 6.72, dsp_pct: 4.49, bram_pct: 6.67, ff_pct: 6.72 },
    BlockResources { name: "RP-5", lut_pct: 6.24, dsp_pct: 6.46, bram_pct: 6.39, ff_pct: 6.24 },
    BlockResources { name: "RP-6", lut_pct: 8.74, dsp_pct: 8.24, bram_pct: 8.15, ff_pct: 8.74 },
    BlockResources { name: "RP-7", lut_pct: 7.32, dsp_pct: 7.30, bram_pct: 7.22, ff_pct: 7.32 },
    BlockResources { name: "COMBO1", lut_pct: 0.72, dsp_pct: 0.56, bram_pct: 0.74, ff_pct: 0.72 },
    BlockResources { name: "COMBO2", lut_pct: 0.59, dsp_pct: 0.84, bram_pct: 0.83, ff_pct: 0.59 },
    BlockResources { name: "COMBO3", lut_pct: 0.59, dsp_pct: 0.84, bram_pct: 0.83, ff_pct: 0.59 },
    BlockResources { name: "Switch-1", lut_pct: 3.46, dsp_pct: 4.49, bram_pct: 2.96, ff_pct: 3.46 },
    BlockResources { name: "Switch-2", lut_pct: 1.81, dsp_pct: 0.98, bram_pct: 0.0, ff_pct: 1.82 },
    BlockResources { name: "DMA", lut_pct: 2.25, dsp_pct: 0.0, bram_pct: 1.30, ff_pct: 0.48 },
    BlockResources { name: "DFX-Decoupler", lut_pct: 0.04, dsp_pct: 0.0, bram_pct: 0.0, ff_pct: 0.008 },
    BlockResources { name: "AXI-Interconnect", lut_pct: 0.67, dsp_pct: 0.0, bram_pct: 0.0, ff_pct: 0.58 },
    BlockResources { name: "Other-static", lut_pct: 2.41, dsp_pct: 0.0, bram_pct: 0.0, ff_pct: 1.61 },
];

/// Paper Table 7: smallest-pblock (RP-3) capacity used for sizing.
pub const RP3_CAPACITY: Resources = Resources::new(26_480.0, 276.0, 69.0, 52_960.0);

/// Paper Table 7: resources of a full-size per-pblock ensemble.
pub fn pblock_ensemble_resources(kind: DetectorKind) -> (usize, Resources) {
    match kind {
        DetectorKind::Loda => (35, Resources::new(16_783.0, 122.0, 54.5, 11_478.0)),
        DetectorKind::RsHash => (25, Resources::new(23_732.0, 68.0, 50.0, 14_012.0)),
        DetectorKind::XStream => (20, Resources::new(23_908.0, 80.0, 60.0, 12_617.0)),
    }
}

/// Per-sub-detector marginal cost (Table 7 aggregate / R).
pub fn per_instance_resources(kind: DetectorKind) -> Resources {
    let (r, total) = pblock_ensemble_resources(kind);
    total.scale(1.0 / r as f64)
}

/// Resource model: answers "how many sub-detectors fit in this pblock?" and
/// tracks the fabric's total utilisation (used by Table 6/7 experiments and
/// the Fig 17 scalability sweep).
#[derive(Clone, Debug)]
pub struct ResourceModel;

impl ResourceModel {
    /// Maximum ensemble size of `kind` fitting in `cap` (paper §4.3).
    pub fn max_ensemble(kind: DetectorKind, cap: &Resources) -> usize {
        let unit = per_instance_resources(kind);
        let mut r = 0usize;
        loop {
            let next = unit.scale((r + 1) as f64);
            if !next.fits(cap) {
                return r;
            }
            r += 1;
            if r > 100_000 {
                return r; // degenerate caps
            }
        }
    }

    /// Device-level utilisation summary for a set of blocks.
    pub fn total_pct(blocks: &[BlockResources]) -> (f64, f64, f64, f64) {
        let mut t = (0.0, 0.0, 0.0, 0.0);
        for b in blocks {
            t.0 += b.lut_pct;
            t.1 += b.dsp_pct;
            t.2 += b.bram_pct;
            t.3 += b.ff_pct;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_totals_match_paper() {
        // Paper total row: 62.5% LUT, 52.69% DSP, 56.67% BRAM, 60.42% FF.
        let (lut, dsp, bram, ff) = ResourceModel::total_pct(&TABLE6_BLOCKS);
        // LUT tolerance is wider: the paper's per-row figures sum to 63.1
        // against its own 62.5 total (rounding in the published table).
        assert!((lut - 62.5).abs() < 0.7, "lut={lut}");
        assert!((dsp - 52.69).abs() < 0.4, "dsp={dsp}");
        assert!((bram - 56.67).abs() < 0.4, "bram={bram}");
        assert!((ff - 60.42).abs() < 0.4, "ff={ff}");
    }

    #[test]
    fn paper_ensembles_fit_rp3() {
        // Paper §4.3: 35 Loda / 25 RS-Hash / 20 xStream fit the smallest pblock.
        for kind in DetectorKind::ALL {
            let (r, total) = pblock_ensemble_resources(kind);
            assert!(total.fits(&RP3_CAPACITY), "{kind:?}");
            let max = ResourceModel::max_ensemble(kind, &RP3_CAPACITY);
            assert!(max >= r, "{kind:?}: model says only {max} fit");
            // The paper sized these to ~80-90% utilisation; one-few more may
            // fit the linear model, but not 25% more.
            assert!(max <= r + r / 4 + 1, "{kind:?}: model says {max} fit");
        }
    }

    #[test]
    fn utilisation_of_full_ensembles_is_80_to_95_pct() {
        // Paper §4.4: "80%-90% logic use of all seven partial blocks".
        for kind in DetectorKind::ALL {
            let (_, total) = pblock_ensemble_resources(kind);
            let u = total.max_utilisation(&RP3_CAPACITY);
            assert!((0.7..=0.95).contains(&u), "{kind:?}: {u}");
        }
    }

    #[test]
    fn fits_and_scale_behave() {
        let a = Resources::new(10.0, 1.0, 1.0, 10.0);
        assert!(a.fits(&a));
        assert!(!a.scale(1.01).fits(&a));
        assert_eq!(a.scale(2.0).lut, 20.0);
        assert_eq!(a.add(&a).ff, 20.0);
    }

    #[test]
    fn rp3_is_smallest_ad_pblock() {
        let rp3 = TABLE6_BLOCKS[2];
        for b in &TABLE6_BLOCKS[..7] {
            assert!(rp3.lut_pct <= b.lut_pct);
        }
    }
}
