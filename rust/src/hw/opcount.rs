//! Operation-count formulas (paper Table 11) and GOPS (Table 12).

use crate::detectors::DetectorKind;

/// Workload descriptor for the closed-form op counts.
#[derive(Clone, Copy, Debug)]
pub struct OpParams {
    /// Stream length N.
    pub n: u64,
    /// Dimensionality d.
    pub d: u64,
    /// Ensemble size R.
    pub r: u64,
    /// CMS rows w.
    pub w: u64,
    /// xStream projection size k.
    pub k: u64,
}

/// Paper Table 11 — total operations to process the stream.
pub fn op_count(kind: DetectorKind, p: OpParams) -> u64 {
    let OpParams { n, d, r, w, k } = p;
    match kind {
        // OP = N * (2Rd + 7R + 2)
        DetectorKind::Loda => n * (2 * r * d + 7 * r + 2),
        // OP = N * (5Rdw + 4Rd + 11Rw + R + 2)
        DetectorKind::RsHash => n * (5 * r * d * w + 4 * r * d + 11 * r * w + r + 2),
        // OP = N * (2Rdk + 5Rdw + 15Rw + 2R + 2)
        DetectorKind::XStream => n * (2 * r * d * k + 5 * r * d * w + 15 * r * w + 2 * r + 2),
    }
}

/// Giga-operations per second given a runtime.
pub fn gops(ops: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    ops as f64 / seconds / 1.0e9
}

/// Arithmetic intensity: ops per byte moved over the stream interface
/// (f32 in, f32 score out — matches the paper's roofline byte accounting).
pub fn arithmetic_intensity(kind: DetectorKind, p: OpParams) -> f64 {
    let bytes = p.n * (p.d + 1) * 4;
    op_count(kind, p) as f64 / bytes as f64
}

/// Paper Table 12 values for side-by-side reporting (CPU, fSEAD) GOPS.
pub fn paper_gops(kind: DetectorKind, dataset: &str) -> Option<(f64, f64)> {
    let v = match (kind, dataset) {
        (DetectorKind::Loda, "cardio") => (1.690, 4.748),
        (DetectorKind::Loda, "shuttle") => (2.049, 8.789),
        (DetectorKind::Loda, "smtp3") => (1.402, 7.924),
        (DetectorKind::Loda, "http3") => (0.776, 4.748),
        (DetectorKind::RsHash, "cardio") => (6.772, 20.858),
        (DetectorKind::RsHash, "shuttle") => (6.353, 29.797),
        (DetectorKind::RsHash, "smtp3") => (4.197, 27.533),
        (DetectorKind::RsHash, "http3") => (4.331, 28.282),
        (DetectorKind::XStream, "cardio") => (15.427, 57.544),
        (DetectorKind::XStream, "shuttle") => (11.050, 67.959),
        (DetectorKind::XStream, "smtp3") => (6.623, 47.554),
        (DetectorKind::XStream, "http3") => (5.878, 48.551),
        _ => return None,
    };
    Some(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(n: u64, d: u64, r: u64) -> OpParams {
        OpParams { n, d, r, w: 2, k: 20 }
    }

    #[test]
    fn loda_formula_exact() {
        // N=10, R=3, d=4 → 10 * (2*3*4 + 7*3 + 2) = 10 * 47
        assert_eq!(op_count(DetectorKind::Loda, p(10, 4, 3)), 470);
    }

    #[test]
    fn rshash_formula_exact() {
        // N=1, R=2, d=3, w=2 → 5*2*3*2 + 4*2*3 + 11*2*2 + 2 + 2 = 60+24+44+4
        assert_eq!(op_count(DetectorKind::RsHash, p(1, 3, 2)), 132);
    }

    #[test]
    fn xstream_formula_exact() {
        // N=1, R=2, d=3, w=2, k=20 → 2*2*3*20 + 5*2*3*2 + 15*2*2 + 2*2 + 2
        assert_eq!(op_count(DetectorKind::XStream, p(1, 3, 2)), 240 + 60 + 60 + 6);
    }

    #[test]
    fn op_count_monotone_in_every_parameter() {
        let base = p(100, 5, 10);
        for kind in DetectorKind::ALL {
            let b = op_count(kind, base);
            assert!(op_count(kind, OpParams { n: 200, ..base }) > b);
            assert!(op_count(kind, OpParams { d: 6, ..base }) > b);
            assert!(op_count(kind, OpParams { r: 11, ..base }) > b);
        }
    }

    #[test]
    fn xstream_has_most_ops_per_sample() {
        // §4.4: xStream is the most compute-intensive of the three.
        let q = p(1, 3, 20);
        assert!(
            op_count(DetectorKind::XStream, q) > op_count(DetectorKind::RsHash, q)
                && op_count(DetectorKind::XStream, q) > op_count(DetectorKind::Loda, q)
        );
    }

    #[test]
    fn gops_of_known_quantities() {
        assert!((gops(2_000_000_000, 2.0) - 1.0).abs() < 1e-12);
        assert_eq!(gops(100, 0.0), 0.0);
    }

    #[test]
    fn paper_gops_table_complete() {
        for kind in DetectorKind::ALL {
            for ds in ["cardio", "shuttle", "smtp3", "http3"] {
                let (cpu, fpga) = paper_gops(kind, ds).unwrap();
                assert!(fpga > cpu, "{kind:?}/{ds}: fSEAD must beat CPU in Table 12");
            }
        }
    }
}
