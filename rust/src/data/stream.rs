//! Chunked streaming (paper block ①, the WINDOWER, at transfer granularity).
//!
//! Streams are cut into fixed-size chunks matching the artifact chunk size;
//! the final chunk is zero-padded with a validity mask — masked samples
//! neither score nor touch detector state (enforced by the JAX model and
//! checked in `python/tests/test_model.py`).
//!
//! Payloads are shared, immutable `Arc<[f32]>` buffers: a chunk fanned out
//! to several consumers (switch pumps, bypass RMs, DMA channels, the
//! combiner) clones the pointer, never the samples. Every full chunk of a
//! stream also shares one all-ones mask allocation.

use std::sync::Arc;

/// One streaming transfer unit: `chunk × d` samples + validity mask.
#[derive(Clone, Debug)]
pub struct Chunk {
    /// Monotone sequence number within the stream.
    pub seq: u64,
    /// Row-major `[chunk, d]`, zero-padded past `n_valid`. Shared and
    /// immutable — fan-out clones the `Arc`, not the buffer.
    pub data: Arc<[f32]>,
    /// 1.0 for valid rows, 0.0 for padding. Shared like `data`.
    pub mask: Arc<[f32]>,
    /// Number of valid leading rows.
    pub n_valid: usize,
    /// True on the final chunk of the stream.
    pub last: bool,
}

impl Chunk {
    pub fn rows(&self) -> usize {
        self.mask.len()
    }
}

/// Iterator cutting a row-major `[n, d]` slice into padded chunks.
pub struct ChunkStream<'a> {
    data: &'a [f32],
    d: usize,
    chunk: usize,
    offset: usize, // in samples
    seq: u64,
    /// The all-ones mask shared by every full chunk of this stream.
    full_mask: Arc<[f32]>,
}

impl<'a> ChunkStream<'a> {
    pub fn new(data: &'a [f32], d: usize, chunk: usize) -> Self {
        assert!(d > 0 && chunk > 0);
        assert_eq!(data.len() % d, 0, "data not a whole number of samples");
        let full_mask: Arc<[f32]> = vec![1.0f32; chunk].into();
        ChunkStream { data, d, chunk, offset: 0, seq: 0, full_mask }
    }

    pub fn total_samples(&self) -> usize {
        self.data.len() / self.d
    }

    pub fn total_chunks(&self) -> usize {
        self.total_samples().div_ceil(self.chunk).max(1)
    }
}

impl<'a> Iterator for ChunkStream<'a> {
    type Item = Chunk;

    fn next(&mut self) -> Option<Chunk> {
        let n = self.total_samples();
        if self.offset >= n && !(n == 0 && self.seq == 0) {
            return None;
        }
        let valid = (n - self.offset).min(self.chunk);
        let mut data = vec![0f32; self.chunk * self.d];
        data[..valid * self.d]
            .copy_from_slice(&self.data[self.offset * self.d..(self.offset + valid) * self.d]);
        let mask: Arc<[f32]> = if valid == self.chunk {
            self.full_mask.clone()
        } else {
            let mut m = vec![0f32; self.chunk];
            m[..valid].fill(1.0);
            m.into()
        };
        let chunk = Chunk {
            seq: self.seq,
            data: data.into(),
            mask,
            n_valid: valid,
            last: self.offset + valid >= n,
        };
        self.offset += self.chunk;
        self.seq += 1;
        Some(chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_multiple_has_no_padding() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 6 samples, d=2
        let chunks: Vec<Chunk> = ChunkStream::new(&data, 2, 3).collect();
        assert_eq!(chunks.len(), 2);
        assert!(chunks.iter().all(|c| c.n_valid == 3));
        assert!(chunks[1].last && !chunks[0].last);
        assert_eq!(&chunks[0].data[..], &data[..6]);
    }

    #[test]
    fn tail_chunk_is_padded_and_masked() {
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect(); // 5 samples, d=2
        let chunks: Vec<Chunk> = ChunkStream::new(&data, 2, 4).collect();
        assert_eq!(chunks.len(), 2);
        let tail = &chunks[1];
        assert_eq!(tail.n_valid, 1);
        assert_eq!(&tail.mask[..], &[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(&tail.data[..2], &data[8..10]);
        assert!(tail.data[2..].iter().all(|&v| v == 0.0));
        assert!(tail.last);
    }

    #[test]
    fn seq_numbers_monotone() {
        let data = vec![0f32; 20 * 2];
        let seqs: Vec<u64> = ChunkStream::new(&data, 2, 4).map(|c| c.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_stream_yields_one_empty_last_chunk() {
        let chunks: Vec<Chunk> = ChunkStream::new(&[], 3, 4).collect();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].n_valid, 0);
        assert!(chunks[0].last);
    }

    #[test]
    fn total_chunks_matches_iteration() {
        for n in [1usize, 4, 5, 8, 9] {
            let data = vec![0f32; n * 3];
            let cs = ChunkStream::new(&data, 3, 4);
            let expect = cs.total_chunks();
            assert_eq!(ChunkStream::new(&data, 3, 4).count(), expect, "n={n}");
        }
    }

    #[test]
    fn full_chunks_share_one_mask_allocation() {
        let data = vec![0f32; 9 * 2]; // 9 samples, chunk 4 → 2 full + 1 padded
        let chunks: Vec<Chunk> = ChunkStream::new(&data, 2, 4).collect();
        assert_eq!(chunks.len(), 3);
        assert!(Arc::ptr_eq(&chunks[0].mask, &chunks[1].mask));
        assert!(!Arc::ptr_eq(&chunks[0].mask, &chunks[2].mask));
        // Cloning a chunk shares payloads instead of copying them.
        let dup = chunks[0].clone();
        assert!(Arc::ptr_eq(&dup.data, &chunks[0].data));
    }
}
