//! Synthetic stand-ins for the paper's Table 3 datasets.
//!
//! The real ODDS/KDD files are not redistributable (and this environment is
//! offline), so we generate seeded datasets with the same cardinality,
//! dimensionality and contamination: clustered Gaussian inliers with mild
//! mean drift (streams exhibit concept drift, §1) plus two outlier modes —
//! uniform background points and inflated cluster tails. This preserves the
//! geometry that the detectors' AUC trends depend on; absolute AUC values
//! differ from the paper and both are reported by the harness.

use super::Dataset;
use crate::detectors::prng::Prng;

/// Paper Table 3 rows.
#[derive(Clone, Copy, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub outliers: usize,
    /// Inlier cluster count (chosen per dataset character).
    pub clusters: usize,
}

pub const PROFILES: [DatasetProfile; 4] = [
    DatasetProfile { name: "cardio", n: 1831, d: 21, outliers: 176, clusters: 3 },
    DatasetProfile { name: "shuttle", n: 49097, d: 9, outliers: 3511, clusters: 4 },
    DatasetProfile { name: "smtp3", n: 95156, d: 3, outliers: 30, clusters: 3 },
    DatasetProfile { name: "http3", n: 567498, d: 3, outliers: 2211, clusters: 3 },
];

pub fn profile(name: &str) -> Option<&'static DatasetProfile> {
    PROFILES.iter().find(|p| p.name == name)
}

/// Generate a dataset by profile name (None for unknown names).
pub fn generate(name: &str, seed: u64) -> Option<Dataset> {
    profile(name).map(|p| generate_profile(p, seed))
}

/// Generate from an explicit profile (used by tests with tiny profiles).
pub fn generate_profile(p: &DatasetProfile, seed: u64) -> Dataset {
    let mut rng = Prng::new(seed ^ fxhash(p.name));
    let d = p.d;
    // Cluster means in [-2, 2]^d, per-dim stddev in [0.05, 0.4].
    let mut means = vec![0f32; p.clusters * d];
    let mut stds = vec![0f32; p.clusters * d];
    // Slow linear mean drift per cluster (concept drift over the stream).
    let mut drift = vec![0f32; p.clusters * d];
    for c in 0..p.clusters {
        for di in 0..d {
            means[c * d + di] = rng.uniform_in(-2.0, 2.0) as f32;
            stds[c * d + di] = rng.uniform_in(0.05, 0.4) as f32;
            drift[c * d + di] = rng.uniform_in(-0.5, 0.5) as f32;
        }
    }
    // Outlier positions: spread uniformly through the stream.
    let mut is_outlier = vec![false; p.n];
    let mut placed = 0;
    while placed < p.outliers {
        let i = rng.below(p.n);
        if !is_outlier[i] {
            is_outlier[i] = true;
            placed += 1;
        }
    }
    let mut data = vec![0f32; p.n * d];
    for i in 0..p.n {
        let t = i as f32 / p.n as f32; // drift phase
        let row = &mut data[i * d..(i + 1) * d];
        if is_outlier[i] && rng.uniform() < 0.5 {
            // Mode A: uniform background point in the expanded box.
            for (di, v) in row.iter_mut().enumerate() {
                let _ = di;
                *v = rng.uniform_in(-4.0, 4.0) as f32;
            }
        } else {
            let c = rng.below(p.clusters);
            let inflate = if is_outlier[i] { 6.0 } else { 1.0 }; // Mode B: fat tail
            for di in 0..d {
                let m = means[c * d + di] + t * drift[c * d + di];
                let s = stds[c * d + di] * inflate;
                row[di] = m + (rng.gaussian() as f32) * s;
            }
        }
    }
    Dataset { name: p.name.to_string(), d, data, labels: is_outlier }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_match_paper_table3() {
        let cardio = profile("cardio").unwrap();
        assert_eq!((cardio.n, cardio.d, cardio.outliers), (1831, 21, 176));
        let http3 = profile("http3").unwrap();
        assert_eq!((http3.n, http3.d, http3.outliers), (567498, 3, 2211));
        let smtp3 = profile("smtp3").unwrap();
        assert!((smtp3.outliers as f64 / smtp3.n as f64 - 0.0003).abs() < 1e-4);
    }

    #[test]
    fn generated_shape_and_contamination() {
        let ds = generate("cardio", 7).unwrap();
        assert_eq!(ds.n(), 1831);
        assert_eq!(ds.d, 21);
        assert_eq!(ds.outliers(), 176);
        assert!((ds.contamination() - 0.0961).abs() < 0.001);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate("smtp3", 3).unwrap();
        let b = generate("smtp3", 3).unwrap();
        assert_eq!(a.data[..300], b.data[..300]);
        let c = generate("smtp3", 4).unwrap();
        assert_ne!(a.data[..300], c.data[..300]);
    }

    #[test]
    fn values_are_finite_and_bounded() {
        let ds = generate("shuttle", 1).unwrap().prefix(5000);
        assert!(ds.data.iter().all(|v| v.is_finite() && v.abs() < 50.0));
    }

    #[test]
    fn outliers_are_separable_in_principle() {
        // Mean distance from global centroid should be larger for outliers.
        let ds = generate("cardio", 5).unwrap();
        let d = ds.d;
        let n = ds.n();
        let mut centroid = vec![0f64; d];
        for i in 0..n {
            for (di, c) in centroid.iter_mut().enumerate() {
                *c += ds.data[i * d + di] as f64;
            }
        }
        for c in centroid.iter_mut() {
            *c /= n as f64;
        }
        let dist = |i: usize| -> f64 {
            (0..d).map(|di| (ds.data[i * d + di] as f64 - centroid[di]).powi(2)).sum::<f64>().sqrt()
        };
        let (mut od, mut id, mut oc, mut ic) = (0f64, 0f64, 0usize, 0usize);
        for i in 0..n {
            if ds.labels[i] {
                od += dist(i);
                oc += 1;
            } else {
                id += dist(i);
                ic += 1;
            }
        }
        assert!(od / oc as f64 > id / ic as f64 * 1.2);
    }
}
