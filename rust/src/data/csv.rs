//! Minimal CSV loader for real datasets (ODDS export convention: numeric
//! feature columns, last column = label with non-zero ⇒ anomaly). Supports
//! an optional header row and blank-line tolerance. No quoting — anomaly
//! benchmarks are plain numeric matrices.

use super::Dataset;
use anyhow::{bail, Context, Result};

pub fn load_csv(path: &str, name: &str) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_csv(&text, name)
}

pub fn parse_csv(text: &str, name: &str) -> Result<Dataset> {
    let mut data = Vec::new();
    let mut labels = Vec::new();
    let mut d: Option<usize> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if fields.len() < 2 {
            bail!("{name}:{} has {} fields, need >= 2", lineno + 1, fields.len());
        }
        let parsed: Result<Vec<f32>, _> = fields.iter().map(|f| f.parse::<f32>()).collect();
        let row = match parsed {
            Ok(row) => row,
            Err(_) if lineno == 0 => continue, // header row
            Err(e) => bail!("{name}:{}: {e}", lineno + 1),
        };
        let dim = row.len() - 1;
        match d {
            None => d = Some(dim),
            Some(expect) if expect != dim => {
                bail!("{name}:{}: {dim} features, expected {expect}", lineno + 1)
            }
            _ => {}
        }
        data.extend_from_slice(&row[..dim]);
        labels.push(row[dim] != 0.0);
    }
    let d = d.context("empty CSV")?;
    Ok(Dataset { name: name.to_string(), d, data, labels })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_numeric_csv() {
        let ds = parse_csv("1.0,2.0,0\n3.0,4.0,1\n", "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d, 2);
        assert_eq!(ds.labels, vec![false, true]);
        assert_eq!(ds.sample(1), &[3.0, 4.0]);
    }

    #[test]
    fn skips_header_and_blank_lines() {
        let ds = parse_csv("f1,f2,label\n\n1,2,0\n\n5,6,1\n", "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.labels, vec![false, true]);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("1,2,0\n1,2,3,0\n", "t").is_err());
    }

    #[test]
    fn rejects_non_numeric_data_row() {
        assert!(parse_csv("1,2,0\nx,y,1\n", "t").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_csv("", "t").is_err());
        assert!(parse_csv("header,only,row\n", "t").is_err());
    }

    #[test]
    fn nonzero_label_is_anomaly() {
        let ds = parse_csv("0,1\n0,2\n0,0\n", "t").unwrap();
        assert_eq!(ds.labels, vec![true, true, false]);
    }
}
