//! Dataset substrate: the paper's four evaluation datasets (Table 3) as
//! seeded synthetic generators (see DESIGN.md §6 substitution 2), a CSV
//! loader for dropping in the real ODDS files, and a chunking streamer that
//! feeds the fabric.

pub mod csv;
pub mod stream;
pub mod synth;

pub use stream::{ChunkStream, Chunk};
pub use synth::{DatasetProfile, PROFILES};

/// An in-memory labelled dataset (row-major `[n, d]`).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub d: usize,
    /// Row-major samples `[n * d]`.
    pub data: Vec<f32>,
    /// Ground truth: true = anomaly.
    pub labels: Vec<bool>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.data.len() / self.d
        }
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn outliers(&self) -> usize {
        self.labels.iter().filter(|&&l| l).count()
    }

    /// Fraction of anomalies — the paper's contamination rate.
    pub fn contamination(&self) -> f64 {
        if self.labels.is_empty() {
            0.0
        } else {
            self.outliers() as f64 / self.labels.len() as f64
        }
    }

    /// First `n` samples (stream prefix) — used to cap experiment run time.
    pub fn prefix(&self, n: usize) -> Dataset {
        let n = n.min(self.n());
        Dataset {
            name: self.name.clone(),
            d: self.d,
            data: self.data[..n * self.d].to_vec(),
            labels: self.labels[..n].to_vec(),
        }
    }

    /// Warm-up prefix used for parameter-range estimation (min(W·4, n)).
    pub fn warmup(&self, window: usize) -> &[f32] {
        let n = (window * 4).min(self.n());
        &self.data[..n * self.d]
    }

    /// Load a named paper dataset: real CSV from `data_dir` if present
    /// (`<name>.csv`), else the synthetic generator.
    pub fn load(name: &str, seed: u64, data_dir: Option<&str>) -> Option<Dataset> {
        if let Some(dir) = data_dir {
            let path = format!("{dir}/{name}.csv");
            if std::path::Path::new(&path).exists() {
                if let Ok(ds) = csv::load_csv(&path, name) {
                    return Some(ds);
                }
            }
        }
        synth::generate(name, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_truncates() {
        let ds = synth::generate("cardio", 0).unwrap();
        let p = ds.prefix(100);
        assert_eq!(p.n(), 100);
        assert_eq!(p.d, ds.d);
        assert_eq!(p.sample(5), ds.sample(5));
    }

    #[test]
    fn load_falls_back_to_synth() {
        let ds = Dataset::load("smtp3", 1, Some("/nonexistent")).unwrap();
        assert_eq!(ds.d, 3);
    }

    #[test]
    fn unknown_dataset_is_none() {
        assert!(Dataset::load("nope", 1, None).is_none());
    }
}
