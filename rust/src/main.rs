//! fSEAD CLI — the leader entrypoint. Subcommands are filled in by the
//! experiment harness (`fsead exp …`), the one-shot runner (`fsead run …`),
//! the persistent streaming session server (`fsead serve …`), its
//! network-facing frame protocol (`fsead net …`) and the
//! resource/reconfiguration inspectors.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match fsead::exp::cli_main(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
