//! Batched, lock-free execution engine — the fast path beside the
//! paper-faithful lock-step baseline (`threaded.rs`).
//!
//! The paper's CPU comparison system merges every sample under a mutex and
//! a barrier, which caps its speed-up at 4 threads (Fig 11). That
//! contention is an artifact of the synchronisation scheme, not of the
//! computation: sub-detectors partitioned across threads share *no* state
//! (each slice owns its own windows), so nothing forces per-sample
//! synchronisation. This runner exploits that:
//!
//! - each lane owns its sub-detector slice **and its own partial-score
//!   vector**, scoring the stream chunk-by-chunk through the detectors'
//!   hand-optimised [`crate::detectors::Detector::update_batch`] loops;
//! - no mutex, no barrier — lanes never touch shared mutable state;
//! - partials are merged in a single pass after all lanes finish.
//!
//! Since the multi-lane pblock work the lane machinery lives in
//! [`super::lanes`]: this runner builds a [`super::lanes::Lane`] array and
//! drives it through a [`super::lanes::LanePool`] of resident worker
//! threads (replacing the per-call `std::thread::scope` spawn pattern) —
//! the **same** pool/lane/merge code the fabric's multi-lane pblocks keep
//! alive across bursts and server sessions. The pool input is shared as an
//! `Arc`, costing this one-shot entry point a single O(n·d) copy of the
//! dataset per call (amortised against O(n·d·r) scoring work).
//!
//! Scores are numerically equivalent to [`super::run_sequential`] within
//! 1e-4 (the partition changes only the f32 summation order — the same
//! tolerance `run_threaded` is held to) and the per-lane chunk loop is
//! bit-identical to that lane's `update` loop.
//!
//! The `chunk_size_does_not_change_scores` property below is also what the
//! fabric's burst data plane leans on: a pblock that drains its inbox and
//! scores the concatenated backlog through one `update_batch` call
//! (`fabric::pblock::LoadedRm::process_burst`) produces bit-identical
//! scores to the per-flit loop, because chunk boundaries never affect
//! `update_batch` arithmetic.

use std::sync::Arc;

use super::lanes::{build_lanes, merge_lanes_into, LaneInput, LanePool};
use crate::data::Dataset;
use crate::defaults;
use crate::detectors::DetectorSpec;

/// Default samples per `update_batch` call. Large enough to amortise the
/// virtual dispatch and keep the inner loops hot; small enough that a
/// worker's working set (chunk × d inputs + chunk partials) stays cached.
pub const DEFAULT_CHUNK: usize = defaults::CHUNK;

/// Run `spec` over `ds` with `threads` workers, lock-free, merging once.
/// Returns per-sample ensemble scores (mean over all R sub-detectors).
pub fn run_batched(spec: &DetectorSpec, ds: &Dataset, threads: usize) -> Vec<f32> {
    run_batched_chunked(spec, ds, threads, DEFAULT_CHUNK)
}

/// [`run_batched`] with an explicit chunk size (exposed for the parity
/// property tests and chunk-size sweeps; chunk is clamped to ≥ 1).
pub fn run_batched_chunked(
    spec: &DetectorSpec,
    ds: &Dataset,
    threads: usize,
    chunk: usize,
) -> Vec<f32> {
    let threads = threads.max(1).min(spec.r);
    let chunk = chunk.max(1);
    let n = ds.n();
    let warmup = ds.warmup(spec.window);
    let data: &[f32] = &ds.data;
    let d = ds.d;

    if threads == 1 {
        // Single worker: still the batch fast path, no partition overhead.
        let mut det = spec.build(warmup);
        let mut out = vec![0f32; n];
        let mut i = 0;
        while i < n {
            let m = chunk.min(n - i);
            det.update_batch(&data[i * d..(i + m) * d], &mut out[i..i + m]);
            i += m;
        }
        return out;
    }

    // Equal partition of sub-detectors (identical to the lock-step runner,
    // via `partition_r` inside `build_lanes`), scored by resident lane
    // workers and merged in one pass — the same machinery the fabric's
    // multi-lane pblocks run, exercised here in one-shot form.
    let mut lanes = build_lanes(spec, warmup, threads);
    let pool = LanePool::new(lanes.len());
    let input = LaneInput::Rows(Arc::new(data.to_vec()));
    pool.score(&mut lanes, &input, n, chunk).expect("lane pool failed");
    let mut out = vec![0f32; n];
    merge_lanes_into(&lanes, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_profile, DatasetProfile};
    use crate::detectors::{DetectorKind, DetectorSpec};
    use crate::ensemble::{run_sequential, run_threaded};

    fn tiny_ds() -> Dataset {
        let p = DatasetProfile { name: "t", n: 180, d: 3, outliers: 9, clusters: 2 };
        generate_profile(&p, 4)
    }

    #[test]
    fn batched_matches_sequential_for_all_kinds() {
        let ds = tiny_ds();
        for kind in DetectorKind::ALL {
            let spec = DetectorSpec::new(kind, 3, 6, 5);
            let seq = run_sequential(&spec, &ds);
            for t in [1, 2, 3, 4] {
                let fast = run_batched(&spec, &ds, t);
                for (i, (a, b)) in seq.iter().zip(&fast).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "{kind:?} t={t} sample {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn one_thread_is_bit_identical_to_sequential() {
        // A single worker runs the full ensemble in sequential accumulation
        // order, so even the f32 bits agree.
        let ds = tiny_ds();
        for chunk in [1usize, 7, 180, 1000] {
            let spec = DetectorSpec::new(DetectorKind::Loda, 3, 4, 1);
            let fast = run_batched_chunked(&spec, &ds, 1, chunk);
            assert_eq!(fast, run_sequential(&spec, &ds), "chunk={chunk}");
        }
    }

    #[test]
    fn chunk_size_does_not_change_scores() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::RsHash, 3, 5, 7);
        let base = run_batched_chunked(&spec, &ds, 2, 64);
        for chunk in [1usize, 3, 179, 181] {
            assert_eq!(run_batched_chunked(&spec, &ds, 2, chunk), base, "chunk={chunk}");
        }
    }

    #[test]
    fn batched_matches_lockstep_partition() {
        // Same sub-detector partition + same weighted merge arithmetic as
        // the lock-step baseline (only the f32 merge order differs — the
        // lock-step accumulator adds partials in thread-arrival order).
        let ds = tiny_ds();
        for kind in DetectorKind::ALL {
            let spec = DetectorSpec::new(kind, 3, 7, 9); // 7 % 3 != 0: uneven
            for t in [2, 3] {
                let slow = run_threaded(&spec, &ds, t);
                let fast = run_batched(&spec, &ds, t);
                for (i, (a, b)) in slow.iter().zip(&fast).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "{kind:?} t={t} sample {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn more_threads_than_subdetectors_is_clamped() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::XStream, 3, 3, 1);
        let scores = run_batched(&spec, &ds, 16);
        assert_eq!(scores.len(), 180);
        assert!(scores.iter().all(|s| s.is_finite()));
    }
}
