//! Resident lane workers: intra-partition instance parallelism.
//!
//! The paper's scalability claim — "multiple instances can be placed within
//! a pblock to improve performance" (§4, Fig 9) — maps onto this module: a
//! partition's ensemble is split into **lanes** (sub-detector slices built
//! with [`DetectorSpec::build_slice`], the same equal partition the CPU
//! runners use), and a [`LanePool`] of **resident worker threads** scores
//! all lanes of a burst concurrently. Workers are spawned once per pool —
//! once per partition in the fabric and the session server, once per call
//! in [`crate::ensemble::run_batched`] — and stay parked on their job
//! channels between bursts, so steady-state scoring never pays a thread
//! spawn (the `std::thread::scope` per-call pattern this replaces).
//!
//! # Ownership protocol
//!
//! Lane detectors are owned by the caller (a [`Lane`] array inside the
//! loaded RM), not by the worker threads: each [`LanePool::score`] call
//! moves every lane's boxed detector and partial-score buffer into a job,
//! the workers score and hand both back, and the pool restores them before
//! returning. That keeps RM lifecycle operations — DFX hot-swap (replace
//! the whole lane array between flits), reset, describe — ordinary moves on
//! the service thread, while the scoring itself runs in parallel.
//!
//! # Arithmetic contract
//!
//! Pooled and inline ([`score_inline`]) execution run byte-for-byte the
//! same per-lane job ([`run_lane_job`]): chunked `update_batch` over the
//! shared input rows into a private partial vector, scaled by the lane's
//! ensemble weight `(hi − lo) / r`. [`merge_lanes_into`] then sums the
//! partials in lane-index order — exactly `run_batched`'s merge pass — so
//! lane count only changes the f32 summation order (the established 1e-5
//! partition tolerance) and a single lane is bit-identical to the
//! unpartitioned ensemble loop.

//! # Fault containment
//!
//! Workers score inside `catch_unwind` when the pool is fault-armed
//! ([`LanePool::arm_faults`]): a panicking detector rolls its sliding
//! window back to the pre-job state and retries once, so a transient panic
//! (including an injected one) recovers **bit-exactly** on the worker —
//! rung 0 of the supervisor's escalation ladder. A second panic, or a
//! worker that genuinely dies, still surfaces as the PR-5 clean `Err` on
//! the caller; [`LanePool::respawn`] then restores the worker threads
//! without touching lane state, and the caller decides whether to retry
//! the burst or escalate to an RM reload.

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::detectors::{Detector, DetectorSpec};

/// Sentinel for "no lane/worker selected" in the fault-injection cells.
const NONE_SELECTED: usize = usize::MAX;

/// One contained lane fault, reported by the worker that handled it.
#[derive(Clone, Debug)]
pub struct LaneFaultNote {
    pub lane: usize,
    pub worker: usize,
    /// Taxonomy tag: `lane_panic_retried` (rolled back + rescored in
    /// place, bit-exact) or `lane_panic_unrecovered` (retry also panicked;
    /// the caller must reload the RM).
    pub kind: &'static str,
    /// Catch → successful retry (or final failure) latency.
    pub latency_us: u64,
    pub detail: String,
}

/// State shared between a pool handle and its workers: fault arming,
/// one-shot injection cells and the contained-fault log.
struct PoolShared {
    armed: AtomicBool,
    /// Lane index whose next job panics once (consumed by the worker).
    panic_lane: AtomicUsize,
    /// Worker index that exits after its next job (simulated thread death).
    exit_worker: AtomicUsize,
    notes: Mutex<Vec<LaneFaultNote>>,
}

impl PoolShared {
    fn new() -> PoolShared {
        PoolShared {
            armed: AtomicBool::new(false),
            panic_lane: AtomicUsize::new(NONE_SELECTED),
            exit_worker: AtomicUsize::new(NONE_SELECTED),
            notes: Mutex::new(Vec::new()),
        }
    }

    fn note(&self, n: LaneFaultNote) {
        self.notes.lock().unwrap().push(n);
    }
}

/// Lane worker threads spawned process-wide (telemetry; the residency tests
/// assert this does not grow per burst or per server session).
static WORKERS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total lane worker threads ever spawned in this process.
pub fn total_workers_spawned() -> u64 {
    WORKERS_SPAWNED.load(Ordering::SeqCst)
}

/// Input rows shared by every lane of one scoring call. Both variants are
/// cheap pointer clones per lane — samples are never copied per lane.
#[derive(Clone)]
pub enum LaneInput {
    /// A flit payload straight off the data plane (per-flit servicing).
    Flit(Arc<[f32]>),
    /// Concatenated burst rows; the burst path reclaims the allocation via
    /// [`Arc::try_unwrap`] once all lanes have dropped their clones.
    Rows(Arc<Vec<f32>>),
}

impl LaneInput {
    #[inline]
    pub fn rows(&self) -> &[f32] {
        match self {
            LaneInput::Flit(a) => a,
            LaneInput::Rows(v) => v,
        }
    }
}

/// One lane: a sub-detector slice of the partition's ensemble plus its
/// reusable weighted partial-score buffer.
pub struct Lane {
    /// `None` only while the detector is in flight inside a worker.
    det: Option<Box<dyn Detector>>,
    /// Ensemble merge weight: `(hi − lo) / r_total` for slice `[lo, hi)`.
    weight: f32,
    /// Weighted partial scores of the most recent scoring call.
    out: Vec<f32>,
}

impl Lane {
    pub fn new(det: Box<dyn Detector>, weight: f32) -> Lane {
        Lane { det: Some(det), weight, out: Vec::new() }
    }

    pub fn weight(&self) -> f32 {
        self.weight
    }

    /// The lane's detector (`None` only mid-flight inside a scoring call).
    pub fn det_mut(&mut self) -> Option<&mut Box<dyn Detector>> {
        self.det.as_mut()
    }

    pub fn det(&self) -> Option<&(dyn Detector)> {
        self.det.as_deref()
    }
}

/// Build the lane array for `spec`: an equal sub-detector partition (shared
/// with the CPU ensemble runners via `partition_r`) with per-lane merge
/// weights. `lanes` is clamped to `[1, spec.r]`.
pub fn build_lanes(spec: &DetectorSpec, warmup: &[f32], lanes: usize) -> Vec<Lane> {
    let lanes = lanes.clamp(1, spec.r);
    let r_total = spec.r as f32;
    crate::ensemble::partition_r(spec.r, lanes)
        .iter()
        .map(|&(lo, hi)| Lane::new(spec.build_slice(warmup, lo, hi), (hi - lo) as f32 / r_total))
        .collect()
}

/// Score one lane job: chunked `update_batch` over rows `[0, n)` of `data`
/// into `out`, then scale by the lane weight. This single function is the
/// arithmetic shared by pooled workers and [`score_inline`], so the two
/// execution styles are bit-identical by construction.
fn run_lane_job(
    det: &mut dyn Detector,
    data: &[f32],
    n: usize,
    chunk: usize,
    weight: f32,
    out: &mut Vec<f32>,
) {
    let d = det.d();
    let chunk = chunk.max(1);
    out.clear();
    out.resize(n, 0.0);
    let mut i = 0;
    while i < n {
        let m = chunk.min(n - i);
        det.update_batch(&data[i * d..(i + m) * d], &mut out[i..i + m]);
        i += m;
    }
    if weight != 1.0 {
        for v in out.iter_mut() {
            *v *= weight;
        }
    }
}

/// Score every lane sequentially on the calling thread — the poolless
/// fallback (tests, one-off `LoadedRm::process` calls). Same arithmetic as
/// the pooled path.
pub fn score_inline(
    lanes: &mut [Lane],
    input: &LaneInput,
    n: usize,
    chunk: usize,
) -> Result<()> {
    for (li, lane) in lanes.iter_mut().enumerate() {
        let Some(det) = lane.det.as_mut() else {
            return Err(lost_lane(li));
        };
        let mut out = std::mem::take(&mut lane.out);
        run_lane_job(det.as_mut(), input.rows(), n, chunk, lane.weight, &mut out);
        lane.out = out;
    }
    Ok(())
}

/// A lane whose detector never came back from a failed earlier burst: the
/// RM is unusable and must be rebuilt (session episodes and hot-swaps do
/// exactly that). Kept an `Err`, never a panic, so a wedged partition
/// fails its stream instead of aborting the process on the next run.
fn lost_lane(lane: usize) -> anyhow::Error {
    anyhow!("lane {lane} lost its detector in a failed earlier burst — the RM must be rebuilt")
}

/// Merge the weighted lane partials into `out` (`out.len()` rows) in
/// lane-index order — the same single merge pass as `run_batched`.
pub fn merge_lanes_into(lanes: &[Lane], out: &mut [f32]) {
    let n = out.len();
    match lanes.split_first() {
        None => out.fill(0.0),
        Some((first, rest)) => {
            out.copy_from_slice(&first.out[..n]);
            for lane in rest {
                for (o, p) in out.iter_mut().zip(&lane.out[..n]) {
                    *o += p;
                }
            }
        }
    }
}

struct Job {
    lane: usize,
    det: Box<dyn Detector>,
    input: LaneInput,
    n: usize,
    chunk: usize,
    weight: f32,
    out: Vec<f32>,
    /// Per-call reply channel: results of one `score` call can never leak
    /// into a later call (a straggler from an aborted call delivers into a
    /// dead channel), and a worker that dies mid-job drops its sender, so
    /// the caller sees a disconnect instead of hanging.
    reply: Sender<JobDone>,
}

struct JobDone {
    lane: usize,
    det: Box<dyn Detector>,
    out: Vec<f32>,
    /// The job panicked twice (rollback retry included): the partials are
    /// unusable and the caller must reload the RM.
    failed: bool,
}

struct PoolIo {
    jobs: Vec<Sender<Job>>,
}

/// A pool of resident lane worker threads. Spawned once (per partition, or
/// per `run_batched` call), parked on job channels between scoring calls,
/// joined on drop. `Sync`: the channel ends live behind one mutex, so a
/// shared reference can score from any service thread (calls serialize —
/// each pool has a single logical user, its partition's service loop).
pub struct LanePool {
    io: Mutex<PoolIo>,
    shared: Arc<PoolShared>,
    workers: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

fn spawn_worker(w: usize, shared: Arc<PoolShared>) -> (Sender<Job>, JoinHandle<()>) {
    let (job_tx, job_rx) = channel::<Job>();
    WORKERS_SPAWNED.fetch_add(1, Ordering::SeqCst);
    let handle = std::thread::Builder::new()
        .name(format!("lane-{w}"))
        .spawn(move || worker_loop(w, shared, job_rx))
        .expect("spawn lane worker");
    (job_tx, handle)
}

impl LanePool {
    /// Spawn `workers` resident lane threads.
    pub fn new(workers: usize) -> LanePool {
        assert!(workers > 0, "a lane pool needs at least one worker");
        let shared = Arc::new(PoolShared::new());
        let mut jobs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (job_tx, handle) = spawn_worker(w, Arc::clone(&shared));
            jobs.push(job_tx);
            handles.push(handle);
        }
        LanePool { io: Mutex::new(PoolIo { jobs }), shared, workers, handles: Mutex::new(handles) }
    }

    /// Resident worker threads in this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Arm fault containment: workers score inside `catch_unwind` with a
    /// pre-job window checkpoint, so a panicking lane rolls back and
    /// retries in place. Off by default — the unarmed hot path is exactly
    /// the pre-fault-tolerance code.
    pub fn arm_faults(&self) {
        self.shared.armed.store(true, Ordering::SeqCst);
    }

    /// Inject a one-shot panic into lane `lane`'s next job (consumed by the
    /// worker that picks it up).
    pub fn inject_lane_panic(&self, lane: usize) {
        self.shared.panic_lane.store(lane, Ordering::SeqCst);
    }

    /// Inject a one-shot worker death: worker `worker` finishes (and
    /// replies to) its next job, then exits its loop — the next dispatch to
    /// it fails like a genuine thread death, exercising the respawn path
    /// without losing any detector.
    pub fn inject_worker_exit(&self, worker: usize) {
        self.shared.exit_worker.store(worker % self.workers, Ordering::SeqCst);
    }

    /// Drain the contained-fault log (panics caught and retried by the
    /// workers since the last call).
    pub fn take_fault_notes(&self) -> Vec<LaneFaultNote> {
        std::mem::take(&mut *self.shared.notes.lock().unwrap())
    }

    /// Respawn every worker slot: fresh job channels and threads replace
    /// the old ones (a live old worker parks out when its sender drops; a
    /// dead one is simply superseded). Lane state is untouched — after a
    /// respawn the caller retries the failed burst on the same lane array.
    pub fn respawn(&self) {
        let mut io = self.io.lock().unwrap();
        let mut handles = self.handles.lock().unwrap();
        for w in 0..self.workers {
            let (job_tx, handle) = spawn_worker(w, Arc::clone(&self.shared));
            io.jobs[w] = job_tx;
            handles.push(handle);
        }
    }

    /// Score rows `[0, n)` of `input` through every lane concurrently:
    /// detectors and partial buffers round-trip through the workers
    /// (lane `i` on worker `i % workers`, so a lane array larger than the
    /// pool still completes). Blocks until all lanes are done; on return
    /// every lane holds its weighted partials for [`merge_lanes_into`].
    pub fn score(
        &self,
        lanes: &mut [Lane],
        input: &LaneInput,
        n: usize,
        chunk: usize,
    ) -> Result<()> {
        let io = self.io.lock().unwrap();
        // One private reply channel per call: a straggler from an aborted
        // earlier call delivers into that call's dead channel instead of
        // corrupting this lane array, and a worker that panics mid-job
        // drops its reply sender, surfacing here as a disconnect rather
        // than a hang. Long jobs simply take as long as they take — the
        // same semantics as the scoped join this pool replaced.
        let (reply_tx, reply_rx) = channel::<JobDone>();
        let mut sent = 0usize;
        let mut dead_worker: Option<usize> = None;
        for (li, lane) in lanes.iter_mut().enumerate() {
            let Some(det) = lane.det.take() else {
                return Err(lost_lane(li));
            };
            let job = Job {
                lane: li,
                det,
                input: input.clone(),
                n,
                chunk,
                weight: lane.weight,
                out: std::mem::take(&mut lane.out),
                reply: reply_tx.clone(),
            };
            match io.jobs[li % io.jobs.len()].send(job) {
                Ok(()) => sent += 1,
                Err(std::sync::mpsc::SendError(job)) => {
                    // The worker's receiver is gone (thread death): recover
                    // this lane's detector and stop dispatching — lanes that
                    // did ship still round-trip below, so the array stays
                    // whole and a respawn + retry can recover the burst.
                    lane.det = Some(job.det);
                    lane.out = job.out;
                    dead_worker = Some(li % io.jobs.len());
                    break;
                }
            }
        }
        drop(reply_tx);
        let mut failed_lane: Option<usize> = None;
        let mut got = 0usize;
        while got < sent {
            let Ok(done) = reply_rx.recv() else {
                break; // a worker died mid-job: its lane's detector is lost
            };
            let lane = &mut lanes[done.lane];
            lane.det = Some(done.det);
            lane.out = done.out;
            if done.failed {
                failed_lane = Some(done.lane);
            }
            got += 1;
        }
        if got < sent {
            return Err(anyhow!(
                "a lane worker died mid-burst (detector panicked?) — lane results lost"
            ));
        }
        if let Some(w) = dead_worker {
            return Err(anyhow!("lane worker {w} is dead — respawn the pool and retry the burst"));
        }
        if let Some(l) = failed_lane {
            return Err(anyhow!(
                "lane {l} panicked during scoring and its rollback retry failed — reload the RM"
            ));
        }
        Ok(())
    }
}

impl Drop for LanePool {
    fn drop(&mut self) {
        // Dropping the job senders parks every worker out of its recv loop;
        // join so no lane thread outlives its partition.
        self.io.get_mut().unwrap().jobs.clear();
        for h in self.handles.get_mut().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(worker: usize, shared: Arc<PoolShared>, jobs: Receiver<Job>) {
    while let Ok(job) = jobs.recv() {
        let Job { lane, mut det, input, n, chunk, weight, mut out, reply } = job;
        let mut failed = false;
        if !shared.armed.load(Ordering::Relaxed) {
            // Unarmed hot path: exactly the pre-fault-tolerance scoring.
            run_lane_job(det.as_mut(), input.rows(), n, chunk, weight, &mut out);
        } else {
            // Fault-armed: checkpoint the lane's window so a caught panic
            // can roll back to the pre-job state and rescore bit-exactly.
            let saved = det.window_state().cloned();
            let inject = shared
                .panic_lane
                .compare_exchange(lane, NONE_SELECTED, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok();
            let t0 = std::time::Instant::now();
            let mut attempt = 0usize;
            loop {
                let first = attempt == 0;
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if inject && first {
                        panic!("injected fault: panic in lane {lane} on worker {worker}");
                    }
                    run_lane_job(det.as_mut(), input.rows(), n, chunk, weight, &mut out);
                }));
                match res {
                    Ok(()) => {
                        if attempt > 0 {
                            shared.note(LaneFaultNote {
                                lane,
                                worker,
                                kind: "lane_panic_retried",
                                latency_us: t0.elapsed().as_micros() as u64,
                                detail: format!(
                                    "rolled back and rescored after {attempt} panic(s)"
                                ),
                            });
                        }
                        break;
                    }
                    Err(_) => {
                        if let (Some(w), Some(cur)) = (saved.as_ref(), det.window_state_mut()) {
                            let _ = cur.load(
                                w.counts(),
                                w.ring(),
                                w.pos(),
                                w.n(),
                                w.log2_denom(),
                            );
                        }
                        attempt += 1;
                        if attempt > 1 {
                            failed = true;
                            shared.note(LaneFaultNote {
                                lane,
                                worker,
                                kind: "lane_panic_unrecovered",
                                latency_us: t0.elapsed().as_micros() as u64,
                                detail: "rollback retry panicked again — RM reload required"
                                    .to_string(),
                            });
                            break;
                        }
                    }
                }
            }
        }
        drop(input); // release the shared rows before handing back (burst
                     // scratch reclamation relies on the refcount dropping)
        if reply.send(JobDone { lane, det, out, failed }).is_err() {
            continue; // caller aborted this burst; keep serving the pool
        }
        // Injected thread death fires only after the reply so no detector
        // is ever lost to it: the *next* dispatch to this worker fails.
        if shared
            .exit_worker
            .compare_exchange(worker, NONE_SELECTED, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            shared.note(LaneFaultNote {
                lane,
                worker,
                kind: "worker_exit",
                latency_us: 0,
                detail: format!("worker {worker} exited after its job (injected)"),
            });
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::prng::Prng;
    use crate::detectors::{DetectorKind, DetectorSpec};

    fn stream(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    fn spec(kind: DetectorKind, r: usize) -> DetectorSpec {
        let mut s = DetectorSpec::new(kind, 3, r, 7);
        s.window = 16;
        s.bins = 8;
        s.modulus = 32;
        s.k = 4;
        s
    }

    #[test]
    fn pooled_matches_inline_bit_for_bit() {
        let data = stream(60, 3, 1);
        let input = LaneInput::Rows(Arc::new(data.clone()));
        for kind in DetectorKind::ALL {
            let sp = spec(kind, 5); // 5 % 2 != 0: uneven slices
            let warmup = &data[..16 * 3];
            let mut pooled = build_lanes(&sp, warmup, 2);
            let mut inline = build_lanes(&sp, warmup, 2);
            let pool = LanePool::new(2);
            pool.score(&mut pooled, &input, 60, usize::MAX).unwrap();
            score_inline(&mut inline, &input, 60, usize::MAX).unwrap();
            let mut a = vec![0f32; 60];
            let mut b = vec![0f32; 60];
            merge_lanes_into(&pooled, &mut a);
            merge_lanes_into(&inline, &mut b);
            assert_eq!(a, b, "{kind:?}");
        }
    }

    #[test]
    fn single_lane_is_bit_identical_to_full_ensemble() {
        let data = stream(50, 3, 2);
        let sp = spec(DetectorKind::Loda, 4);
        let warmup = &data[..16 * 3];
        let mut det = sp.build(warmup);
        let expect = det.run_stream(&data);
        let mut lanes = build_lanes(&sp, warmup, 1);
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].weight(), 1.0);
        score_inline(&mut lanes, &LaneInput::Rows(Arc::new(data.clone())), 50, usize::MAX)
            .unwrap();
        let mut got = vec![0f32; 50];
        merge_lanes_into(&lanes, &mut got);
        assert_eq!(got, expect);
    }

    #[test]
    fn lane_count_is_clamped_and_weights_sum_to_one() {
        let sp = spec(DetectorKind::RsHash, 3);
        let lanes = build_lanes(&sp, &[], 16);
        assert_eq!(lanes.len(), 3, "lanes clamp to r");
        let total: f32 = lanes.iter().map(|l| l.weight()).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pool_survives_more_lanes_than_workers() {
        let data = stream(40, 3, 3);
        let sp = spec(DetectorKind::XStream, 6);
        let warmup = &data[..16 * 3];
        let mut lanes = build_lanes(&sp, warmup, 3);
        let pool = LanePool::new(2); // lane 2 shares worker 0
        let input = LaneInput::Rows(Arc::new(data.clone()));
        pool.score(&mut lanes, &input, 40, usize::MAX).unwrap();
        let mut inline = build_lanes(&sp, warmup, 3);
        score_inline(&mut inline, &input, 40, usize::MAX).unwrap();
        let mut a = vec![0f32; 40];
        let mut b = vec![0f32; 40];
        merge_lanes_into(&lanes, &mut a);
        merge_lanes_into(&inline, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn burst_rows_are_reclaimable_after_score() {
        let data = stream(30, 3, 4);
        let sp = spec(DetectorKind::Loda, 4);
        let mut lanes = build_lanes(&sp, &data[..16 * 3], 2);
        let pool = LanePool::new(2);
        let rows = Arc::new(data.clone());
        let input = LaneInput::Rows(Arc::clone(&rows));
        pool.score(&mut lanes, &input, 30, usize::MAX).unwrap();
        drop(input);
        // All lane clones dropped once score() returned: the burst scratch
        // allocation comes back to the caller.
        assert!(Arc::try_unwrap(rows).is_ok(), "workers must not retain the rows");
    }

    #[test]
    fn injected_lane_panic_is_caught_rolled_back_and_retried_bit_exactly() {
        let data = stream(48, 3, 6);
        let sp = spec(DetectorKind::Loda, 4);
        let warmup = &data[..16 * 3];
        let input = LaneInput::Rows(Arc::new(data.clone()));

        let mut clean = build_lanes(&sp, warmup, 2);
        let clean_pool = LanePool::new(2);
        clean_pool.score(&mut clean, &input, 48, usize::MAX).unwrap();
        let mut want = vec![0f32; 48];
        merge_lanes_into(&clean, &mut want);

        let mut lanes = build_lanes(&sp, warmup, 2);
        let pool = LanePool::new(2);
        pool.arm_faults();
        pool.inject_lane_panic(1);
        pool.score(&mut lanes, &input, 48, usize::MAX).unwrap();
        let mut got = vec![0f32; 48];
        merge_lanes_into(&lanes, &mut got);
        assert_eq!(got, want, "rollback + retry must be bit-exact");
        let notes = pool.take_fault_notes();
        assert_eq!(notes.len(), 1);
        assert_eq!((notes[0].lane, notes[0].kind), (1, "lane_panic_retried"));
        assert!(pool.take_fault_notes().is_empty(), "notes drain once");
    }

    #[test]
    fn injected_worker_exit_is_recovered_by_respawn() {
        let data = stream(32, 3, 8);
        let sp = spec(DetectorKind::RsHash, 4);
        let warmup = &data[..16 * 3];
        let input = LaneInput::Rows(Arc::new(data.clone()));

        let mut reference = build_lanes(&sp, warmup, 2);
        let mut lanes = build_lanes(&sp, warmup, 2);
        let pool = LanePool::new(2);
        pool.arm_faults();
        pool.inject_worker_exit(0);
        // The worker replies before exiting, so this call still succeeds…
        pool.score(&mut lanes, &input, 32, usize::MAX).unwrap();
        score_inline(&mut reference, &input, 32, usize::MAX).unwrap();
        // …and the next dispatch hits the dead worker: clean Err, no lane
        // detector lost.
        let err = pool.score(&mut lanes, &input, 32, usize::MAX).unwrap_err();
        assert!(err.to_string().contains("respawn the pool"), "{err}");
        assert!(lanes.iter().all(|l| l.det().is_some()), "no detector may be lost");
        pool.respawn();
        pool.score(&mut lanes, &input, 32, usize::MAX).unwrap();
        score_inline(&mut reference, &input, 32, usize::MAX).unwrap();
        let mut got = vec![0f32; 32];
        let mut want = vec![0f32; 32];
        merge_lanes_into(&lanes, &mut got);
        merge_lanes_into(&reference, &mut want);
        assert_eq!(got, want, "post-respawn scoring must match the inline reference");
        let kinds: Vec<&str> = pool.take_fault_notes().iter().map(|n| n.kind).collect();
        assert!(kinds.contains(&"worker_exit"), "{kinds:?}");
    }

    #[test]
    fn workers_are_spawned_once_per_pool() {
        let before = total_workers_spawned();
        let data = stream(20, 3, 5);
        let sp = spec(DetectorKind::Loda, 4);
        let mut lanes = build_lanes(&sp, &data[..16 * 3], 2);
        let pool = LanePool::new(2);
        // Other tests may spawn pools concurrently in this binary, so the
        // process-wide counter is a lower bound here; the exact spawn-once
        // accounting lives in tests/lane_parity.rs, which serializes.
        assert!(total_workers_spawned() >= before + 2);
        assert_eq!(pool.workers(), 2);
        let input = LaneInput::Rows(Arc::new(data));
        for _ in 0..8 {
            pool.score(&mut lanes, &input, 20, usize::MAX).unwrap();
        }
        assert_eq!(pool.workers(), 2, "scoring must never respawn workers");
    }
}
