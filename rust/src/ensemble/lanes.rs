//! Resident lane workers: intra-partition instance parallelism.
//!
//! The paper's scalability claim — "multiple instances can be placed within
//! a pblock to improve performance" (§4, Fig 9) — maps onto this module: a
//! partition's ensemble is split into **lanes** (sub-detector slices built
//! with [`DetectorSpec::build_slice`], the same equal partition the CPU
//! runners use), and a [`LanePool`] of **resident worker threads** scores
//! all lanes of a burst concurrently. Workers are spawned once per pool —
//! once per partition in the fabric and the session server, once per call
//! in [`crate::ensemble::run_batched`] — and stay parked on their job
//! channels between bursts, so steady-state scoring never pays a thread
//! spawn (the `std::thread::scope` per-call pattern this replaces).
//!
//! # Ownership protocol
//!
//! Lane detectors are owned by the caller (a [`Lane`] array inside the
//! loaded RM), not by the worker threads: each [`LanePool::score`] call
//! moves every lane's boxed detector and partial-score buffer into a job,
//! the workers score and hand both back, and the pool restores them before
//! returning. That keeps RM lifecycle operations — DFX hot-swap (replace
//! the whole lane array between flits), reset, describe — ordinary moves on
//! the service thread, while the scoring itself runs in parallel.
//!
//! # Arithmetic contract
//!
//! Pooled and inline ([`score_inline`]) execution run byte-for-byte the
//! same per-lane job ([`run_lane_job`]): chunked `update_batch` over the
//! shared input rows into a private partial vector, scaled by the lane's
//! ensemble weight `(hi − lo) / r`. [`merge_lanes_into`] then sums the
//! partials in lane-index order — exactly `run_batched`'s merge pass — so
//! lane count only changes the f32 summation order (the established 1e-5
//! partition tolerance) and a single lane is bit-identical to the
//! unpartitioned ensemble loop.

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::detectors::{Detector, DetectorSpec};

/// Lane worker threads spawned process-wide (telemetry; the residency tests
/// assert this does not grow per burst or per server session).
static WORKERS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total lane worker threads ever spawned in this process.
pub fn total_workers_spawned() -> u64 {
    WORKERS_SPAWNED.load(Ordering::SeqCst)
}

/// Input rows shared by every lane of one scoring call. Both variants are
/// cheap pointer clones per lane — samples are never copied per lane.
#[derive(Clone)]
pub enum LaneInput {
    /// A flit payload straight off the data plane (per-flit servicing).
    Flit(Arc<[f32]>),
    /// Concatenated burst rows; the burst path reclaims the allocation via
    /// [`Arc::try_unwrap`] once all lanes have dropped their clones.
    Rows(Arc<Vec<f32>>),
}

impl LaneInput {
    #[inline]
    pub fn rows(&self) -> &[f32] {
        match self {
            LaneInput::Flit(a) => a,
            LaneInput::Rows(v) => v,
        }
    }
}

/// One lane: a sub-detector slice of the partition's ensemble plus its
/// reusable weighted partial-score buffer.
pub struct Lane {
    /// `None` only while the detector is in flight inside a worker.
    det: Option<Box<dyn Detector>>,
    /// Ensemble merge weight: `(hi − lo) / r_total` for slice `[lo, hi)`.
    weight: f32,
    /// Weighted partial scores of the most recent scoring call.
    out: Vec<f32>,
}

impl Lane {
    pub fn new(det: Box<dyn Detector>, weight: f32) -> Lane {
        Lane { det: Some(det), weight, out: Vec::new() }
    }

    pub fn weight(&self) -> f32 {
        self.weight
    }

    /// The lane's detector (`None` only mid-flight inside a scoring call).
    pub fn det_mut(&mut self) -> Option<&mut Box<dyn Detector>> {
        self.det.as_mut()
    }

    pub fn det(&self) -> Option<&(dyn Detector)> {
        self.det.as_deref()
    }
}

/// Build the lane array for `spec`: an equal sub-detector partition (shared
/// with the CPU ensemble runners via `partition_r`) with per-lane merge
/// weights. `lanes` is clamped to `[1, spec.r]`.
pub fn build_lanes(spec: &DetectorSpec, warmup: &[f32], lanes: usize) -> Vec<Lane> {
    let lanes = lanes.clamp(1, spec.r);
    let r_total = spec.r as f32;
    crate::ensemble::partition_r(spec.r, lanes)
        .iter()
        .map(|&(lo, hi)| Lane::new(spec.build_slice(warmup, lo, hi), (hi - lo) as f32 / r_total))
        .collect()
}

/// Score one lane job: chunked `update_batch` over rows `[0, n)` of `data`
/// into `out`, then scale by the lane weight. This single function is the
/// arithmetic shared by pooled workers and [`score_inline`], so the two
/// execution styles are bit-identical by construction.
fn run_lane_job(
    det: &mut dyn Detector,
    data: &[f32],
    n: usize,
    chunk: usize,
    weight: f32,
    out: &mut Vec<f32>,
) {
    let d = det.d();
    let chunk = chunk.max(1);
    out.clear();
    out.resize(n, 0.0);
    let mut i = 0;
    while i < n {
        let m = chunk.min(n - i);
        det.update_batch(&data[i * d..(i + m) * d], &mut out[i..i + m]);
        i += m;
    }
    if weight != 1.0 {
        for v in out.iter_mut() {
            *v *= weight;
        }
    }
}

/// Score every lane sequentially on the calling thread — the poolless
/// fallback (tests, one-off `LoadedRm::process` calls). Same arithmetic as
/// the pooled path.
pub fn score_inline(
    lanes: &mut [Lane],
    input: &LaneInput,
    n: usize,
    chunk: usize,
) -> Result<()> {
    for (li, lane) in lanes.iter_mut().enumerate() {
        let Some(det) = lane.det.as_mut() else {
            return Err(lost_lane(li));
        };
        let mut out = std::mem::take(&mut lane.out);
        run_lane_job(det.as_mut(), input.rows(), n, chunk, lane.weight, &mut out);
        lane.out = out;
    }
    Ok(())
}

/// A lane whose detector never came back from a failed earlier burst: the
/// RM is unusable and must be rebuilt (session episodes and hot-swaps do
/// exactly that). Kept an `Err`, never a panic, so a wedged partition
/// fails its stream instead of aborting the process on the next run.
fn lost_lane(lane: usize) -> anyhow::Error {
    anyhow!("lane {lane} lost its detector in a failed earlier burst — the RM must be rebuilt")
}

/// Merge the weighted lane partials into `out` (`out.len()` rows) in
/// lane-index order — the same single merge pass as `run_batched`.
pub fn merge_lanes_into(lanes: &[Lane], out: &mut [f32]) {
    let n = out.len();
    match lanes.split_first() {
        None => out.fill(0.0),
        Some((first, rest)) => {
            out.copy_from_slice(&first.out[..n]);
            for lane in rest {
                for (o, p) in out.iter_mut().zip(&lane.out[..n]) {
                    *o += p;
                }
            }
        }
    }
}

struct Job {
    lane: usize,
    det: Box<dyn Detector>,
    input: LaneInput,
    n: usize,
    chunk: usize,
    weight: f32,
    out: Vec<f32>,
    /// Per-call reply channel: results of one `score` call can never leak
    /// into a later call (a straggler from an aborted call delivers into a
    /// dead channel), and a worker that dies mid-job drops its sender, so
    /// the caller sees a disconnect instead of hanging.
    reply: Sender<JobDone>,
}

struct JobDone {
    lane: usize,
    det: Box<dyn Detector>,
    out: Vec<f32>,
}

struct PoolIo {
    jobs: Vec<Sender<Job>>,
}

/// A pool of resident lane worker threads. Spawned once (per partition, or
/// per `run_batched` call), parked on job channels between scoring calls,
/// joined on drop. `Sync`: the channel ends live behind one mutex, so a
/// shared reference can score from any service thread (calls serialize —
/// each pool has a single logical user, its partition's service loop).
pub struct LanePool {
    io: Mutex<PoolIo>,
    handles: Vec<JoinHandle<()>>,
}

impl LanePool {
    /// Spawn `workers` resident lane threads.
    pub fn new(workers: usize) -> LanePool {
        assert!(workers > 0, "a lane pool needs at least one worker");
        let mut jobs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (job_tx, job_rx) = channel::<Job>();
            WORKERS_SPAWNED.fetch_add(1, Ordering::SeqCst);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("lane-{w}"))
                    .spawn(move || worker_loop(job_rx))
                    .expect("spawn lane worker"),
            );
            jobs.push(job_tx);
        }
        LanePool { io: Mutex::new(PoolIo { jobs }), handles }
    }

    /// Resident worker threads in this pool.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Score rows `[0, n)` of `input` through every lane concurrently:
    /// detectors and partial buffers round-trip through the workers
    /// (lane `i` on worker `i % workers`, so a lane array larger than the
    /// pool still completes). Blocks until all lanes are done; on return
    /// every lane holds its weighted partials for [`merge_lanes_into`].
    pub fn score(
        &self,
        lanes: &mut [Lane],
        input: &LaneInput,
        n: usize,
        chunk: usize,
    ) -> Result<()> {
        let io = self.io.lock().unwrap();
        // One private reply channel per call: a straggler from an aborted
        // earlier call delivers into that call's dead channel instead of
        // corrupting this lane array, and a worker that panics mid-job
        // drops its reply sender, surfacing here as a disconnect rather
        // than a hang. Long jobs simply take as long as they take — the
        // same semantics as the scoped join this pool replaced.
        let (reply_tx, reply_rx) = channel::<JobDone>();
        for (li, lane) in lanes.iter_mut().enumerate() {
            let Some(det) = lane.det.take() else {
                return Err(lost_lane(li));
            };
            let job = Job {
                lane: li,
                det,
                input: input.clone(),
                n,
                chunk,
                weight: lane.weight,
                out: std::mem::take(&mut lane.out),
                reply: reply_tx.clone(),
            };
            io.jobs[li % io.jobs.len()]
                .send(job)
                .map_err(|_| anyhow!("lane worker exited — lane pool is dead"))?;
        }
        drop(reply_tx);
        for _ in 0..lanes.len() {
            let done = reply_rx.recv().map_err(|_| {
                anyhow!("a lane worker died mid-burst (detector panicked?) — lane results lost")
            })?;
            let lane = &mut lanes[done.lane];
            lane.det = Some(done.det);
            lane.out = done.out;
        }
        Ok(())
    }
}

impl Drop for LanePool {
    fn drop(&mut self) {
        // Dropping the job senders parks every worker out of its recv loop;
        // join so no lane thread outlives its partition.
        self.io.get_mut().unwrap().jobs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(jobs: Receiver<Job>) {
    while let Ok(job) = jobs.recv() {
        let Job { lane, mut det, input, n, chunk, weight, mut out, reply } = job;
        run_lane_job(det.as_mut(), input.rows(), n, chunk, weight, &mut out);
        drop(input); // release the shared rows before handing back (burst
                     // scratch reclamation relies on the refcount dropping)
        if reply.send(JobDone { lane, det, out }).is_err() {
            continue; // caller aborted this burst; keep serving the pool
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::prng::Prng;
    use crate::detectors::{DetectorKind, DetectorSpec};

    fn stream(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    fn spec(kind: DetectorKind, r: usize) -> DetectorSpec {
        let mut s = DetectorSpec::new(kind, 3, r, 7);
        s.window = 16;
        s.bins = 8;
        s.modulus = 32;
        s.k = 4;
        s
    }

    #[test]
    fn pooled_matches_inline_bit_for_bit() {
        let data = stream(60, 3, 1);
        let input = LaneInput::Rows(Arc::new(data.clone()));
        for kind in DetectorKind::ALL {
            let sp = spec(kind, 5); // 5 % 2 != 0: uneven slices
            let warmup = &data[..16 * 3];
            let mut pooled = build_lanes(&sp, warmup, 2);
            let mut inline = build_lanes(&sp, warmup, 2);
            let pool = LanePool::new(2);
            pool.score(&mut pooled, &input, 60, usize::MAX).unwrap();
            score_inline(&mut inline, &input, 60, usize::MAX).unwrap();
            let mut a = vec![0f32; 60];
            let mut b = vec![0f32; 60];
            merge_lanes_into(&pooled, &mut a);
            merge_lanes_into(&inline, &mut b);
            assert_eq!(a, b, "{kind:?}");
        }
    }

    #[test]
    fn single_lane_is_bit_identical_to_full_ensemble() {
        let data = stream(50, 3, 2);
        let sp = spec(DetectorKind::Loda, 4);
        let warmup = &data[..16 * 3];
        let mut det = sp.build(warmup);
        let expect = det.run_stream(&data);
        let mut lanes = build_lanes(&sp, warmup, 1);
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].weight(), 1.0);
        score_inline(&mut lanes, &LaneInput::Rows(Arc::new(data.clone())), 50, usize::MAX)
            .unwrap();
        let mut got = vec![0f32; 50];
        merge_lanes_into(&lanes, &mut got);
        assert_eq!(got, expect);
    }

    #[test]
    fn lane_count_is_clamped_and_weights_sum_to_one() {
        let sp = spec(DetectorKind::RsHash, 3);
        let lanes = build_lanes(&sp, &[], 16);
        assert_eq!(lanes.len(), 3, "lanes clamp to r");
        let total: f32 = lanes.iter().map(|l| l.weight()).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pool_survives_more_lanes_than_workers() {
        let data = stream(40, 3, 3);
        let sp = spec(DetectorKind::XStream, 6);
        let warmup = &data[..16 * 3];
        let mut lanes = build_lanes(&sp, warmup, 3);
        let pool = LanePool::new(2); // lane 2 shares worker 0
        let input = LaneInput::Rows(Arc::new(data.clone()));
        pool.score(&mut lanes, &input, 40, usize::MAX).unwrap();
        let mut inline = build_lanes(&sp, warmup, 3);
        score_inline(&mut inline, &input, 40, usize::MAX).unwrap();
        let mut a = vec![0f32; 40];
        let mut b = vec![0f32; 40];
        merge_lanes_into(&lanes, &mut a);
        merge_lanes_into(&inline, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn burst_rows_are_reclaimable_after_score() {
        let data = stream(30, 3, 4);
        let sp = spec(DetectorKind::Loda, 4);
        let mut lanes = build_lanes(&sp, &data[..16 * 3], 2);
        let pool = LanePool::new(2);
        let rows = Arc::new(data.clone());
        let input = LaneInput::Rows(Arc::clone(&rows));
        pool.score(&mut lanes, &input, 30, usize::MAX).unwrap();
        drop(input);
        // All lane clones dropped once score() returned: the burst scratch
        // allocation comes back to the caller.
        assert!(Arc::try_unwrap(rows).is_ok(), "workers must not retain the rows");
    }

    #[test]
    fn workers_are_spawned_once_per_pool() {
        let before = total_workers_spawned();
        let data = stream(20, 3, 5);
        let sp = spec(DetectorKind::Loda, 4);
        let mut lanes = build_lanes(&sp, &data[..16 * 3], 2);
        let pool = LanePool::new(2);
        // Other tests may spawn pools concurrently in this binary, so the
        // process-wide counter is a lower bound here; the exact spawn-once
        // accounting lives in tests/lane_parity.rs, which serializes.
        assert!(total_workers_spawned() >= before + 2);
        assert_eq!(pool.workers(), 2);
        let input = LaneInput::Rows(Arc::new(data));
        for _ in 0..8 {
            pool.score(&mut lanes, &input, 20, usize::MAX).unwrap();
        }
        assert_eq!(pool.workers(), 2, "scoring must never respawn workers");
    }
}
