//! Multi-threaded CPU baseline with the paper's synchronisation scheme:
//! sub-detectors are distributed equally across threads; after every sample
//! the partial scores are merged under a mutex and a barrier enforces
//! streaming lock-step ("pthread_mutex_lock ... placed between different
//! threads to guarantee the streaming mode execution", §4.4). This is the
//! contention source that caps the paper's speed-up at 4 threads (Fig 11).

use std::sync::{Barrier, Mutex};

use crate::data::Dataset;
use crate::detectors::DetectorSpec;

/// Run `spec` over `ds` with `threads` worker threads.
/// Returns per-sample ensemble scores (mean over all R sub-detectors).
pub fn run_threaded(spec: &DetectorSpec, ds: &Dataset, threads: usize) -> Vec<f32> {
    let threads = threads.max(1).min(spec.r);
    if threads == 1 {
        return super::run_sequential(spec, ds);
    }
    let n = ds.n();
    let warmup = ds.warmup(spec.window);
    let ranges = super::partition_r(spec.r, threads);

    let acc: Mutex<Vec<f32>> = Mutex::new(vec![0f32; n]);
    let barrier = Barrier::new(threads);
    // Scoped threads borrow the dataset directly — no per-call clone.
    let data: &[f32] = &ds.data;
    let d = ds.d;
    let r_total = spec.r as f32;

    std::thread::scope(|scope| {
        for &(lo, hi) in &ranges {
            let (acc, barrier) = (&acc, &barrier);
            let mut det = spec.build_slice(warmup, lo, hi);
            let weight = (hi - lo) as f32 / r_total;
            scope.spawn(move || {
                for i in 0..n {
                    let x = &data[i * d..(i + 1) * d];
                    let partial = det.update(x) * weight;
                    {
                        // Per-sample merge under the mutex (paper's scheme).
                        let mut scores = acc.lock().unwrap();
                        scores[i] += partial;
                    }
                    // Lock-step: no thread may advance to sample i+1 before
                    // sample i's ensemble score is complete.
                    barrier.wait();
                }
            });
        }
    });

    acc.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_profile, DatasetProfile};
    use crate::detectors::{DetectorKind, DetectorSpec};
    use crate::ensemble::run_sequential;

    fn tiny_ds() -> Dataset {
        let p = DatasetProfile { name: "t", n: 150, d: 3, outliers: 8, clusters: 2 };
        generate_profile(&p, 2)
    }

    #[test]
    fn threaded_matches_sequential_for_all_kinds() {
        let ds = tiny_ds();
        for kind in DetectorKind::ALL {
            let spec = DetectorSpec::new(kind, 3, 6, 5);
            let seq = run_sequential(&spec, &ds);
            for t in [2, 3, 4] {
                let par = run_threaded(&spec, &ds, t);
                for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "{kind:?} t={t} sample {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn one_thread_is_sequential() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::Loda, 3, 4, 1);
        assert_eq!(run_threaded(&spec, &ds, 1), run_sequential(&spec, &ds));
    }

    #[test]
    fn more_threads_than_subdetectors_is_clamped() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::RsHash, 3, 3, 1);
        let scores = run_threaded(&spec, &ds, 16);
        assert_eq!(scores.len(), 150);
        assert!(scores.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn uneven_partition_still_averages_correctly() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::XStream, 3, 7, 9); // 7 % 3 != 0
        let seq = run_sequential(&spec, &ds);
        let par = run_threaded(&spec, &ds, 3);
        for (a, b) in seq.iter().zip(&par) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
