//! CPU-baseline ensemble runners — the paper's GCC+pthread comparison
//! system (§4.4), reimplemented with std::thread.
//!
//! The sequential runner iterates sub-detectors in a loop (the paper's
//! single-thread case, Figures 12–14: time grows linearly with R); the
//! threaded runner partitions sub-detectors equally across threads with a
//! per-sample mutex + barrier synchronisation, reproducing the contention
//! behaviour of Figure 11.

pub mod threaded;

pub use threaded::run_threaded;

use crate::data::Dataset;
use crate::detectors::DetectorSpec;

/// Run the full ensemble on one thread; returns per-sample ensemble scores.
pub fn run_sequential(spec: &DetectorSpec, ds: &Dataset) -> Vec<f32> {
    let mut det = spec.build(ds.warmup(spec.window));
    det.run_stream(&ds.data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_profile, DatasetProfile};
    use crate::detectors::{DetectorKind, DetectorSpec};

    fn tiny_ds() -> Dataset {
        let p = DatasetProfile { name: "t", n: 200, d: 4, outliers: 10, clusters: 2 };
        generate_profile(&p, 1)
    }

    #[test]
    fn sequential_scores_whole_stream() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::Loda, 4, 8, 3);
        let scores = run_sequential(&spec, &ds);
        assert_eq!(scores.len(), 200);
        assert!(scores.iter().all(|s| s.is_finite()));
    }
}
