//! CPU ensemble runners: the paper's comparison baselines plus a lock-free
//! batched fast path.
//!
//! # Execution modes
//!
//! Three runners share one partitioning scheme (sub-detectors split equally
//! across workers) but differ in synchronisation:
//!
//! - [`run_sequential`] — one thread, sub-detectors in a loop (the paper's
//!   single-thread case, Figures 12–14: time grows linearly with R).
//! - [`run_threaded`] ([`ExecMode::LockStep`]) — the paper-faithful §4.4
//!   baseline: after *every sample* the partial scores are merged under a
//!   mutex and a barrier enforces streaming lock-step, reproducing the
//!   contention that caps Figure 11's speed-up at 4 threads. Kept verbatim
//!   so the Fig 11 reproduction never drifts.
//! - [`run_batched`] ([`ExecMode::Batched`]) — the fast path: each worker
//!   scores whole chunks through [`crate::detectors::Detector::update_batch`]
//!   into its own partial vector; no mutex, no barrier, one merge pass at
//!   the end. Numerically equivalent to `run_sequential` within 1e-4
//!   (property-tested); typically ≥ 3× faster than lock-step at 4 threads
//!   and, unlike it, it keeps scaling past 4 (see
//!   `benches/throughput_modes.rs` / `BENCH_throughput.json`).
//!
//! The batched runner's lane machinery (sub-detector slices + resident
//! worker pool + weighted merge) is factored into [`lanes`] and shared with
//! the fabric's multi-lane pblocks (`fabric::pblock`), where the same pool
//! stays alive across bursts and server sessions.

pub mod batched;
pub mod lanes;
pub mod threaded;

pub use batched::{run_batched, run_batched_chunked, DEFAULT_CHUNK};
pub use lanes::{Lane, LanePool};
pub use threaded::run_threaded;

use crate::data::Dataset;
use crate::detectors::DetectorSpec;

/// Execution strategy selector, shared by the CPU ensemble runners and the
/// fabric data plane: for the runners it picks lock-step vs lock-free
/// threading; for fabric pblocks it picks per-flit vs burst inbox
/// servicing (`fabric::pblock`). Routed through `[fabric] exec` in the
/// TOML config and `fsead --exec`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExecMode {
    /// Paper-faithful baseline: per-sample mutex merge + barrier in the
    /// runners (§4.4, Fig 11); one flit per RM invocation in the fabric.
    LockStep,
    /// The fast path: lock-free chunked workers / burst-drained pblock
    /// inboxes, amortising per-transfer overhead.
    Batched,
}

impl ExecMode {
    pub const ALL: [ExecMode; 2] = [ExecMode::LockStep, ExecMode::Batched];

    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::LockStep => "lockstep",
            ExecMode::Batched => "batched",
        }
    }

    pub fn parse(s: &str) -> Option<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "lockstep" | "lock-step" => Some(ExecMode::LockStep),
            "batched" | "batch" | "fast" => Some(ExecMode::Batched),
            _ => None,
        }
    }
}

/// Equal partition of `r` sub-detectors over `threads` workers (paper:
/// "equally distribute the same number of sub-detectors to each CPU
/// thread"). Shared by both multi-threaded runners so their partitions are
/// identical by construction — the batched/lock-step parity tests rely on
/// that.
pub(crate) fn partition_r(r: usize, threads: usize) -> Vec<(usize, usize)> {
    let base = r / threads;
    let extra = r % threads;
    let mut ranges = Vec::with_capacity(threads);
    let mut r0 = 0;
    for t in 0..threads {
        let len = base + usize::from(t < extra);
        ranges.push((r0, r0 + len));
        r0 += len;
    }
    ranges
}

/// Run the full ensemble on one thread; returns per-sample ensemble scores.
pub fn run_sequential(spec: &DetectorSpec, ds: &Dataset) -> Vec<f32> {
    let mut det = spec.build(ds.warmup(spec.window));
    det.run_stream(&ds.data)
}

/// Run with `threads` workers under the selected [`ExecMode`].
pub fn run_ensemble(spec: &DetectorSpec, ds: &Dataset, threads: usize, mode: ExecMode) -> Vec<f32> {
    match mode {
        ExecMode::LockStep => run_threaded(spec, ds, threads),
        ExecMode::Batched => run_batched(spec, ds, threads),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_profile, DatasetProfile};
    use crate::detectors::{DetectorKind, DetectorSpec};

    fn tiny_ds() -> Dataset {
        let p = DatasetProfile { name: "t", n: 200, d: 4, outliers: 10, clusters: 2 };
        generate_profile(&p, 1)
    }

    #[test]
    fn sequential_scores_whole_stream() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::Loda, 4, 8, 3);
        let scores = run_sequential(&spec, &ds);
        assert_eq!(scores.len(), 200);
        assert!(scores.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn exec_mode_dispatch_and_parse() {
        let ds = tiny_ds();
        let spec = DetectorSpec::new(DetectorKind::RsHash, 4, 6, 3);
        let seq = run_sequential(&spec, &ds);
        for mode in ExecMode::ALL {
            assert_eq!(ExecMode::parse(mode.as_str()), Some(mode));
            let scores = run_ensemble(&spec, &ds, 3, mode);
            for (a, b) in seq.iter().zip(&scores) {
                assert!((a - b).abs() < 1e-4, "{mode:?}: {a} vs {b}");
            }
        }
        assert_eq!(ExecMode::parse("fast"), Some(ExecMode::Batched));
        assert_eq!(ExecMode::parse("nope"), None);
    }
}
