//! Model-combination methods (paper Table 2): Averaging, Maximization and
//! Weighted Average for continuous scores; OR and majority Voting for
//! binary labels. These are the native-rust counterparts of the combo-RM
//! artifacts (`combo_*.hlo.txt`) and are used by the CPU baseline and as a
//! software fallback inside combo pblocks.

/// Score combination methods (general & global, §2.2).
#[derive(Clone, Debug, PartialEq)]
pub enum ScoreCombiner {
    /// GG_A: arithmetic mean.
    Averaging,
    /// GG_M: element-wise maximum.
    Maximization,
    /// GG_WA: weighted mean; weights are renormalised over present inputs.
    WeightedAverage(Vec<f32>),
}

impl ScoreCombiner {
    /// Combine `inputs[k][i]` (k streams × n samples) into one score stream.
    pub fn combine(&self, inputs: &[&[f32]]) -> Vec<f32> {
        assert!(!inputs.is_empty());
        let n = inputs[0].len();
        assert!(inputs.iter().all(|s| s.len() == n), "misaligned score streams");
        match self {
            ScoreCombiner::Averaging => (0..n)
                .map(|i| inputs.iter().map(|s| s[i]).sum::<f32>() / inputs.len() as f32)
                .collect(),
            ScoreCombiner::Maximization => (0..n)
                .map(|i| inputs.iter().map(|s| s[i]).fold(f32::NEG_INFINITY, f32::max))
                .collect(),
            ScoreCombiner::WeightedAverage(w) => {
                assert!(w.len() >= inputs.len(), "need one weight per input");
                let tot: f32 = w[..inputs.len()].iter().sum();
                let tot = if tot.abs() < 1e-12 { 1.0 } else { tot };
                (0..n)
                    .map(|i| {
                        inputs.iter().zip(w).map(|(s, &wi)| s[i] * wi).sum::<f32>() / tot
                    })
                    .collect()
            }
        }
    }

    pub fn parse(s: &str) -> Option<ScoreCombiner> {
        match s.to_ascii_lowercase().as_str() {
            "avg" | "averaging" | "gg_a" => Some(ScoreCombiner::Averaging),
            "max" | "maximization" | "gg_m" => Some(ScoreCombiner::Maximization),
            "wavg" | "weighted" | "gg_wa" => Some(ScoreCombiner::WeightedAverage(vec![])),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ScoreCombiner::Averaging => "avg",
            ScoreCombiner::Maximization => "max",
            ScoreCombiner::WeightedAverage(_) => "wavg",
        }
    }
}

/// Label combination methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LabelCombiner {
    /// Anomaly if any input says anomaly (the paper's default for labels).
    Or,
    /// Majority vote; ties resolve to anomaly (don't-miss bias, §4.2).
    Voting,
}

impl LabelCombiner {
    pub fn combine(&self, inputs: &[&[bool]]) -> Vec<bool> {
        assert!(!inputs.is_empty());
        let n = inputs[0].len();
        assert!(inputs.iter().all(|s| s.len() == n), "misaligned label streams");
        match self {
            LabelCombiner::Or => (0..n).map(|i| inputs.iter().any(|s| s[i])).collect(),
            LabelCombiner::Voting => (0..n)
                .map(|i| {
                    let votes = inputs.iter().filter(|s| s[i]).count();
                    2 * votes >= inputs.len()
                })
                .collect(),
        }
    }

    pub fn parse(s: &str) -> Option<LabelCombiner> {
        match s.to_ascii_lowercase().as_str() {
            "or" => Some(LabelCombiner::Or),
            "vote" | "voting" => Some(LabelCombiner::Voting),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averaging_is_mean() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        assert_eq!(ScoreCombiner::Averaging.combine(&[&a, &b]), vec![2.0, 3.0]);
    }

    #[test]
    fn maximization_is_elementwise_max() {
        let a = [1.0f32, 5.0];
        let b = [3.0f32, 4.0];
        assert_eq!(ScoreCombiner::Maximization.combine(&[&a, &b]), vec![3.0, 5.0]);
    }

    #[test]
    fn weighted_average_renormalises() {
        let a = [1.0f32];
        let b = [3.0f32];
        let c = ScoreCombiner::WeightedAverage(vec![0.75, 0.25]);
        assert_eq!(c.combine(&[&a, &b]), vec![1.5]);
    }

    #[test]
    fn weighted_equal_weights_matches_avg() {
        let a = [0.5f32, 1.0];
        let b = [1.5f32, 3.0];
        let w = ScoreCombiner::WeightedAverage(vec![0.5, 0.5]);
        assert_eq!(w.combine(&[&a, &b]), ScoreCombiner::Averaging.combine(&[&a, &b]));
    }

    #[test]
    fn or_is_any() {
        let a = [true, false, false];
        let b = [false, false, true];
        assert_eq!(LabelCombiner::Or.combine(&[&a, &b]), vec![true, false, true]);
    }

    #[test]
    fn voting_majority_with_anomaly_ties() {
        let a = [true, true, false];
        let b = [false, true, false];
        // tie (1/2) → anomaly; 2/2 → anomaly; 0/2 → normal
        assert_eq!(LabelCombiner::Voting.combine(&[&a, &b]), vec![true, true, false]);
    }

    #[test]
    fn single_input_is_identity() {
        let a = [0.1f32, 0.9];
        assert_eq!(ScoreCombiner::Averaging.combine(&[&a]), a.to_vec());
        let l = [true, false];
        assert_eq!(LabelCombiner::Voting.combine(&[&l]), l.to_vec());
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(ScoreCombiner::parse("avg"), Some(ScoreCombiner::Averaging));
        assert_eq!(LabelCombiner::parse("or"), Some(LabelCombiner::Or));
        assert_eq!(ScoreCombiner::parse("bogus"), None);
    }
}
