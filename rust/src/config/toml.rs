//! Minimal TOML-subset parser (serde/toml are unavailable offline —
//! DESIGN.md §6 substitution 4). Supports what fSEAD configs need:
//! `[section]` and `[section.sub]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, comments and blank lines.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path section name → key → value.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    /// Keys at the root (before any section header) live under "".
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key)?.as_int()
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_float()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }

    /// All section names with the given prefix (e.g. every `[pblock.*]`).
    pub fn sections_with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        self.sections.keys().filter(move |s| s.starts_with(prefix)).map(|s| s.as_str())
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut current = String::new();
    doc.sections.entry(current.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            let name = name.trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            current = name.to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected `key = value`", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.sections.get_mut(&current).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string: {s}");
        };
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            bail!("unterminated array: {s}");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>> =
            split_top_level(inner).into_iter().map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s}")
}

/// Split on commas that are not inside quotes (flat arrays only).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(doc.get_int("", "a"), Some(1));
        assert_eq!(doc.get_float("", "b"), Some(2.5));
        assert_eq!(doc.get_str("", "c"), Some("hi"));
        assert_eq!(doc.get_bool("", "d"), Some(true));
    }

    #[test]
    fn parses_sections_and_subsections() {
        let doc = parse("[fabric]\npblocks = 7\n[pblock.1]\nkind = \"loda\"\n").unwrap();
        assert_eq!(doc.get_int("fabric", "pblocks"), Some(7));
        assert_eq!(doc.get_str("pblock.1", "kind"), Some("loda"));
        let subs: Vec<_> = doc.sections_with_prefix("pblock.").collect();
        assert_eq!(subs, vec!["pblock.1"]);
    }

    #[test]
    fn parses_arrays() {
        let doc = parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\n").unwrap();
        let xs = doc.get("", "xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_int(), Some(3));
        let ys = doc.get("", "ys").unwrap().as_array().unwrap();
        assert_eq!(ys[1].as_str(), Some("b"));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = parse("# header\n\na = 1 # trailing\ns = \"with # hash\"\n").unwrap();
        assert_eq!(doc.get_int("", "a"), Some(1));
        assert_eq!(doc.get_str("", "s"), Some("with # hash"));
    }

    #[test]
    fn int_coerces_to_float_but_not_reverse() {
        let doc = parse("a = 3\nb = 1.5\n").unwrap();
        assert_eq!(doc.get_float("", "a"), Some(3.0));
        assert_eq!(doc.get_int("", "b"), None);
    }

    #[test]
    fn errors_are_reported_with_line_numbers() {
        let err = parse("a = 1\nbroken line\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("k = \"open\n").is_err());
        assert!(parse("k = [1, 2\n").is_err());
    }

    #[test]
    fn empty_doc_is_fine() {
        let doc = parse("").unwrap();
        assert!(doc.get("", "x").is_none());
    }
}
