//! Configuration system: a TOML-subset file describes the fabric topology
//! (which RM occupies each pblock, which stream feeds it, how combos
//! aggregate), detector hyper-parameters and the dataset. Presets reproduce
//! the paper's Figure 7 composition examples.
//!
//! # Knob-naming convention
//!
//! Quantities carry their unit as a suffix so a config file reads without
//! the reference open: durations are `*_ms` (`open_timeout_ms`,
//! `stall_timeout_ms`), flit-cadenced counters are `*_flits`
//! (`idle_evict_flits`, `cooldown_flits`, `checkpoint_every_flits`),
//! per-volume rates name the volume (`rate_per_kflit`), and record counts
//! are `*_records`. Unsuffixed numbers are unitless (slots, sizes, ids).
//! New sections — `[fabric.operator]` included — follow the same rule.

pub mod toml;

use anyhow::{bail, Context, Result};
use toml::Doc;

use crate::defaults;
use crate::detectors::DetectorKind;
use crate::ensemble::ExecMode;

/// What occupies a reconfigurable partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RmKind {
    /// A detector ensemble RM.
    Detector(DetectorKind),
    /// Identity/bypass RM (paper Fig 20).
    Bypass,
    /// Default empty RM (power saving until configured, §3.2).
    Empty,
}

impl RmKind {
    pub fn parse(s: &str) -> Option<RmKind> {
        match s.to_ascii_lowercase().as_str() {
            "bypass" | "identity" => Some(RmKind::Bypass),
            "empty" | "default" => Some(RmKind::Empty),
            other => DetectorKind::parse(other).map(RmKind::Detector),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RmKind::Detector(k) => k.as_str(),
            RmKind::Bypass => "bypass",
            RmKind::Empty => "empty",
        }
    }
}

/// What the shell does with a pblock's traffic during the DFX dark window
/// (the Table-13 bitstream-download interval while the region is isolated).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DarkPolicy {
    /// Emit zero-score placeholder flits so downstream framing (combo
    /// joins, output DMAs) stays sample-aligned across the swap. Default.
    Bypass,
    /// Drop the flits at the decoupler (the raw isolation behaviour); the
    /// pblock's output stream is shorter by the dark window.
    Drop,
}

impl DarkPolicy {
    pub fn parse(s: &str) -> Option<DarkPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "bypass" => Some(DarkPolicy::Bypass),
            "drop" => Some(DarkPolicy::Drop),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DarkPolicy::Bypass => "bypass",
            DarkPolicy::Drop => "drop",
        }
    }
}

/// One detector choice in the adaptive controller's pool: a kind plus an
/// ensemble size (`r = 0` means the paper's per-pblock default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolEntry {
    pub kind: DetectorKind,
    pub r: usize,
}

impl PoolEntry {
    /// Parse `"loda"` or `"loda:8"`.
    pub fn parse(s: &str) -> Option<PoolEntry> {
        let (kind, r) = match s.split_once(':') {
            Some((k, r)) => (k, r.trim().parse().ok()?),
            None => (s, 0),
        };
        Some(PoolEntry { kind: DetectorKind::parse(kind.trim())?, r })
    }
}

/// One scripted hot-swap: at pblock-input flit `at_flit` of the next run,
/// replace the RM in `pblock` with `rm`.
#[derive(Clone, Copy, Debug)]
pub struct ScriptedSwap {
    pub pblock: usize,
    pub at_flit: u64,
    pub rm: RmKind,
    pub r: usize,
    /// Dark-window length in flits; None derives it from the Table-13
    /// model at `DfxCfg::samples_per_sec`.
    pub dark_flits: Option<u64>,
}

/// Live-DFX configuration (`[fabric.dfx]` + `[fabric.dfx.swap.N]`).
#[derive(Clone, Debug)]
pub struct DfxCfg {
    /// Run the adaptive reconfiguration controller during `Fabric::run`.
    pub adaptive: bool,
    /// Dark-window traffic handling.
    pub policy: DarkPolicy,
    /// Modelled stream rate used to convert the Table-13 download latency
    /// into a dark window measured in flits.
    pub samples_per_sec: f64,
    /// Sliding window (scores) the drift detector compares against the
    /// baseline.
    pub window: usize,
    /// Scores used to establish the per-pblock baseline statistics.
    pub baseline: usize,
    /// Drift threshold in baseline standard deviations.
    pub threshold: f64,
    /// Minimum flits between adaptive swaps of the same pblock.
    pub cooldown_flits: u64,
    /// Detector pool the controller cycles through on drift.
    pub pool: Vec<PoolEntry>,
    /// Scripted swap schedule, armed at fabric construction.
    pub swaps: Vec<ScriptedSwap>,
}

impl Default for DfxCfg {
    fn default() -> Self {
        DfxCfg {
            adaptive: false,
            policy: DarkPolicy::Bypass,
            samples_per_sec: 100_000.0,
            window: 128,
            baseline: 256,
            threshold: 4.0,
            cooldown_flits: 256,
            pool: vec![],
            swaps: vec![],
        }
    }
}

/// What the input DMA does with non-finite sample values (NaN/±Inf) at
/// ingress (`[fabric] non_finite`). Corrupt input is the most common
/// real-world fault; screening it at the DMA keeps garbage out of every
/// detector window at once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NonFinite {
    /// Refuse the stream: the run fails with a diagnostic naming the first
    /// offending sample. Default — silent corruption is worse than a stop.
    Error,
    /// Sanitize in place: NaN → 0.0, ±Inf → ±f32::MAX.
    Clamp,
}

impl NonFinite {
    pub fn parse(s: &str) -> Option<NonFinite> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(NonFinite::Error),
            "clamp" => Some(NonFinite::Clamp),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            NonFinite::Error => "error",
            NonFinite::Clamp => "clamp",
        }
    }
}

/// Fault kinds accepted in `[fabric.faults.inject.N]` — kept as strings
/// here (converted by `fabric::faults::InjectedFault::from_spec`) so the
/// config layer stays free of fabric types.
pub const FAULT_KINDS: [&str; 5] =
    ["lane_panic", "worker_exit", "state_corrupt", "stall", "inbox_stall"];

/// One scripted fault injection (`[fabric.faults.inject.N]`).
#[derive(Clone, Debug)]
pub struct InjectSpec {
    /// Injection id, echoed in every event it produces (defaults to the
    /// section suffix `N`).
    pub id: String,
    /// Target partition (1-based pblock id).
    pub pblock: usize,
    /// Partition-input flit at which the fault fires.
    pub at_flit: u64,
    /// One of [`FAULT_KINDS`].
    pub kind: String,
    /// Lane (for `lane_panic`) or worker (for `worker_exit`) index.
    pub lane: usize,
    /// Stall duration in milliseconds (for `stall` / `inbox_stall`).
    pub ms: u64,
}

/// Fault-injection + recovery configuration (`[fabric.faults]`). Entirely
/// off by default: with `enabled = false` none of the fault hooks in the
/// data plane run and the fabric is bit-transparent to this section.
#[derive(Clone, Debug)]
pub struct FaultsCfg {
    /// Master switch (also raised by `fsead --faults`).
    pub enabled: bool,
    /// Seed for the pseudo-random injection plan (0 = derive from the
    /// fabric seed).
    pub seed: u64,
    /// Background fault rate per partition, in faults per 1000 input
    /// flits (0 = scripted injections only).
    pub rate_per_kflit: f64,
    /// Checkpoint the detector state every N healthy flits (0 = never; a
    /// rung-1 reload then cold-starts instead of resuming).
    pub checkpoint_every_flits: u64,
    /// Duration of randomly planned stall faults.
    pub stall_ms: u64,
    /// Heartbeat watchdog: a partition stuck *processing* longer than this
    /// is flagged as stalled.
    pub stall_timeout_ms: u64,
    /// Rung-1 reloads per partition before rung 2 quarantines it.
    pub max_reloads: u32,
    /// Base backoff before a reload; doubles per successive reload.
    pub backoff_ms: u64,
    /// Dark-window override for supervisor reloads (None = Table-13 model,
    /// same as a planned swap).
    pub dark_flits: Option<u64>,
    /// How long the service loop blocks waiting for a requested reload to
    /// be staged before carrying on degraded.
    pub reload_wait_ms: u64,
    /// Scripted injections (`[fabric.faults.inject.N]`).
    pub injections: Vec<InjectSpec>,
}

impl Default for FaultsCfg {
    fn default() -> Self {
        FaultsCfg {
            enabled: false,
            seed: 0,
            rate_per_kflit: 0.0,
            checkpoint_every_flits: 8,
            stall_ms: 20,
            stall_timeout_ms: 10,
            max_reloads: 2,
            backoff_ms: 1,
            dark_flits: None,
            reload_wait_ms: 100,
            injections: vec![],
        }
    }
}

/// What `open()` does when every compatible partition slot is taken.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Queue on the admission condvar (bounded by `max_waiters`).
    Block,
    /// Refuse immediately with a typed `AdmitError::Saturated` — a
    /// saturated server degrades by shedding load, never by parking
    /// clients on the condvar.
    Shed,
}

impl OverloadPolicy {
    pub fn parse(s: &str) -> Option<OverloadPolicy> {
        match s {
            "block" => Some(OverloadPolicy::Block),
            "shed" => Some(OverloadPolicy::Shed),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            OverloadPolicy::Block => "block",
            OverloadPolicy::Shed => "shed",
        }
    }
}

/// Streaming-session server configuration (`[fabric.server]`), consumed by
/// [`crate::fabric::server::FabricServer`] and the `fsead serve` CLI.
#[derive(Clone, Debug)]
pub struct ServerCfg {
    /// Depth, in flits, of each session's bounded inbox — the backpressure
    /// window between a client's `push` and the partition's service loop. A
    /// full inbox blocks the producer; flits are never dropped or reordered.
    pub inbox_flits: usize,
    /// Maximum clients allowed to wait in the admission queue (all
    /// partitions busy) before `open` refuses instead of queueing.
    pub max_waiters: usize,
    /// Sessions one partition may interleave (K). 1 = the dedicated
    /// one-session-per-partition plane; K > 1 selects the multiplexing
    /// service loop (round-robin over per-session inboxes, per-session RM
    /// state swapped through the snapshot codec).
    pub sessions_per_partition: usize,
    /// Idle-eviction threshold, in partition service ticks: a session
    /// whose inbox stays empty this long is checkpointed into the session
    /// store (LRU first) and its slot freed. 0 disables eviction.
    pub idle_evict_flits: u64,
    /// Admission deadline for `open()`/`resume()` in milliseconds; a
    /// client still queued when it expires gets a typed timeout error
    /// instead of blocking forever. 0 = wait indefinitely.
    pub open_timeout_ms: u64,
    /// Overload behaviour when all slots are busy: queue or shed.
    pub overload: OverloadPolicy,
    /// Durable score sink: append every output flit's scores to this file
    /// as length-prefixed, CRC-framed records. `None` disables the sink.
    pub sink_path: Option<String>,
    /// fsync the score sink every N records (1 = after every record).
    pub sink_fsync_records: usize,
    /// Directory suspended-session tickets are spilled to (and resumable
    /// from, including by a fresh process). `None` keeps tickets in memory
    /// with the caller only.
    pub spill_dir: Option<String>,
    /// When fault injection quarantines a dedicated partition, checkpoint
    /// its session into the session store (for `resume` elsewhere) instead
    /// of failing it in place. Off by default — quarantine behaviour is
    /// then identical to earlier releases.
    pub evict_quarantined: bool,
    /// First session id this server assigns (`fsead net --session-base`).
    /// A router fronting N workers gives each a distinct base (e.g.
    /// `i << 32`) so session ids — the consistent-hashing key and the
    /// resume duplicate-detection key — never collide across processes.
    /// 0 (the default) is bit-transparent to earlier releases.
    pub session_id_base: u64,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            inbox_flits: 64,
            max_waiters: 64,
            sessions_per_partition: 1,
            idle_evict_flits: 0,
            open_timeout_ms: 0,
            overload: OverloadPolicy::Block,
            sink_path: None,
            sink_fsync_records: 32,
            spill_dir: None,
            evict_quarantined: false,
            session_id_base: 0,
        }
    }
}

/// Operator-plane configuration (`[fabric.operator]`): the live
/// `/metrics` + run-control HTTP listener served next to `fsead serve`
/// (see [`crate::fabric::operator`]). Disabled by default — with the plane
/// off the server is bit-transparent.
#[derive(Clone, Debug)]
pub struct OperatorCfg {
    /// Start the operator listener alongside the fabric server.
    pub enabled: bool,
    /// Listen address, e.g. `127.0.0.1:9091` (port 0 picks a free port).
    pub addr: String,
    /// Optional bearer token; when set, every request must carry
    /// `Authorization: Bearer <token>`.
    pub auth_token: Option<String>,
}

impl Default for OperatorCfg {
    fn default() -> Self {
        OperatorCfg { enabled: false, addr: "127.0.0.1:9091".into(), auth_token: None }
    }
}

/// Network serving plane configuration (`[fabric.net]`): the `fsead net`
/// TCP listener speaking the length-prefixed session frame protocol
/// (see [`crate::fabric::net`]). Disabled by default.
#[derive(Clone, Debug)]
pub struct NetCfg {
    /// Start the network listener alongside the fabric server.
    pub enabled: bool,
    /// Listen address, e.g. `127.0.0.1:9191` (port 0 picks a free port).
    pub addr: String,
    /// Concurrent-connection cap; connections past it are refused with a
    /// `server_busy` status frame instead of spawning a handler.
    pub max_connections: usize,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg { enabled: false, addr: "127.0.0.1:9191".into(), max_connections: 256 }
    }
}

/// Session-router configuration (`[fabric.router]`): the `fsead route`
/// process that shards sessions across N downstream `fsead net` workers by
/// consistent hashing on session id and keeps streams alive through worker
/// join/leave/death (see [`crate::fabric::router`]). Disabled by default —
/// with the router off, clients speak to a worker directly and nothing in
/// the wire protocol changes.
#[derive(Clone, Debug)]
pub struct RouterCfg {
    /// Run the router (only meaningful to `fsead route` / config-driven
    /// deployments; the fabric server itself never starts one).
    pub enabled: bool,
    /// Router listen address, e.g. `127.0.0.1:9290` (port 0 picks a port).
    pub addr: String,
    /// Downstream `fsead net` worker addresses (`workers = ["host:port", …]`).
    pub workers: Vec<String>,
    /// Concurrent client-connection cap, as `[fabric.net] max_connections`.
    pub max_connections: usize,
    /// Health-probe cadence in milliseconds (0 disables the prober; worker
    /// death is then only detected on forward errors).
    pub heartbeat_ms: u64,
    /// Consecutive probe/forward failures before a worker is ejected from
    /// the ring.
    pub max_failures: u32,
    /// Pushes between router-held ticket checkpoints — the replay window
    /// that bounds both recovery cost and worst-case loss.
    pub checkpoint_pushes: u64,
    /// Soft cap, in bytes, on the per-session replay buffer; crossing it
    /// forces an early checkpoint (and, if checkpointing keeps failing,
    /// bounded loss reported as `resume_gap`).
    pub replay_cap_bytes: usize,
    /// Worker TCP connect timeout in milliseconds.
    pub connect_timeout_ms: u64,
    /// Worker socket read/write timeout in milliseconds (0 = none). A
    /// wedged worker trips this and is treated as failed.
    pub io_timeout_ms: u64,
    /// Total retry budget (connect + resume + replay) per recovery, in
    /// milliseconds, before the router moves to the next candidate worker.
    pub retry_deadline_ms: u64,
    /// First back-off delay between retries, in milliseconds (doubles up
    /// to the deadline).
    pub backoff_base_ms: u64,
}

impl Default for RouterCfg {
    fn default() -> Self {
        RouterCfg {
            enabled: false,
            addr: "127.0.0.1:9290".into(),
            workers: Vec::new(),
            max_connections: 256,
            heartbeat_ms: 250,
            max_failures: 3,
            checkpoint_pushes: 8,
            replay_cap_bytes: 4 << 20,
            connect_timeout_ms: 1_000,
            io_timeout_ms: 5_000,
            retry_deadline_ms: 3_000,
            backoff_base_ms: 10,
        }
    }
}

/// Detector hyper-parameters (paper Table 4).
#[derive(Clone, Copy, Debug)]
pub struct DetectorHyper {
    pub window: usize,
    pub bins: usize,
    pub w: usize,
    pub modulus: usize,
    pub k: usize,
}

impl Default for DetectorHyper {
    fn default() -> Self {
        DetectorHyper {
            window: defaults::WINDOW,
            bins: defaults::LODA_BINS,
            w: defaults::CMS_ROWS,
            modulus: defaults::CMS_MOD,
            k: defaults::XSTREAM_K,
        }
    }
}

/// One pblock assignment.
#[derive(Clone, Debug)]
pub struct PblockCfg {
    /// 1-based pblock id (RP-1 … RP-7).
    pub id: usize,
    pub rm: RmKind,
    /// Ensemble size (defaults to the paper's per-pblock R).
    pub r: usize,
    /// Which input stream (DMA channel) feeds this pblock.
    pub stream: usize,
    /// Detector instances placed in this partition (paper §4 "multiple
    /// instances can be placed within a pblock"): the RM becomes `lanes`
    /// sub-detector slices scored by resident lane workers. `0` inherits
    /// the `[fabric] lanes` default; the effective count is clamped to the
    /// RM's ensemble size. CPU-native detector RMs only.
    pub lanes: usize,
}

/// One combo-pblock assignment.
#[derive(Clone, Debug)]
pub struct ComboCfg {
    /// 1-based combo id (COMBO1 … COMBO3).
    pub id: usize,
    /// avg | max | wavg (scores) — label combining is configured separately.
    pub method: String,
    /// AD pblock ids whose score streams feed this combo (max 4 — the
    /// paper's combo pblocks have four input ports).
    pub inputs: Vec<usize>,
    /// Weights for wavg.
    pub weights: Vec<f32>,
}

/// Dataset selection.
#[derive(Clone, Debug)]
pub struct DatasetCfg {
    pub name: String,
    pub data_dir: Option<String>,
    /// 0 = the full stream.
    pub max_samples: usize,
}

/// Top-level configuration.
#[derive(Clone, Debug)]
pub struct FseadConfig {
    pub seed: u64,
    pub chunk: usize,
    pub artifact_dir: String,
    /// Execute detector RMs on the PJRT "FPGA" (false = CPU-native RMs,
    /// useful for fast tests and the CPU baseline comparison).
    pub use_fpga: bool,
    /// How pblocks drain their inboxes: `Batched` (burst servicing, the
    /// production fast path — default) or `LockStep` (paper-faithful
    /// per-flit loop). TOML: `exec = "batched" | "lockstep"` in `[fabric]`.
    pub exec: ExecMode,
    /// Default lane count per pblock partition (intra-partition instance
    /// parallelism). `1` = the single-lane data plane. Overridable per
    /// pblock via `PblockCfg::lanes` / `[pblock.N] lanes`, and from the CLI
    /// with `fsead --lanes`. TOML: `lanes = N` in `[fabric]`.
    pub lanes: usize,
    pub hyper: DetectorHyper,
    pub dataset: DatasetCfg,
    pub pblocks: Vec<PblockCfg>,
    pub combos: Vec<ComboCfg>,
    /// Live-DFX: dark-window policy, scripted swap schedule, adaptive
    /// controller settings.
    pub dfx: DfxCfg,
    /// Streaming-session server settings (`[fabric.server]`).
    pub server: ServerCfg,
    /// Operator plane: `/metrics` + run-control API (`[fabric.operator]`).
    pub operator: OperatorCfg,
    /// Network serving plane: the `fsead net` frame protocol (`[fabric.net]`).
    pub net: NetCfg,
    /// Session router: `fsead route` sharding over workers (`[fabric.router]`).
    pub router: RouterCfg,
    /// Fault injection + supervised recovery (`[fabric.faults]`).
    pub faults: FaultsCfg,
    /// Ingress policy for non-finite sample values (`[fabric] non_finite`).
    pub non_finite: NonFinite,
}

impl Default for FseadConfig {
    fn default() -> Self {
        FseadConfig {
            seed: 42,
            chunk: defaults::CHUNK,
            artifact_dir: "artifacts".to_string(),
            use_fpga: true,
            exec: ExecMode::Batched,
            lanes: 1,
            hyper: DetectorHyper::default(),
            dataset: DatasetCfg { name: "cardio".into(), data_dir: None, max_samples: 0 },
            pblocks: vec![],
            combos: vec![],
            dfx: DfxCfg::default(),
            server: ServerCfg::default(),
            operator: OperatorCfg::default(),
            net: NetCfg::default(),
            router: RouterCfg::default(),
            faults: FaultsCfg::default(),
            non_finite: NonFinite::Error,
        }
    }
}

impl FseadConfig {
    pub fn from_str(text: &str) -> Result<FseadConfig> {
        let doc = toml::parse(text)?;
        Self::from_doc(&doc)
    }

    pub fn from_file(path: &str) -> Result<FseadConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_str(&text).with_context(|| format!("parsing {path}"))
    }

    fn from_doc(doc: &Doc) -> Result<FseadConfig> {
        let mut cfg = FseadConfig::default();
        if let Some(v) = doc.get_int("fabric", "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_int("fabric", "chunk") {
            cfg.chunk = v as usize;
        }
        if let Some(v) = doc.get_str("fabric", "artifacts") {
            cfg.artifact_dir = v.to_string();
        }
        if let Some(v) = doc.get_bool("fabric", "use_fpga") {
            cfg.use_fpga = v;
        }
        if let Some(v) = doc.get_str("fabric", "exec") {
            cfg.exec = ExecMode::parse(v)
                .with_context(|| format!("[fabric]: unknown exec mode {v:?}"))?;
        }
        if let Some(v) = doc.get_int("fabric", "lanes") {
            if v <= 0 {
                bail!("[fabric]: lanes must be >= 1 (got {v})");
            }
            cfg.lanes = v as usize;
        }
        if let Some(v) = doc.get_str("fabric", "non_finite") {
            cfg.non_finite = NonFinite::parse(v)
                .with_context(|| format!("[fabric]: unknown non_finite policy {v:?}"))?;
        }
        if let Some(v) = doc.get_int("detector", "window") {
            cfg.hyper.window = v as usize;
        }
        if let Some(v) = doc.get_int("detector", "bins") {
            cfg.hyper.bins = v as usize;
        }
        if let Some(v) = doc.get_int("detector", "cms_rows") {
            cfg.hyper.w = v as usize;
        }
        if let Some(v) = doc.get_int("detector", "cms_mod") {
            cfg.hyper.modulus = v as usize;
        }
        if let Some(v) = doc.get_int("detector", "k") {
            cfg.hyper.k = v as usize;
        }
        if let Some(v) = doc.get_str("dataset", "name") {
            cfg.dataset.name = v.to_string();
        }
        if let Some(v) = doc.get_str("dataset", "data_dir") {
            if !v.is_empty() {
                cfg.dataset.data_dir = Some(v.to_string());
            }
        }
        if let Some(v) = doc.get_int("dataset", "max_samples") {
            cfg.dataset.max_samples = v as usize;
        }
        // [fabric.server] — streaming-session server. Negative values would
        // wrap through `as usize` into effectively-unbounded queues, so
        // they are rejected here rather than silently accepted.
        if let Some(v) = doc.get_int("fabric.server", "inbox_flits") {
            if v <= 0 {
                bail!("[fabric.server]: inbox_flits must be positive (got {v})");
            }
            cfg.server.inbox_flits = v as usize;
        }
        if let Some(v) = doc.get_int("fabric.server", "max_waiters") {
            if v < 0 {
                bail!("[fabric.server]: max_waiters must be >= 0 (got {v})");
            }
            cfg.server.max_waiters = v as usize;
        }
        if let Some(v) = doc.get_int("fabric.server", "sessions_per_partition") {
            if v <= 0 {
                bail!("[fabric.server]: sessions_per_partition must be >= 1 (got {v})");
            }
            cfg.server.sessions_per_partition = v as usize;
        }
        if let Some(v) = doc.get_int("fabric.server", "idle_evict_flits") {
            if v < 0 {
                bail!("[fabric.server]: idle_evict_flits must be >= 0 (got {v})");
            }
            cfg.server.idle_evict_flits = v as u64;
        }
        if let Some(v) = doc.get_int("fabric.server", "open_timeout_ms") {
            if v < 0 {
                bail!("[fabric.server]: open_timeout_ms must be >= 0 (got {v})");
            }
            cfg.server.open_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_str("fabric.server", "overload") {
            cfg.server.overload = OverloadPolicy::parse(v).with_context(|| {
                format!("[fabric.server]: unknown overload policy {v:?} (block | shed)")
            })?;
        }
        if let Some(v) = doc.get_str("fabric.server", "sink_path") {
            if !v.is_empty() {
                cfg.server.sink_path = Some(v.to_string());
            }
        }
        if let Some(v) = doc.get_int("fabric.server", "sink_fsync_records") {
            if v <= 0 {
                bail!("[fabric.server]: sink_fsync_records must be >= 1 (got {v})");
            }
            cfg.server.sink_fsync_records = v as usize;
        }
        if let Some(v) = doc.get_str("fabric.server", "spill_dir") {
            if !v.is_empty() {
                cfg.server.spill_dir = Some(v.to_string());
            }
        }
        if let Some(v) = doc.get_bool("fabric.server", "evict_quarantined") {
            cfg.server.evict_quarantined = v;
        }
        if let Some(v) = doc.get_int("fabric.server", "session_id_base") {
            if v < 0 {
                bail!("[fabric.server]: session_id_base must be >= 0 (got {v})");
            }
            cfg.server.session_id_base = v as u64;
        }
        // [fabric.operator] — the /metrics + run-control listener
        if let Some(v) = doc.get_bool("fabric.operator", "enabled") {
            cfg.operator.enabled = v;
        }
        if let Some(v) = doc.get_str("fabric.operator", "addr") {
            if v.is_empty() {
                bail!("[fabric.operator]: addr must not be empty (host:port, e.g. 127.0.0.1:9091)");
            }
            if !v.contains(':') {
                bail!("[fabric.operator]: addr needs a port (host:port, got {v:?})");
            }
            cfg.operator.addr = v.to_string();
        }
        if let Some(v) = doc.get_str("fabric.operator", "auth_token") {
            if v.is_empty() {
                bail!(
                    "[fabric.operator]: auth_token must not be empty — omit the key \
                     to serve without auth"
                );
            }
            cfg.operator.auth_token = Some(v.to_string());
        }
        // [fabric.net] — the session frame-protocol listener
        if let Some(v) = doc.get_bool("fabric.net", "enabled") {
            cfg.net.enabled = v;
        }
        if let Some(v) = doc.get_str("fabric.net", "addr") {
            if v.is_empty() {
                bail!("[fabric.net]: addr must not be empty (host:port, e.g. 127.0.0.1:9191)");
            }
            if !v.contains(':') {
                bail!("[fabric.net]: addr needs a port (host:port, got {v:?})");
            }
            cfg.net.addr = v.to_string();
        }
        if let Some(v) = doc.get_int("fabric.net", "max_connections") {
            if v <= 0 {
                bail!("[fabric.net]: max_connections must be >= 1 (got {v})");
            }
            cfg.net.max_connections = v as usize;
        }
        // [fabric.router] — session sharding over worker processes
        if let Some(v) = doc.get_bool("fabric.router", "enabled") {
            cfg.router.enabled = v;
        }
        if let Some(v) = doc.get_str("fabric.router", "addr") {
            if v.is_empty() {
                bail!("[fabric.router]: addr must not be empty (host:port, e.g. 127.0.0.1:9290)");
            }
            if !v.contains(':') {
                bail!("[fabric.router]: addr needs a port (host:port, got {v:?})");
            }
            cfg.router.addr = v.to_string();
        }
        if let Some(arr) = doc.get("fabric.router", "workers").and_then(|v| v.as_array()) {
            for v in arr {
                let s = v
                    .as_str()
                    .context("[fabric.router]: workers entries are \"host:port\" strings")?;
                if !s.contains(':') {
                    bail!("[fabric.router]: worker address needs a port (host:port, got {s:?})");
                }
                cfg.router.workers.push(s.to_string());
            }
        }
        if let Some(v) = doc.get_int("fabric.router", "max_connections") {
            if v <= 0 {
                bail!("[fabric.router]: max_connections must be >= 1 (got {v})");
            }
            cfg.router.max_connections = v as usize;
        }
        if let Some(v) = doc.get_int("fabric.router", "heartbeat_ms") {
            if v < 0 {
                bail!("[fabric.router]: heartbeat_ms must be >= 0 (got {v})");
            }
            cfg.router.heartbeat_ms = v as u64;
        }
        if let Some(v) = doc.get_int("fabric.router", "max_failures") {
            if v <= 0 {
                bail!("[fabric.router]: max_failures must be >= 1 (got {v})");
            }
            cfg.router.max_failures = v as u32;
        }
        if let Some(v) = doc.get_int("fabric.router", "checkpoint_pushes") {
            if v <= 0 {
                bail!("[fabric.router]: checkpoint_pushes must be >= 1 (got {v})");
            }
            cfg.router.checkpoint_pushes = v as u64;
        }
        if let Some(v) = doc.get_int("fabric.router", "replay_cap_bytes") {
            if v <= 0 {
                bail!("[fabric.router]: replay_cap_bytes must be >= 1 (got {v})");
            }
            cfg.router.replay_cap_bytes = v as usize;
        }
        if let Some(v) = doc.get_int("fabric.router", "connect_timeout_ms") {
            if v <= 0 {
                bail!("[fabric.router]: connect_timeout_ms must be >= 1 (got {v})");
            }
            cfg.router.connect_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_int("fabric.router", "io_timeout_ms") {
            if v < 0 {
                bail!("[fabric.router]: io_timeout_ms must be >= 0 (got {v})");
            }
            cfg.router.io_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_int("fabric.router", "retry_deadline_ms") {
            if v <= 0 {
                bail!("[fabric.router]: retry_deadline_ms must be >= 1 (got {v})");
            }
            cfg.router.retry_deadline_ms = v as u64;
        }
        if let Some(v) = doc.get_int("fabric.router", "backoff_base_ms") {
            if v <= 0 {
                bail!("[fabric.router]: backoff_base_ms must be >= 1 (got {v})");
            }
            cfg.router.backoff_base_ms = v as u64;
        }
        // [fabric.dfx] — live reconfiguration
        if let Some(v) = doc.get_bool("fabric.dfx", "enabled") {
            cfg.dfx.adaptive = v;
        }
        if let Some(v) = doc.get_str("fabric.dfx", "policy") {
            cfg.dfx.policy = DarkPolicy::parse(v)
                .with_context(|| format!("[fabric.dfx]: unknown dark-window policy {v:?}"))?;
        }
        if let Some(v) = doc.get_float("fabric.dfx", "samples_per_sec") {
            cfg.dfx.samples_per_sec = v;
        }
        if let Some(v) = doc.get_int("fabric.dfx", "window") {
            cfg.dfx.window = v as usize;
        }
        if let Some(v) = doc.get_int("fabric.dfx", "baseline") {
            cfg.dfx.baseline = v as usize;
        }
        if let Some(v) = doc.get_float("fabric.dfx", "threshold") {
            cfg.dfx.threshold = v;
        }
        if let Some(v) = doc.get_int("fabric.dfx", "cooldown_flits") {
            cfg.dfx.cooldown_flits = v as u64;
        }
        if let Some(arr) = doc.get("fabric.dfx", "pool").and_then(|v| v.as_array()) {
            for v in arr {
                let s = v
                    .as_str()
                    .context("[fabric.dfx]: pool entries are \"kind\" or \"kind:r\" strings")?;
                let entry = PoolEntry::parse(s)
                    .with_context(|| format!("[fabric.dfx]: bad pool entry {s:?}"))?;
                cfg.dfx.pool.push(entry);
            }
        }
        // [fabric.dfx.swap.N] — scripted swap schedule
        for name in doc.sections_with_prefix("fabric.dfx.swap.") {
            let pblock = doc
                .get_int(name, "pblock")
                .with_context(|| format!("[{name}]: missing pblock id"))?
                as usize;
            let at_flit =
                doc.get_int(name, "at_flit").with_context(|| format!("[{name}]: missing at_flit"))?
                    as u64;
            let rm_str =
                doc.get_str(name, "rm").with_context(|| format!("[{name}]: missing rm"))?;
            let rm = RmKind::parse(rm_str)
                .with_context(|| format!("[{name}]: unknown rm {rm_str:?}"))?;
            let default_r = match rm {
                RmKind::Detector(k) => k.pblock_r(),
                _ => 0,
            };
            let r = doc.get_int(name, "r").map(|v| v as usize).unwrap_or(default_r);
            let dark_flits = doc.get_int(name, "dark_flits").map(|v| v as u64);
            cfg.dfx.swaps.push(ScriptedSwap { pblock, at_flit, rm, r, dark_flits });
        }
        cfg.dfx.swaps.sort_by_key(|s| (s.at_flit, s.pblock));
        // [fabric.faults] — fault injection + supervised recovery
        if let Some(v) = doc.get_bool("fabric.faults", "enabled") {
            cfg.faults.enabled = v;
        }
        if let Some(v) = doc.get_int("fabric.faults", "seed") {
            cfg.faults.seed = v as u64;
        }
        if let Some(v) = doc.get_float("fabric.faults", "rate_per_kflit") {
            if v < 0.0 {
                bail!("[fabric.faults]: rate_per_kflit must be >= 0 (got {v})");
            }
            cfg.faults.rate_per_kflit = v;
        }
        if let Some(v) = doc.get_int("fabric.faults", "checkpoint_every_flits") {
            if v < 0 {
                bail!("[fabric.faults]: checkpoint_every_flits must be >= 0 (got {v})");
            }
            cfg.faults.checkpoint_every_flits = v as u64;
        }
        if let Some(v) = doc.get_int("fabric.faults", "stall_ms") {
            cfg.faults.stall_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.get_int("fabric.faults", "stall_timeout_ms") {
            cfg.faults.stall_timeout_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.get_int("fabric.faults", "max_reloads") {
            if v < 0 {
                bail!("[fabric.faults]: max_reloads must be >= 0 (got {v})");
            }
            cfg.faults.max_reloads = v as u32;
        }
        if let Some(v) = doc.get_int("fabric.faults", "backoff_ms") {
            cfg.faults.backoff_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("fabric.faults", "dark_flits") {
            if v <= 0 {
                bail!("[fabric.faults]: dark_flits must be >= 1 (got {v})");
            }
            cfg.faults.dark_flits = Some(v as u64);
        }
        if let Some(v) = doc.get_int("fabric.faults", "reload_wait_ms") {
            cfg.faults.reload_wait_ms = v.max(0) as u64;
        }
        // [fabric.faults.inject.N] — scripted injections
        for name in doc.sections_with_prefix("fabric.faults.inject.") {
            let suffix = &name["fabric.faults.inject.".len()..];
            let id = doc.get_str(name, "id").unwrap_or(suffix).to_string();
            let pblock = doc
                .get_int(name, "pblock")
                .with_context(|| format!("[{name}]: missing pblock id"))?
                as usize;
            let at_flit =
                doc.get_int(name, "at_flit").with_context(|| format!("[{name}]: missing at_flit"))?
                    as u64;
            let kind =
                doc.get_str(name, "kind").with_context(|| format!("[{name}]: missing kind"))?;
            let lane = doc.get_int(name, "lane").map(|v| v.max(0) as usize).unwrap_or(0);
            let ms = doc.get_int(name, "ms").map(|v| v.max(1) as u64).unwrap_or(20);
            cfg.faults.injections.push(InjectSpec {
                id,
                pblock,
                at_flit,
                kind: kind.to_string(),
                lane,
                ms,
            });
        }
        cfg.faults.injections.sort_by(|a, b| (a.at_flit, a.pblock).cmp(&(b.at_flit, b.pblock)));
        // [pblock.N] sections
        for name in doc.sections_with_prefix("pblock.") {
            let id: usize = name["pblock.".len()..]
                .parse()
                .with_context(|| format!("bad pblock id in [{name}]"))?;
            if !(1..=defaults::NUM_AD_PBLOCKS).contains(&id) {
                bail!("[{name}]: pblock id must be 1..={}", defaults::NUM_AD_PBLOCKS);
            }
            let rm_str = doc.get_str(name, "rm").unwrap_or("empty");
            let rm = RmKind::parse(rm_str)
                .with_context(|| format!("[{name}]: unknown rm {rm_str:?}"))?;
            let default_r = match rm {
                RmKind::Detector(k) => k.pblock_r(),
                _ => 0,
            };
            let r = doc.get_int(name, "r").map(|v| v as usize).unwrap_or(default_r);
            let stream = doc.get_int(name, "stream").map(|v| v as usize).unwrap_or(0);
            let lanes = match doc.get_int(name, "lanes") {
                Some(v) if v <= 0 => bail!("[{name}]: lanes must be >= 1 (got {v})"),
                Some(v) => v as usize,
                None => 0, // inherit [fabric] lanes
            };
            cfg.pblocks.push(PblockCfg { id, rm, r, stream, lanes });
        }
        cfg.pblocks.sort_by_key(|p| p.id);
        // [combo.N] sections
        for name in doc.sections_with_prefix("combo.") {
            let id: usize = name["combo.".len()..]
                .parse()
                .with_context(|| format!("bad combo id in [{name}]"))?;
            if !(1..=defaults::NUM_COMBO_PBLOCKS).contains(&id) {
                bail!("[{name}]: combo id must be 1..={}", defaults::NUM_COMBO_PBLOCKS);
            }
            let method = doc.get_str(name, "method").unwrap_or("avg").to_string();
            let inputs: Vec<usize> = doc
                .get(name, "inputs")
                .and_then(|v| v.as_array())
                .map(|a| a.iter().filter_map(|v| v.as_int()).map(|v| v as usize).collect())
                .unwrap_or_default();
            let weights: Vec<f32> = doc
                .get(name, "weights")
                .and_then(|v| v.as_array())
                .map(|a| a.iter().filter_map(|v| v.as_float()).map(|v| v as f32).collect())
                .unwrap_or_default();
            cfg.combos.push(ComboCfg { id, method, inputs, weights });
        }
        cfg.combos.sort_by_key(|c| c.id);
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural validation: distinct ids, combo fan-in ≤ 4, combo inputs
    /// reference configured detector pblocks, no pblock feeds two combos.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for p in &self.pblocks {
            if !seen.insert(p.id) {
                bail!("duplicate pblock id {}", p.id);
            }
            if matches!(p.rm, RmKind::Detector(_)) && p.r == 0 {
                bail!("pblock {} has a detector RM with r = 0", p.id);
            }
        }
        let mut consumed = std::collections::HashSet::new();
        let mut combo_ids = std::collections::HashSet::new();
        for c in &self.combos {
            if !combo_ids.insert(c.id) {
                bail!("duplicate combo id {}", c.id);
            }
            if c.inputs.is_empty() || c.inputs.len() > 4 {
                bail!("combo {} must have 1..=4 inputs (has {})", c.id, c.inputs.len());
            }
            for &input in &c.inputs {
                let Some(p) = self.pblocks.iter().find(|p| p.id == input) else {
                    bail!("combo {} references unconfigured pblock {input}", c.id);
                };
                if p.rm == RmKind::Empty {
                    bail!("combo {} references empty pblock {input}", c.id);
                }
                if !consumed.insert(input) {
                    bail!("pblock {input} feeds more than one combo");
                }
            }
            if c.method == "wavg" && c.weights.len() < c.inputs.len() {
                bail!("combo {}: wavg needs one weight per input", c.id);
            }
        }
        if self.lanes == 0 {
            bail!("[fabric]: lanes must be >= 1");
        }
        if self.dfx.samples_per_sec <= 0.0 {
            bail!("[fabric.dfx]: samples_per_sec must be > 0");
        }
        if self.server.inbox_flits == 0 {
            bail!("[fabric.server]: inbox_flits must be > 0 (a zero-depth inbox deadlocks)");
        }
        if self.server.sessions_per_partition == 0 {
            bail!(
                "[fabric.server]: sessions_per_partition must be >= 1 (a zero-slot \
                 partition can never admit a session)"
            );
        }
        if self.server.sink_fsync_records == 0 {
            bail!("[fabric.server]: sink_fsync_records must be >= 1");
        }
        if self.operator.enabled && self.operator.addr.is_empty() {
            bail!("[fabric.operator]: enabled without a listen addr (host:port)");
        }
        if self.operator.auth_token.as_deref() == Some("") {
            bail!("[fabric.operator]: auth_token must not be empty — use None to serve without auth");
        }
        if self.net.enabled && self.net.addr.is_empty() {
            bail!("[fabric.net]: enabled without a listen addr (host:port)");
        }
        if self.net.max_connections == 0 {
            bail!("[fabric.net]: max_connections must be >= 1");
        }
        if self.router.enabled {
            if self.router.addr.is_empty() {
                bail!("[fabric.router]: enabled without a listen addr (host:port)");
            }
            if self.router.workers.is_empty() {
                bail!("[fabric.router]: enabled without any workers — list the downstream \
                       fsead net addresses in `workers`");
            }
        }
        if self.router.max_connections == 0 {
            bail!("[fabric.router]: max_connections must be >= 1");
        }
        if self.router.max_failures == 0 {
            bail!("[fabric.router]: max_failures must be >= 1");
        }
        if self.router.checkpoint_pushes == 0 {
            bail!("[fabric.router]: checkpoint_pushes must be >= 1");
        }
        let lifecycle = self.server.sessions_per_partition > 1 || self.server.idle_evict_flits > 0;
        if lifecycle {
            // The multiplexing service loop swaps per-session RM state
            // through the snapshot codec, which only exists for CPU
            // detector RMs, and it does not run the DFX gate or the fault
            // hooks — refuse the combinations here with named errors
            // instead of panicking deep inside `FabricServer::start`.
            if self.use_fpga {
                bail!(
                    "[fabric.server]: sessions_per_partition > 1 / idle_evict_flits require \
                     CPU detector RMs (their state snapshots; FPGA RM state lives on the device)"
                );
            }
            if self.dfx.adaptive || !self.dfx.swaps.is_empty() {
                bail!(
                    "[fabric.server]: partition multiplexing/eviction cannot run together \
                     with live DFX swaps — disable [fabric.dfx] or set \
                     sessions_per_partition = 1 and idle_evict_flits = 0"
                );
            }
            if self.faults.enabled {
                bail!(
                    "[fabric.server]: partition multiplexing/eviction cannot run together \
                     with fault injection — the supervisor ladder owns the dedicated plane"
                );
            }
            for p in &self.pblocks {
                if !matches!(p.rm, RmKind::Detector(_)) {
                    bail!(
                        "[fabric.server]: pblock {} has RM {:?} — multiplexed/evictable \
                         partitions need detector RMs (their window state snapshots)",
                        p.id,
                        p.rm.as_str()
                    );
                }
            }
        }
        // A drop-policy dark window deletes flits from one input of a
        // lock-step combo join, desynchronising the seq numbers mid-run —
        // reject it up front instead of failing deep inside the pass.
        if self.dfx.policy == DarkPolicy::Drop {
            for s in &self.dfx.swaps {
                if consumed.contains(&s.pblock) {
                    bail!(
                        "[fabric.dfx]: drop policy would desynchronise the combo fed by \
                         pblock {} — use policy = \"bypass\" for combo-fed pblocks",
                        s.pblock
                    );
                }
            }
            if self.dfx.adaptive && !consumed.is_empty() {
                bail!(
                    "[fabric.dfx]: the adaptive controller with drop policy cannot run on a \
                     fabric with combo-fed pblocks — use policy = \"bypass\""
                );
            }
        }
        if self.dfx.adaptive {
            if self.dfx.pool.is_empty() {
                bail!("[fabric.dfx]: adaptive controller enabled with an empty detector pool");
            }
            if self.dfx.window == 0 || self.dfx.baseline == 0 {
                bail!("[fabric.dfx]: window and baseline must be > 0");
            }
        }
        for s in &self.dfx.swaps {
            if !(1..=defaults::NUM_AD_PBLOCKS).contains(&s.pblock) {
                bail!(
                    "[fabric.dfx.swap]: pblock id must be 1..={} (got {})",
                    defaults::NUM_AD_PBLOCKS,
                    s.pblock
                );
            }
            if matches!(s.rm, RmKind::Detector(_)) && s.r == 0 {
                bail!("[fabric.dfx.swap]: detector swap for pblock {} has r = 0", s.pblock);
            }
        }
        for inj in &self.faults.injections {
            if !FAULT_KINDS.contains(&inj.kind.as_str()) {
                bail!(
                    "[fabric.faults.inject]: unknown fault kind {:?} (expected one of {})",
                    inj.kind,
                    FAULT_KINDS.join(" | ")
                );
            }
            if !(1..=defaults::NUM_AD_PBLOCKS).contains(&inj.pblock) {
                bail!(
                    "[fabric.faults.inject]: pblock id must be 1..={} (got {})",
                    defaults::NUM_AD_PBLOCKS,
                    inj.pblock
                );
            }
        }
        Ok(())
    }

    /// Configured lane count for a pblock: its own `lanes` when set,
    /// otherwise the `[fabric] lanes` default (≥ 1 either way). The
    /// *effective* count is further clamped to the loaded RM's ensemble
    /// size when the lane array is built.
    pub fn lanes_for(&self, p: &PblockCfg) -> usize {
        let lanes = if p.lanes > 0 { p.lanes } else { self.lanes };
        lanes.max(1)
    }

    /// Apply a CLI-level lane override (`fsead --lanes`): set the
    /// `[fabric]` default and clear per-pblock values so the flag really
    /// applies to every partition.
    pub fn override_lanes(&mut self, lanes: usize) {
        self.lanes = lanes;
        for p in &mut self.pblocks {
            p.lanes = 0;
        }
    }

    /// Pblock ids whose outputs are routed straight to the host (not into a
    /// combo) — the switch-1 → output-DMA routes of Fig 7(a).
    pub fn direct_outputs(&self) -> Vec<usize> {
        let consumed: std::collections::HashSet<usize> =
            self.combos.iter().flat_map(|c| c.inputs.iter().copied()).collect();
        self.pblocks
            .iter()
            .filter(|p| p.rm != RmKind::Empty && !consumed.contains(&p.id))
            .map(|p| p.id)
            .collect()
    }

    // -- paper Figure 7 presets --------------------------------------------

    /// Fig 7(a): seven independent pblocks on seven streams, no combos.
    pub fn fig7a(kind: DetectorKind) -> FseadConfig {
        let mut cfg = FseadConfig::default();
        for id in 1..=7 {
            cfg.pblocks.push(PblockCfg {
                id,
                rm: RmKind::Detector(kind),
                r: kind.pblock_r(),
                stream: id - 1,
                lanes: 0,
            });
        }
        cfg
    }

    /// Fig 7(b): three applications — Loda×3 → COMBO1 on stream 0, RS-Hash×2
    /// → COMBO2 on stream 1, xStream×2 → COMBO3 on stream 2.
    pub fn fig7b() -> FseadConfig {
        let mut cfg = FseadConfig::default();
        let mk = |id: usize, kind: DetectorKind, stream: usize| PblockCfg {
            id,
            rm: RmKind::Detector(kind),
            r: kind.pblock_r(),
            stream,
            lanes: 0,
        };
        cfg.pblocks = vec![
            mk(1, DetectorKind::Loda, 0),
            mk(2, DetectorKind::Loda, 0),
            mk(3, DetectorKind::Loda, 0),
            mk(4, DetectorKind::RsHash, 1),
            mk(5, DetectorKind::RsHash, 1),
            mk(6, DetectorKind::XStream, 2),
            mk(7, DetectorKind::XStream, 2),
        ];
        cfg.combos = vec![
            ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2, 3], weights: vec![] },
            ComboCfg { id: 2, method: "avg".into(), inputs: vec![4, 5], weights: vec![] },
            ComboCfg { id: 3, method: "avg".into(), inputs: vec![6, 7], weights: vec![] },
        ];
        cfg
    }

    /// Fig 7(c): maximally parallel homogeneous ensemble — all seven pblocks
    /// on one stream, averaged by COMBO1(+2 cascade modelled as one combo
    /// stage with fan-in 7 split 4+3 via COMBO1/COMBO2 into COMBO3).
    pub fn fig7c(kind: DetectorKind) -> FseadConfig {
        let mut cfg = FseadConfig::default();
        for id in 1..=7 {
            cfg.pblocks.push(PblockCfg {
                id,
                rm: RmKind::Detector(kind),
                r: kind.pblock_r(),
                stream: 0,
                lanes: 0,
            });
        }
        cfg.combos = vec![
            ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2, 3, 4], weights: vec![] },
            ComboCfg { id: 2, method: "avg".into(), inputs: vec![5, 6, 7], weights: vec![] },
        ];
        cfg
    }

    /// Fig 7(d): heterogeneous ensemble — Loda×3 + RS-Hash×2 + xStream×2 on
    /// one stream, aggregated per type then combined.
    pub fn fig7d() -> FseadConfig {
        let mut cfg = FseadConfig::fig7b();
        for p in &mut cfg.pblocks {
            p.stream = 0;
        }
        cfg
    }

    /// Paper Table 5 combination id, e.g. "A7", "C223" (A=Loda ×k, B=RS-Hash
    /// ×k, C=xStream ×k in pblock order).
    pub fn from_combo_code(code: &str) -> Result<FseadConfig> {
        let mut cfg = FseadConfig::default();
        let bytes = code.as_bytes();
        let mut id = 1usize;
        let mut i = 0;
        while i < bytes.len() {
            let kind = match bytes[i] {
                b'A' | b'a' => DetectorKind::Loda,
                b'B' | b'b' => DetectorKind::RsHash,
                b'C' | b'c' => DetectorKind::XStream,
                other => bail!("bad detector letter {:?} in {code}", other as char),
            };
            i += 1;
            let mut count = 0usize;
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                count = count * 10 + (bytes[i] - b'0') as usize;
                i += 1;
            }
            // Code like "C223" means counts per letter-position; a single
            // letter+number pair like "A7" means 7 pblocks of A.
            if count == 0 {
                bail!("missing count after detector letter in {code}");
            }
            for _ in 0..count {
                if id > defaults::NUM_AD_PBLOCKS {
                    bail!("{code} needs more than {} pblocks", defaults::NUM_AD_PBLOCKS);
                }
                cfg.pblocks.push(PblockCfg {
                    id,
                    rm: RmKind::Detector(kind),
                    r: kind.pblock_r(),
                    stream: 0,
                    lanes: 0,
                });
                id += 1;
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[fabric]
seed = 7
chunk = 128
use_fpga = false

[detector]
window = 64
bins = 10

[dataset]
name = "shuttle"
max_samples = 1000

[pblock.1]
rm = "loda"
stream = 0

[pblock.2]
rm = "xstream"
r = 5
stream = 0

[combo.1]
method = "avg"
inputs = [1, 2]
"#;

    #[test]
    fn full_roundtrip() {
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.chunk, 128);
        assert!(!cfg.use_fpga);
        assert_eq!(cfg.hyper.window, 64);
        assert_eq!(cfg.dataset.name, "shuttle");
        assert_eq!(cfg.pblocks.len(), 2);
        assert_eq!(cfg.pblocks[0].rm, RmKind::Detector(DetectorKind::Loda));
        assert_eq!(cfg.pblocks[0].r, 35); // default pblock R
        assert_eq!(cfg.pblocks[1].r, 5);
        assert_eq!(cfg.combos[0].inputs, vec![1, 2]);
        assert!(cfg.direct_outputs().is_empty());
    }

    #[test]
    fn exec_mode_parses_and_defaults_to_batched() {
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert_eq!(cfg.exec, ExecMode::Batched);
        let lock = FseadConfig::from_str("[fabric]\nexec = \"lockstep\"\n").unwrap();
        assert_eq!(lock.exec, ExecMode::LockStep);
        let fast = FseadConfig::from_str("[fabric]\nexec = \"batched\"\n").unwrap();
        assert_eq!(fast.exec, ExecMode::Batched);
        assert!(FseadConfig::from_str("[fabric]\nexec = \"warp\"\n").is_err());
    }

    #[test]
    fn rejects_combo_referencing_unknown_pblock() {
        let bad = "[pblock.1]\nrm = \"loda\"\n[combo.1]\ninputs = [1, 5]\n";
        assert!(FseadConfig::from_str(bad).is_err());
    }

    #[test]
    fn rejects_fan_in_over_four() {
        let mut cfg = FseadConfig::fig7a(DetectorKind::Loda);
        cfg.combos.push(ComboCfg {
            id: 1,
            method: "avg".into(),
            inputs: vec![1, 2, 3, 4, 5],
            weights: vec![],
        });
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_pblock_feeding_two_combos() {
        let mut cfg = FseadConfig::fig7a(DetectorKind::Loda);
        cfg.combos = vec![
            ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2], weights: vec![] },
            ComboCfg { id: 2, method: "avg".into(), inputs: vec![2, 3], weights: vec![] },
        ];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn presets_validate() {
        FseadConfig::fig7a(DetectorKind::Loda).validate().unwrap();
        FseadConfig::fig7b().validate().unwrap();
        FseadConfig::fig7c(DetectorKind::RsHash).validate().unwrap();
        FseadConfig::fig7d().validate().unwrap();
    }

    #[test]
    fn fig7a_routes_directly_to_host() {
        let cfg = FseadConfig::fig7a(DetectorKind::XStream);
        assert_eq!(cfg.direct_outputs(), vec![1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn combo_codes_parse() {
        let a7 = FseadConfig::from_combo_code("A7").unwrap();
        assert_eq!(a7.pblocks.len(), 7);
        assert!(a7.pblocks.iter().all(|p| p.rm == RmKind::Detector(DetectorKind::Loda)));
        let c223 = FseadConfig::from_combo_code("A2B2C3").unwrap();
        assert_eq!(c223.pblocks.len(), 7);
        assert_eq!(c223.pblocks[6].rm, RmKind::Detector(DetectorKind::XStream));
        assert!(FseadConfig::from_combo_code("A9").is_err());
        assert!(FseadConfig::from_combo_code("X2").is_err());
    }

    #[test]
    fn dfx_section_parses() {
        let text = r#"
[pblock.1]
rm = "loda"

[fabric.dfx]
enabled = true
policy = "drop"
samples_per_sec = 50000
window = 64
baseline = 128
threshold = 2.5
cooldown_flits = 32
pool = ["loda:8", "rshash", "xstream:4"]

[fabric.dfx.swap.1]
pblock = 1
at_flit = 40
rm = "rshash"
r = 4
dark_flits = 3

[fabric.dfx.swap.2]
pblock = 1
at_flit = 10
rm = "xstream"
r = 2
"#;
        let cfg = FseadConfig::from_str(text).unwrap();
        assert!(cfg.dfx.adaptive);
        assert_eq!(cfg.dfx.policy, DarkPolicy::Drop);
        assert_eq!(cfg.dfx.samples_per_sec, 50_000.0);
        assert_eq!(cfg.dfx.window, 64);
        assert_eq!(cfg.dfx.baseline, 128);
        assert_eq!(cfg.dfx.threshold, 2.5);
        assert_eq!(cfg.dfx.cooldown_flits, 32);
        assert_eq!(
            cfg.dfx.pool,
            vec![
                PoolEntry { kind: DetectorKind::Loda, r: 8 },
                PoolEntry { kind: DetectorKind::RsHash, r: 0 },
                PoolEntry { kind: DetectorKind::XStream, r: 4 },
            ]
        );
        // Schedule is sorted by (at_flit, pblock); default r comes from the
        // paper's per-pblock sizes, explicit dark_flits is preserved.
        assert_eq!(cfg.dfx.swaps.len(), 2);
        assert_eq!(cfg.dfx.swaps[0].at_flit, 10);
        assert_eq!(cfg.dfx.swaps[0].rm, RmKind::Detector(DetectorKind::XStream));
        assert_eq!(cfg.dfx.swaps[0].dark_flits, None);
        assert_eq!(cfg.dfx.swaps[1].at_flit, 40);
        assert_eq!(cfg.dfx.swaps[1].dark_flits, Some(3));
    }

    #[test]
    fn dfx_defaults_are_off() {
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert!(!cfg.dfx.adaptive);
        assert_eq!(cfg.dfx.policy, DarkPolicy::Bypass);
        assert!(cfg.dfx.swaps.is_empty());
    }

    #[test]
    fn dfx_validation_rejects_bad_sections() {
        // Adaptive without a pool.
        assert!(FseadConfig::from_str("[fabric.dfx]\nenabled = true\n").is_err());
        // Unknown policy.
        assert!(FseadConfig::from_str("[fabric.dfx]\npolicy = \"vanish\"\n").is_err());
        // Swap targeting a pblock outside the fabric.
        let bad = "[fabric.dfx.swap.1]\npblock = 9\nat_flit = 1\nrm = \"loda\"\n";
        assert!(FseadConfig::from_str(bad).is_err());
        // Detector swap with r = 0.
        let bad = "[fabric.dfx.swap.1]\npblock = 1\nat_flit = 1\nrm = \"loda\"\nr = 0\n";
        assert!(FseadConfig::from_str(bad).is_err());
    }

    #[test]
    fn drop_policy_rejected_for_combo_fed_swap_targets() {
        let base = "[pblock.1]\nrm = \"loda\"\n[pblock.2]\nrm = \"loda\"\n\
                    [combo.1]\ninputs = [1, 2]\n\
                    [fabric.dfx.swap.1]\npblock = 1\nat_flit = 2\nrm = \"rshash\"\nr = 2\n";
        // Bypass (default) keeps the join aligned — accepted.
        assert!(FseadConfig::from_str(base).is_ok());
        // Drop would desynchronise the combo join — rejected at load time.
        let drop = format!("[fabric.dfx]\npolicy = \"drop\"\n{base}");
        assert!(FseadConfig::from_str(&drop).is_err());
        // Adaptive + drop on a combo-carrying fabric is rejected too.
        let adaptive = "[fabric.dfx]\npolicy = \"drop\"\nenabled = true\npool = [\"loda:2\"]\n\
                        [pblock.1]\nrm = \"loda\"\n[pblock.2]\nrm = \"loda\"\n\
                        [combo.1]\ninputs = [1, 2]\n";
        assert!(FseadConfig::from_str(adaptive).is_err());
    }

    #[test]
    fn pool_entries_parse() {
        assert_eq!(
            PoolEntry::parse("loda:12"),
            Some(PoolEntry { kind: DetectorKind::Loda, r: 12 })
        );
        assert_eq!(PoolEntry::parse("rshash"), Some(PoolEntry { kind: DetectorKind::RsHash, r: 0 }));
        assert_eq!(PoolEntry::parse("loda:x"), None);
        assert_eq!(PoolEntry::parse("nope"), None);
    }

    #[test]
    fn lanes_parse_inherit_and_validate() {
        // Default: single lane everywhere.
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert_eq!(cfg.lanes, 1);
        assert!(cfg.pblocks.iter().all(|p| p.lanes == 0));
        assert!(cfg.pblocks.iter().all(|p| cfg.lanes_for(p) == 1));
        // [fabric] lanes is the default, [pblock.N] lanes overrides it.
        let text = "[fabric]\nlanes = 4\n\n[pblock.1]\nrm = \"loda\"\n\n\
                    [pblock.2]\nrm = \"rshash\"\nlanes = 2\n";
        let cfg = FseadConfig::from_str(text).unwrap();
        assert_eq!(cfg.lanes, 4);
        assert_eq!(cfg.lanes_for(&cfg.pblocks[0]), 4);
        assert_eq!(cfg.lanes_for(&cfg.pblocks[1]), 2);
        // Zero / negative lane counts are rejected up front.
        assert!(FseadConfig::from_str("[fabric]\nlanes = 0\n").is_err());
        assert!(FseadConfig::from_str("[fabric]\nlanes = -2\n").is_err());
        assert!(FseadConfig::from_str("[pblock.1]\nrm = \"loda\"\nlanes = 0\n").is_err());
        let mut bad = FseadConfig::default();
        bad.lanes = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn server_section_parses_with_defaults() {
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert_eq!(cfg.server.inbox_flits, 64);
        assert_eq!(cfg.server.max_waiters, 64);
        let text = "[fabric.server]\ninbox_flits = 8\nmax_waiters = 2\n";
        let cfg = FseadConfig::from_str(text).unwrap();
        assert_eq!(cfg.server.inbox_flits, 8);
        assert_eq!(cfg.server.max_waiters, 2);
        // A zero-depth inbox can never admit a flit — rejected up front.
        assert!(FseadConfig::from_str("[fabric.server]\ninbox_flits = 0\n").is_err());
        // Negative values must not wrap into unbounded queues.
        assert!(FseadConfig::from_str("[fabric.server]\ninbox_flits = -1\n").is_err());
        assert!(FseadConfig::from_str("[fabric.server]\nmax_waiters = -3\n").is_err());
    }

    #[test]
    fn server_lifecycle_knobs_parse_and_validate() {
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert_eq!(cfg.server.sessions_per_partition, 1);
        assert_eq!(cfg.server.idle_evict_flits, 0);
        assert_eq!(cfg.server.open_timeout_ms, 0);
        assert_eq!(cfg.server.overload, OverloadPolicy::Block);
        assert_eq!(cfg.server.sink_path, None);
        assert_eq!(cfg.server.sink_fsync_records, 32);
        assert_eq!(cfg.server.spill_dir, None);
        assert!(!cfg.server.evict_quarantined);
        let text = "[fabric.server]\nsessions_per_partition = 4\nidle_evict_flits = 32\n\
                    open_timeout_ms = 250\noverload = \"shed\"\n\
                    sink_path = \"scores.fsk\"\nsink_fsync_records = 8\n\
                    spill_dir = \"spill\"\nevict_quarantined = true\n";
        let cfg = FseadConfig::from_str(text).unwrap();
        assert_eq!(cfg.server.sessions_per_partition, 4);
        assert_eq!(cfg.server.idle_evict_flits, 32);
        assert_eq!(cfg.server.open_timeout_ms, 250);
        assert_eq!(cfg.server.overload, OverloadPolicy::Shed);
        assert_eq!(cfg.server.sink_path.as_deref(), Some("scores.fsk"));
        assert_eq!(cfg.server.sink_fsync_records, 8);
        assert_eq!(cfg.server.spill_dir.as_deref(), Some("spill"));
        assert!(cfg.server.evict_quarantined);
        // Named refusals at load time, not panics deep in start().
        assert!(FseadConfig::from_str("[fabric.server]\nsessions_per_partition = 0\n").is_err());
        assert!(FseadConfig::from_str("[fabric.server]\nsessions_per_partition = -2\n").is_err());
        assert!(FseadConfig::from_str("[fabric.server]\nidle_evict_flits = -1\n").is_err());
        assert!(FseadConfig::from_str("[fabric.server]\nopen_timeout_ms = -1\n").is_err());
        assert!(FseadConfig::from_str("[fabric.server]\noverload = \"panic\"\n").is_err());
        assert!(FseadConfig::from_str("[fabric.server]\nsink_fsync_records = 0\n").is_err());
        // Structural refusals: multiplexing needs CPU detector RMs and no
        // DFX/fault machinery on the same partitions.
        let mut cfg = FseadConfig {
            use_fpga: false,
            pblocks: vec![PblockCfg {
                id: 1,
                rm: RmKind::Detector(DetectorKind::Loda),
                r: 2,
                stream: 0,
                lanes: 0,
            }],
            ..FseadConfig::default()
        };
        cfg.server.sessions_per_partition = 2;
        cfg.validate().unwrap();
        let mut fpga = cfg.clone();
        fpga.use_fpga = true;
        assert!(fpga.validate().is_err(), "FPGA RMs cannot multiplex");
        let mut faulty = cfg.clone();
        faulty.faults.enabled = true;
        assert!(faulty.validate().is_err(), "faults + multiplexing must be refused");
        let mut adaptive = cfg.clone();
        adaptive.dfx.adaptive = true;
        adaptive.dfx.pool.push(PoolEntry { kind: DetectorKind::Loda, r: 2 });
        assert!(adaptive.validate().is_err(), "adaptive DFX + multiplexing must be refused");
        let mut bypass = cfg.clone();
        bypass.pblocks[0].rm = RmKind::Bypass;
        assert!(bypass.validate().is_err(), "bypass RMs have no state to multiplex");
    }

    #[test]
    fn operator_section_parses_with_defaults() {
        // Off by default — the plane must be bit-transparent when absent.
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert!(!cfg.operator.enabled);
        assert_eq!(cfg.operator.addr, "127.0.0.1:9091");
        assert_eq!(cfg.operator.auth_token, None);
        let text = "[fabric.operator]\nenabled = true\naddr = \"0.0.0.0:9900\"\n\
                    auth_token = \"s3cret\"\n";
        let cfg = FseadConfig::from_str(text).unwrap();
        assert!(cfg.operator.enabled);
        assert_eq!(cfg.operator.addr, "0.0.0.0:9900");
        assert_eq!(cfg.operator.auth_token.as_deref(), Some("s3cret"));
        // Named refusals at load time.
        assert!(FseadConfig::from_str("[fabric.operator]\naddr = \"\"\n").is_err());
        assert!(FseadConfig::from_str("[fabric.operator]\naddr = \"localhost\"\n").is_err());
        assert!(FseadConfig::from_str("[fabric.operator]\nauth_token = \"\"\n").is_err());
        let mut bad = FseadConfig::default();
        bad.operator.enabled = true;
        bad.operator.addr.clear();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn net_section_parses_with_defaults() {
        // Off by default — sessions stay in-process unless asked for.
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert!(!cfg.net.enabled);
        assert_eq!(cfg.net.addr, "127.0.0.1:9191");
        assert_eq!(cfg.net.max_connections, 256);
        let text = "[fabric.net]\nenabled = true\naddr = \"0.0.0.0:9900\"\n\
                    max_connections = 8\n";
        let cfg = FseadConfig::from_str(text).unwrap();
        assert!(cfg.net.enabled);
        assert_eq!(cfg.net.addr, "0.0.0.0:9900");
        assert_eq!(cfg.net.max_connections, 8);
        // Named refusals at load time.
        assert!(FseadConfig::from_str("[fabric.net]\naddr = \"\"\n").is_err());
        assert!(FseadConfig::from_str("[fabric.net]\naddr = \"localhost\"\n").is_err());
        assert!(FseadConfig::from_str("[fabric.net]\nmax_connections = 0\n").is_err());
        let mut bad = FseadConfig::default();
        bad.net.enabled = true;
        bad.net.addr.clear();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn router_section_parses_with_defaults() {
        // Off by default — a single worker without a router in front is
        // bit-transparent to a direct `fsead net` connection.
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert!(!cfg.router.enabled);
        assert_eq!(cfg.router.addr, "127.0.0.1:9290");
        assert!(cfg.router.workers.is_empty());
        assert_eq!(cfg.router.heartbeat_ms, 250);
        assert_eq!(cfg.router.max_failures, 3);
        assert_eq!(cfg.router.checkpoint_pushes, 8);
        let text = "[fabric.router]\nenabled = true\naddr = \"0.0.0.0:9290\"\n\
                    workers = [\"127.0.0.1:9191\", \"127.0.0.1:9192\"]\n\
                    heartbeat_ms = 100\nmax_failures = 2\ncheckpoint_pushes = 4\n\
                    io_timeout_ms = 2000\nretry_deadline_ms = 1500\nbackoff_base_ms = 5\n";
        let cfg = FseadConfig::from_str(text).unwrap();
        assert!(cfg.router.enabled);
        assert_eq!(cfg.router.addr, "0.0.0.0:9290");
        assert_eq!(cfg.router.workers, vec!["127.0.0.1:9191", "127.0.0.1:9192"]);
        assert_eq!(cfg.router.heartbeat_ms, 100);
        assert_eq!(cfg.router.max_failures, 2);
        assert_eq!(cfg.router.checkpoint_pushes, 4);
        assert_eq!(cfg.router.io_timeout_ms, 2000);
        assert_eq!(cfg.router.retry_deadline_ms, 1500);
        assert_eq!(cfg.router.backoff_base_ms, 5);
        // Named refusals at load time.
        assert!(FseadConfig::from_str("[fabric.router]\naddr = \"\"\n").is_err());
        assert!(FseadConfig::from_str("[fabric.router]\naddr = \"localhost\"\n").is_err());
        assert!(FseadConfig::from_str("[fabric.router]\nworkers = [\"nope\"]\n").is_err());
        assert!(FseadConfig::from_str("[fabric.router]\nmax_failures = 0\n").is_err());
        assert!(FseadConfig::from_str("[fabric.router]\ncheckpoint_pushes = 0\n").is_err());
        // Enabled without workers is a deployment error, caught at validate.
        let mut bad = FseadConfig::default();
        bad.router.enabled = true;
        assert!(bad.validate().is_err(), "router with an empty worker list");
    }

    #[test]
    fn session_id_base_parses_and_defaults_to_zero() {
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert_eq!(cfg.server.session_id_base, 0);
        let cfg =
            FseadConfig::from_str("[fabric.server]\nsession_id_base = 4294967296\n").unwrap();
        assert_eq!(cfg.server.session_id_base, 1u64 << 32);
        assert!(FseadConfig::from_str("[fabric.server]\nsession_id_base = -1\n").is_err());
    }

    #[test]
    fn faults_default_entirely_off() {
        let cfg = FseadConfig::from_str(SAMPLE).unwrap();
        assert!(!cfg.faults.enabled);
        assert_eq!(cfg.faults.rate_per_kflit, 0.0);
        assert!(cfg.faults.injections.is_empty());
        assert_eq!(cfg.faults.checkpoint_every_flits, 8);
        assert_eq!(cfg.faults.max_reloads, 2);
        assert_eq!(cfg.faults.dark_flits, None);
        assert_eq!(cfg.non_finite, NonFinite::Error);
    }

    #[test]
    fn faults_section_parses() {
        let text = r#"
[fabric]
non_finite = "clamp"

[pblock.1]
rm = "loda"

[fabric.faults]
enabled = true
seed = 9
rate_per_kflit = 2.5
checkpoint_every_flits = 4
stall_ms = 15
stall_timeout_ms = 5
max_reloads = 3
backoff_ms = 2
dark_flits = 1
reload_wait_ms = 50

[fabric.faults.inject.1]
pblock = 1
at_flit = 40
kind = "state_corrupt"

[fabric.faults.inject.2]
id = "wedge"
pblock = 1
at_flit = 10
kind = "stall"
ms = 12

[fabric.faults.inject.3]
pblock = 1
at_flit = 20
kind = "lane_panic"
lane = 1
"#;
        let cfg = FseadConfig::from_str(text).unwrap();
        assert_eq!(cfg.non_finite, NonFinite::Clamp);
        assert!(cfg.faults.enabled);
        assert_eq!(cfg.faults.seed, 9);
        assert_eq!(cfg.faults.rate_per_kflit, 2.5);
        assert_eq!(cfg.faults.checkpoint_every_flits, 4);
        assert_eq!(cfg.faults.stall_ms, 15);
        assert_eq!(cfg.faults.stall_timeout_ms, 5);
        assert_eq!(cfg.faults.max_reloads, 3);
        assert_eq!(cfg.faults.backoff_ms, 2);
        assert_eq!(cfg.faults.dark_flits, Some(1));
        assert_eq!(cfg.faults.reload_wait_ms, 50);
        // Sorted by (at_flit, pblock); id defaults to the section suffix.
        assert_eq!(cfg.faults.injections.len(), 3);
        assert_eq!(cfg.faults.injections[0].id, "wedge");
        assert_eq!(cfg.faults.injections[0].at_flit, 10);
        assert_eq!(cfg.faults.injections[0].ms, 12);
        assert_eq!(cfg.faults.injections[1].kind, "lane_panic");
        assert_eq!(cfg.faults.injections[1].lane, 1);
        assert_eq!(cfg.faults.injections[2].id, "1");
        assert_eq!(cfg.faults.injections[2].kind, "state_corrupt");
    }

    #[test]
    fn faults_validation_rejects_bad_sections() {
        // Unknown fault kind.
        let bad = "[fabric.faults.inject.1]\npblock = 1\nat_flit = 1\nkind = \"gamma_ray\"\n";
        assert!(FseadConfig::from_str(bad).is_err());
        // Pblock out of range.
        let bad = "[fabric.faults.inject.1]\npblock = 9\nat_flit = 1\nkind = \"stall\"\n";
        assert!(FseadConfig::from_str(bad).is_err());
        // Negative rate / zero dark window.
        assert!(FseadConfig::from_str("[fabric.faults]\nrate_per_kflit = -1.0\n").is_err());
        assert!(FseadConfig::from_str("[fabric.faults]\ndark_flits = 0\n").is_err());
        // Unknown non_finite policy.
        assert!(FseadConfig::from_str("[fabric]\nnon_finite = \"ignore\"\n").is_err());
    }

    #[test]
    fn wavg_requires_weights() {
        let bad = "[pblock.1]\nrm = \"loda\"\n[pblock.2]\nrm = \"loda\"\n[combo.1]\nmethod = \"wavg\"\ninputs = [1, 2]\n";
        assert!(FseadConfig::from_str(bad).is_err());
    }
}
