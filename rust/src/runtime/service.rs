//! The PJRT device service: a single thread owning the CPU PJRT client,
//! compiled executables and per-instance detector state.
//!
//! `xla`'s wrapper types hold raw pointers and are `!Send`, so everything
//! PJRT lives here; the rest of the system (pblocks, experiments, the CLI)
//! talks to it through [`RuntimeHandle`] over channels with plain `Vec<f32>`
//! payloads. This also faithfully models *one physical FPGA* shared by all
//! pblocks — requests serialise at the device boundary exactly like DMA
//! transactions serialise on the real board.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use super::artifact::{ArtifactMeta, Registry};
use crate::detectors::params::{LodaParams, RsHashParams, XStreamParams};

/// Parameters for a detector instance (owned by the coordinator).
#[derive(Clone, Debug)]
pub enum DetectorParams {
    Loda(LodaParams),
    RsHash(RsHashParams),
    XStream(XStreamParams),
}

/// Handle to a loaded detector instance (executable + streaming state).
pub type InstanceId = u64;

/// Execution statistics (for §Perf and the GOPS experiments).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
    pub samples: u64,
    /// Detector instances currently resident on the device — a gauge, not a
    /// counter. `LoadedRm` unloads its instance on drop, so under the
    /// session server this stays bounded by the partition count; a steadily
    /// growing value means leaked instances.
    pub instances: u64,
}

enum Job {
    LoadDetector { meta: ArtifactMeta, params: Box<DetectorParams>, reply: Sender<Result<InstanceId>> },
    RunChunk { inst: InstanceId, data: Arc<[f32]>, mask: Arc<[f32]>, reply: Sender<Result<Vec<f32>>> },
    RunChunks { inst: InstanceId, chunks: Vec<(Arc<[f32]>, Arc<[f32]>)>, reply: Sender<Result<Vec<Vec<f32>>>> },
    ResetState { inst: InstanceId, reply: Sender<Result<()>> },
    DropInstance { inst: InstanceId, reply: Sender<Result<()>> },
    RunBypass { d: usize, data: Arc<[f32]>, reply: Sender<Result<Vec<f32>>> },
    RunCombo { method: String, scores: Vec<f32>, active: Vec<f32>, weights: Arc<[f32]>, reply: Sender<Result<Vec<f32>>> },
    /// Compile an artifact without instantiating (reconfiguration timing).
    Precompile { name: String, reply: Sender<Result<f64>> },
    Stats { reply: Sender<RuntimeStats> },
    Shutdown,
}

/// Cheap cloneable handle used across the fabric.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Job>,
}

macro_rules! ask {
    ($self:ident, $job:expr) => {{
        let (reply, rx) = channel();
        let job = $job(reply);
        $self
            .tx
            .send(job)
            .map_err(|_| anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }};
}

impl RuntimeHandle {
    pub fn load_detector(&self, meta: &ArtifactMeta, params: DetectorParams) -> Result<InstanceId> {
        ask!(self, |reply| Job::LoadDetector {
            meta: meta.clone(),
            params: Box::new(params),
            reply
        })
    }

    /// Run one padded chunk; returns per-sample scores (0 beyond the mask).
    /// Accepts `Vec<f32>` or shared `Arc<[f32]>` payloads — flit payloads
    /// are submitted without copying.
    pub fn run_chunk(
        &self,
        inst: InstanceId,
        data: impl Into<Arc<[f32]>>,
        mask: impl Into<Arc<[f32]>>,
    ) -> Result<Vec<f32>> {
        let (data, mask) = (data.into(), mask.into());
        ask!(self, |reply| Job::RunChunk { inst, data, mask, reply })
    }

    /// Batched submission: run a burst of `(data, mask)` chunks in stream
    /// order with a single channel round-trip (the fast-path plumbing — the
    /// per-chunk request/reply hop is part of the L3 marshalling overhead
    /// measured by `fsead exp perf`). Payloads are shared `Arc` buffers, so
    /// submitting a burst of flits clones pointers, never samples. State
    /// threads through the burst exactly as it does across individual
    /// [`RuntimeHandle::run_chunk`] calls; scores come back per chunk.
    pub fn run_chunks(
        &self,
        inst: InstanceId,
        chunks: Vec<(Arc<[f32]>, Arc<[f32]>)>,
    ) -> Result<Vec<Vec<f32>>> {
        ask!(self, |reply| Job::RunChunks { inst, chunks, reply })
    }

    pub fn reset_state(&self, inst: InstanceId) -> Result<()> {
        ask!(self, |reply| Job::ResetState { inst, reply })
    }

    pub fn drop_instance(&self, inst: InstanceId) -> Result<()> {
        ask!(self, |reply| Job::DropInstance { inst, reply })
    }

    pub fn run_bypass(&self, d: usize, data: impl Into<Arc<[f32]>>) -> Result<Vec<f32>> {
        let data = data.into();
        ask!(self, |reply| Job::RunBypass { d, data, reply })
    }

    /// Combine up to 4 score streams (flattened row-major `[C,4]`).
    /// `weights` is shared — combo pblocks pad it once per stream and clone
    /// the pointer per flit.
    pub fn run_combo(
        &self,
        method: &str,
        scores: Vec<f32>,
        active: Vec<f32>,
        weights: impl Into<Arc<[f32]>>,
    ) -> Result<Vec<f32>> {
        let weights = weights.into();
        ask!(self, |reply| Job::RunCombo {
            method: method.to_string(),
            scores,
            active,
            weights,
            reply
        })
    }

    /// Compile (or hit the cache for) an artifact; returns compile seconds.
    pub fn precompile(&self, name: &str) -> Result<f64> {
        ask!(self, |reply| Job::Precompile { name: name.to_string(), reply })
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let (reply, rx) = channel();
        self.tx.send(Job::Stats { reply }).map_err(|_| anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))
    }

    /// A handle not backed by any device thread — every request errors with
    /// "runtime service is down". For unit tests that need a
    /// `RuntimeHandle` value without starting PJRT.
    pub fn disconnected() -> RuntimeHandle {
        let (tx, _rx) = channel();
        RuntimeHandle { tx }
    }
}

/// The running service; drop or call [`Runtime::shutdown`] to stop.
pub struct Runtime {
    tx: Sender<Job>,
    join: Option<std::thread::JoinHandle<()>>,
    registry: Registry,
}

impl Runtime {
    /// Start the device thread over an artifact directory.
    pub fn start(artifact_dir: &str) -> Result<Runtime> {
        // Quiet the TFRT client's INFO chatter unless the user overrides.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let registry = Registry::load(artifact_dir)?;
        let (tx, rx) = channel();
        let reg = registry.clone();
        let join = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || service_main(reg, rx))
            .context("spawning device thread")?;
        Ok(Runtime { tx, join: Some(join), registry })
    }

    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.clone() }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Service internals (PJRT-side; never leaves the device thread)
// ---------------------------------------------------------------------------

struct Instance {
    meta: ArtifactMeta,
    exe_name: String,
    params: Vec<xla::Literal>,
    state: Vec<xla::Literal>,
}

struct Service {
    client: xla::PjRtClient,
    registry: Registry,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    instances: HashMap<InstanceId, Instance>,
    next_id: InstanceId,
    stats: RuntimeStats,
}

fn service_main(registry: Registry, rx: Receiver<Job>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Drain jobs with errors; cannot operate without a client.
            for job in rx.iter() {
                fail_job(job, &format!("PJRT client failed to start: {e}"));
            }
            return;
        }
    };
    let mut svc = Service {
        client,
        registry,
        exes: HashMap::new(),
        instances: HashMap::new(),
        next_id: 1,
        stats: RuntimeStats::default(),
    };
    for job in rx.iter() {
        match job {
            Job::Shutdown => break,
            Job::Stats { reply } => {
                let mut stats = svc.stats.clone();
                stats.instances = svc.instances.len() as u64;
                let _ = reply.send(stats);
            }
            Job::LoadDetector { meta, params, reply } => {
                let _ = reply.send(svc.load_detector(&meta, *params));
            }
            Job::RunChunk { inst, data, mask, reply } => {
                // One-chunk burst: the single-flit path shares the burst
                // executor, so there is one device-invocation protocol.
                let _ = reply.send(
                    svc.run_chunks(inst, &[(data, mask)])
                        .map(|mut v| v.pop().expect("one chunk in, one score out")),
                );
            }
            Job::RunChunks { inst, chunks, reply } => {
                let _ = reply.send(svc.run_chunks(inst, &chunks));
            }
            Job::ResetState { inst, reply } => {
                let _ = reply.send(svc.reset_state(inst));
            }
            Job::DropInstance { inst, reply } => {
                let _ = reply.send(svc.drop_instance(inst));
            }
            Job::RunBypass { d, data, reply } => {
                let _ = reply.send(svc.run_bypass(d, &data));
            }
            Job::RunCombo { method, scores, active, weights, reply } => {
                let _ = reply.send(svc.run_combo(&method, scores, active, weights));
            }
            Job::Precompile { name, reply } => {
                let _ = reply.send(svc.precompile(&name));
            }
        }
    }
}

fn fail_job(job: Job, msg: &str) {
    let err = || anyhow!("{msg}");
    match job {
        Job::LoadDetector { reply, .. } => drop(reply.send(Err(err()))),
        Job::RunChunk { reply, .. } => drop(reply.send(Err(err()))),
        Job::RunChunks { reply, .. } => drop(reply.send(Err(err()))),
        Job::ResetState { reply, .. } => drop(reply.send(Err(err()))),
        Job::DropInstance { reply, .. } => drop(reply.send(Err(err()))),
        Job::RunBypass { reply, .. } => drop(reply.send(Err(err()))),
        Job::RunCombo { reply, .. } => drop(reply.send(Err(err()))),
        Job::Precompile { reply, .. } => drop(reply.send(Err(err()))),
        Job::Stats { reply } => drop(reply.send(RuntimeStats::default())),
        Job::Shutdown => {}
    }
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Results accumulated across a burst; survives a mid-burst error so the
/// caller can record the work that actually ran.
#[derive(Default)]
struct BurstAcc {
    scores: Vec<Vec<f32>>,
    valid: u64,
    exec_secs: f64,
}

/// Inner loop of [`Service::run_chunks`] — and, via a one-chunk burst, of
/// the single-flit path — split out so the threaded state and the per-chunk
/// accounting can be written back even when a chunk fails mid-burst.
fn execute_burst(
    exe: &xla::PjRtLoadedExecutable,
    meta: &ArtifactMeta,
    params: &[xla::Literal],
    state: &mut Vec<xla::Literal>,
    chunks: &[(Arc<[f32]>, Arc<[f32]>)],
    acc: &mut BurstAcc,
) -> Result<()> {
    let dims_x = [meta.chunk as i64, meta.d as i64];
    let dims_m = [meta.chunk as i64];
    let n_outputs = 1 + state.len();
    acc.scores.reserve(chunks.len());
    for (data, mask) in chunks {
        let x = lit_f32(data, &dims_x)?;
        let m = lit_f32(mask, &dims_m)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(2 + params.len() + state.len());
        args.push(&x);
        args.push(&m);
        for p in params {
            args.push(p);
        }
        for s in state.iter() {
            args.push(s);
        }
        let t0 = Instant::now();
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        acc.exec_secs += t0.elapsed().as_secs_f64();
        drop(args);
        let mut parts = result.to_tuple()?;
        if parts.len() != n_outputs {
            bail!("artifact {} returned {}-tuple, expected {n_outputs}", meta.name, parts.len());
        }
        let scores = parts.remove(0).to_vec::<f32>()?;
        acc.valid += mask.iter().filter(|&&v| v > 0.5).count() as u64;
        *state = parts; // thread the updated state into the next chunk
        acc.scores.push(scores);
    }
    Ok(())
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

impl Service {
    /// Compile an artifact (cached by name); returns compile seconds.
    fn ensure_exe(&mut self, name: &str) -> Result<f64> {
        if self.exes.contains_key(name) {
            return Ok(0.0);
        }
        let meta = self
            .registry
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?
            .clone();
        let path = self.registry.path(&meta);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.compiles += 1;
        self.stats.compile_secs += dt;
        self.exes.insert(name.to_string(), exe);
        Ok(dt)
    }

    fn precompile(&mut self, name: &str) -> Result<f64> {
        self.ensure_exe(name)
    }

    fn zero_state(meta: &ArtifactMeta) -> Result<Vec<xla::Literal>> {
        let (r, window) = (meta.r as i64, meta.window as i64);
        let mut state = Vec::with_capacity(4);
        match meta.kind.as_str() {
            "loda" => {
                let bins = meta.bins as i64;
                state.push(lit_i32(&vec![0; (r * bins) as usize], &[r, bins])?);
                state.push(lit_i32(&vec![0; (r * window) as usize], &[r, window])?);
            }
            "rshash" | "xstream" => {
                let (w, m) = (meta.w as i64, meta.modulus as i64);
                state.push(lit_i32(&vec![0; (r * w * m) as usize], &[r, w, m])?);
                state.push(lit_i32(&vec![0; (r * w * window) as usize], &[r, w, window])?);
            }
            other => bail!("artifact kind {other:?} has no detector state"),
        }
        state.push(lit_i32(&[0], &[1])?); // pos
        state.push(lit_i32(&[0], &[1])?); // n
        Ok(state)
    }

    fn param_literals(meta: &ArtifactMeta, params: &DetectorParams) -> Result<Vec<xla::Literal>> {
        let (r, d) = (meta.r as i64, meta.d as i64);
        match (meta.kind.as_str(), params) {
            ("loda", DetectorParams::Loda(p)) => {
                if p.r != meta.r || p.d != meta.d {
                    bail!("loda params [r={} d={}] mismatch artifact {}", p.r, p.d, meta.name);
                }
                Ok(vec![
                    lit_f32(&p.prj, &[r, d])?,
                    lit_f32(&p.pmin, &[r])?,
                    lit_f32(&p.pmax, &[r])?,
                ])
            }
            ("rshash", DetectorParams::RsHash(p)) => {
                if p.r != meta.r || p.d != meta.d {
                    bail!("rshash params [r={} d={}] mismatch artifact {}", p.r, p.d, meta.name);
                }
                Ok(vec![
                    lit_f32(&p.dmin, &[d])?,
                    lit_f32(&p.dmax, &[d])?,
                    lit_f32(&p.alpha, &[r, d])?,
                    lit_f32(&p.f, &[r])?,
                ])
            }
            ("xstream", DetectorParams::XStream(p)) => {
                if p.r != meta.r || p.d != meta.d || p.k != meta.k || p.w != meta.w {
                    bail!("xstream params mismatch artifact {}", meta.name);
                }
                let (k, w) = (meta.k as i64, meta.w as i64);
                Ok(vec![
                    lit_f32(&p.proj, &[r, d, k])?,
                    lit_f32(&p.shift, &[r, w, k])?,
                    lit_f32(&p.width, &[r, k])?,
                ])
            }
            (kind, _) => bail!("params do not match artifact kind {kind:?}"),
        }
    }

    fn load_detector(&mut self, meta: &ArtifactMeta, params: DetectorParams) -> Result<InstanceId> {
        if !self.registry.available(meta) {
            bail!("artifact file missing for {} — run `make artifacts`", meta.name);
        }
        self.ensure_exe(&meta.name)?;
        let inst = Instance {
            meta: meta.clone(),
            exe_name: meta.name.clone(),
            params: Self::param_literals(meta, &params)?,
            state: Self::zero_state(meta)?,
        };
        let id = self.next_id;
        self.next_id += 1;
        self.instances.insert(id, inst);
        Ok(id)
    }

    /// Burst execution with everything burst-invariant hoisted — one
    /// instance lookup, one shape validation pass, one executable lookup
    /// and one stats update for the whole backlog. The single-flit path
    /// (`Job::RunChunk`) runs through here as a one-chunk burst, so there
    /// is exactly one device-invocation protocol. State threads
    /// chunk-to-chunk; on a mid-burst device error both the threaded state
    /// and the stats reflect the chunks that completed, exactly as they
    /// would across repeated single-chunk calls.
    fn run_chunks(
        &mut self,
        id: InstanceId,
        chunks: &[(Arc<[f32]>, Arc<[f32]>)],
    ) -> Result<Vec<Vec<f32>>> {
        if chunks.is_empty() {
            return Ok(Vec::new());
        }
        let inst = self.instances.get_mut(&id).with_context(|| format!("no instance {id}"))?;
        let (c, d) = (inst.meta.chunk, inst.meta.d);
        for (i, (data, mask)) in chunks.iter().enumerate() {
            if data.len() != c * d || mask.len() != c {
                bail!(
                    "burst chunk {i} shape mismatch for {}: got data={} mask={}, want [{c},{d}]",
                    inst.meta.name,
                    data.len(),
                    mask.len()
                );
            }
        }
        let exe = self.exes.get(&inst.exe_name).expect("exe loaded with instance");
        let mut state = std::mem::take(&mut inst.state);
        let mut acc = BurstAcc::default();
        let res = execute_burst(exe, &inst.meta, &inst.params, &mut state, chunks, &mut acc);
        inst.state = state;
        self.stats.executions += acc.scores.len() as u64;
        self.stats.execute_secs += acc.exec_secs;
        self.stats.samples += acc.valid;
        res?;
        Ok(acc.scores)
    }

    fn reset_state(&mut self, id: InstanceId) -> Result<()> {
        let inst = self.instances.get_mut(&id).with_context(|| format!("no instance {id}"))?;
        inst.state = Self::zero_state(&inst.meta)?;
        Ok(())
    }

    fn drop_instance(&mut self, id: InstanceId) -> Result<()> {
        self.instances.remove(&id).map(|_| ()).with_context(|| format!("no instance {id}"))
    }

    fn run_bypass(&mut self, d: usize, data: &[f32]) -> Result<Vec<f32>> {
        let meta = self.registry.find_bypass(d)?.clone();
        if data.len() != meta.chunk * d {
            bail!("bypass d={d}: got {} values, want {}", data.len(), meta.chunk * d);
        }
        self.ensure_exe(&meta.name)?;
        let x = lit_f32(data, &[meta.chunk as i64, d as i64])?;
        let exe = self.exes.get(&meta.name).unwrap();
        let t0 = Instant::now();
        let result = exe.execute::<&xla::Literal>(&[&x])?[0][0].to_literal_sync()?;
        self.stats.executions += 1;
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        result.to_tuple1()?.to_vec::<f32>().map_err(Into::into)
    }

    fn run_combo(
        &mut self,
        method: &str,
        scores: Vec<f32>,
        active: Vec<f32>,
        weights: Arc<[f32]>,
    ) -> Result<Vec<f32>> {
        let meta = self.registry.find_combo(method)?.clone();
        if scores.len() != meta.chunk * 4 || active.len() != 4 {
            bail!(
                "combo {method}: got scores={} active={}, want [{},4] and [4]",
                scores.len(),
                active.len(),
                meta.chunk
            );
        }
        self.ensure_exe(&meta.name)?;
        let s = lit_f32(&scores, &[meta.chunk as i64, 4])?;
        let a = lit_f32(&active, &[4])?;
        let exe = self.exes.get(&meta.name).unwrap();
        let t0 = Instant::now();
        let result = if method == "wavg" {
            // Combo pblocks pre-pad the shared weights to 4 once per stream;
            // pad a local copy only for direct callers that did not.
            let w = if weights.len() == 4 {
                lit_f32(&weights, &[4])?
            } else {
                let mut w4 = weights.to_vec();
                w4.resize(4, 0.0);
                lit_f32(&w4, &[4])?
            };
            exe.execute::<&xla::Literal>(&[&s, &a, &w])?[0][0].to_literal_sync()?
        } else {
            exe.execute::<&xla::Literal>(&[&s, &a])?[0][0].to_literal_sync()?
        };
        self.stats.executions += 1;
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        result.to_tuple1()?.to_vec::<f32>().map_err(Into::into)
    }
}
