//! Artifact registry: parses `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) and resolves detector/bypass/combo variants to
//! their HLO-text files. One artifact ≙ one "RM bitstream" of the paper.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::detectors::DetectorKind;

/// Metadata of one AOT artifact (mirrors `manifest.Variant` in python).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// loda | rshash | xstream | bypass | combo
    pub kind: String,
    pub d: usize,
    pub r: usize,
    pub chunk: usize,
    pub window: usize,
    pub bins: usize,
    pub w: usize,
    pub modulus: usize,
    pub k: usize,
    /// avg | max | wavg | or | vote | "-"
    pub combo: String,
    pub quantize: bool,
    pub file: String,
}

impl ArtifactMeta {
    pub fn detector_kind(&self) -> Option<DetectorKind> {
        DetectorKind::parse(&self.kind)
    }

    fn parse_line(line: &str) -> Result<ArtifactMeta> {
        let mut kv = BTreeMap::new();
        for tok in line.split_whitespace() {
            let Some((k, v)) = tok.split_once('=') else {
                bail!("bad manifest token {tok:?}");
            };
            kv.insert(k.to_string(), v.to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).with_context(|| format!("manifest line missing key {k:?}: {line}"))
        };
        let num = |k: &str| -> Result<usize> {
            get(k)?.parse::<usize>().with_context(|| format!("bad {k} in manifest: {line}"))
        };
        Ok(ArtifactMeta {
            name: get("name")?.clone(),
            kind: get("kind")?.clone(),
            d: num("d")?,
            r: num("r")?,
            chunk: num("chunk")?,
            window: num("window")?,
            bins: num("bins")?,
            w: num("w")?,
            modulus: num("mod")?,
            k: num("k")?,
            combo: get("combo")?.clone(),
            quantize: get("quantize")? == "1",
            file: get("file")?.clone(),
        })
    }
}

/// All artifacts in a directory.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    dir: PathBuf,
    by_name: BTreeMap<String, ArtifactMeta>,
}

impl Registry {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &str) -> Result<Registry> {
        let dir = PathBuf::from(dir);
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!("reading {} — run `make artifacts` first", manifest.display())
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: PathBuf, text: &str) -> Result<Registry> {
        let mut by_name = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let meta = ArtifactMeta::parse_line(line)?;
            by_name.insert(meta.name.clone(), meta);
        }
        Ok(Registry { dir, by_name })
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(|s| s.as_str())
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name)
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Does the HLO file actually exist on disk?
    pub fn available(&self, meta: &ArtifactMeta) -> bool {
        self.path(meta).exists()
    }

    /// Resolve a detector variant.
    pub fn find_detector(
        &self,
        kind: DetectorKind,
        d: usize,
        r: usize,
        quantize: bool,
    ) -> Result<&ArtifactMeta> {
        self.by_name
            .values()
            .find(|m| {
                m.kind == kind.as_str() && m.d == d && m.r == r && m.quantize == quantize
            })
            .with_context(|| {
                format!(
                    "no artifact for {} d={d} r={r} quantize={quantize}; available: [{}]",
                    kind.as_str(),
                    self.names().collect::<Vec<_>>().join(", ")
                )
            })
    }

    pub fn find_bypass(&self, d: usize) -> Result<&ArtifactMeta> {
        self.by_name
            .values()
            .find(|m| m.kind == "bypass" && m.d == d)
            .with_context(|| format!("no bypass artifact for d={d}"))
    }

    pub fn find_combo(&self, method: &str) -> Result<&ArtifactMeta> {
        self.by_name
            .values()
            .find(|m| m.kind == "combo" && m.combo == method)
            .with_context(|| format!("no combo artifact for method {method:?}"))
    }

    /// Path of `manifest.txt` relative checks for staleness, used by `make`.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
name=loda_d3_r4 kind=loda d=3 r=4 chunk=256 window=128 bins=20 w=2 mod=128 k=20 combo=- quantize=1 file=loda_d3_r4.hlo.txt
name=bypass_d3 kind=bypass d=3 r=0 chunk=256 window=128 bins=20 w=2 mod=128 k=20 combo=- quantize=1 file=bypass_d3.hlo.txt
name=combo_avg kind=combo d=0 r=0 chunk=256 window=128 bins=20 w=2 mod=128 k=20 combo=avg quantize=1 file=combo_avg.hlo.txt
";

    #[test]
    fn parses_sample_manifest() {
        let reg = Registry::parse(PathBuf::from("/tmp"), SAMPLE).unwrap();
        assert_eq!(reg.len(), 3);
        let loda = reg.find_detector(DetectorKind::Loda, 3, 4, true).unwrap();
        assert_eq!(loda.window, 128);
        assert_eq!(loda.file, "loda_d3_r4.hlo.txt");
        assert!(reg.find_bypass(3).is_ok());
        assert!(reg.find_combo("avg").is_ok());
    }

    #[test]
    fn missing_variant_lists_alternatives() {
        let reg = Registry::parse(PathBuf::from("/tmp"), SAMPLE).unwrap();
        let err = reg.find_detector(DetectorKind::XStream, 3, 4, true).unwrap_err().to_string();
        assert!(err.contains("loda_d3_r4"), "{err}");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Registry::parse(PathBuf::from("/tmp"), "name=x garbage\n").is_err());
        assert!(Registry::parse(PathBuf::from("/tmp"), "kind=loda\n").is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // Integration sanity: if `make artifacts` has run, the real manifest
        // must parse and contain the full-size pblock variants.
        if let Ok(reg) = Registry::load("artifacts") {
            for kind in DetectorKind::ALL {
                for d in [3usize, 9, 21] {
                    assert!(
                        reg.find_detector(kind, d, kind.pblock_r(), true).is_ok(),
                        "{kind:?} d={d}"
                    );
                }
            }
        }
    }
}
