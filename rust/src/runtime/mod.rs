//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client from a single device-service thread.
//! See `service.rs` for why PJRT is confined to one thread.

pub mod artifact;
pub mod service;

pub use artifact::{ArtifactMeta, Registry};
pub use service::{DetectorParams, InstanceId, Runtime, RuntimeHandle, RuntimeStats};

use crate::config::DetectorHyper;
use crate::detectors::params::{LodaParams, RsHashParams, XStreamParams};
use crate::detectors::DetectorKind;

/// Generate coordinator-owned parameters for a detector instance — the same
/// values the CPU baseline uses, enabling exact parity runs.
pub fn generate_params(
    kind: DetectorKind,
    seed: u64,
    r: usize,
    d: usize,
    hyper: &DetectorHyper,
    warmup: &[f32],
) -> DetectorParams {
    match kind {
        DetectorKind::Loda => DetectorParams::Loda(LodaParams::generate(seed, r, d, warmup)),
        DetectorKind::RsHash => {
            DetectorParams::RsHash(RsHashParams::generate(seed, r, d, hyper.window, warmup))
        }
        DetectorKind::XStream => DetectorParams::XStream(XStreamParams::generate(
            seed,
            r,
            d,
            hyper.k,
            hyper.w,
            warmup,
        )),
    }
}
