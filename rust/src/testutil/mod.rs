//! Property-testing mini-framework (proptest is unavailable offline —
//! DESIGN.md §6 substitution 4).
//!
//! `forall` runs a property over `cases` randomly generated inputs from a
//! seeded [`Gen`]; on failure it retries with progressively simpler sizes
//! (a light-weight stand-in for shrinking) and reports the failing seed so
//! the case can be replayed deterministically.

use crate::detectors::prng::Prng;

/// Random input source handed to generators and properties.
pub struct Gen {
    pub rng: Prng,
    /// Size hint in [0, 1]: generators should scale magnitude/length by it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Prng::new(seed), size }
    }

    /// usize in [lo, hi], scaled down for small sizes.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + if scaled == 0 { 0 } else { self.rng.below(scaled + 1) }
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo as f64, hi as f64) as f32
    }

    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn gaussian_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.gaussian() as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.uniform() < 0.5
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `prop` over `cases` generated inputs. Panics with the failing seed.
/// Case sizes ramp from small to large so early failures are simple ones.
pub fn forall<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = fx64(name);
    for case in 0..cases {
        let size = 0.1 + 0.9 * (case as f64 / cases.max(1) as f64);
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // "Shrink": retry the same seed at smaller sizes to report the
            // simplest reproduction we can find.
            let mut simplest = (size, msg.clone());
            for shrink in 1..=4 {
                let s = size / (1 << shrink) as f64;
                let mut g = Gen::new(seed, s);
                if let Err(m) = prop(&mut g) {
                    simplest = (s, m);
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, size {:.3}):\n  {}",
                simplest.0, simplest.1
            );
        }
    }
}

/// Convenience assert for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

fn fx64(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("add-commutes", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            prop_assert!(a + b == b + a, "{a} + {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        forall("always-fails", 3, |_| Err("nope".to_string()));
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_len = 0;
        forall("size-ramp", 20, |g| {
            let len = g.usize_in(0, 100);
            if len > max_len {
                max_len = len;
            }
            Ok(())
        });
        assert!(max_len > 50, "sizes never ramped: {max_len}");
    }

    #[test]
    fn deterministic_given_name() {
        let mut first: Vec<usize> = vec![];
        forall("det", 5, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = vec![];
        forall("det", 5, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
