//! ROC-AUC via the Mann–Whitney U statistic (rank-based, tie-aware) plus the
//! paper's score post-processing: normalisation to [0,1) and thresholding by
//! the known contamination rate (§4.1).

/// Area under the ROC curve for `scores` against binary `truth`
/// (true = anomaly). Tie-aware: tied scores get average ranks.
/// Returns 0.5 when either class is empty.
pub fn auc_roc(scores: &[f32], truth: &[bool]) -> f64 {
    assert_eq!(scores.len(), truth.len());
    let n_pos = truth.iter().filter(|&&t| t).count();
    let n_neg = truth.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal));
    // Average ranks over tie groups; accumulate rank-sum of positives.
    let mut rank_sum_pos = 0f64;
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        // 1-based ranks i+1 ..= j+1 → average
        let avg_rank = (i + 1 + j + 1) as f64 / 2.0;
        for &idx in &order[i..=j] {
            if truth[idx] {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos as f64 * (n_pos as f64 + 1.0)) / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Min-max normalise scores to [0, 1) (paper §4.1). Constant vectors map to 0.
pub fn normalize_scores(scores: &[f32]) -> Vec<f32> {
    let lo = scores.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !(hi > lo) {
        return vec![0.0; scores.len()];
    }
    let span = (hi - lo) * (1.0 + 1e-6); // keep strictly < 1
    scores.iter().map(|&s| (s - lo) / span).collect()
}

/// Binarise scores by contamination rate: the top `contamination` fraction
/// becomes label 1 (paper §4.1 — "the anomaly percentage ... the users know
/// in advance").
pub fn labels_from_scores(scores: &[f32], contamination: f64) -> Vec<bool> {
    if scores.is_empty() {
        return vec![];
    }
    let k = ((scores.len() as f64) * contamination).round() as usize;
    let k = k.clamp(0, scores.len());
    if k == 0 {
        return vec![false; scores.len()];
    }
    let mut sorted: Vec<f32> = scores.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    let threshold = sorted[k - 1];
    scores.iter().map(|&s| s >= threshold).collect()
}

/// AUC of binary labels against truth — used for the paper's AUC-L columns.
pub fn auc_labels(labels: &[bool], truth: &[bool]) -> f64 {
    let as_scores: Vec<f32> = labels.iter().map(|&l| if l { 1.0 } else { 0.0 }).collect();
    auc_roc(&as_scores, truth)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_gives_one() {
        let scores = [0.1, 0.2, 0.3, 0.9, 0.95];
        let truth = [false, false, false, true, true];
        assert!((auc_roc(&scores, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_separation_gives_zero() {
        let scores = [0.9, 0.8, 0.1, 0.2];
        let truth = [false, false, true, true];
        assert!(auc_roc(&scores, &truth).abs() < 1e-12);
    }

    #[test]
    fn symmetric_interleave_is_half() {
        // Positive ranks {1,4}, negative ranks {2,3} → U = 2 → AUC = 0.5.
        let scores = [0.1, 0.2, 0.3, 0.4];
        let truth = [true, false, false, true];
        assert!((auc_roc(&scores, &truth) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mostly_inverted_interleave_is_quarter() {
        // Positive ranks {1,3} → U = 1 → AUC = 0.25.
        let scores = [0.1, 0.2, 0.3, 0.4];
        let truth = [true, false, true, false];
        assert!((auc_roc(&scores, &truth) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn all_ties_is_half() {
        let scores = [0.5; 6];
        let truth = [true, false, true, false, false, true];
        assert!((auc_roc(&scores, &truth) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_class_returns_half() {
        assert_eq!(auc_roc(&[1.0, 2.0], &[false, false]), 0.5);
        assert_eq!(auc_roc(&[1.0, 2.0], &[true, true]), 0.5);
    }

    #[test]
    fn auc_invariant_under_monotone_transform() {
        let scores = [0.3f32, 1.5, -0.2, 0.9, 2.4, 0.01];
        let truth = [false, true, false, false, true, false];
        let a = auc_roc(&scores, &truth);
        let transformed: Vec<f32> = scores.iter().map(|&s| (2.0 * s).exp()).collect();
        let b = auc_roc(&transformed, &truth);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn normalize_maps_into_unit_interval() {
        let n = normalize_scores(&[3.0, -1.0, 5.0, 0.0]);
        assert!(n.iter().all(|&v| (0.0..1.0).contains(&v)));
        assert_eq!(n[1], 0.0);
        assert!(n[2] > 0.999);
    }

    #[test]
    fn normalize_constant_is_zero() {
        assert_eq!(normalize_scores(&[2.0, 2.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn labels_pick_top_contamination_fraction() {
        let scores = [0.1, 0.9, 0.2, 0.8, 0.3, 0.7, 0.4, 0.6, 0.5, 0.0];
        let labels = labels_from_scores(&scores, 0.2);
        let n_pos = labels.iter().filter(|&&l| l).count();
        assert_eq!(n_pos, 2);
        assert!(labels[1] && labels[3]);
    }

    #[test]
    fn zero_contamination_gives_no_labels() {
        assert!(labels_from_scores(&[1.0, 2.0, 3.0], 0.0).iter().all(|&l| !l));
    }

    #[test]
    fn label_auc_matches_balanced_accuracy_identity() {
        // For binary predictions AUC = (TPR + TNR) / 2.
        let labels = [true, true, false, false, true, false];
        let truth = [true, false, false, false, true, true];
        let tpr = 2.0 / 3.0;
        let tnr = 2.0 / 3.0;
        assert!((auc_labels(&labels, &truth) - (tpr + tnr) / 2.0).abs() < 1e-12);
    }
}
