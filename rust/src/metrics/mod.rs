//! Evaluation metrics: ROC-AUC (the paper's standard metric, §4.1),
//! score normalisation, thresholding to labels, and summary statistics.

pub mod auc;
pub mod stats;

pub use auc::{auc_roc, labels_from_scores, normalize_scores};
pub use stats::{mean, variance, OnlineStats};
