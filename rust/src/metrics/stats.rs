//! Summary statistics for the experiment harness (mean / variance over the
//! 10-seed repetitions of Fig 10 and Table 5) and a Welford online
//! accumulator for streaming telemetry.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (matches the paper's reported AUC variance scale).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Welford's online mean/variance/min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [0.3, -1.2, 4.5, 2.2, 0.0, 7.7];
        let mut os = OnlineStats::new();
        for &x in &xs {
            os.push(x);
        }
        assert!((os.mean() - mean(&xs)).abs() < 1e-12);
        assert!((os.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(os.min(), -1.2);
        assert_eq!(os.max(), 7.7);
        assert_eq!(os.n(), 6);
    }
}
