//! Execution modes (`fsead exp modes`): sequential vs lock-step (the paper's
//! §4.4 scheme) vs the lock-free batched engine, on the Fig-11 workload
//! shape — R=64 sub-detectors over a synthetic stream, 4 worker threads.
//! This is the CPU-side half of the perf trajectory recorded by
//! `benches/throughput_modes.rs` (`BENCH_throughput.json`).

use anyhow::Result;
use std::time::Instant;

use super::report::Table;
use super::ExpCtx;
use crate::data::synth::{generate_profile, DatasetProfile};
use crate::detectors::{DetectorKind, DetectorSpec};
use crate::ensemble::{run_ensemble, run_sequential, ExecMode};

/// Acceptance workload: R=64 sub-detectors, 4 threads.
pub const R: usize = 64;
pub const THREADS: usize = 4;

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let n = ctx.max_samples.unwrap_or(20_000).min(20_000);
    let profile = DatasetProfile { name: "modes", n, d: 8, outliers: n / 100, clusters: 3 };
    let ds = generate_profile(&profile, ctx.seed);
    let mut out = format!(
        "== Execution modes: sequential / lock-step / batched (synthetic n={} d={} R={R}, {THREADS} threads) ==\n",
        ds.n(),
        ds.d
    );
    let mut t = Table::new(vec!["detector", "mode", "time", "samples/s", "vs lock-step"]);
    for kind in DetectorKind::ALL {
        let spec = DetectorSpec::new(kind, ds.d, R, ctx.seed);
        let t0 = Instant::now();
        let seq = run_sequential(&spec, &ds);
        let t_seq = t0.elapsed().as_secs_f64();
        let mut t_lock = f64::NAN;
        for mode in ExecMode::ALL {
            let t0 = Instant::now();
            let scores = run_ensemble(&spec, &ds, THREADS, mode);
            let dt = t0.elapsed().as_secs_f64();
            if mode == ExecMode::LockStep {
                t_lock = dt;
            }
            // Every mode must agree with the sequential reference.
            for (i, (a, b)) in seq.iter().zip(&scores).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{kind:?} {mode:?} diverged at sample {i}: {a} vs {b}"
                );
            }
            t.row(vec![
                kind.as_str().into(),
                mode.as_str().into(),
                format!("{:.1} ms", dt * 1e3),
                format!("{:.0}", ds.n() as f64 / dt),
                format!("{:.2}x", t_lock / dt),
            ]);
        }
        t.row(vec![
            kind.as_str().into(),
            "sequential".into(),
            format!("{:.1} ms", t_seq * 1e3),
            format!("{:.0}", ds.n() as f64 / t_seq),
            format!("{:.2}x", t_lock / t_seq),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "lock-step reproduces Fig 11's mutex+barrier contention; batched is the\n\
         production path (lock-free chunked workers, one merge pass).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_quickly_on_small_prefix() {
        let ctx = ExpCtx { max_samples: Some(400), ..Default::default() };
        let out = run(&ctx).unwrap();
        assert!(out.contains("batched"));
        assert!(out.contains("lockstep"));
        assert!(out.contains("sequential"));
    }
}
