//! Tables 8–10: accuracy and execution-time comparison, CPU vs fSEAD, for
//! one detector across the four datasets.
//!
//! - AUC columns are *measured*: the CPU baseline and the PJRT "FPGA" run
//!   the same parameters over the same stream (quantized artifacts vs f32
//!   CPU — the paper's ap_fixed<32,16> vs float32 situation).
//! - CPU time is measured on the rust baseline (4 threads, paper §4.4).
//! - FPGA time is the calibrated model (DESIGN.md §6 substitution 1); the
//!   PJRT wall-clock is also reported as "sim".
//! Paper values are printed alongside for every cell.

use anyhow::Result;
use std::time::Instant;

use super::report::Table;
use super::{score_label_auc, ExpCtx, DATASETS};
use crate::config::{FseadConfig, PblockCfg, RmKind};
use crate::detectors::{DetectorKind, DetectorSpec};
use crate::ensemble::run_threaded;
use crate::fabric::Fabric;
use crate::hw::timing::FpgaTimingModel;

pub struct Row {
    pub dataset: String,
    pub auc_s_cpu: f64,
    pub auc_s_fpga: f64,
    pub auc_l_cpu: f64,
    pub auc_l_fpga: f64,
    pub cpu_ms: f64,
    pub fpga_model_ms: f64,
    pub fpga_sim_ms: f64,
    pub speedup: f64,
    pub n: usize,
}

/// Full-fabric homogeneous ensemble scores through the PJRT path (falls
/// back to CPU-quantized RMs when artifacts are unavailable).
fn fpga_scores(
    ctx: &ExpCtx,
    kind: DetectorKind,
    ds: &crate::data::Dataset,
) -> Result<(Vec<f32>, f64)> {
    let mut cfg = FseadConfig::default();
    cfg.seed = ctx.seed;
    cfg.artifact_dir = ctx.artifact_dir.clone();
    cfg.use_fpga = ctx.use_fpga && ctx.artifacts_available();
    cfg.chunk = if cfg.use_fpga { 256 } else { 512 };
    for id in 1..=7usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(kind),
            r: kind.pblock_r(),
            stream: 0,
            lanes: 0,
        });
    }
    let mut fabric = Fabric::new(cfg, vec![ds.clone()])?;
    let out = fabric.run()?;
    // Host-side averaging of the seven pblock ensembles (≡ combo cascade).
    let streams: Vec<&Vec<f32>> = out.pblock_scores.values().collect();
    let n = streams[0].len();
    let mut combined = vec![0f32; n];
    for s in &streams {
        for (c, v) in combined.iter_mut().zip(s.iter()) {
            *c += *v / streams.len() as f32;
        }
    }
    Ok((combined, out.wall_secs))
}

/// CPU baseline: one ensemble of 7×pblock_r sub-detectors on 4 threads.
fn cpu_scores(ctx: &ExpCtx, kind: DetectorKind, ds: &crate::data::Dataset) -> (Vec<f32>, f64) {
    let r = 7 * kind.pblock_r();
    let spec = DetectorSpec::new(kind, ds.d, r, ctx.seed);
    let t0 = Instant::now();
    let scores = run_threaded(&spec, ds, 4);
    (scores, t0.elapsed().as_secs_f64())
}

pub fn evaluate(ctx: &ExpCtx, kind: DetectorKind, dataset: &str) -> Result<Row> {
    let ds = ctx.dataset(dataset, ctx.seed)?;
    let contamination = ds.contamination();
    let (cpu, cpu_secs) = cpu_scores(ctx, kind, &ds);
    let (fpga, sim_secs) = fpga_scores(ctx, kind, &ds)?;
    let (auc_s_cpu, auc_l_cpu) = score_label_auc(&cpu, &ds.labels, contamination);
    let (auc_s_fpga, auc_l_fpga) = score_label_auc(&fpga, &ds.labels, contamination);
    let model = FpgaTimingModel::default();
    let fpga_model = model.exec_time_s(kind, ds.n(), ds.d);
    Ok(Row {
        dataset: dataset.to_string(),
        auc_s_cpu,
        auc_s_fpga,
        auc_l_cpu,
        auc_l_fpga,
        cpu_ms: cpu_secs * 1e3,
        fpga_model_ms: fpga_model * 1e3,
        fpga_sim_ms: sim_secs * 1e3,
        speedup: cpu_secs / fpga_model,
        n: ds.n(),
    })
}

pub fn run(ctx: &ExpCtx, kind: DetectorKind) -> Result<String> {
    let table_no = match kind {
        DetectorKind::Loda => 8,
        DetectorKind::RsHash => 9,
        DetectorKind::XStream => 10,
    };
    let mut out = format!(
        "== Table {table_no}: CPU vs fSEAD for {} (R = {} over 7 pblocks) ==\n",
        kind.as_str(),
        7 * kind.pblock_r()
    );
    if ctx.max_samples.is_some() {
        out.push_str("(streams capped — use --full for paper-scale runs)\n");
    }
    let mut t = Table::new(vec![
        "Dataset",
        "n",
        "AUC-S cpu",
        "AUC-S fpga",
        "AUC-L cpu",
        "AUC-L fpga",
        "t_cpu",
        "t_fpga model",
        "t_fpga sim",
        "speedup",
        "paper t_cpu/t_fpga/speedup",
    ]);
    for dataset in DATASETS {
        let row = evaluate(ctx, kind, dataset)?;
        let p_cpu = FpgaTimingModel::paper_cpu_ms(kind, dataset).unwrap();
        let p_fpga = FpgaTimingModel::paper_exec_ms(kind, dataset).unwrap();
        t.row(vec![
            row.dataset.clone(),
            row.n.to_string(),
            format!("{:.4}", row.auc_s_cpu),
            format!("{:.4}", row.auc_s_fpga),
            format!("{:.4}", row.auc_l_cpu),
            format!("{:.4}", row.auc_l_fpga),
            format!("{:.1} ms", row.cpu_ms),
            format!("{:.1} ms", row.fpga_model_ms),
            format!("{:.1} ms", row.fpga_sim_ms),
            format!("{:.2}x", row.speedup),
            format!("{p_cpu:.0}/{p_fpga:.1}/{:.2}x", p_cpu / p_fpga),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "shape check: speed-up grows with stream size; CPU and FPGA AUC agree to ~1e-3.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_cardio_no_fpga() {
        let ctx = ExpCtx {
            seeds: 1,
            max_samples: Some(1200),
            use_fpga: false,
            ..Default::default()
        };
        let row = evaluate(&ctx, DetectorKind::Loda, "cardio").unwrap();
        assert!((0.4..=1.0).contains(&row.auc_s_cpu));
        // CPU f32 vs CPU-quantized stand-in agree closely.
        assert!((row.auc_s_cpu - row.auc_s_fpga).abs() < 0.02);
        assert!(row.fpga_model_ms > 0.8);
        assert!(row.speedup > 0.0);
    }
}
