//! Tables 11 & 12: operation-count formulas and GOPS comparison.
//! OPs come from the paper's closed forms; CPU GOPS uses our measured
//! baseline time, fSEAD GOPS uses the calibrated FPGA timing model.
//! Paper GOPS are printed alongside.

use anyhow::Result;
use std::time::Instant;

use super::report::Table;
use super::{ExpCtx, DATASETS};
use crate::detectors::{DetectorKind, DetectorSpec};
use crate::ensemble::run_threaded;
use crate::hw::opcount::{gops, op_count, paper_gops, OpParams};
use crate::hw::timing::FpgaTimingModel;

pub fn params_for(kind: DetectorKind, n: usize, d: usize) -> OpParams {
    OpParams {
        n: n as u64,
        d: d as u64,
        r: (7 * kind.pblock_r()) as u64,
        w: crate::defaults::CMS_ROWS as u64,
        k: crate::defaults::XSTREAM_K as u64,
    }
}

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::from(
        "== Table 11: Operation counts ==\n\
         Loda:    OP = N(2Rd + 7R + 2)\n\
         RS-Hash: OP = N(5Rdw + 4Rd + 11Rw + R + 2)\n\
         xStream: OP = N(2Rdk + 5Rdw + 15Rw + 2R + 2)\n\n\
         == Table 12: GOPS (CPU measured | fSEAD model | paper cpu/fsead) ==\n",
    );
    let model = FpgaTimingModel::default();
    let mut t = Table::new(vec![
        "Detector",
        "Dataset",
        "OPs (1e9)",
        "GOPS cpu",
        "GOPS fsead",
        "paper cpu",
        "paper fsead",
    ]);
    for kind in DetectorKind::ALL {
        for dataset in DATASETS {
            let ds = ctx.dataset(dataset, ctx.seed)?;
            let p = params_for(kind, ds.n(), ds.d);
            let ops = op_count(kind, p);
            let spec = DetectorSpec::new(kind, ds.d, p.r as usize, ctx.seed);
            let t0 = Instant::now();
            run_threaded(&spec, &ds, 4);
            let cpu_secs = t0.elapsed().as_secs_f64();
            let fpga_secs = model.exec_time_s(kind, ds.n(), ds.d);
            let (p_cpu, p_fpga) = paper_gops(kind, dataset).unwrap();
            t.row(vec![
                kind.as_str().to_string(),
                dataset.to_string(),
                format!("{:.3}", ops as f64 / 1e9),
                format!("{:.2}", gops(ops, cpu_secs)),
                format!("{:.2}", gops(ops, fpga_secs)),
                format!("{p_cpu:.2}"),
                format!("{p_fpga:.2}"),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str("shape check: fSEAD GOPS > CPU GOPS everywhere; xStream highest of the three.\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsead_model_gops_beats_cpu_shape() {
        // Using paper stream sizes and the timing model only (no wall-clock),
        // the GOPS ordering of Table 12 must reproduce.
        let model = FpgaTimingModel::default();
        for kind in DetectorKind::ALL {
            for p in &crate::data::synth::PROFILES {
                let op = op_count(kind, params_for(kind, p.n, p.d));
                let g_fpga = gops(op, model.exec_time_s(kind, p.n, p.d));
                let g_cpu = gops(op, FpgaTimingModel::paper_cpu_ms(kind, p.name).unwrap() / 1e3);
                assert!(g_fpga > g_cpu, "{kind:?}/{}", p.name);
            }
        }
    }
}
