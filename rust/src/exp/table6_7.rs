//! Tables 6 & 7: FPGA resource partition and per-pblock ensemble sizing
//! (the resource-model experiments; values are the calibrated model, with
//! the paper's figures as the reference column).

use anyhow::Result;

use super::report::Table;
use super::ExpCtx;
use crate::detectors::DetectorKind;
use crate::hw::floorplan;
use crate::hw::resources::{
    pblock_ensemble_resources, ResourceModel, RP3_CAPACITY, TABLE6_BLOCKS,
};

pub fn run(ctx: &ExpCtx) -> Result<String> {
    run_with_floorplan(ctx, false)
}

pub fn run_with_floorplan(_ctx: &ExpCtx, with_floorplan: bool) -> Result<String> {
    let mut out = String::from("== Table 6: Resource partition of FPGA blocks ==\n");
    let mut t = Table::new(vec!["Block", "LUT %", "DSP %", "BRAM %", "FF %"]);
    for b in &TABLE6_BLOCKS {
        t.row(vec![
            b.name.to_string(),
            format!("{:.2}", b.lut_pct),
            format!("{:.2}", b.dsp_pct),
            format!("{:.2}", b.bram_pct),
            format!("{:.3}", b.ff_pct),
        ]);
    }
    let (lut, dsp, bram, ff) = ResourceModel::total_pct(&TABLE6_BLOCKS);
    t.row(vec![
        "Total (paper: 62.5/52.69/56.67/60.42)".to_string(),
        format!("{lut:.2}"),
        format!("{dsp:.2}"),
        format!("{bram:.2}"),
        format!("{ff:.2}"),
    ]);
    out.push_str(&t.render());

    out.push_str("\n== Table 7: Max ensemble per pblock (RP-3, the smallest) ==\n");
    let mut t = Table::new(vec![
        "Detector",
        "R (paper)",
        "R (model)",
        "LUT",
        "DSP",
        "BRAM",
        "FF",
        "binding util",
    ]);
    for kind in DetectorKind::ALL {
        let (r_paper, res) = pblock_ensemble_resources(kind);
        let r_model = ResourceModel::max_ensemble(kind, &RP3_CAPACITY);
        t.row(vec![
            kind.as_str().to_string(),
            r_paper.to_string(),
            r_model.to_string(),
            format!("{:.0}", res.lut),
            format!("{:.0}", res.dsp),
            format!("{:.1}", res.bram),
            format!("{:.0}", res.ff),
            format!("{:.1}%", res.max_utilisation(&RP3_CAPACITY) * 100.0),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nFull-fabric homogeneous capacity: {} Loda / {} RS-Hash / {} xStream sub-detectors (paper: 245/175/140)\n",
        7 * DetectorKind::Loda.pblock_r(),
        7 * DetectorKind::RsHash.pblock_r(),
        7 * DetectorKind::XStream.pblock_r(),
    ));
    if with_floorplan {
        out.push_str("\n== Figure 8/9: floorplan (abstract grid) ==\n");
        out.push_str(&floorplan::render());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_both_tables() {
        let out = run(&ExpCtx::default()).unwrap();
        assert!(out.contains("Table 6") && out.contains("Table 7"));
        assert!(out.contains("RP-3"));
        assert!(out.contains("245/175/140"));
    }

    #[test]
    fn floorplan_rendering_included_when_requested() {
        let out = run_with_floorplan(&ExpCtx::default(), true).unwrap();
        assert!(out.contains("Figure 8/9"));
    }
}
