//! `fsead serve` — drive the persistent streaming session server
//! ([`crate::fabric::server::FabricServer`]).
//!
//! Two drivers:
//!
//! - **Synthetic load** (default): N client threads open sessions over the
//!   configured partitions, stream seeded synthetic sensor data chunk by
//!   chunk in lock-step (push → receive scores), close, and repeat —
//!   reporting sessions/sec, samples/sec and per-chunk round-trip latency
//!   percentiles. [`synthetic_load`] is shared with
//!   `benches/serve_sessions.rs`, which writes the same numbers to
//!   `BENCH_serve.json`.
//! - **stdin** (`--stdin`): a line protocol (`open <d> [pblock]`,
//!   `push <v…>`, `suspend`, `resume <id>`, `close`, `quit`) with JSONL
//!   events on stdout — one JSON object per score delivery / lifecycle
//!   event.

use anyhow::{bail, Context, Result};
use std::time::Instant;

use super::ExpCtx;
use crate::config::{FseadConfig, PblockCfg, RmKind};
use crate::data::synth::{generate_profile, DatasetProfile};
use crate::detectors::DetectorKind;
use crate::fabric::net::NetServer;
use crate::fabric::operator::OperatorServer;
use crate::fabric::server::{AdmitError, FabricServer, Session, SessionSpec};
use std::sync::Arc;

/// Aggregate numbers from one synthetic-load pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadReport {
    /// Client threads driving sessions concurrently.
    pub clients: usize,
    pub sessions: u64,
    pub samples: u64,
    pub wall_secs: f64,
    pub sessions_per_sec: f64,
    pub samples_per_sec: f64,
    /// Per-chunk push→score round-trip latency percentiles (ms). Only
    /// meaningful when `latency_samples > 0` — async-drain runs (a config
    /// whose drop-policy dark windows break 1:1 framing) measure nothing.
    pub chunk_latency_p50_ms: f64,
    pub chunk_latency_p99_ms: f64,
    /// Round-trips behind the percentiles (0 = latency not measured).
    pub latency_samples: u64,
}

fn percentile_ms(sorted_secs: &[f64], p: f64) -> f64 {
    if sorted_secs.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_secs.len() - 1) as f64 * p).round() as usize;
    sorted_secs[idx] * 1e3
}

/// Drive `clients` concurrent session loops against a running server:
/// each client streams `rounds` sessions of `samples` synthetic samples,
/// chunk by chunk in lock-step, and verifies it got one score back per
/// sample. Returns the merged throughput/latency report.
pub fn synthetic_load(
    server: &FabricServer,
    clients: usize,
    rounds: usize,
    samples: usize,
) -> Result<LoadReport> {
    let chunk = server.config().chunk;
    let window = server.config().hyper.window;
    // Lock-step (push one flit, block for its score flit) assumes 1:1
    // input→score framing. A drop-policy dark window deletes flits, so a
    // config that can trigger one (scripted schedule or adaptive
    // controller) must poll asynchronously instead — blocking would wait
    // forever on a score that was dropped.
    let dfx = &server.config().dfx;
    let lockstep = dfx.policy == crate::config::DarkPolicy::Bypass
        || (!dfx.adaptive && dfx.swaps.is_empty());
    let t0 = Instant::now();
    let mut all_latencies: Vec<f64> = Vec::new();
    let mut sessions = 0u64;
    let mut total_samples = 0u64;
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for client in 0..clients {
            handles.push(scope.spawn(move || -> Result<(u64, u64, Vec<f64>)> {
                let mut latencies = Vec::new();
                let mut done = 0u64;
                let mut scored = 0u64;
                for round in 0..rounds {
                    let profile = DatasetProfile {
                        name: "serve",
                        n: samples,
                        d: 3,
                        outliers: samples / 50,
                        clusters: 2,
                    };
                    let ds = generate_profile(&profile, (client * 131 + round) as u64 + 1);
                    let mut session = server.open(SessionSpec::for_dataset(&ds, window))?;
                    let mut got = 0usize;
                    for block in ds.data.chunks(chunk * ds.d) {
                        let t = Instant::now();
                        session.push(block)?;
                        if lockstep && block.len() == chunk * ds.d {
                            // One full input flit ⇒ one score flit back.
                            let scores =
                                session.recv_scores().context("score stream ended early")?;
                            latencies.push(t.elapsed().as_secs_f64());
                            got += scores.len();
                        } else {
                            got += session.poll_scores().len();
                        }
                    }
                    let closed = session.close()?;
                    got += closed.scores.len();
                    // Drop-policy dark windows legitimately shorten the
                    // score stream; otherwise every sample must score.
                    if got != ds.n() && (lockstep || got > ds.n()) {
                        bail!("session returned {got} scores for {} samples", ds.n());
                    }
                    done += 1;
                    scored += got as u64;
                }
                Ok((done, scored, latencies))
            }));
        }
        for h in handles {
            let (done, scored, lat) =
                h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??;
            sessions += done;
            total_samples += scored;
            all_latencies.extend(lat);
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    all_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(LoadReport {
        clients,
        sessions,
        samples: total_samples,
        wall_secs: wall,
        sessions_per_sec: sessions as f64 / wall,
        samples_per_sec: total_samples as f64 / wall,
        chunk_latency_p50_ms: percentile_ms(&all_latencies, 0.50),
        chunk_latency_p99_ms: percentile_ms(&all_latencies, 0.99),
        latency_samples: all_latencies.len() as u64,
    })
}

/// Default serving topology when no config file is given: four Loda
/// partitions on CPU RMs (or the PJRT device when artifacts are built in
/// the configured `--artifacts` directory).
fn default_topology(ctx: &ExpCtx) -> FseadConfig {
    let mut cfg = FseadConfig {
        use_fpga: ctx.artifacts_available(),
        chunk: 128,
        ..FseadConfig::default()
    };
    for id in 1..=4usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 4,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

/// `fsead serve [config.toml] [--clients N] [--rounds N] [--samples N]
/// [--mux K] [--idle-evict N] [--open-timeout MS] [--shed] [--sink PATH]
/// [--spill-dir DIR] [--operator ADDR] [--stdin]`.
pub fn cli(ctx: &ExpCtx, args: &[&str]) -> Result<()> {
    let mut config: Option<&str> = None;
    let mut clients = 4usize;
    let mut rounds = 2usize;
    let mut samples = 2048usize;
    let mut stdin_mode = false;
    let mut mux: Option<usize> = None;
    let mut idle_evict: Option<u64> = None;
    let mut open_timeout: Option<u64> = None;
    let mut shed = false;
    let mut sink: Option<String> = None;
    let mut spill_dir: Option<String> = None;
    let mut operator: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        let next = |i: &mut usize| -> Result<&str> {
            *i += 1;
            args.get(*i).copied().context("missing flag value")
        };
        match args[i] {
            "--clients" => clients = next(&mut i)?.parse().context("--clients")?,
            "--rounds" => rounds = next(&mut i)?.parse().context("--rounds")?,
            "--samples" => samples = next(&mut i)?.parse().context("--samples")?,
            "--mux" => mux = Some(next(&mut i)?.parse().context("--mux")?),
            "--idle-evict" => idle_evict = Some(next(&mut i)?.parse().context("--idle-evict")?),
            "--open-timeout" => {
                open_timeout = Some(next(&mut i)?.parse().context("--open-timeout")?)
            }
            "--shed" => shed = true,
            "--sink" => sink = Some(next(&mut i)?.to_string()),
            "--spill-dir" => spill_dir = Some(next(&mut i)?.to_string()),
            "--operator" => operator = Some(next(&mut i)?.to_string()),
            "--stdin" => stdin_mode = true,
            other if config.is_none() && !other.starts_with('-') => config = Some(other),
            other => bail!("serve: unexpected argument {other:?}"),
        }
        i += 1;
    }
    if clients == 0 || rounds == 0 || samples == 0 {
        bail!("serve: --clients, --rounds and --samples must be > 0");
    }
    let mut cfg = match config {
        Some(path) => FseadConfig::from_file(path)?,
        None => default_topology(ctx),
    };
    if !ctx.use_fpga {
        cfg.use_fpga = false;
    }
    if let Some(mode) = ctx.exec {
        cfg.exec = mode;
    }
    if ctx.dfx {
        cfg.dfx.adaptive = true;
    }
    if let Some(lanes) = ctx.lanes {
        cfg.override_lanes(lanes);
    }
    if let Some(k) = mux {
        cfg.server.sessions_per_partition = k;
    }
    if let Some(n) = idle_evict {
        cfg.server.idle_evict_flits = n;
    }
    if let Some(ms) = open_timeout {
        cfg.server.open_timeout_ms = ms;
    }
    if shed {
        cfg.server.overload = crate::config::OverloadPolicy::Shed;
    }
    if let Some(path) = sink {
        cfg.server.sink_path = Some(path);
    }
    if let Some(dir) = spill_dir {
        cfg.server.spill_dir = Some(dir);
    }
    if let Some(addr) = operator {
        cfg.operator.enabled = true;
        cfg.operator.addr = addr;
    }
    cfg.artifact_dir = ctx.artifact_dir.clone();
    // Lifecycle overrides go through the same named refusals as a config
    // file (multiplexing needs CPU detector RMs, and so on).
    cfg.validate()?;
    // The operator plane shares the server through an Arc; with the plane
    // disabled the Arc is sole-owned and the path below is unchanged.
    let server = Arc::new(FabricServer::start(cfg)?);
    let op_cfg = server.config().operator.clone();
    let operator = if op_cfg.enabled {
        let op =
            OperatorServer::start(&op_cfg.addr, op_cfg.auth_token.clone(), Arc::clone(&server))?;
        println!(
            "operator plane on http://{} (GET /metrics /state, POST /swap /drain /controller)",
            op.addr()
        );
        Some(op)
    } else {
        None
    };
    println!(
        "serving {} partition(s) (exec={}, fpga={}, lanes={}, inbox={} flits)",
        server.partitions().len(),
        server.config().exec.as_str(),
        server.config().use_fpga,
        server.config().lanes,
        server.config().server.inbox_flits
    );
    if stdin_mode {
        stdin_driver(&server)?;
    } else {
        let report = synthetic_load(&server, clients, rounds, samples)?;
        println!(
            "serve: {} session(s) from {} client(s) in {:.1} ms — {:.1} sessions/s, {:.0} samples/s",
            report.sessions,
            report.clients,
            report.wall_secs * 1e3,
            report.sessions_per_sec,
            report.samples_per_sec
        );
        if report.latency_samples > 0 {
            println!(
                "  per-chunk round-trip latency: p50 {:.3} ms, p99 {:.3} ms ({} round-trips)",
                report.chunk_latency_p50_ms, report.chunk_latency_p99_ms, report.latency_samples
            );
        } else {
            println!("  per-chunk latency not measured (async drain mode)");
        }
    }
    // Stop the operator first: joining its accept thread drops that Arc
    // clone, so the unwrap below normally succeeds and shuts the fabric
    // down with a collected summary. A straggling scrape connection can
    // still hold a clone for a moment — then the last drop runs the same
    // shutdown, we just report the served count from the live counter.
    drop(operator);
    let served = server.sessions_served();
    match Arc::try_unwrap(server) {
        Ok(server) => {
            let summary = server.shutdown()?;
            println!("server closed after {} session(s)", summary.sessions_served);
        }
        Err(server) => {
            drop(server);
            println!("server closed after {served} session(s)");
        }
    }
    Ok(())
}

/// `fsead net ADDR [config.toml] [--mux K] [--idle-evict N]
/// [--open-timeout MS] [--shed] [--sink PATH] [--spill-dir DIR]
/// [--operator ADDR] [--max-conns N] [--session-base N] [--for-secs N]`.
///
/// Starts the fabric server and the frame-protocol listener
/// ([`NetServer`], see `rust/src/fabric/net.rs` for the wire format) on
/// `ADDR`. Runs until `--for-secs` elapses, or — without it — until stdin
/// reaches EOF or a `quit` line arrives (so a driving process can hold
/// the server up exactly as long as it needs).
pub fn net_cli(ctx: &ExpCtx, args: &[&str]) -> Result<()> {
    let mut addr: Option<&str> = None;
    let mut config: Option<&str> = None;
    let mut mux: Option<usize> = None;
    let mut idle_evict: Option<u64> = None;
    let mut open_timeout: Option<u64> = None;
    let mut shed = false;
    let mut sink: Option<String> = None;
    let mut spill_dir: Option<String> = None;
    let mut operator: Option<String> = None;
    let mut max_conns: Option<usize> = None;
    let mut session_base: Option<u64> = None;
    let mut for_secs: Option<u64> = None;
    let mut i = 0;
    while i < args.len() {
        let next = |i: &mut usize| -> Result<&str> {
            *i += 1;
            args.get(*i).copied().context("missing flag value")
        };
        match args[i] {
            "--mux" => mux = Some(next(&mut i)?.parse().context("--mux")?),
            "--idle-evict" => idle_evict = Some(next(&mut i)?.parse().context("--idle-evict")?),
            "--open-timeout" => {
                open_timeout = Some(next(&mut i)?.parse().context("--open-timeout")?)
            }
            "--shed" => shed = true,
            "--sink" => sink = Some(next(&mut i)?.to_string()),
            "--spill-dir" => spill_dir = Some(next(&mut i)?.to_string()),
            "--operator" => operator = Some(next(&mut i)?.to_string()),
            "--max-conns" => max_conns = Some(next(&mut i)?.parse().context("--max-conns")?),
            "--session-base" => {
                session_base = Some(next(&mut i)?.parse().context("--session-base")?)
            }
            "--for-secs" => for_secs = Some(next(&mut i)?.parse().context("--for-secs")?),
            other if addr.is_none() && !other.starts_with('-') => addr = Some(other),
            other if config.is_none() && !other.starts_with('-') => config = Some(other),
            other => bail!("net: unexpected argument {other:?}"),
        }
        i += 1;
    }
    let addr = addr.context("usage: fsead net ADDR [config.toml] (e.g. 127.0.0.1:9191)")?;
    let mut cfg = match config {
        Some(path) => FseadConfig::from_file(path)?,
        None => default_topology(ctx),
    };
    if !ctx.use_fpga {
        cfg.use_fpga = false;
    }
    if let Some(mode) = ctx.exec {
        cfg.exec = mode;
    }
    if ctx.dfx {
        cfg.dfx.adaptive = true;
    }
    if let Some(lanes) = ctx.lanes {
        cfg.override_lanes(lanes);
    }
    if let Some(k) = mux {
        cfg.server.sessions_per_partition = k;
    }
    if let Some(n) = idle_evict {
        cfg.server.idle_evict_flits = n;
    }
    if let Some(ms) = open_timeout {
        cfg.server.open_timeout_ms = ms;
    }
    if shed {
        cfg.server.overload = crate::config::OverloadPolicy::Shed;
    }
    if let Some(path) = sink {
        cfg.server.sink_path = Some(path);
    }
    if let Some(dir) = spill_dir {
        cfg.server.spill_dir = Some(dir);
    }
    if let Some(op) = operator {
        cfg.operator.enabled = true;
        cfg.operator.addr = op;
    }
    cfg.net.enabled = true;
    cfg.net.addr = addr.to_string();
    if let Some(n) = max_conns {
        cfg.net.max_connections = n;
    }
    if let Some(base) = session_base {
        // Routed fleets give each worker a distinct base (e.g. i << 32) so
        // session ids never collide when tickets move between workers.
        cfg.server.session_id_base = base;
    }
    cfg.artifact_dir = ctx.artifact_dir.clone();
    cfg.validate()?;
    let server = Arc::new(FabricServer::start(cfg)?);
    let op_cfg = server.config().operator.clone();
    let op = if op_cfg.enabled {
        let op =
            OperatorServer::start(&op_cfg.addr, op_cfg.auth_token.clone(), Arc::clone(&server))?;
        println!("operator plane on http://{}", op.addr());
        Some(op)
    } else {
        None
    };
    let net = NetServer::start(&server.config().net.addr.clone(), Arc::clone(&server))?;
    println!(
        "net plane on {} ({} partition(s), exec={}, fpga={}, inbox={} flits, max {} conns)",
        net.addr(),
        server.partitions().len(),
        server.config().exec.as_str(),
        server.config().use_fpga,
        server.config().server.inbox_flits,
        server.config().net.max_connections
    );
    match for_secs {
        Some(secs) => std::thread::sleep(std::time::Duration::from_secs(secs)),
        None => {
            use std::io::BufRead;
            let stdin = std::io::stdin();
            for line in stdin.lock().lines() {
                if line?.trim() == "quit" {
                    break;
                }
            }
        }
    }
    net.stop();
    drop(op);
    let served = server.sessions_served();
    match Arc::try_unwrap(server) {
        Ok(server) => {
            let summary = server.shutdown()?;
            println!("net server closed after {} session(s)", summary.sessions_served);
        }
        Err(server) => {
            // A connection handler still holds a clone (client attached at
            // shutdown); the last drop runs the same teardown.
            drop(server);
            println!("net server closed after {served} session(s)");
        }
    }
    Ok(())
}

/// `fsead route ADDR [config.toml] [--workers a:p,b:p,…] [--heartbeat-ms N]
/// [--max-failures N] [--checkpoint-pushes N] [--max-conns N]
/// [--for-secs N]`.
///
/// Starts the fault-tolerant session router
/// ([`crate::fabric::router::Router`]): clients speak the ordinary
/// `fsead net` frame protocol to `ADDR`, and their sessions are sharded
/// across the named workers by consistent hashing, checkpointed into
/// router-held tickets, and re-homed transparently when a worker dies or
/// drains. Workers come from `--workers` (comma-separated or repeated) or
/// `[fabric.router] workers` in the config. Runs until `--for-secs`
/// elapses, or — without it — until stdin reaches EOF or a `quit` line
/// arrives.
pub fn route_cli(ctx: &ExpCtx, args: &[&str]) -> Result<()> {
    let mut addr: Option<&str> = None;
    let mut config: Option<&str> = None;
    let mut workers: Vec<String> = Vec::new();
    let mut heartbeat_ms: Option<u64> = None;
    let mut max_failures: Option<u32> = None;
    let mut checkpoint_pushes: Option<u64> = None;
    let mut max_conns: Option<usize> = None;
    let mut for_secs: Option<u64> = None;
    let mut i = 0;
    while i < args.len() {
        let next = |i: &mut usize| -> Result<&str> {
            *i += 1;
            args.get(*i).copied().context("missing flag value")
        };
        match args[i] {
            "--workers" => {
                for w in next(&mut i)?.split(',') {
                    let w = w.trim();
                    if !w.is_empty() {
                        workers.push(w.to_string());
                    }
                }
            }
            "--heartbeat-ms" => {
                heartbeat_ms = Some(next(&mut i)?.parse().context("--heartbeat-ms")?)
            }
            "--max-failures" => {
                max_failures = Some(next(&mut i)?.parse().context("--max-failures")?)
            }
            "--checkpoint-pushes" => {
                checkpoint_pushes = Some(next(&mut i)?.parse().context("--checkpoint-pushes")?)
            }
            "--max-conns" => max_conns = Some(next(&mut i)?.parse().context("--max-conns")?),
            "--for-secs" => for_secs = Some(next(&mut i)?.parse().context("--for-secs")?),
            other if addr.is_none() && !other.starts_with('-') => addr = Some(other),
            other if config.is_none() && !other.starts_with('-') => config = Some(other),
            other => bail!("route: unexpected argument {other:?}"),
        }
        i += 1;
    }
    let addr =
        addr.context("usage: fsead route ADDR --workers host:port,… (e.g. 127.0.0.1:9290)")?;
    let mut cfg = match config {
        Some(path) => FseadConfig::from_file(path)?,
        None => {
            let _ = ctx; // the router never builds a fabric of its own
            FseadConfig::default()
        }
    };
    cfg.router.enabled = true;
    cfg.router.addr = addr.to_string();
    if !workers.is_empty() {
        cfg.router.workers = workers;
    }
    if let Some(ms) = heartbeat_ms {
        cfg.router.heartbeat_ms = ms;
    }
    if let Some(n) = max_failures {
        cfg.router.max_failures = n;
    }
    if let Some(n) = checkpoint_pushes {
        cfg.router.checkpoint_pushes = n;
    }
    if let Some(n) = max_conns {
        cfg.router.max_connections = n;
    }
    if cfg.router.workers.is_empty() {
        bail!("route: no workers — pass --workers or set [fabric.router] workers");
    }
    let router = crate::fabric::router::Router::start(&cfg.router)?;
    println!(
        "router plane on {} ({} worker(s), heartbeat {} ms, eject after {} failure(s), \
         checkpoint every {} push(es))",
        router.addr(),
        cfg.router.workers.len(),
        cfg.router.heartbeat_ms,
        cfg.router.max_failures,
        cfg.router.checkpoint_pushes
    );
    match for_secs {
        Some(secs) => std::thread::sleep(std::time::Duration::from_secs(secs)),
        None => {
            use std::io::BufRead;
            let stdin = std::io::stdin();
            for line in stdin.lock().lines() {
                if line?.trim() == "quit" {
                    break;
                }
            }
        }
    }
    let stats = router.stats();
    router.stop();
    println!(
        "router closed: {} opened, {} rerouted, {} lost, {} checkpoint(s), {} ejection(s)",
        stats.opened, stats.rerouted, stats.lost, stats.checkpoints, stats.ejections
    );
    Ok(())
}

/// Surface an admission refusal as a distinct JSONL status line so a
/// `--stdin` operator can react (retry, back off, resume elsewhere)
/// instead of losing the whole driver. Non-admission errors still abort.
fn admit_status(op: &str, err: anyhow::Error) -> Result<()> {
    let Some(e) = err.downcast_ref::<AdmitError>() else {
        return Err(err);
    };
    let code = match e {
        AdmitError::Saturated => "saturated",
        AdmitError::Timeout { .. } => "timeout",
        AdmitError::QueueFull { .. } => "queue_full",
        AdmitError::ShuttingDown => "shutting_down",
    };
    println!(
        "{{\"event\":\"admit_error\",\"op\":\"{op}\",\"code\":\"{code}\",\"detail\":{}}}",
        crate::fabric::operator::json_string(&e.to_string())
    );
    Ok(())
}

fn emit_scores(session: u64, scores: &[f32]) {
    let vals: Vec<String> = scores.iter().map(|v| format!("{v:.6}")).collect();
    println!("{{\"event\":\"scores\",\"session\":{session},\"values\":[{}]}}", vals.join(","));
}

/// Line-protocol driver over stdin, one JSONL event per line on stdout.
/// `suspend` checkpoints the open session into a ticket held in memory
/// (and in `spill_dir` when configured); `resume <id>` continues it.
fn stdin_driver(server: &FabricServer) -> Result<()> {
    use std::io::BufRead;
    let stdin = std::io::stdin();
    let mut session: Option<Session> = None;
    let mut tickets: std::collections::BTreeMap<u64, crate::fabric::SessionTicket> =
        Default::default();
    for line in stdin.lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut words = line.split_whitespace();
        match words.next().unwrap_or_default() {
            "open" => {
                if session.is_some() {
                    bail!("a session is already open — close it first");
                }
                let d: usize = words.next().context("usage: open <d> [pblock]")?.parse()?;
                let pblock: Option<usize> =
                    words.next().map(|v| v.parse()).transpose().context("bad pblock id")?;
                let mut spec = SessionSpec::new(d, vec![]);
                spec.pblock = pblock;
                let s = match server.open(spec) {
                    Ok(s) => s,
                    Err(err) => {
                        admit_status("open", err)?;
                        continue;
                    }
                };
                println!(
                    "{{\"event\":\"open\",\"session\":{},\"pblock\":{}}}",
                    s.id(),
                    s.pblock()
                );
                session = Some(s);
            }
            "suspend" => {
                let s = session.take().context("no open session")?;
                let id = s.id();
                let (ticket, scores) = s.suspend()?;
                if !scores.is_empty() {
                    emit_scores(id, &scores);
                }
                println!(
                    "{{\"event\":\"suspend\",\"session\":{id},\"flits\":{},\"samples\":{}}}",
                    ticket.flits, ticket.samples
                );
                tickets.insert(id, ticket);
            }
            "resume" => {
                if session.is_some() {
                    bail!("a session is already open — close it first");
                }
                let id: u64 = words.next().context("usage: resume <session-id>")?.parse()?;
                let ticket = tickets.remove(&id).with_context(|| {
                    format!("no suspended ticket for session {id} in this process")
                })?;
                let s = match server.resume(ticket.clone()) {
                    Ok(s) => s,
                    Err(err) => {
                        // Keep the ticket so the operator can retry once
                        // the admission pressure clears.
                        tickets.insert(id, ticket);
                        admit_status("resume", err)?;
                        continue;
                    }
                };
                println!(
                    "{{\"event\":\"resume\",\"session\":{},\"pblock\":{}}}",
                    s.id(),
                    s.pblock()
                );
                session = Some(s);
            }
            "push" => {
                let s = session.as_mut().context("no open session")?;
                let vals: Vec<f32> = words
                    .map(|v| v.parse::<f32>())
                    .collect::<std::result::Result<_, _>>()
                    .context("push takes whitespace-separated f32 values")?;
                s.push(&vals)?;
                let scores = s.poll_scores();
                if !scores.is_empty() {
                    emit_scores(s.id(), &scores);
                }
            }
            "close" => {
                let s = session.take().context("no open session")?;
                let id = s.id();
                let closed = s.close()?;
                if !closed.scores.is_empty() {
                    emit_scores(id, &closed.scores);
                }
                println!(
                    "{{\"event\":\"close\",\"session\":{id},\"samples\":{},\"flits\":{},\
                     \"padded_tail\":{}}}",
                    closed.samples, closed.flits, closed.padded_tail
                );
            }
            "quit" => break,
            other => {
                bail!("unknown command {other:?} (open / push / suspend / resume / close / quit)")
            }
        }
    }
    Ok(())
}
