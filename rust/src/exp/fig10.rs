//! Figure 10: ensemble accuracy vs ensemble size on Cardio.
//! AUC mean and variance over repeated runs with different seeds, for
//! R ∈ [3, 200] (paper sweeps the same range; AUC rises then converges,
//! variance falls then converges).

use anyhow::Result;

use super::report::Table;
use super::ExpCtx;
use crate::detectors::{DetectorKind, DetectorSpec};
use crate::ensemble::run_sequential;
use crate::metrics::{mean, normalize_scores, variance, auc_roc};

pub const SWEEP_R: [usize; 7] = [3, 5, 10, 20, 50, 100, 200];

/// AUC samples for one detector/size across seeds.
pub fn auc_sweep(ctx: &ExpCtx, kind: DetectorKind, r: usize) -> Result<Vec<f64>> {
    let mut aucs = Vec::with_capacity(ctx.seeds);
    let ds = ctx.dataset("cardio", ctx.seed)?;
    for s in 0..ctx.seeds {
        let spec = DetectorSpec::new(kind, ds.d, r, ctx.seed.wrapping_add(1_000 + s as u64));
        let scores = run_sequential(&spec, &ds);
        aucs.push(auc_roc(&normalize_scores(&scores), &ds.labels));
    }
    Ok(aucs)
}

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::from(
        "== Figure 10: Ensemble performance vs ensemble size (Cardio) ==\n\
         (paper: AUC rises then converges; variance falls then converges)\n",
    );
    for kind in DetectorKind::ALL {
        out.push_str(&format!("\n-- {} --\n", kind.as_str()));
        let mut t = Table::new(vec!["R", "AUC mean", "AUC var (1e-3)"]);
        let mut means = Vec::new();
        let mut vars = Vec::new();
        for r in SWEEP_R {
            let aucs = auc_sweep(ctx, kind, r)?;
            let m = mean(&aucs);
            let v = variance(&aucs);
            means.push(m);
            vars.push(v);
            t.row(vec![r.to_string(), format!("{m:.4}"), format!("{:.4}", v * 1e3)]);
        }
        out.push_str(&t.render());
        // Trend summary: large ensembles should beat tiny ones on average,
        // and late-sweep variance should not exceed early variance.
        let early = means[0];
        let late = means[means.len() - 1];
        out.push_str(&format!(
            "trend: AUC {early:.4} (R=3) -> {late:.4} (R=200); var {:.2e} -> {:.2e}\n",
            vars[0],
            vars[vars.len() - 1]
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_ctx() -> ExpCtx {
        ExpCtx { seeds: 3, max_samples: Some(1831), ..Default::default() }
    }

    #[test]
    fn bigger_ensembles_do_not_hurt_loda() {
        let ctx = fast_ctx();
        let small = mean(&auc_sweep(&ctx, DetectorKind::Loda, 3).unwrap());
        let large = mean(&auc_sweep(&ctx, DetectorKind::Loda, 50).unwrap());
        assert!(large >= small - 0.05, "AUC degraded: {small:.3} -> {large:.3}");
    }

    #[test]
    fn variance_shrinks_with_ensemble_size() {
        let ctx = ExpCtx { seeds: 5, max_samples: Some(1831), ..Default::default() };
        let v_small = variance(&auc_sweep(&ctx, DetectorKind::RsHash, 3).unwrap());
        let v_large = variance(&auc_sweep(&ctx, DetectorKind::RsHash, 50).unwrap());
        assert!(v_large <= v_small * 2.0 + 1e-6, "variance grew: {v_small:.2e} -> {v_large:.2e}");
    }
}
