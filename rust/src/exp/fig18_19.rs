//! Figures 18 & 19: chip and system power. The resource-proportional power
//! model is calibrated at the paper's measured operating point (full-fabric
//! xStream on HTTP-3 ⇒ 5.232 W dynamic; 30 W board idle ⇒ 35 W working).
//! The CPU numbers are the paper's RAPL measurements, reproduced as the
//! comparison column.

use anyhow::Result;

use super::report::Table;
use super::ExpCtx;
use crate::defaults::FPGA_CLOCK_HZ;
use crate::hw::power::*;
use crate::hw::resources::{Resources, TABLE6_BLOCKS};

pub fn run(_ctx: &ExpCtx) -> Result<String> {
    let model = PowerModel::default();
    let all: Vec<Resources> = TABLE6_BLOCKS.iter().map(|b| b.absolute()).collect();
    let mut out = String::from("== Figure 18: chip power (model) ==\n");
    let mut t = Table::new(vec!["configuration", "static W", "dynamic W", "chip W"]);
    // Idle fabric: static only (default empty RMs, clock-gated pblocks).
    t.row(vec![
        "idle (empty RMs)".to_string(),
        format!("{CHIP_STATIC_W:.2}"),
        "0.00".to_string(),
        format!("{CHIP_STATIC_W:.2}"),
    ]);
    // Single-pblock configurations.
    for blocks in [1usize, 3, 7] {
        let active: Vec<Resources> = TABLE6_BLOCKS[..blocks]
            .iter()
            .chain(&TABLE6_BLOCKS[7..]) // infrastructure always on
            .map(|b| b.absolute())
            .collect();
        let dyn_w = model.dynamic_w(&active, FPGA_CLOCK_HZ);
        t.row(vec![
            format!("{blocks} AD pblock(s) + infra"),
            format!("{CHIP_STATIC_W:.2}"),
            format!("{dyn_w:.3}"),
            format!("{:.3}", CHIP_STATIC_W + dyn_w),
        ]);
    }
    let dyn_full = model.dynamic_w(&all, FPGA_CLOCK_HZ);
    t.row(vec![
        "full fabric (paper meas: 5.232 W dyn)".to_string(),
        format!("{CHIP_STATIC_W:.2}"),
        format!("{dyn_full:.3}"),
        format!("{:.3}", CHIP_STATIC_W + dyn_full),
    ]);
    out.push_str(&t.render());

    out.push_str("\n== Figure 19: system power (model vs paper) ==\n");
    let mut t = Table::new(vec!["platform", "idle W", "working W", "dynamic W"]);
    t.row(vec![
        "fSEAD/ZCU111 (model; paper: 30/35/5.232)".to_string(),
        format!("{PAPER_FPGA_SYSTEM_IDLE_W:.1}"),
        format!("{:.2}", model.system_w(&all, FPGA_CLOCK_HZ)),
        format!("{dyn_full:.3}"),
    ]);
    t.row(vec![
        "CPU i7-10700F (paper RAPL)".to_string(),
        format!("{PAPER_CPU_IDLE_W:.1}"),
        format!("{PAPER_CPU_WORKING_W:.1}"),
        format!("{PAPER_CPU_DYNAMIC_W:.1}"),
    ]);
    out.push_str(&t.render());
    out.push_str(&format!(
        "CPU dynamic / fSEAD dynamic = {:.1}x (paper: >8x)\n",
        PAPER_CPU_DYNAMIC_W / dyn_full
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_reproduces_calibration() {
        let out = run(&ExpCtx::default()).unwrap();
        assert!(out.contains("5.232"));
        assert!(out.contains(">8x"));
    }
}
