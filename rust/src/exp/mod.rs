//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§4). `fsead exp <id>` prints paper-reported values next to
//! modelled/measured values (see DESIGN.md §5 for the index).

pub mod fig10;
pub mod fig11;
pub mod fig12_14;
pub mod fig15_16;
pub mod fig17;
pub mod fig18_19;
pub mod fig20;
pub mod modes;
pub mod perf;
pub mod report;
pub mod serve;
pub mod table3_4;
pub mod table5;
pub mod table6_7;
pub mod table8_10;
pub mod table11_12;
pub mod table13;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;

/// Shared experiment context (CLI flags).
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub seed: u64,
    /// Repetitions for mean/variance experiments (paper uses 10).
    pub seeds: usize,
    pub data_dir: Option<String>,
    /// Per-dataset sample cap (None = full streams).
    pub max_samples: Option<usize>,
    pub artifact_dir: String,
    /// Use the PJRT path where an experiment supports it.
    pub use_fpga: bool,
    /// Override the fabric execution mode (`--exec lockstep|batched`);
    /// None keeps whatever the config file selects.
    pub exec: Option<crate::ensemble::ExecMode>,
    /// Force the adaptive live-DFX controller on (`--dfx`), regardless of
    /// `[fabric.dfx] enabled` in the config.
    pub dfx: bool,
    /// Override the per-pblock lane count (`--lanes N`): intra-partition
    /// instance parallelism via resident lane workers. None keeps the
    /// config file's `[fabric] lanes` / `[pblock.N] lanes` values.
    pub lanes: Option<usize>,
    /// Force the fault campaign on (`--faults`), regardless of
    /// `[fabric.faults] enabled` in the config.
    pub faults: bool,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx {
            seed: 42,
            seeds: 3,
            data_dir: None,
            max_samples: Some(30_000),
            artifact_dir: "artifacts".into(),
            use_fpga: true,
            exec: None,
            dfx: false,
            lanes: None,
            faults: false,
        }
    }
}

impl ExpCtx {
    /// Load a paper dataset, honouring the sample cap.
    pub fn dataset(&self, name: &str, seed: u64) -> Result<Dataset> {
        let ds = Dataset::load(name, seed, self.data_dir.as_deref())
            .with_context(|| format!("unknown dataset {name:?}"))?;
        Ok(match self.max_samples {
            Some(cap) => ds.prefix(cap),
            None => ds,
        })
    }

    pub fn artifacts_available(&self) -> bool {
        std::path::Path::new(&self.artifact_dir).join("manifest.txt").exists()
    }
}

pub const DATASETS: [&str; 4] = ["cardio", "shuttle", "smtp3", "http3"];

/// CLI dispatch.
pub fn cli_main(args: &[String]) -> Result<i32> {
    let mut ctx = ExpCtx::default();
    let mut positional: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--seed" => {
                ctx.seed = next(args, &mut i)?.parse().context("--seed")?;
            }
            "--seeds" => {
                ctx.seeds = next(args, &mut i)?.parse().context("--seeds")?;
            }
            "--data-dir" => {
                ctx.data_dir = Some(next(args, &mut i)?.to_string());
            }
            "--max-samples" => {
                let v: usize = next(args, &mut i)?.parse().context("--max-samples")?;
                ctx.max_samples = if v == 0 { None } else { Some(v) };
            }
            "--full" => {
                ctx.max_samples = None;
                ctx.seeds = 10;
            }
            "--artifacts" => {
                ctx.artifact_dir = next(args, &mut i)?.to_string();
            }
            "--no-fpga" => {
                ctx.use_fpga = false;
            }
            "--exec" => {
                let v = next(args, &mut i)?;
                ctx.exec = Some(
                    crate::ensemble::ExecMode::parse(v)
                        .with_context(|| format!("--exec: unknown mode {v:?}"))?,
                );
            }
            "--dfx" => {
                ctx.dfx = true;
            }
            "--faults" => {
                ctx.faults = true;
            }
            "--lanes" => {
                let v: usize = next(args, &mut i)?.parse().context("--lanes")?;
                if v == 0 {
                    bail!("--lanes must be >= 1");
                }
                ctx.lanes = Some(v);
            }
            other => positional.push(other),
        }
        i += 1;
    }
    match positional.first().copied() {
        None | Some("help") | Some("--help") => {
            print!("{}", usage());
            Ok(0)
        }
        Some("version") => {
            println!("fsead 0.1.0 — composable streaming ensemble anomaly detection");
            Ok(0)
        }
        Some("resources") => {
            let floor = positional.contains(&"--floorplan");
            print!("{}", table6_7::run_with_floorplan(&ctx, floor)?);
            Ok(0)
        }
        Some("artifacts") => {
            let reg = crate::runtime::Registry::load(&ctx.artifact_dir)?;
            for name in reg.names() {
                let meta = reg.get(name).unwrap();
                let ok = if reg.available(meta) { "ok" } else { "MISSING" };
                println!("{name:<24} [{ok}] {}", meta.file);
            }
            Ok(0)
        }
        Some("run") => {
            let config = positional.get(1).copied().context("usage: fsead run <config.toml>")?;
            run_config(&ctx, config)?;
            Ok(0)
        }
        Some("serve") => {
            serve::cli(&ctx, &positional[1..])?;
            Ok(0)
        }
        Some("net") => {
            serve::net_cli(&ctx, &positional[1..])?;
            Ok(0)
        }
        Some("route") => {
            serve::route_cli(&ctx, &positional[1..])?;
            Ok(0)
        }
        Some("exp") => {
            let id = positional.get(1).copied().unwrap_or("all");
            let out = run_experiment(&ctx, id)?;
            print!("{out}");
            Ok(0)
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n{}", usage());
            Ok(2)
        }
    }
}

fn next<'a>(args: &'a [String], i: &mut usize) -> Result<&'a str> {
    *i += 1;
    args.get(*i).map(|s| s.as_str()).context("missing flag value")
}

fn usage() -> String {
    "fsead — composable streaming ensemble anomaly detection (fSEAD reproduction)

USAGE:
  fsead exp <id>            regenerate a paper table/figure (see below)
  fsead run <config.toml>   stream a dataset through a configured fabric
  fsead serve [config.toml] start the persistent streaming session server
                            and drive it with the synthetic-load driver
                            (--clients N --rounds N --samples N), or with a
                            stdin line protocol emitting JSONL (--stdin:
                            open <d> [pblock] / push <v...> / close / quit);
                            --operator ADDR serves live telemetry + run
                            control over HTTP (GET /metrics /state, POST
                            /swap /drain /controller)
  fsead net ADDR [config.toml]    start the streaming session server behind
                            the length-prefixed binary frame protocol on
                            ADDR (Open / Push / Scores / Close / Suspend /
                            Resume — see README \"Network plane\"); tickets
                            from Suspend resume on any server built from the
                            same config; --max-conns N caps concurrent
                            connections, --for-secs N runs for a fixed time
                            (default: until stdin EOF or a `quit` line)
  fsead route ADDR --workers a:p,b:p,…   start the fault-tolerant session
                            router: clients speak the fsead net protocol to
                            ADDR while sessions shard across the workers by
                            consistent hashing, checkpoint into router-held
                            tickets, and re-home transparently on worker
                            death (rerouted/worker_lost/resume_gap statuses);
                            give each worker a distinct --session-base
  fsead resources [--floorplan]   print the FPGA resource model
  fsead artifacts           list AOT artifacts and their status
  fsead version

EXPERIMENTS (fsead exp …):
  table3 table4 fig10 table5 table6 table7 table8 table9 table10
  fig11 fig12 table11 table12 fig15 fig16 fig17 fig18 fig19
  table13 fig20 all
  modes                     sequential / lock-step / batched CPU engines
  perf                      per-layer hot-path profile

FLAGS:
  --seed N          base RNG seed (default 42)
  --seeds N         repetitions for mean/variance experiments (default 3)
  --max-samples N   per-dataset stream cap (0 = full; default 30000)
  --full            full streams + 10 seeds (paper-scale, slow)
  --data-dir DIR    use real CSV datasets (<name>.csv) when present
  --artifacts DIR   AOT artifact directory (default artifacts/)
  --no-fpga         CPU-native RMs instead of the PJRT device
  --exec MODE       fabric pblock servicing: batched (burst fast path,
                    default) or lockstep (paper-faithful per-flit loop);
                    also settable per config via `exec` in [fabric]
  --dfx             enable the adaptive live-DFX controller for `fsead run`
                    (hot-swaps drifting pblocks from the [fabric.dfx] pool
                    while the fabric streams; scripted swaps come from
                    [fabric.dfx.swap.N] sections)
  --lanes N         place N detector instances per pblock partition
                    (intra-partition lanes scored by resident lane worker
                    threads; default 1, also settable via `lanes` in
                    [fabric] or per [pblock.N]; CPU-native RMs only)
  --faults          enable the fault campaign for `fsead run`: scripted
                    ([fabric.faults.inject.N]) and seeded random faults are
                    injected while the partition supervisor recovers through
                    the retry/reload/quarantine ladder; every fault and
                    recovery step is printed as a FAULT line
"
    .to_string()
}

/// Run one experiment by id (or "all").
pub fn run_experiment(ctx: &ExpCtx, id: &str) -> Result<String> {
    let one = |id: &str| -> Result<String> {
        Ok(match id {
            "table3" | "table4" => table3_4::run(ctx)?,
            "fig10" => fig10::run(ctx)?,
            "table5" => table5::run(ctx)?,
            "table6" | "table7" => table6_7::run(ctx)?,
            "table8" => table8_10::run(ctx, crate::detectors::DetectorKind::Loda)?,
            "table9" => table8_10::run(ctx, crate::detectors::DetectorKind::RsHash)?,
            "table10" => table8_10::run(ctx, crate::detectors::DetectorKind::XStream)?,
            "fig11" => fig11::run(ctx)?,
            "fig12" | "fig13" | "fig14" | "fig12-14" => fig12_14::run(ctx)?,
            "table11" | "table12" => table11_12::run(ctx)?,
            "fig15" | "fig16" => fig15_16::run(ctx)?,
            "fig17" => fig17::run(ctx)?,
            "fig18" | "fig19" => fig18_19::run(ctx)?,
            "table13" => table13::run(ctx)?,
            "fig20" => fig20::run(ctx)?,
            "modes" => modes::run(ctx)?,
            "perf" => perf::run(ctx)?,
            other => bail!("unknown experiment {other:?}"),
        })
    };
    if id == "all" {
        let ids = [
            "table3", "fig10", "table5", "table6", "table8", "table9", "table10", "fig11",
            "fig12", "table11", "fig15", "fig17", "fig18", "table13", "fig20",
        ];
        let mut out = String::new();
        for id in ids {
            out.push_str(&one(id)?);
            out.push('\n');
        }
        Ok(out)
    } else {
        one(id)
    }
}

/// `fsead run <config>`: stream the configured dataset through the fabric.
fn run_config(ctx: &ExpCtx, path: &str) -> Result<()> {
    use crate::metrics::{auc_roc, normalize_scores};
    let mut cfg = crate::config::FseadConfig::from_file(path)?;
    if !ctx.use_fpga {
        cfg.use_fpga = false;
    }
    if let Some(mode) = ctx.exec {
        cfg.exec = mode;
    }
    if ctx.dfx {
        cfg.dfx.adaptive = true;
    }
    if ctx.faults {
        cfg.faults.enabled = true;
    }
    if let Some(lanes) = ctx.lanes {
        cfg.override_lanes(lanes);
    }
    cfg.artifact_dir = ctx.artifact_dir.clone();
    if cfg.dataset.data_dir.is_none() {
        cfg.dataset.data_dir = ctx.data_dir.clone();
    }
    let max_streams =
        cfg.pblocks.iter().map(|p| p.stream + 1).max().unwrap_or(1);
    let mut streams = Vec::new();
    for s in 0..max_streams {
        let mut ds = crate::data::Dataset::load(
            &cfg.dataset.name,
            ctx.seed.wrapping_add(s as u64),
            cfg.dataset.data_dir.as_deref(),
        )
        .with_context(|| format!("dataset {:?}", cfg.dataset.name))?;
        if cfg.dataset.max_samples > 0 {
            ds = ds.prefix(cfg.dataset.max_samples);
        } else if let Some(cap) = ctx.max_samples {
            ds = ds.prefix(cap);
        }
        streams.push(ds);
    }
    let contamination = streams[0].contamination();
    let truth = streams[0].labels.clone();
    println!(
        "fabric: {} pblocks, {} combos, dataset {} (n={}, d={}, {:.2}% outliers), fpga={}, exec={}",
        cfg.pblocks.len(),
        cfg.combos.len(),
        cfg.dataset.name,
        streams[0].n(),
        streams[0].d,
        contamination * 100.0,
        cfg.use_fpga,
        cfg.exec.as_str(),
    );
    let mut fabric = crate::fabric::Fabric::new(cfg, streams)?;
    for (id, rm) in fabric.assignments() {
        println!("  RP-{id}: {rm}");
    }
    let out = fabric.run()?;
    println!(
        "run: wall {:.1} ms, modelled FPGA {:.1} ms, {} switch flits",
        out.wall_secs * 1e3,
        out.modeled_fpga_secs * 1e3,
        out.switch_flits
    );
    for ev in &out.swap_events {
        println!("  DFX swap {ev}");
    }
    for ev in &out.fault_events {
        println!("  FAULT {ev}");
    }
    if fabric.config().faults.enabled {
        let clamped: u64 = out.dma_reports.values().map(|r| r.clamped).sum();
        println!(
            "  fault campaign: {} event(s) recorded, {} input value(s) clamped at ingress",
            out.fault_events.len(),
            clamped
        );
    }
    if fabric.config().dfx.adaptive {
        println!(
            "  adaptive controller issued {} swap(s); {} swap(s) executed in total this run \
             (scripted + adaptive)",
            out.adaptive_swaps_issued,
            out.swap_events.len()
        );
    }
    for (id, scores) in &out.pblock_scores {
        let auc = auc_roc(&normalize_scores(scores), &truth);
        println!("  pblock {id}: {} scores, AUC-S {:.4}", scores.len(), auc);
    }
    for (id, scores) in &out.combo_scores {
        let auc = auc_roc(&normalize_scores(scores), &truth);
        println!("  combo {id}: {} scores, AUC-S {:.4}", scores.len(), auc);
    }
    if let Some(stats) = fabric.runtime_stats() {
        println!(
            "device: {} executions, {:.1} ms on device, {} compiles, {} resident instance(s)",
            stats.executions,
            stats.execute_secs * 1e3,
            stats.compiles,
            stats.instances
        );
    }
    Ok(())
}

/// Helper shared by accuracy experiments: run a detector ensemble (CPU
/// baseline path) and return (scores, labels, truth) with normalisation
/// and contamination thresholding applied (paper §4.1).
pub fn score_label_auc(
    scores: &[f32],
    truth: &[bool],
    contamination: f64,
) -> (f64, f64) {
    use crate::metrics::{auc::auc_labels, auc_roc, labels_from_scores, normalize_scores};
    let norm = normalize_scores(scores);
    let auc_s = auc_roc(&norm, truth);
    let labels = labels_from_scores(&norm, contamination);
    let auc_l = auc_labels(&labels, truth);
    (auc_s, auc_l)
}
