//! Table 5: model-combination comparison. Nine pblock assignments
//! (A7, B7, C7, A2B2C3 "C223", …) over the four datasets; score AUC uses
//! averaging, label AUC uses the OR combination (the paper's defaults).
//! Mean and variance over `ctx.seeds` repetitions.

use anyhow::Result;

use super::report::Table;
use super::{ExpCtx, DATASETS};
use crate::combine::LabelCombiner;
use crate::config::FseadConfig;
use crate::fabric::Fabric;
use crate::metrics::{auc::auc_labels, auc_roc, labels_from_scores, mean, normalize_scores, variance};

/// Paper Table 5 model codes mapped to per-letter pblock counts.
/// (e.g. "C223" in the paper = 2×Loda + 2×RS-Hash + 3×xStream.)
pub const MODELS: [(&str, &str); 9] = [
    ("A7", "A7"),
    ("B7", "B7"),
    ("C7", "C7"),
    ("C223", "A2B2C3"),
    ("C232", "A2B3C2"),
    ("C322", "A3B2C2"),
    ("C331", "A3B3C1"),
    ("C313", "A3B1C3"),
    ("C133", "A1B3C3"),
];

/// One (model, dataset, seed) cell: returns (AUC-score, AUC-label).
pub fn evaluate(ctx: &ExpCtx, code: &str, dataset: &str, seed: u64) -> Result<(f64, f64)> {
    let ds = ctx.dataset(dataset, ctx.seed)?;
    let mut cfg = FseadConfig::from_combo_code(code)?;
    cfg.seed = seed;
    cfg.use_fpga = false; // accuracy experiment: CPU RMs (identical math)
    cfg.chunk = 512;
    let contamination = ds.contamination();
    let truth = ds.labels.clone();
    let mut fabric = Fabric::new(cfg, vec![ds])?;
    let out = fabric.run()?;
    let streams: Vec<&Vec<f32>> = out.pblock_scores.values().collect();
    anyhow::ensure!(!streams.is_empty(), "no pblock outputs");
    let n = streams[0].len();
    // Score path: averaging across pblock ensembles (paper §4.2).
    let mut combined = vec![0f32; n];
    for s in &streams {
        for (c, v) in combined.iter_mut().zip(s.iter()) {
            *c += *v / streams.len() as f32;
        }
    }
    let auc_s = auc_roc(&normalize_scores(&combined), &truth);
    // Label path: threshold each pblock by contamination, then OR.
    let label_streams: Vec<Vec<bool>> = streams
        .iter()
        .map(|s| labels_from_scores(&normalize_scores(s), contamination))
        .collect();
    let views: Vec<&[bool]> = label_streams.iter().map(|v| v.as_slice()).collect();
    let or_labels = LabelCombiner::Or.combine(&views);
    let auc_l = auc_labels(&or_labels, &truth);
    Ok((auc_s, auc_l))
}

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::from(
        "== Table 5: Model combination comparison ==\n\
         (score = averaging, label = OR; mean and variance over seeds)\n",
    );
    for dataset in DATASETS {
        out.push_str(&format!("\n-- {dataset} --\n"));
        let mut t = Table::new(vec![
            "Model",
            "AUC-S mean",
            "AUC-S var(1e-3)",
            "AUC-L mean",
            "AUC-L var(1e-3)",
        ]);
        for (label, code) in MODELS {
            let mut ss = Vec::new();
            let mut ls = Vec::new();
            for s in 0..ctx.seeds {
                let (a_s, a_l) = evaluate(ctx, code, dataset, ctx.seed.wrapping_add(31 * s as u64))?;
                ss.push(a_s);
                ls.push(a_l);
            }
            t.row(vec![
                label.to_string(),
                format!("{:.3}", mean(&ss)),
                format!("{:.3}", variance(&ss) * 1e3),
                format!("{:.3}", mean(&ls)),
                format!("{:.3}", variance(&ls) * 1e3),
            ]);
        }
        out.push_str(&t.render());
    }
    out.push_str(
        "\npaper reference (cardio): A7 score 0.933 best single; combined labels beat any single detector.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_ctx() -> ExpCtx {
        ExpCtx { seeds: 1, max_samples: Some(1500), ..Default::default() }
    }

    #[test]
    fn evaluate_yields_sane_aucs() {
        let (s, l) = evaluate(&fast_ctx(), "A2B1C1", "cardio", 1).unwrap();
        assert!((0.3..=1.0).contains(&s), "AUC-S={s}");
        assert!((0.3..=1.0).contains(&l), "AUC-L={l}");
    }

    #[test]
    fn all_model_codes_build() {
        for (_, code) in MODELS {
            let cfg = FseadConfig::from_combo_code(code).unwrap();
            assert_eq!(cfg.pblocks.len(), 7, "{code}");
        }
    }
}
