//! Figure 11: multi-threaded CPU speed-up vs thread count (xStream on
//! HTTP-3). The paper's per-sample mutex synchronisation caps the speed-up
//! at 4 threads; we reproduce the same partitioning + synchronisation
//! scheme and report measured times (note: this container exposes a single
//! CPU core, so measured speed-ups are ≈1 — the *contention* behaviour
//! above 4 threads is still visible as slowdown).

use anyhow::Result;
use std::time::Instant;

use super::report::Table;
use super::ExpCtx;
use crate::detectors::{DetectorKind, DetectorSpec};
use crate::ensemble::{run_batched, run_threaded};

pub const THREADS: [usize; 5] = [1, 2, 4, 8, 16];

/// Paper Fig 11 speed-ups (xStream / HTTP-3) for reference.
pub fn paper_speedup(threads: usize) -> f64 {
    match threads {
        1 => 1.0,
        2 => 1.6,
        4 => 2.1,
        8 => 1.9,
        16 => 1.7,
        _ => f64::NAN,
    }
}

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let cap = ctx.max_samples.unwrap_or(20_000).min(20_000);
    let ds = ctx.dataset("http3", ctx.seed)?.prefix(cap);
    let kind = DetectorKind::XStream;
    let r = 7 * kind.pblock_r();
    let spec = DetectorSpec::new(kind, ds.d, r, ctx.seed);
    let mut out = format!(
        "== Figure 11: CPU speed-up vs threads (xStream, HTTP-3 prefix n={}) ==\n",
        ds.n()
    );
    out.push_str(&format!(
        "(host has {} cores; paper host: 8C/16T i7-10700F, peak at 4 threads)\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    ));
    let mut t = Table::new(vec!["threads", "time", "speedup (measured)", "speedup (paper)"]);
    let mut t1 = None;
    let mut lockstep_times = Vec::new();
    for threads in THREADS {
        let t0 = Instant::now();
        let scores = run_threaded(&spec, &ds, threads);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(scores.len(), ds.n());
        lockstep_times.push(dt);
        let base = *t1.get_or_insert(dt);
        t.row(vec![
            threads.to_string(),
            format!("{:.1} ms", dt * 1e3),
            format!("{:.2}x", base / dt),
            format!("{:.1}x", paper_speedup(threads)),
        ]);
    }
    out.push_str(&t.render());
    out.push_str("paper: 4 threads always best; mutex sync overhead dominates beyond that.\n");

    // The batched lock-free fast path (ExecMode::Batched) on the same
    // workload — same partition, no mutex/barrier. The lock-step table
    // above is the untouched Fig 11 reproduction.
    out.push_str("\n-- batched fast path (lock-free, same partition) --\n");
    let mut tb = Table::new(vec!["threads", "time", "speedup vs lock-step @same threads"]);
    for (i, &threads) in THREADS.iter().enumerate() {
        let t0 = Instant::now();
        let scores = run_batched(&spec, &ds, threads);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(scores.len(), ds.n());
        tb.row(vec![
            threads.to_string(),
            format!("{:.1} ms", dt * 1e3),
            format!("{:.2}x", lockstep_times[i] / dt),
        ]);
    }
    out.push_str(&tb.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_quickly_on_small_prefix() {
        let ctx = ExpCtx { max_samples: Some(600), ..Default::default() };
        let out = run(&ctx).unwrap();
        assert!(out.contains("threads"));
        assert!(out.contains("16"));
    }
}
