//! Figures 12–14: execution time vs ensemble size, CPU vs FPGA, per
//! detector. CPU time is measured (linear in R — the sequential sub-
//! detector loop); FPGA time comes from the calibrated model and is flat in
//! R while the ensemble fits the fabric (spatial parallelism — the paper's
//! headline architectural claim).

use anyhow::Result;
use std::time::Instant;

use super::report::Table;
use super::ExpCtx;
use crate::detectors::{DetectorKind, DetectorSpec};
use crate::ensemble::run_sequential;
use crate::hw::timing::FpgaTimingModel;

pub fn sweep_r(kind: DetectorKind) -> Vec<usize> {
    let unit = kind.pblock_r();
    (1..=7).map(|k| k * unit).collect()
}

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let cap = ctx.max_samples.unwrap_or(10_000).min(10_000);
    let ds = ctx.dataset("shuttle", ctx.seed)?.prefix(cap);
    let model = FpgaTimingModel::default();
    let mut out = format!(
        "== Figures 12-14: execution time vs ensemble size (shuttle prefix n={}) ==\n",
        ds.n()
    );
    for (fig, kind) in [(12, DetectorKind::Loda), (13, DetectorKind::RsHash), (14, DetectorKind::XStream)]
    {
        out.push_str(&format!("\n-- Figure {fig}: {} --\n", kind.as_str()));
        let mut t = Table::new(vec!["R", "t_cpu (measured)", "t_fpga (model)", "ratio"]);
        let mut cpu_times = Vec::new();
        for r in sweep_r(kind) {
            let spec = DetectorSpec::new(kind, ds.d, r, ctx.seed);
            let t0 = Instant::now();
            let scores = run_sequential(&spec, &ds);
            let dt = t0.elapsed().as_secs_f64();
            assert_eq!(scores.len(), ds.n());
            cpu_times.push(dt);
            let fpga = model.exec_time_s(kind, ds.n(), ds.d);
            t.row(vec![
                r.to_string(),
                format!("{:.1} ms", dt * 1e3),
                format!("{:.1} ms", fpga * 1e3),
                format!("{:.1}x", dt / fpga),
            ]);
        }
        out.push_str(&t.render());
        let first = cpu_times[0].max(1e-9);
        let last = cpu_times[cpu_times.len() - 1];
        out.push_str(&format!(
            "CPU scaling: t(R=7u)/t(R=u) = {:.1} (paper: linear in R ⇒ ≈7); FPGA flat.\n",
            last / first
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_time_grows_with_r() {
        let ctx = ExpCtx { max_samples: Some(2_000), ..Default::default() };
        let ds = ctx.dataset("shuttle", 1).unwrap();
        let mut times = Vec::new();
        for r in [10usize, 70] {
            let spec = DetectorSpec::new(DetectorKind::Loda, ds.d, r, 3);
            let t0 = Instant::now();
            run_sequential(&spec, &ds);
            times.push(t0.elapsed().as_secs_f64());
        }
        assert!(times[1] > times[0] * 2.0, "no linear scaling: {times:?}");
    }

    #[test]
    fn sweep_covers_full_fabric() {
        assert_eq!(sweep_r(DetectorKind::Loda).last(), Some(&245));
        assert_eq!(sweep_r(DetectorKind::XStream).last(), Some(&140));
    }
}
