//! Figures 15 & 16: roofline models (CPU and FPGA). For every
//! detector×dataset point we compute arithmetic intensity from the op-count
//! formulas and achieved GOPS from the timing model / paper CPU times, and
//! place it under the machine rooflines.

use anyhow::Result;

use super::report::Table;
use super::table11_12::params_for;
use super::{ExpCtx, DATASETS};
use crate::detectors::DetectorKind;
use crate::hw::opcount::{arithmetic_intensity, gops, op_count, paper_gops};
use crate::hw::roofline::{RooflinePoint, CPU_ROOFLINE, FPGA_ROOFLINE, FSEAD_ROOFLINE};
use crate::hw::timing::FpgaTimingModel;

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let model = FpgaTimingModel::default();
    let mut out = format!(
        "== Figures 15-16: Roofline models ==\n\
         CPU roof:   {} — peak {:.1} GOPS, {:.1} GB/s (ridge at {:.1} op/B)\n\
         FPGA roof:  {} — peak {:.1} GOPS, {:.1} GB/s\n\
         fSEAD roof: {} — peak {:.1} GOPS (paper: 110.4 from 61.57% of pblock resources)\n\n",
        CPU_ROOFLINE.name,
        CPU_ROOFLINE.peak_gops,
        CPU_ROOFLINE.mem_bw_gbs,
        CPU_ROOFLINE.ridge(),
        FPGA_ROOFLINE.name,
        FPGA_ROOFLINE.peak_gops,
        FPGA_ROOFLINE.mem_bw_gbs,
        FSEAD_ROOFLINE.name,
        FSEAD_ROOFLINE.peak_gops,
    );
    let mut t = Table::new(vec![
        "point",
        "AI (op/B)",
        "GOPS cpu(paper)",
        "roof@AI cpu",
        "GOPS fsead(model)",
        "roof@AI fsead",
        "fsead eff",
    ]);
    let mut best_eff = 0.0f64;
    for kind in DetectorKind::ALL {
        for dataset in DATASETS {
            let ds = ctx.dataset(dataset, ctx.seed)?;
            let p = params_for(kind, ds.n(), ds.d);
            let ai = arithmetic_intensity(kind, p);
            let g_cpu = paper_gops(kind, dataset).map(|(c, _)| c).unwrap_or(0.0);
            let g_fsead = gops(op_count(kind, p), model.exec_time_s(kind, ds.n(), ds.d));
            let pt = RooflinePoint {
                label: format!("{}/{}", kind.as_str(), dataset),
                ai,
                gops: g_fsead,
            };
            let eff = pt.efficiency(&FSEAD_ROOFLINE);
            best_eff = best_eff.max(eff);
            t.row(vec![
                pt.label.clone(),
                format!("{ai:.2}"),
                format!("{g_cpu:.2}"),
                format!("{:.1}", CPU_ROOFLINE.attainable(ai)),
                format!("{g_fsead:.2}"),
                format!("{:.1}", FSEAD_ROOFLINE.attainable(ai)),
                format!("{:.0}%", eff * 100.0),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "paper: no algorithm reaches the roof; xStream is closest (their best point 67.96 GOPS = 62% of the 110.4 bound; ours peaks at {:.0}%).\n",
        best_eff * 100.0
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xstream_has_highest_ai() {
        let ctx = ExpCtx { max_samples: Some(1000), ..Default::default() };
        let ds = ctx.dataset("http3", 1).unwrap();
        let ai = |k| arithmetic_intensity(k, params_for(k, ds.n(), ds.d));
        assert!(ai(DetectorKind::XStream) > ai(DetectorKind::RsHash));
        assert!(ai(DetectorKind::RsHash) > ai(DetectorKind::Loda));
    }

    #[test]
    fn no_point_exceeds_device_roof() {
        let ctx = ExpCtx { max_samples: Some(5000), ..Default::default() };
        let model = FpgaTimingModel::default();
        for kind in DetectorKind::ALL {
            for dsn in DATASETS {
                let ds = ctx.dataset(dsn, 1).unwrap();
                let p = params_for(kind, ds.n(), ds.d);
                let g = gops(op_count(kind, p), model.exec_time_s(kind, ds.n(), ds.d));
                assert!(
                    g <= FPGA_ROOFLINE.peak_gops * 1.05,
                    "{kind:?}/{dsn}: {g:.1} GOPS above device roof"
                );
            }
        }
    }
}
