//! Markdown-ish table rendering for experiment output: every experiment
//! prints paper-reported values next to model/measured values so the
//! reproduction quality is visible at a glance.

/// A simple column-aligned table printer.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format milliseconds.
pub fn ms(seconds: f64) -> String {
    format!("{:.2} ms", seconds * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new(vec!["a", "long-header"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["wide-cell", "x"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].contains("long-header"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        Table::new(vec!["a"]).row(vec!["1", "2"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(ms(0.00463), "4.63 ms");
    }
}
