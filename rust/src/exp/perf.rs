//! §Perf profiling harness (`fsead exp perf`): per-layer hot-path
//! measurements used for the EXPERIMENTS.md §Perf iteration log.
//!
//! - device time per chunk / per sample for every full-size detector
//!   artifact (the L1+L2 cost as compiled by XLA);
//! - marshalling overhead: wall time around the device call (L3 cost:
//!   literal construction, channel hops, state threading);
//! - CPU-baseline per-sample cost for reference.

use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

use super::report::Table;
use super::ExpCtx;
use crate::data::stream::ChunkStream;
use crate::detectors::{DetectorKind, DetectorSpec};
use crate::runtime::{generate_params, Runtime};

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::from("== §Perf: hot-path profile ==\n");
    if !ctx.artifacts_available() {
        out.push_str("artifacts missing — run `make artifacts` first\n");
        return Ok(out);
    }
    let rt = Runtime::start(&ctx.artifact_dir)?;
    let handle = rt.handle();
    let hyper = crate::config::DetectorHyper::default();
    let mut t = Table::new(vec![
        "artifact",
        "chunks",
        "wall ms/chunk",
        "burst ms/chunk",
        "device ms/chunk",
        "marshal %",
        "device µs/sample",
        "cpu µs/sample",
    ]);
    let d = 9usize;
    let ds = ctx.dataset("shuttle", ctx.seed)?.prefix(ctx.max_samples.unwrap_or(10_000).min(10_000));
    for kind in DetectorKind::ALL {
        let r = kind.pblock_r();
        let meta = rt.registry().find_detector(kind, d, r, true)?.clone();
        let params = generate_params(kind, ctx.seed, r, d, &hyper, ds.warmup(hyper.window));
        let inst = handle.load_detector(&meta, params)?;
        // Warm-up chunk (first execution includes lazy initialisation).
        let mut chunks = ChunkStream::new(&ds.data, d, meta.chunk);
        let first = chunks.next().unwrap();
        handle.run_chunk(inst, first.data, first.mask)?;
        let before = handle.stats()?;
        let t0 = Instant::now();
        let mut n_chunks = 0u64;
        let mut n_samples = 0u64;
        for c in chunks {
            n_samples += c.n_valid as u64;
            handle.run_chunk(inst, c.data, c.mask)?;
            n_chunks += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        let after = handle.stats()?;
        let dev = after.execute_secs - before.execute_secs;
        // Burst submission: one channel round-trip for the whole stream
        // (the fast-path plumbing) — isolates the per-chunk hop cost.
        // Payloads are shared Arcs, so building the burst copies nothing.
        // Skip the warm-up chunk so the burst covers the same chunk set as
        // the per-chunk wall measurement above and the columns compare.
        let burst: Vec<(Arc<[f32]>, Arc<[f32]>)> = ChunkStream::new(&ds.data, d, meta.chunk)
            .skip(1)
            .map(|c| (c.data, c.mask))
            .collect();
        let n_burst = burst.len().max(1) as f64;
        let t2 = Instant::now();
        handle.run_chunks(inst, burst)?;
        let burst_wall = t2.elapsed().as_secs_f64();
        // CPU baseline per-sample (same R).
        let spec = DetectorSpec::new(kind, d, r, ctx.seed);
        let mut det = spec.build(ds.warmup(hyper.window));
        let t1 = Instant::now();
        det.run_stream(&ds.data);
        let cpu = t1.elapsed().as_secs_f64();
        t.row(vec![
            meta.name.clone(),
            n_chunks.to_string(),
            format!("{:.3}", wall * 1e3 / n_chunks as f64),
            format!("{:.3}", burst_wall * 1e3 / n_burst),
            format!("{:.3}", dev * 1e3 / n_chunks as f64),
            format!("{:.1}", (wall - dev) / wall * 100.0),
            format!("{:.2}", dev * 1e6 / n_samples as f64),
            format!("{:.2}", cpu * 1e6 / ds.n() as f64),
        ]);
    }
    out.push_str(&t.render());
    let stats = handle.stats()?;
    out.push_str(&format!(
        "device totals: {} executions, {:.1} ms execute, {} compiles ({:.1} ms)\n",
        stats.executions,
        stats.execute_secs * 1e3,
        stats.compiles,
        stats.compile_secs * 1e3
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perf_harness_runs_when_artifacts_present() {
        let ctx = ExpCtx { max_samples: Some(1500), ..Default::default() };
        let out = run(&ctx).unwrap();
        assert!(out.contains("§Perf"));
    }
}
