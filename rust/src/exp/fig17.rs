//! Figure 17: scalability inside a single pblock (RP-1, Cardio).
//! Sub-detector throughput — ensemble size × sample rate — scales linearly
//! with the resource utilisation of the pblock at a fixed 188 MHz clock,
//! because per-sample latency is independent of R (spatial parallelism).
//! We sweep utilisation 20–80 %, size the ensemble from the resource model
//! and report modelled throughput; with artifacts present we additionally
//! measure PJRT throughput for the test-size ensemble as a sanity point.

use anyhow::Result;

use super::report::Table;
use super::ExpCtx;
use crate::detectors::DetectorKind;
use crate::hw::resources::{per_instance_resources, TABLE6_BLOCKS};
use crate::hw::timing::FpgaTimingModel;

pub const UTIL_PCTS: [f64; 4] = [20.0, 40.0, 60.0, 80.0];

/// Ensemble size achieving roughly `util` % of RP-1's binding resource.
pub fn r_at_util(kind: DetectorKind, util_pct: f64) -> usize {
    let cap = TABLE6_BLOCKS[0].absolute(); // RP-1
    let unit = per_instance_resources(kind);
    let per_unit_util = unit.max_utilisation(&cap);
    ((util_pct / 100.0) / per_unit_util).floor() as usize
}

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let ds = ctx.dataset("cardio", ctx.seed)?;
    let model = FpgaTimingModel::default();
    let mut out = format!(
        "== Figure 17: scalability inside RP-1 (Cardio, n={}, 188 MHz) ==\n",
        ds.n()
    );
    for kind in DetectorKind::ALL {
        out.push_str(&format!("\n-- {} --\n", kind.as_str()));
        let mut t = Table::new(vec![
            "util %",
            "R (model)",
            "samples/s",
            "sub-detector samples/s (1e6)",
        ]);
        let secs = model.exec_time_s(kind, ds.n(), ds.d) - model.overhead_s;
        let sps = ds.n() as f64 / secs;
        let mut aggs = Vec::new();
        for util in UTIL_PCTS {
            let r = r_at_util(kind, util);
            let agg = r as f64 * sps;
            aggs.push(agg);
            t.row(vec![
                format!("{util:.0}"),
                r.to_string(),
                format!("{sps:.0}"),
                format!("{:.2}", agg / 1e6),
            ]);
        }
        out.push_str(&t.render());
        let ratio = aggs[aggs.len() - 1] / aggs[0].max(1e-9);
        out.push_str(&format!(
            "linearity: throughput(80%)/throughput(20%) = {ratio:.1} (ideal 4.0)\n"
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilisation_sweep_is_monotone_in_r() {
        for kind in DetectorKind::ALL {
            let rs: Vec<usize> = UTIL_PCTS.iter().map(|&u| r_at_util(kind, u)).collect();
            assert!(rs.windows(2).all(|w| w[1] >= w[0]), "{kind:?}: {rs:?}");
            assert!(rs[0] >= 1, "{kind:?}: 20% fits at least one sub-detector");
        }
    }

    #[test]
    fn rp1_at_80pct_close_to_pblock_r() {
        // The paper sizes 35/25/20 at 80-90% of the smallest pblock; RP-1 is
        // slightly larger than RP-3, so 80% util lands in the same ballpark.
        for kind in DetectorKind::ALL {
            let r80 = r_at_util(kind, 80.0);
            let paper = kind.pblock_r();
            assert!(
                (paper as f64 * 0.6..=paper as f64 * 1.4).contains(&(r80 as f64)),
                "{kind:?}: r80={r80} vs paper {paper}"
            );
        }
    }
}
