//! Table 13: partial-reconfiguration time per pblock, both directions
//! (Function → Identity and Identity → Function). The DFX download model is
//! calibrated to the paper's PYNQ measurements; our fabric's *actual* swap
//! cost (RM build + artifact compile) is measured separately and reported
//! under "swap (this system)".

use anyhow::Result;

use super::report::Table;
use super::ExpCtx;
use crate::config::{DetectorHyper, RmKind};
use crate::detectors::DetectorKind;
use crate::fabric::pblock::Pblock;
use crate::fabric::reconfig::{DfxManager, ReconfigModel};

/// Paper Table 13 (ms) for reference: (block, fn→id, id→fn).
pub const PAPER: [(&str, f64, f64); 10] = [
    ("RP-1", 607.8, 606.3),
    ("RP-2", 606.1, 611.3),
    ("RP-3", 604.5, 607.2),
    ("RP-4", 606.1, 606.0),
    ("RP-5", 608.9, 606.9),
    ("RP-6", 609.6, 608.1),
    ("RP-7", 609.5, 607.5),
    ("COMBO1", 587.2, 582.9),
    ("COMBO2", 582.7, 580.1),
    ("COMBO3", 579.8, 581.9),
];

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let model = ReconfigModel::default();
    let mut out = String::from("== Table 13: partial reconfiguration time (ms) ==\n");
    let mut t = Table::new(vec![
        "block",
        "model fn->id",
        "paper fn->id",
        "model id->fn",
        "paper id->fn",
    ]);
    for (block, p_fi, p_if) in PAPER {
        let m_fi = model.time_ms(block, false).unwrap();
        let m_if = model.time_ms(block, true).unwrap();
        t.row(vec![
            block.to_string(),
            format!("{m_fi:.1}"),
            format!("{p_fi:.1}"),
            format!("{m_if:.1}"),
            format!("{p_if:.1}"),
        ]);
    }
    out.push_str(&t.render());

    // Measured: actual swap cost in this system (CPU RM rebuild; and the
    // PJRT artifact compile when artifacts are present).
    out.push_str("\nswap (this system):\n");
    let hyper = DetectorHyper::default();
    let mgr = DfxManager::default();
    let mut pb = Pblock::new(1);
    let warmup: Vec<f32> = (0..hyper.window * 3).map(|i| (i as f32 * 0.37).sin()).collect();
    let rep = mgr.reconfigure(
        &mut pb,
        RmKind::Detector(DetectorKind::Loda),
        8,
        3,
        ctx.seed,
        &hyper,
        &warmup,
        None,
        false,
        1,
    )?;
    out.push_str(&format!(
        "  RP-1 empty -> loda(cpu): {:.3} ms measured (model {:.1} ms)\n",
        rep.actual_ms, rep.model_ms
    ));
    if ctx.use_fpga && ctx.artifacts_available() {
        let rt = crate::runtime::Runtime::start(&ctx.artifact_dir)?;
        let secs = rt.handle().precompile("loda_d3_r4")?;
        let cached = rt.handle().precompile("loda_d3_r4")?;
        out.push_str(&format!(
            "  artifact compile (loda_d3_r4): {:.1} ms cold, {:.3} ms cached — the analogue of the bitstream download\n",
            secs * 1e3,
            cached * 1e3
        ));
    }
    out.push_str("paper trend: larger region ⇒ longer download; COMBO blocks ~25-30 ms faster than AD pblocks.\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_within_paper_noise_everywhere() {
        // Table 13's own direction-to-direction spread is up to ±2.6 ms
        // (e.g. RP-2: 606.1 vs 611.3), so the model is held to 6 ms of every
        // individual cell and 3 ms of each block's two-direction mean.
        let model = ReconfigModel::default();
        for (block, p_fi, p_if) in PAPER {
            let m_fi = model.time_ms(block, false).unwrap();
            let m_if = model.time_ms(block, true).unwrap();
            assert!((m_fi - p_fi).abs() < 6.0, "{block}: {m_fi} vs {p_fi}");
            assert!((m_if - p_if).abs() < 6.0, "{block}: {m_if} vs {p_if}");
            let mean_model = (m_fi + m_if) / 2.0;
            let mean_paper = (p_fi + p_if) / 2.0;
            assert!((mean_model - mean_paper).abs() < 3.0, "{block} mean: {mean_model} vs {mean_paper}");
        }
    }
}
