//! Figure 20: default channel latency with empty (bypass) logic.
//! The paper measures 0.77 ms for DMA → pblock → Switch-1 → DMA and
//! 0.80 ms for the full path through both switches and a combo slot,
//! dominated by the Linux/PYNQ driver rather than switch routing. We
//! measure the same two paths through our fabric (single 256-sample chunk)
//! and report both alongside the paper's numbers.

use anyhow::Result;
use std::time::Instant;

use super::report::Table;
use super::ExpCtx;
use crate::config::{ComboCfg, FseadConfig, PblockCfg, RmKind};
use crate::data::Dataset;
use crate::fabric::Fabric;

fn one_chunk_dataset(chunk: usize, d: usize) -> Dataset {
    let data: Vec<f32> = (0..chunk * d).map(|i| (i as f32 * 0.013).sin()).collect();
    Dataset { name: "latency".into(), d, data, labels: vec![false; chunk] }
}

/// Measure the short path: DMA → bypass pblock → Switch-1 → DMA.
pub fn measure_short_path(ctx: &ExpCtx, use_fpga: bool) -> Result<f64> {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = use_fpga;
    cfg.artifact_dir = ctx.artifact_dir.clone();
    cfg.chunk = 256;
    cfg.pblocks.push(PblockCfg { id: 1, rm: RmKind::Bypass, r: 0, stream: 0, lanes: 0 });
    let ds = one_chunk_dataset(cfg.chunk, 3);
    let mut fabric = Fabric::new(cfg, vec![ds])?;
    // Warm the path (thread spawn, PJRT compile), then measure.
    fabric.run()?;
    let t0 = Instant::now();
    fabric.run()?;
    Ok(t0.elapsed().as_secs_f64())
}

/// Measure the full path: DMA → bypass → SW1 → SW2 → combo → SW2 → DMA.
pub fn measure_full_path(ctx: &ExpCtx, use_fpga: bool) -> Result<f64> {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = use_fpga;
    cfg.artifact_dir = ctx.artifact_dir.clone();
    cfg.chunk = 256;
    cfg.pblocks.push(PblockCfg { id: 1, rm: RmKind::Bypass, r: 0, stream: 0, lanes: 0 });
    // A 1-input averaging combo is the identity — the paper's empty-logic
    // channel through both switches and a combo slot.
    cfg.combos.push(ComboCfg { id: 1, method: "avg".into(), inputs: vec![1], weights: vec![] });
    // Bypass emits d=3 wide flits; native combo handles any width.
    let ds = one_chunk_dataset(cfg.chunk, 1);
    let mut fabric = Fabric::new(cfg, vec![ds])?;
    fabric.run()?;
    let t0 = Instant::now();
    fabric.run()?;
    Ok(t0.elapsed().as_secs_f64())
}

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::from("== Figure 20: bypass channel latency (one 256-sample chunk) ==\n");
    let mut t = Table::new(vec!["path", "measured", "paper"]);
    let short_native = measure_short_path(ctx, false)?;
    t.row(vec![
        "DMA->bypass->SW1->DMA (native)".to_string(),
        format!("{:.3} ms", short_native * 1e3),
        "0.77 ms".to_string(),
    ]);
    let full_native = measure_full_path(ctx, false)?;
    t.row(vec![
        "DMA->bypass->SW1->SW2->combo->SW2->DMA (native)".to_string(),
        format!("{:.3} ms", full_native * 1e3),
        "0.80 ms".to_string(),
    ]);
    if ctx.use_fpga && ctx.artifacts_available() {
        let short_fpga = measure_short_path(ctx, true)?;
        t.row(vec![
            "DMA->bypass->SW1->DMA (PJRT bypass artifact)".to_string(),
            format!("{:.3} ms", short_fpga * 1e3),
            "0.77 ms".to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "system overhead per pass ~{:.3} ms; paper: latency dominated by host framework, not switch routing (ours: thread/channel wakeups, not crossbar logic).\nmax system latency for pblocks with compute latency L1+L2: ~overhead + L1 + L2.\n",
        full_native * 1e3
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_paths_measure_quickly() {
        let ctx = ExpCtx { use_fpga: false, ..Default::default() };
        let short = measure_short_path(&ctx, false).unwrap();
        let full = measure_full_path(&ctx, false).unwrap();
        assert!(short > 0.0 && short < 0.5, "short={short}");
        assert!(full > 0.0 && full < 0.5, "full={full}");
    }
}
