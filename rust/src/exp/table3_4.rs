//! Tables 3 & 4: dataset attributes and detector hyper-parameters.
//! Table 3 prints the paper's attributes next to the generated (or loaded)
//! datasets' actual attributes — they must agree by construction.

use anyhow::Result;

use super::report::Table;
use super::{ExpCtx, DATASETS};
use crate::data::synth;
use crate::defaults;

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::from("== Table 3: Datasets (paper | this repo) ==\n");
    let mut t = Table::new(vec![
        "Dataset",
        "n (paper)",
        "n (ours)",
        "d (paper)",
        "d (ours)",
        "outliers (paper)",
        "outliers (ours)",
        "%outliers",
    ]);
    for name in DATASETS {
        let p = synth::profile(name).unwrap();
        // Attribute check against the actual loaded dataset (uncapped).
        let full = crate::data::Dataset::load(name, ctx.seed, ctx.data_dir.as_deref()).unwrap();
        t.row(vec![
            name.to_string(),
            p.n.to_string(),
            full.n().to_string(),
            p.d.to_string(),
            full.d.to_string(),
            p.outliers.to_string(),
            full.outliers().to_string(),
            format!("{:.2}", full.contamination() * 100.0),
        ]);
    }
    out.push_str(&t.render());

    out.push_str("\n== Table 4: Hyper-parameters ==\n");
    let mut t = Table::new(vec!["Detector", "window", "Bins", "CMS-w", "CMS-MOD", "K"]);
    t.row(vec![
        "Loda".to_string(),
        defaults::WINDOW.to_string(),
        defaults::LODA_BINS.to_string(),
        "1".to_string(),
        "-".to_string(),
        "-".to_string(),
    ]);
    t.row(vec![
        "RS-Hash".to_string(),
        defaults::WINDOW.to_string(),
        "-".to_string(),
        defaults::CMS_ROWS.to_string(),
        defaults::CMS_MOD.to_string(),
        "-".to_string(),
    ]);
    t.row(vec![
        "xStream".to_string(),
        defaults::WINDOW.to_string(),
        "-".to_string(),
        defaults::CMS_ROWS.to_string(),
        defaults::CMS_MOD.to_string(),
        defaults::XSTREAM_K.to_string(),
    ]);
    out.push_str(&t.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_attributes_match_paper() {
        let ctx = ExpCtx { max_samples: Some(100), ..Default::default() };
        let out = run(&ctx).unwrap();
        assert!(out.contains("cardio"));
        assert!(out.contains("567498")); // http3 n, paper and ours
        assert!(out.contains("9.61")); // cardio contamination
    }
}
