//! Loda (paper Algorithm 1) — projection + histogram core, 1×W window.
//!
//! This is the CPU baseline (the paper's GCC implementation, in rust).
//! Semantics match the JAX model exactly: read-count-before-insert, denom
//! `max(min(n,W),1)`, score `log2(denom) − log2(max(c,1))` averaged over R.

use super::params::LodaParams;
use super::quantize::q16;
use super::window::SlidingCounts;
use super::Detector;

#[derive(Clone, Debug)]
pub struct Loda {
    params: LodaParams,
    bins: usize,
    counts: SlidingCounts,
    /// Apply Q16.16 to the ensemble score (FPGA-flavoured arithmetic).
    pub quantize: bool,
    idx_buf: Vec<i32>,
    /// Per-sub-detector histogram span, hoisted out of the per-sample loop.
    span: Vec<f32>,
}

impl Loda {
    pub fn new(params: LodaParams, bins: usize, window: usize) -> Self {
        let r = params.r;
        let span: Vec<f32> =
            (0..r).map(|ri| (params.pmax[ri] - params.pmin[ri]).max(1e-12)).collect();
        Loda {
            params,
            bins,
            counts: SlidingCounts::new(r, bins, window),
            quantize: false,
            idx_buf: vec![0; r],
            span,
        }
    }

    #[inline]
    fn bin_index(&self, ri: usize, z: f32) -> i32 {
        let pmin = self.params.pmin[ri];
        let idx = ((z - pmin) / self.span[ri] * self.bins as f32).floor();
        (idx as i32).clamp(0, self.bins as i32 - 1)
    }
}

impl Detector for Loda {
    fn update(&mut self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.params.d);
        let (r, d) = (self.params.r, self.params.d);
        let dl = self.counts.log2_denom();
        let mut sum = 0f32;
        for ri in 0..r {
            // ③ Projection (sparse dot product)
            let w = &self.params.prj[ri * d..(ri + 1) * d];
            let mut z = 0f32;
            for (wi, xi) in w.iter().zip(x) {
                z += wi * xi;
            }
            // ④ Histogram lookup
            let idx = self.bin_index(ri, z);
            self.idx_buf[ri] = idx;
            let c = self.counts.get(ri, idx) as f32;
            // ⑥ Score (log2(denom) cached by the sliding window)
            sum += dl - c.max(1.0).log2();
        }
        // ⑤ Sliding-window update
        self.counts.insert(&self.idx_buf);
        // ⑦ Score averaging
        let score = sum / r as f32;
        if self.quantize {
            q16(score)
        } else {
            score
        }
    }

    /// Batch fast path: bit-identical to the `update` loop, but log2(denom)
    /// is computed once per sample instead of R times, the histogram span is
    /// precomputed, and lookup + window insert are fused per row.
    fn update_batch(&mut self, xs: &[f32], out: &mut [f32]) {
        let (r, d) = (self.params.r, self.params.d);
        debug_assert_eq!(xs.len(), out.len() * d);
        let binsf = self.bins as f32;
        let bmax = self.bins as i32 - 1;
        for (x, o) in xs.chunks_exact(d).zip(out.iter_mut()) {
            let dl = self.counts.log2_denom();
            let mut sum = 0f32;
            for ri in 0..r {
                // ③ Projection (sparse dot product)
                let w = &self.params.prj[ri * d..(ri + 1) * d];
                let mut z = 0f32;
                for (wi, xi) in w.iter().zip(x) {
                    z += wi * xi;
                }
                // ④+⑤ Histogram lookup fused with the window insert
                let pmin = self.params.pmin[ri];
                let idx = (((z - pmin) / self.span[ri] * binsf).floor() as i32).clamp(0, bmax);
                let c = self.counts.get_insert(ri, idx) as f32;
                // ⑥ Score
                sum += dl - c.max(1.0).log2();
            }
            self.counts.advance();
            // ⑦ Score averaging
            let score = sum / r as f32;
            *o = if self.quantize { q16(score) } else { score };
        }
    }

    fn reset(&mut self) {
        self.counts.reset();
    }

    fn r(&self) -> usize {
        self.params.r
    }

    fn d(&self) -> usize {
        self.params.d
    }

    fn name(&self) -> &'static str {
        "loda"
    }

    fn window_state(&self) -> Option<&SlidingCounts> {
        Some(&self.counts)
    }

    fn window_state_mut(&mut self) -> Option<&mut SlidingCounts> {
        Some(&mut self.counts)
    }
}

impl Loda {
    /// Count-table snapshot (for parity tests against the PJRT state).
    pub fn hist(&self) -> &[i32] {
        self.counts.counts()
    }

    pub fn params(&self) -> &LodaParams {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::prng::Prng;

    fn mk(r: usize, d: usize, seed: u64) -> (Loda, Vec<f32>) {
        let mut p = Prng::new(seed);
        let data: Vec<f32> = (0..64 * d).map(|_| p.gaussian() as f32).collect();
        let params = LodaParams::generate(seed, r, d, &data[..16 * d]);
        (Loda::new(params, 8, 8), data)
    }

    #[test]
    fn first_sample_scores_zero() {
        let (mut det, data) = mk(4, 3, 1);
        // denom=1, c clamp 1 → log2(1)-log2(1) = 0
        assert_eq!(det.update(&data[0..3]), 0.0);
    }

    #[test]
    fn repeated_sample_becomes_unsurprising() {
        let (mut det, data) = mk(4, 3, 2);
        let x = &data[0..3];
        let mut last = f32::INFINITY;
        for _ in 0..8 {
            last = det.update(x);
        }
        // After the window fills with x, count==window → score ≈ 0.
        assert!(last.abs() < 1e-6, "score={last}");
    }

    #[test]
    fn outlier_scores_higher_than_inliers() {
        let (mut det, data) = mk(8, 3, 3);
        let mut inlier_score = 0f32;
        for s in 0..20 {
            inlier_score = det.update(&data[s * 3..(s + 1) * 3]);
        }
        let outlier = [50.0f32, -50.0, 50.0];
        let outlier_score = det.update(&outlier);
        assert!(outlier_score > inlier_score, "{outlier_score} <= {inlier_score}");
    }

    #[test]
    fn reset_restores_initial_behaviour() {
        let (mut det, data) = mk(4, 3, 4);
        let s0 = det.update(&data[0..3]);
        for s in 1..10 {
            det.update(&data[s * 3..(s + 1) * 3]);
        }
        det.reset();
        assert_eq!(det.update(&data[0..3]), s0);
    }

    #[test]
    fn update_batch_matches_update_exactly() {
        let (mut a, data) = mk(6, 3, 9);
        let (mut b, _) = mk(6, 3, 9);
        let single: Vec<f32> = data.chunks_exact(3).map(|x| a.update(x)).collect();
        let mut batch = vec![0f32; 64];
        b.update_batch(&data, &mut batch);
        assert_eq!(single, batch);
        assert_eq!(a.hist(), b.hist());
    }

    #[test]
    fn quantized_scores_on_q16_grid() {
        let (mut det, data) = mk(4, 3, 5);
        det.quantize = true;
        for s in 0..20 {
            let sc = det.update(&data[s * 3..(s + 1) * 3]) as f64;
            assert!((sc * 65536.0 - (sc * 65536.0).round()).abs() < 1e-3);
        }
    }
}
