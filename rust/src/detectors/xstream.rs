//! xStream (paper Algorithm 3) — dense projection + half-space-chain CMS.

use super::jenkins::jenkins_mod_i32;
use super::params::XStreamParams;
use super::quantize::q16;
use super::window::SlidingCounts;
use super::Detector;

#[derive(Clone, Debug)]
pub struct XStream {
    params: XStreamParams,
    modulus: usize,
    counts: SlidingCounts, // rows = R*w
    pub quantize: bool,
    idx_buf: Vec<i32>,
    z_buf: Vec<f32>,
    key_buf: Vec<i32>,
    /// Precomputed `[R, w, K]` bin scales (2^(row+1) / width), hoisting a
    /// division per projection dim per row out of the per-sample loop.
    scale: Vec<f32>,
    /// `params.proj` transposed to `[R, K, d]` row-major so the projection's
    /// inner dot product walks contiguous memory (the `[R, d, K]` original
    /// strides by K per dimension, defeating autovectorisation). The
    /// multiplication order over `d` is unchanged, so scores stay
    /// bit-identical to the untransposed loop.
    projt: Vec<f32>,
}

impl XStream {
    pub fn new(params: XStreamParams, modulus: usize, window: usize) -> Self {
        let (r, d, w, k) = (params.r, params.d, params.w, params.k);
        let mut scale = vec![0f32; r * w * k];
        for ri in 0..r {
            for row in 0..w {
                let pow = (1u32 << (row + 1)) as f32;
                for ki in 0..k {
                    let width = params.width[ri * k + ki].max(1e-12);
                    scale[(ri * w + row) * k + ki] = pow / width;
                }
            }
        }
        let mut projt = vec![0f32; r * k * d];
        for ri in 0..r {
            for di in 0..d {
                for ki in 0..k {
                    projt[(ri * k + ki) * d + di] = params.proj[(ri * d + di) * k + ki];
                }
            }
        }
        XStream {
            params,
            modulus,
            counts: SlidingCounts::new(r * w, modulus, window),
            quantize: false,
            idx_buf: vec![0; r * w],
            z_buf: vec![0.0; k],
            key_buf: vec![0; k],
            scale,
            projt,
        }
    }
}

impl Detector for XStream {
    fn update(&mut self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.params.d);
        let (r, d, k, w) = (self.params.r, self.params.d, self.params.k, self.params.w);
        let dl = self.counts.log2_denom();
        let mut sum = 0f32;
        for ri in 0..r {
            // ③ Projection [d] → [K]: contiguous dot products through the
            //   transposed [R, K, d] matrix (same order over d ⇒ same bits).
            for ki in 0..k {
                let row = &self.projt[(ri * k + ki) * d..(ri * k + ki + 1) * d];
                let mut z = 0f32;
                for (xi, wi) in x.iter().zip(row) {
                    z += xi * wi;
                }
                self.z_buf[ki] = z;
            }
            // ④ perbins + hash per CMS row; row i (1-based) halves bin width.
            let mut min_weighted = f32::INFINITY;
            for row in 0..w {
                let pow = (1u32 << (row + 1)) as f32; // 2^(row+1)
                let base = (ri * w + row) * k;
                for ki in 0..k {
                    let shift = self.params.shift[base + ki];
                    self.key_buf[ki] =
                        ((self.z_buf[ki] - shift) * self.scale[base + ki]).floor() as i32;
                }
                let idx = jenkins_mod_i32(&self.key_buf, (row + 1) as u32, self.modulus as u32);
                self.idx_buf[ri * w + row] = idx;
                let c = self.counts.get(ri * w + row, idx) as f32;
                min_weighted = min_weighted.min(c * pow);
            }
            // ⑥ Score (log2(denom) cached by the sliding window)
            sum += dl - (1.0 + min_weighted).log2();
        }
        // ⑤ Sliding-window update
        self.counts.insert(&self.idx_buf);
        let score = sum / r as f32;
        if self.quantize {
            q16(score)
        } else {
            score
        }
    }

    /// Batch fast path: bit-identical to the `update` loop. log2(denom)
    /// comes from the sliding window's cache (recomputed only while the
    /// window fills), bin scales come from the precomputed table (a
    /// division per dim per row in `update`), the projection walks the
    /// transposed `[R, K, d]` matrix contiguously, and the per-row CMS
    /// get+insert pair is fused.
    fn update_batch(&mut self, xs: &[f32], out: &mut [f32]) {
        let (r, d, k, w) = (self.params.r, self.params.d, self.params.k, self.params.w);
        debug_assert_eq!(xs.len(), out.len() * d);
        let modulus = self.modulus as u32;
        for (x, o) in xs.chunks_exact(d).zip(out.iter_mut()) {
            let dl = self.counts.log2_denom();
            let mut sum = 0f32;
            for ri in 0..r {
                // ③ Projection [d] → [K]: contiguous [R, K, d] rows
                for ki in 0..k {
                    let row = &self.projt[(ri * k + ki) * d..(ri * k + ki + 1) * d];
                    let mut z = 0f32;
                    for (xi, wi) in x.iter().zip(row) {
                        z += xi * wi;
                    }
                    self.z_buf[ki] = z;
                }
                // ④+⑤ perbins + hash per CMS row, fused with the window insert
                let mut min_weighted = f32::INFINITY;
                for row in 0..w {
                    let pow = (1u32 << (row + 1)) as f32;
                    let base = (ri * w + row) * k;
                    for ki in 0..k {
                        let shift = self.params.shift[base + ki];
                        self.key_buf[ki] =
                            ((self.z_buf[ki] - shift) * self.scale[base + ki]).floor() as i32;
                    }
                    let idx = jenkins_mod_i32(&self.key_buf, (row + 1) as u32, modulus);
                    let c = self.counts.get_insert(ri * w + row, idx) as f32;
                    min_weighted = min_weighted.min(c * pow);
                }
                // ⑥ Score
                sum += dl - (1.0 + min_weighted).log2();
            }
            self.counts.advance();
            let score = sum / r as f32;
            *o = if self.quantize { q16(score) } else { score };
        }
    }

    fn reset(&mut self) {
        self.counts.reset();
    }

    fn r(&self) -> usize {
        self.params.r
    }

    fn d(&self) -> usize {
        self.params.d
    }

    fn name(&self) -> &'static str {
        "xstream"
    }

    fn window_state(&self) -> Option<&SlidingCounts> {
        Some(&self.counts)
    }

    fn window_state_mut(&mut self) -> Option<&mut SlidingCounts> {
        Some(&mut self.counts)
    }
}

impl XStream {
    pub fn cms(&self) -> &[i32] {
        self.counts.counts()
    }

    pub fn params(&self) -> &XStreamParams {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::prng::Prng;

    fn mk(r: usize, d: usize, seed: u64) -> (XStream, Vec<f32>) {
        let mut p = Prng::new(seed);
        let data: Vec<f32> = (0..128 * d).map(|_| p.gaussian() as f32).collect();
        let params = XStreamParams::generate(seed, r, d, 4, 2, &data[..32 * d]);
        (XStream::new(params, 64, 16), data)
    }

    #[test]
    fn scores_finite() {
        let (mut det, data) = mk(5, 3, 1);
        for s in 0..64 {
            assert!(det.update(&data[s * 3..(s + 1) * 3]).is_finite());
        }
    }

    #[test]
    fn repeated_sample_converges_to_low_score() {
        let (mut det, data) = mk(5, 3, 2);
        let x = &data[0..3];
        let mut last = f32::INFINITY;
        for _ in 0..32 {
            last = det.update(x);
        }
        // All window mass at x's bins → min weighted count is large → small score.
        assert!(last < 2.0, "score={last}");
    }

    #[test]
    fn deeper_rows_use_finer_bins() {
        // Two points inside one row-1 bin can split at row 2: row-2 count
        // can only be ≤ row-1 count for the same insertions.
        let (mut det, data) = mk(1, 3, 3);
        for s in 0..32 {
            det.update(&data[s * 3..(s + 1) * 3]);
        }
        let cms = det.cms();
        let max_row1: i32 = cms[0..64].iter().copied().max().unwrap();
        let max_row2: i32 = cms[64..128].iter().copied().max().unwrap();
        // Not a strict theorem under hashing, but with 64 buckets / 16 window
        // collisions are rare; the deterministic seed keeps this stable.
        assert!(max_row2 <= max_row1 + 1);
    }

    #[test]
    fn transposed_projection_mirrors_params() {
        // projt is a pure layout change of params.proj: [R, d, K] → [R, K, d].
        let (det, _) = mk(3, 4, 11);
        let p = det.params();
        let (r, d, k) = (p.r, p.d, p.k);
        for ri in 0..r {
            for di in 0..d {
                for ki in 0..k {
                    assert_eq!(
                        det.projt[(ri * k + ki) * d + di],
                        p.proj[(ri * d + di) * k + ki],
                        "ri={ri} di={di} ki={ki}"
                    );
                }
            }
        }
    }

    #[test]
    fn update_batch_matches_update_exactly() {
        let (mut a, data) = mk(4, 3, 9);
        let (mut b, _) = mk(4, 3, 9);
        let single: Vec<f32> = data.chunks_exact(3).map(|x| a.update(x)).collect();
        let mut batch = vec![0f32; 128];
        b.update_batch(&data, &mut batch);
        assert_eq!(single, batch);
        assert_eq!(a.cms(), b.cms());
    }

    #[test]
    fn reset_is_clean() {
        let (mut det, data) = mk(3, 3, 4);
        let s0 = det.update(&data[0..3]);
        for s in 1..20 {
            det.update(&data[s * 3..(s + 1) * 3]);
        }
        det.reset();
        assert_eq!(det.update(&data[0..3]), s0);
    }
}
