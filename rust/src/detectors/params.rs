//! Detector parameter generation (the paper's "parameters in on-chip
//! memory"). The rust coordinator owns the parameters: the same values feed
//! the CPU baseline and — as runtime inputs — the PJRT artifacts, enabling
//! exact parity experiments (paper Tables 8–10 AUC columns).
//!
//! Ranges (Loda's projection min/max, RS-Hash's per-dim min/max, xStream's
//! bin widths) are estimated from a warm-up prefix of the stream, mirroring
//! the paper's host-side initialisation before streaming starts.

use super::prng::Prng;

/// Loda (Algorithm 1): sparse random projections + histogram range.
#[derive(Clone, Debug)]
pub struct LodaParams {
    pub r: usize,
    pub d: usize,
    /// Row-major `[R, d]` projection matrix (√d-sparse N(0,1) rows).
    pub prj: Vec<f32>,
    /// Per-sub-detector histogram range `[R]`.
    pub pmin: Vec<f32>,
    pub pmax: Vec<f32>,
}

/// RS-Hash (Algorithm 2): normalisation stats + per-sub-detector grid.
#[derive(Clone, Debug)]
pub struct RsHashParams {
    pub r: usize,
    pub d: usize,
    /// Per-dimension min/max `[d]` for normalisation to [0,1].
    pub dmin: Vec<f32>,
    pub dmax: Vec<f32>,
    /// Grid offsets `[R, d]`, α ∈ U[0, f_r).
    pub alpha: Vec<f32>,
    /// Grid cell sizes `[R]`, f ∈ U(1/√W, 1−1/√W).
    pub f: Vec<f32>,
}

/// xStream (Algorithm 3): dense projections + half-space-chain bins.
#[derive(Clone, Debug)]
pub struct XStreamParams {
    pub r: usize,
    pub d: usize,
    pub k: usize,
    pub w: usize,
    /// `[R, d, K]` dense N(0,1)/√K projections.
    pub proj: Vec<f32>,
    /// `[R, w, K]` random bin shifts.
    pub shift: Vec<f32>,
    /// `[R, K]` base bin widths (row i uses width/2^i).
    pub width: Vec<f32>,
}

impl LodaParams {
    /// Generate for `r` sub-detectors over `d` dims; `warmup` is a prefix of
    /// the stream (row-major `[n, d]`) used to set histogram ranges.
    pub fn generate(seed: u64, r: usize, d: usize, warmup: &[f32]) -> Self {
        let root = Prng::new(seed);
        let nnz = (d as f64).sqrt().ceil() as usize;
        let mut prj = vec![0f32; r * d];
        for ri in 0..r {
            let mut p = root.child(ri as u64);
            for dim in p.choose_k(d, nnz) {
                prj[ri * d + dim] = p.gaussian() as f32;
            }
        }
        let (pmin, pmax) = project_range(&prj, r, d, warmup);
        LodaParams { r, d, prj, pmin, pmax }
    }

    /// Sub-range view for thread partitioning (sub-detectors `[r0, r1)`).
    pub fn slice(&self, r0: usize, r1: usize) -> Self {
        LodaParams {
            r: r1 - r0,
            d: self.d,
            prj: self.prj[r0 * self.d..r1 * self.d].to_vec(),
            pmin: self.pmin[r0..r1].to_vec(),
            pmax: self.pmax[r0..r1].to_vec(),
        }
    }
}

/// Project the warm-up prefix and return per-sub-detector [min, max] with a
/// 10 % margin each side (fallback ±3σ of the projection norm when empty).
fn project_range(prj: &[f32], r: usize, d: usize, warmup: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let n = if d == 0 { 0 } else { warmup.len() / d };
    let mut pmin = vec![f32::INFINITY; r];
    let mut pmax = vec![f32::NEG_INFINITY; r];
    for s in 0..n {
        let x = &warmup[s * d..(s + 1) * d];
        for ri in 0..r {
            let w = &prj[ri * d..(ri + 1) * d];
            let z: f32 = w.iter().zip(x).map(|(a, b)| a * b).sum();
            pmin[ri] = pmin[ri].min(z);
            pmax[ri] = pmax[ri].max(z);
        }
    }
    for ri in 0..r {
        if n == 0 || pmin[ri] >= pmax[ri] {
            let norm: f32 = prj[ri * d..(ri + 1) * d].iter().map(|w| w * w).sum::<f32>().sqrt();
            let s = 3.0 * norm.max(1e-6);
            pmin[ri] = -s;
            pmax[ri] = s;
        } else {
            let margin = 0.1 * (pmax[ri] - pmin[ri]).max(1e-6);
            pmin[ri] -= margin;
            pmax[ri] += margin;
        }
    }
    (pmin, pmax)
}

impl RsHashParams {
    pub fn generate(seed: u64, r: usize, d: usize, window: usize, warmup: &[f32]) -> Self {
        let root = Prng::new(seed);
        let (dmin, dmax) = dim_range(d, warmup);
        let srt = 1.0 / (window as f64).sqrt();
        let (flo, fhi) = (srt.min(0.49), (1.0 - srt).max(0.51));
        let mut alpha = vec![0f32; r * d];
        let mut f = vec![0f32; r];
        for ri in 0..r {
            let mut p = root.child(ri as u64);
            let fr = p.uniform_in(flo, fhi) as f32;
            f[ri] = fr;
            for dim in 0..d {
                alpha[ri * d + dim] = (p.uniform() as f32) * fr;
            }
        }
        RsHashParams { r, d, dmin, dmax, alpha, f }
    }

    pub fn slice(&self, r0: usize, r1: usize) -> Self {
        RsHashParams {
            r: r1 - r0,
            d: self.d,
            dmin: self.dmin.clone(),
            dmax: self.dmax.clone(),
            alpha: self.alpha[r0 * self.d..r1 * self.d].to_vec(),
            f: self.f[r0..r1].to_vec(),
        }
    }
}

/// Per-dimension [min, max] of the warm-up prefix (fallback [0,1]).
fn dim_range(d: usize, warmup: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let n = if d == 0 { 0 } else { warmup.len() / d };
    let mut dmin = vec![f32::INFINITY; d];
    let mut dmax = vec![f32::NEG_INFINITY; d];
    for s in 0..n {
        for dim in 0..d {
            let v = warmup[s * d + dim];
            dmin[dim] = dmin[dim].min(v);
            dmax[dim] = dmax[dim].max(v);
        }
    }
    for dim in 0..d {
        if n == 0 || dmin[dim] > dmax[dim] {
            dmin[dim] = 0.0;
            dmax[dim] = 1.0;
        }
    }
    (dmin, dmax)
}

impl XStreamParams {
    pub fn generate(seed: u64, r: usize, d: usize, k: usize, w: usize, warmup: &[f32]) -> Self {
        let root = Prng::new(seed);
        let scale = 1.0 / (k as f64).sqrt();
        let mut proj = vec![0f32; r * d * k];
        let mut shift = vec![0f32; r * w * k];
        let mut width = vec![0f32; r * k];
        let n = if d == 0 { 0 } else { warmup.len() / d };
        for ri in 0..r {
            let mut p = root.child(ri as u64);
            for di in 0..d {
                for ki in 0..k {
                    proj[(ri * d + di) * k + ki] = (p.gaussian() * scale) as f32;
                }
            }
            // Base bin width per projected dim: the full warm-up range, so
            // CMS row i (width/2^i) yields 2^i bins per dimension. All K
            // dims are hashed into one cell key (Algorithm 3's perbins), so
            // coarse top rows are essential — finer widths make every cell
            // unique and the density estimate degenerates to zero counts.
            for ki in 0..k {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for s in 0..n {
                    let x = &warmup[s * d..(s + 1) * d];
                    let mut z = 0f32;
                    for di in 0..d {
                        z += x[di] * proj[(ri * d + di) * k + ki];
                    }
                    lo = lo.min(z);
                    hi = hi.max(z);
                }
                let wdt = if n == 0 || hi <= lo { 1.0 } else { (hi - lo).max(1e-3) };
                width[ri * k + ki] = wdt;
                for wi in 0..w {
                    shift[(ri * w + wi) * k + ki] = (p.uniform() as f32) * wdt;
                }
            }
        }
        XStreamParams { r, d, k, w, proj, shift, width }
    }

    pub fn slice(&self, r0: usize, r1: usize) -> Self {
        let (d, k, w) = (self.d, self.k, self.w);
        XStreamParams {
            r: r1 - r0,
            d,
            k,
            w,
            proj: self.proj[r0 * d * k..r1 * d * k].to_vec(),
            shift: self.shift[r0 * w * k..r1 * w * k].to_vec(),
            width: self.width[r0 * k..r1 * k].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn warmup(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    #[test]
    fn loda_rows_are_sqrt_d_sparse() {
        let d = 16;
        let lp = LodaParams::generate(1, 8, d, &warmup(32, d, 2));
        for ri in 0..8 {
            let nnz = lp.prj[ri * d..(ri + 1) * d].iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, 4); // ceil(sqrt(16))
        }
    }

    #[test]
    fn loda_range_covers_warmup_projections() {
        let d = 5;
        let wu = warmup(64, d, 3);
        let lp = LodaParams::generate(7, 4, d, &wu);
        for s in 0..64 {
            for ri in 0..4 {
                let z: f32 = (0..d).map(|i| lp.prj[ri * d + i] * wu[s * d + i]).sum();
                assert!(z >= lp.pmin[ri] && z <= lp.pmax[ri]);
            }
        }
    }

    #[test]
    fn loda_empty_warmup_fallback_is_symmetric() {
        let lp = LodaParams::generate(1, 3, 4, &[]);
        for ri in 0..3 {
            assert!(lp.pmin[ri] < 0.0 && lp.pmax[ri] > 0.0);
            assert!((lp.pmin[ri] + lp.pmax[ri]).abs() < 1e-6);
        }
    }

    #[test]
    fn rshash_f_in_paper_range() {
        let rp = RsHashParams::generate(2, 16, 3, 128, &warmup(16, 3, 4));
        let srt = 1.0 / 128f64.sqrt();
        for &f in &rp.f {
            assert!((f as f64) > srt - 1e-6 && (f as f64) < 1.0 - srt + 1e-6);
        }
        // alpha ∈ [0, f)
        for ri in 0..16 {
            for di in 0..3 {
                let a = rp.alpha[ri * 3 + di];
                assert!(a >= 0.0 && a < rp.f[ri]);
            }
        }
    }

    #[test]
    fn xstream_widths_positive() {
        let xp = XStreamParams::generate(3, 4, 6, 5, 2, &warmup(40, 6, 5));
        assert!(xp.width.iter().all(|&w| w > 0.0));
        // shift ∈ [0, width)
        for ri in 0..4 {
            for wi in 0..2 {
                for ki in 0..5 {
                    let s = xp.shift[(ri * 2 + wi) * 5 + ki];
                    assert!(s >= 0.0 && s < xp.width[ri * 5 + ki]);
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = LodaParams::generate(9, 4, 6, &warmup(8, 6, 1));
        let b = LodaParams::generate(9, 4, 6, &warmup(8, 6, 1));
        assert_eq!(a.prj, b.prj);
        assert_eq!(a.pmin, b.pmin);
    }

    #[test]
    fn slice_matches_full_generation_subrange() {
        let full = XStreamParams::generate(11, 6, 4, 3, 2, &warmup(16, 4, 6));
        let part = full.slice(2, 5);
        assert_eq!(part.r, 3);
        assert_eq!(part.proj[..], full.proj[2 * 4 * 3..5 * 4 * 3]);
        assert_eq!(part.width[..], full.width[2 * 3..5 * 3]);
    }

    #[test]
    fn different_subdetectors_get_different_params() {
        let lp = LodaParams::generate(5, 8, 9, &[]);
        let r0 = &lp.prj[0..9];
        let r1 = &lp.prj[9..18];
        assert_ne!(r0, r1);
    }
}
