//! RS-Hash (paper Algorithm 2) — randomized subspace grid + CMS core.

use super::jenkins::jenkins_mod_i32;
use super::params::RsHashParams;
use super::quantize::q16;
use super::window::SlidingCounts;
use super::Detector;

#[derive(Clone, Debug)]
pub struct RsHash {
    params: RsHashParams,
    w: usize,
    modulus: usize,
    counts: SlidingCounts, // rows = R*w
    pub quantize: bool,
    idx_buf: Vec<i32>,
    key_buf: Vec<i32>,
    /// Per-dimension normalisation span, hoisted out of the per-sample loop.
    span: Vec<f32>,
}

impl RsHash {
    pub fn new(params: RsHashParams, w: usize, modulus: usize, window: usize) -> Self {
        let (r, d) = (params.r, params.d);
        let span: Vec<f32> =
            (0..d).map(|di| (params.dmax[di] - params.dmin[di]).max(1e-12)).collect();
        RsHash {
            params,
            w,
            modulus,
            counts: SlidingCounts::new(r * w, modulus, window),
            quantize: false,
            idx_buf: vec![0; r * w],
            key_buf: vec![0; d],
            span,
        }
    }
}

impl Detector for RsHash {
    fn update(&mut self, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.params.d);
        let (r, d, w) = (self.params.r, self.params.d, self.w);
        let dl = self.counts.log2_denom();
        let mut sum = 0f32;
        for ri in 0..r {
            // ③ Projection: normalise + integer grid (matches the kernel's
            //    f32 op order: norm, +α, /f, floor).
            let f = self.params.f[ri];
            for di in 0..d {
                let norm = (x[di] - self.params.dmin[di]) / self.span[di];
                let prj = (norm + self.params.alpha[ri * d + di]) / f;
                self.key_buf[di] = prj.floor() as i32;
            }
            // ④ Hash per CMS row (seed = 1-based row), gather counts.
            let mut min_c = i32::MAX;
            for row in 0..w {
                let idx = jenkins_mod_i32(&self.key_buf, (row + 1) as u32, self.modulus as u32);
                self.idx_buf[ri * w + row] = idx;
                min_c = min_c.min(self.counts.get(ri * w + row, idx));
            }
            // ⑥ Score (log2(denom) cached by the sliding window)
            sum += dl - (1.0 + min_c as f32).log2();
        }
        // ⑤ Sliding-window update
        self.counts.insert(&self.idx_buf);
        let score = sum / r as f32;
        if self.quantize {
            q16(score)
        } else {
            score
        }
    }

    /// Batch fast path: bit-identical to the `update` loop, with log2(denom)
    /// computed once per sample instead of R times and the per-row CMS
    /// get+insert pair fused (no idx_buf round-trip).
    fn update_batch(&mut self, xs: &[f32], out: &mut [f32]) {
        let (r, d, w) = (self.params.r, self.params.d, self.w);
        debug_assert_eq!(xs.len(), out.len() * d);
        let modulus = self.modulus as u32;
        for (x, o) in xs.chunks_exact(d).zip(out.iter_mut()) {
            let dl = self.counts.log2_denom();
            let mut sum = 0f32;
            for ri in 0..r {
                // ③ Projection: normalise + integer grid
                let f = self.params.f[ri];
                let alpha = &self.params.alpha[ri * d..(ri + 1) * d];
                for di in 0..d {
                    let norm = (x[di] - self.params.dmin[di]) / self.span[di];
                    let prj = (norm + alpha[di]) / f;
                    self.key_buf[di] = prj.floor() as i32;
                }
                // ④+⑤ Hash per CMS row, count fused with the window insert
                let mut min_c = i32::MAX;
                for row in 0..w {
                    let idx = jenkins_mod_i32(&self.key_buf, (row + 1) as u32, modulus);
                    min_c = min_c.min(self.counts.get_insert(ri * w + row, idx));
                }
                // ⑥ Score
                sum += dl - (1.0 + min_c as f32).log2();
            }
            self.counts.advance();
            let score = sum / r as f32;
            *o = if self.quantize { q16(score) } else { score };
        }
    }

    fn reset(&mut self) {
        self.counts.reset();
    }

    fn r(&self) -> usize {
        self.params.r
    }

    fn d(&self) -> usize {
        self.params.d
    }

    fn name(&self) -> &'static str {
        "rshash"
    }

    fn window_state(&self) -> Option<&SlidingCounts> {
        Some(&self.counts)
    }

    fn window_state_mut(&mut self) -> Option<&mut SlidingCounts> {
        Some(&mut self.counts)
    }
}

impl RsHash {
    pub fn cms(&self) -> &[i32] {
        self.counts.counts()
    }

    pub fn params(&self) -> &RsHashParams {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::prng::Prng;

    fn mk(r: usize, d: usize, seed: u64) -> (RsHash, Vec<f32>) {
        let mut p = Prng::new(seed);
        let data: Vec<f32> = (0..128 * d).map(|_| p.gaussian() as f32).collect();
        let params = RsHashParams::generate(seed, r, d, 16, &data[..32 * d]);
        (RsHash::new(params, 2, 64, 16), data)
    }

    #[test]
    fn scores_finite_and_nonnegative_after_warmup() {
        let (mut det, data) = mk(6, 4, 1);
        for s in 0..64 {
            let sc = det.update(&data[s * 4..(s + 1) * 4]);
            assert!(sc.is_finite());
            assert!(sc >= -1e-5, "score={sc}");
        }
    }

    #[test]
    fn repeated_sample_scores_drop() {
        let (mut det, data) = mk(6, 4, 2);
        let x = &data[0..4];
        let first = det.update(x);
        let mut last = first;
        for _ in 0..16 {
            last = det.update(x);
        }
        assert!(last <= first);
    }

    #[test]
    fn novel_region_scores_high() {
        let (mut det, data) = mk(8, 4, 3);
        let mut base = 0f32;
        for s in 0..32 {
            base = det.update(&data[s * 4..(s + 1) * 4]);
        }
        let sc = det.update(&[100.0, -100.0, 100.0, -100.0]);
        assert!(sc >= base);
    }

    #[test]
    fn cms_row_totals_respect_window() {
        let (mut det, data) = mk(3, 4, 4);
        for s in 0..40 {
            det.update(&data[s * 4..(s + 1) * 4]);
        }
        // rows = R*w = 6; each row total == window
        let cms = det.cms();
        for row in 0..6 {
            let total: i32 = cms[row * 64..(row + 1) * 64].iter().sum();
            assert_eq!(total, 16);
        }
    }

    #[test]
    fn update_batch_matches_update_exactly() {
        let (mut a, data) = mk(5, 4, 9);
        let (mut b, _) = mk(5, 4, 9);
        let single: Vec<f32> = data.chunks_exact(4).map(|x| a.update(x)).collect();
        let mut batch = vec![0f32; 128];
        b.update_batch(&data, &mut batch);
        assert_eq!(single, batch);
        assert_eq!(a.cms(), b.cms());
    }

    #[test]
    fn deterministic_across_instances() {
        let (mut a, data) = mk(4, 3, 5);
        let (mut b, _) = mk(4, 3, 5);
        for s in 0..32 {
            let x = &data[s * 3..(s + 1) * 3];
            assert_eq!(a.update(x), b.update(x));
        }
    }
}
