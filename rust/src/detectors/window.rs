//! Sliding-window count tables (paper block ⑤).
//!
//! One structure serves both cores: Loda's per-sub-detector histogram
//! (`rows = R`, `width = bins`) and the CMS of RS-Hash/xStream
//! (`rows = R·w`, `width = MOD`). A ring buffer remembers the table index
//! each of the last `W` samples touched per row, so the oldest sample can be
//! evicted exactly — identical semantics to the JAX model's scan state.

/// Why [`SlidingCounts::load`] refused a snapshot. Typed so callers
/// (checkpoint restore, ticket resume, the operator plane's protocol
/// front ends) can map the refusal onto a status code instead of matching
/// on a formatted string.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WindowLoadError {
    /// The snapshot's counts/ring lengths do not match this window's
    /// `rows × width × window` geometry.
    ShapeMismatch {
        rows: usize,
        width: usize,
        ring_len: usize,
        snapshot_counts: usize,
        snapshot_ring: usize,
    },
    /// The snapshot's ring cursor does not fit this window.
    PosOutOfRange { pos: usize, window: usize },
}

impl std::fmt::Display for WindowLoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WindowLoadError::ShapeMismatch {
                rows,
                width,
                ring_len,
                snapshot_counts,
                snapshot_ring,
            } => write!(
                f,
                "window shape mismatch: {rows}x{width} counts / ring {ring_len} vs snapshot \
                 {snapshot_counts} / {snapshot_ring}"
            ),
            WindowLoadError::PosOutOfRange { pos, window } => {
                write!(f, "ring position {pos} out of range (window {window})")
            }
        }
    }
}

impl std::error::Error for WindowLoadError {}

/// Windowed count tables: `rows × width` counts + `rows × window` ring.
#[derive(Clone, Debug)]
pub struct SlidingCounts {
    rows: usize,
    width: usize,
    window: usize,
    counts: Vec<i32>,
    ring: Vec<i32>,
    pos: usize,
    n: u64,
    /// Cached `denom().log2()`. The denominator only changes while the
    /// window is still filling (`n ≤ window`), so the cache is refreshed in
    /// [`SlidingCounts::advance`] during that phase and then frozen —
    /// saving a `log2` per sample (previously per sample per sub-detector
    /// in the detectors' score loops) for the entire steady state.
    log2_denom: f32,
}

impl SlidingCounts {
    pub fn new(rows: usize, width: usize, window: usize) -> Self {
        assert!(rows > 0 && width > 0 && window > 0);
        SlidingCounts {
            rows,
            width,
            window,
            counts: vec![0; rows * width],
            ring: vec![0; rows * window],
            pos: 0,
            n: 0,
            log2_denom: 0.0, // log2(denom) with n = 0 ⇒ denom = 1
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn window(&self) -> usize {
        self.window
    }

    /// Samples inserted so far.
    #[inline]
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Score denominator: samples currently represented in the window.
    #[inline]
    pub fn denom(&self) -> f32 {
        (self.n.min(self.window as u64)).max(1) as f32
    }

    /// Cached `denom().log2()` — bit-identical to recomputing it (same f32
    /// input, same `log2` call; it is simply memoised across the steady
    /// state where `denom` no longer changes).
    #[inline]
    pub fn log2_denom(&self) -> f32 {
        self.log2_denom
    }

    /// Current count for (row, idx).
    #[inline]
    pub fn get(&self, row: usize, idx: i32) -> i32 {
        debug_assert!((0..self.width as i32).contains(&idx));
        self.counts[row * self.width + idx as usize]
    }

    /// Insert one sample: `idxs[row]` is the table index the sample maps to
    /// in each row. Evicts the sample that falls out of the window.
    pub fn insert(&mut self, idxs: &[i32]) {
        debug_assert_eq!(idxs.len(), self.rows);
        let evict = self.n >= self.window as u64;
        for (row, &idx) in idxs.iter().enumerate() {
            debug_assert!((0..self.width as i32).contains(&idx));
            if evict {
                let old = self.ring[row * self.window + self.pos];
                self.counts[row * self.width + old as usize] -= 1;
            }
            self.counts[row * self.width + idx as usize] += 1;
            self.ring[row * self.window + self.pos] = idx;
        }
        self.advance();
    }

    /// Fused get+insert for one row — the hot pair in the detectors' batch
    /// loops. Returns the pre-insert count (read-count-before-insert, same
    /// semantics as `get` followed by `insert`), then evicts and records the
    /// new index for this row. The caller must touch each row exactly once
    /// per sample and call [`SlidingCounts::advance`] once all rows are done.
    #[inline]
    pub fn get_insert(&mut self, row: usize, idx: i32) -> i32 {
        debug_assert!((0..self.width as i32).contains(&idx));
        let base = row * self.width;
        let c = self.counts[base + idx as usize];
        if self.n >= self.window as u64 {
            let old = self.ring[row * self.window + self.pos];
            self.counts[base + old as usize] -= 1;
        }
        self.counts[base + idx as usize] += 1;
        self.ring[row * self.window + self.pos] = idx;
        c
    }

    /// Advance the ring to the next sample slot after a round of
    /// [`SlidingCounts::get_insert`] calls. Branch-reset instead of `%` —
    /// the modulo was a measurable cost in the per-sample hot path.
    #[inline]
    pub fn advance(&mut self) {
        self.pos += 1;
        if self.pos == self.window {
            self.pos = 0;
        }
        self.n += 1;
        // The denominator saturates once the window is full; refresh the
        // cached log2 only while it can still change.
        if self.n <= self.window as u64 {
            self.log2_denom = self.denom().log2();
        }
    }

    /// Reset to the empty state.
    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.ring.fill(0);
        self.pos = 0;
        self.n = 0;
        self.log2_denom = 0.0;
    }

    /// Raw count table (row-major), e.g. for exporting to the PJRT state.
    pub fn counts(&self) -> &[i32] {
        &self.counts
    }

    /// Raw eviction ring (row-major `rows × window`) — checkpoint surface.
    pub fn ring(&self) -> &[i32] {
        &self.ring
    }

    /// Current ring slot — checkpoint surface.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Fault-injection hook: corrupt the cached score denominator so every
    /// subsequent score goes non-finite. Models an SEU-style upset of
    /// detector state without breaking the count-table invariants — the
    /// window keeps evicting correctly, only the scores are poisoned, which
    /// is exactly what the supervisor's non-finite scan detects. (During the
    /// fill phase `advance` would refresh the cache and self-heal; in the
    /// steady state the poison persists until a reset or restore.)
    pub fn poison(&mut self) {
        self.log2_denom = f32::NAN;
    }

    /// Restore a previously exported state (counts + ring + cursor). The
    /// shape must match this window's `rows × width × window` exactly —
    /// checkpoints never cross detector geometries.
    pub fn load(
        &mut self,
        counts: &[i32],
        ring: &[i32],
        pos: usize,
        n: u64,
        log2_denom: f32,
    ) -> Result<(), WindowLoadError> {
        if counts.len() != self.counts.len() || ring.len() != self.ring.len() {
            return Err(WindowLoadError::ShapeMismatch {
                rows: self.rows,
                width: self.width,
                ring_len: self.ring.len(),
                snapshot_counts: counts.len(),
                snapshot_ring: ring.len(),
            });
        }
        if pos >= self.window {
            return Err(WindowLoadError::PosOutOfRange { pos, window: self.window });
        }
        self.counts.copy_from_slice(counts);
        self.ring.copy_from_slice(ring);
        self.pos = pos;
        self.n = n;
        self.log2_denom = log2_denom;
        Ok(())
    }

    /// Total count in one row — invariant: `min(n, window)`.
    pub fn row_total(&self, row: usize) -> i64 {
        self.counts[row * self.width..(row + 1) * self.width]
            .iter()
            .map(|&c| c as i64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::prng::Prng;

    #[test]
    fn counts_track_inserts_before_window_fills() {
        let mut sc = SlidingCounts::new(2, 8, 4);
        sc.insert(&[1, 2]);
        sc.insert(&[1, 3]);
        assert_eq!(sc.get(0, 1), 2);
        assert_eq!(sc.get(1, 2), 1);
        assert_eq!(sc.get(1, 3), 1);
        assert_eq!(sc.denom(), 2.0);
    }

    #[test]
    fn eviction_keeps_row_total_at_window() {
        let mut sc = SlidingCounts::new(3, 16, 5);
        let mut p = Prng::new(1);
        for _ in 0..100 {
            let idxs: Vec<i32> = (0..3).map(|_| p.below(16) as i32).collect();
            sc.insert(&idxs);
            let expect = sc.n().min(5) as i64;
            for row in 0..3 {
                assert_eq!(sc.row_total(row), expect);
            }
        }
    }

    #[test]
    fn no_negative_counts_ever() {
        let mut sc = SlidingCounts::new(1, 4, 3);
        let mut p = Prng::new(2);
        for _ in 0..500 {
            sc.insert(&[p.below(4) as i32]);
            assert!(sc.counts().iter().all(|&c| c >= 0));
        }
    }

    #[test]
    fn oldest_is_evicted_fifo() {
        let mut sc = SlidingCounts::new(1, 8, 2);
        sc.insert(&[5]);
        sc.insert(&[6]);
        sc.insert(&[7]); // evicts 5
        assert_eq!(sc.get(0, 5), 0);
        assert_eq!(sc.get(0, 6), 1);
        assert_eq!(sc.get(0, 7), 1);
    }

    #[test]
    fn reset_restores_empty_state() {
        let mut sc = SlidingCounts::new(2, 4, 2);
        sc.insert(&[0, 1]);
        sc.reset();
        assert_eq!(sc.n(), 0);
        assert!(sc.counts().iter().all(|&c| c == 0));
        assert_eq!(sc.denom(), 1.0);
    }

    #[test]
    fn get_insert_matches_get_then_insert() {
        // The fused fast path must be state-identical to get + insert,
        // including the old==new eviction corner (pre-insert count read
        // before the outgoing sample is evicted).
        let mut fused = SlidingCounts::new(3, 8, 4);
        let mut plain = SlidingCounts::new(3, 8, 4);
        let mut p = Prng::new(7);
        for _ in 0..200 {
            let idxs: Vec<i32> = (0..3).map(|_| p.below(8) as i32).collect();
            for (row, &idx) in idxs.iter().enumerate() {
                let a = fused.get_insert(row, idx);
                let b = plain.get(row, idx);
                assert_eq!(a, b, "pre-insert count diverged");
            }
            fused.advance();
            plain.insert(&idxs);
            assert_eq!(fused.counts(), plain.counts());
            assert_eq!(fused.n(), plain.n());
            assert_eq!(fused.denom(), plain.denom());
        }
    }

    #[test]
    fn cached_log2_denom_tracks_recomputation() {
        // Bit-identical to recomputing per sample, through fill, steady
        // state and reset.
        let mut sc = SlidingCounts::new(1, 4, 5);
        assert_eq!(sc.log2_denom(), sc.denom().log2());
        for i in 0..20 {
            sc.insert(&[(i % 4) as i32]);
            assert_eq!(sc.log2_denom(), sc.denom().log2(), "n={}", sc.n());
        }
        sc.reset();
        assert_eq!(sc.log2_denom(), 0.0);
        assert_eq!(sc.log2_denom(), sc.denom().log2());
    }

    #[test]
    fn load_roundtrips_exported_state() {
        let mut src = SlidingCounts::new(2, 8, 4);
        let mut p = Prng::new(9);
        for _ in 0..11 {
            let idxs: Vec<i32> = (0..2).map(|_| p.below(8) as i32).collect();
            src.insert(&idxs);
        }
        let mut dst = SlidingCounts::new(2, 8, 4);
        dst.load(src.counts(), src.ring(), src.pos(), src.n(), src.log2_denom()).unwrap();
        assert_eq!(dst.counts(), src.counts());
        assert_eq!(dst.ring(), src.ring());
        assert_eq!(dst.pos(), src.pos());
        assert_eq!(dst.n(), src.n());
        assert_eq!(dst.log2_denom(), src.log2_denom());
        // Continued streams stay in lock-step after the transplant.
        for _ in 0..9 {
            let idxs: Vec<i32> = (0..2).map(|_| p.below(8) as i32).collect();
            src.insert(&idxs);
            dst.insert(&idxs);
            assert_eq!(dst.counts(), src.counts());
        }
        // Shape mismatches are refused.
        let mut other = SlidingCounts::new(2, 4, 4);
        assert!(other.load(src.counts(), src.ring(), src.pos(), src.n(), 1.0).is_err());
    }

    #[test]
    fn poison_makes_scores_non_finite_until_restored() {
        let mut sc = SlidingCounts::new(1, 4, 3);
        for i in 0..6 {
            sc.insert(&[(i % 4) as i32]); // past the fill phase: cache frozen
        }
        sc.poison();
        assert!(sc.log2_denom().is_nan());
        sc.insert(&[1]); // steady state: advance must not refresh the cache
        assert!(sc.log2_denom().is_nan());
        sc.reset();
        assert_eq!(sc.log2_denom(), 0.0);
    }

    #[test]
    fn denom_saturates_at_window() {
        let mut sc = SlidingCounts::new(1, 4, 3);
        for i in 0..10 {
            sc.insert(&[(i % 4) as i32]);
        }
        assert_eq!(sc.denom(), 3.0);
    }
}
