//! CPU baseline detectors (the paper's GCC implementations, in rust) and
//! the substrates they share: PRNG, Jenkins hash, sliding-window counts,
//! parameter generation and Q16.16 quantisation.
//!
//! Each detector consumes one sample per [`Detector::update`] call and
//! returns the ensemble-averaged anomaly score — higher ⇒ more anomalous.

pub mod jenkins;
pub mod loda;
pub mod params;
pub mod prng;
pub mod quantize;
pub mod rshash;
pub mod window;
pub mod xstream;

pub use loda::Loda;
pub use rshash::RsHash;
pub use xstream::XStream;

use crate::defaults;
use params::{LodaParams, RsHashParams, XStreamParams};

/// A streaming ensemble anomaly detector (blocks ①–⑦ of paper Table 1).
pub trait Detector: Send {
    /// Score one sample and update the sliding-window state.
    fn update(&mut self, x: &[f32]) -> f32;
    /// Clear all window state (parameters are kept).
    fn reset(&mut self);
    /// Ensemble size (number of sub-detectors).
    fn r(&self) -> usize;
    /// Input dimensionality.
    fn d(&self) -> usize;
    fn name(&self) -> &'static str;

    /// Score a row-major `[n, d]` batch into `out` (`n = out.len()`).
    ///
    /// Semantically identical to calling [`Detector::update`] per sample
    /// (bit-identical scores, same window state afterwards), but detectors
    /// override it with a hand-optimised loop: per-sample `log2(denom)` and
    /// parameter-derived spans/scales are hoisted out of the R-loop, and
    /// the count-table get+insert pair is fused
    /// ([`window::SlidingCounts::get_insert`]). This is the hot path of the
    /// batched execution engine ([`crate::ensemble::run_batched`]).
    fn update_batch(&mut self, xs: &[f32], out: &mut [f32]) {
        let d = self.d();
        debug_assert_eq!(xs.len(), out.len() * d);
        for (x, o) in xs.chunks_exact(d).zip(out.iter_mut()) {
            *o = self.update(x);
        }
    }

    /// Convenience: score a whole row-major `[n, d]` stream.
    fn run_stream(&mut self, xs: &[f32]) -> Vec<f32> {
        let d = self.d();
        xs.chunks_exact(d).map(|x| self.update(x)).collect()
    }

    /// The detector's mutable sliding-window state, if it has one. All the
    /// dynamic state of the CPU cores lives in a [`window::SlidingCounts`]
    /// (parameters and derived caches rebuild deterministically from the
    /// seed + warm-up), so exposing it is enough for checkpoint/restore
    /// ([`crate::fabric::snapshot`]) and fault injection.
    fn window_state(&self) -> Option<&window::SlidingCounts> {
        None
    }

    /// Mutable access to the sliding-window state (see
    /// [`Detector::window_state`]).
    fn window_state_mut(&mut self) -> Option<&mut window::SlidingCounts> {
        None
    }

    /// Fault-injection hook: corrupt the window state so subsequent scores
    /// go non-finite ([`window::SlidingCounts::poison`]). No-op for
    /// detectors without window state.
    fn poison_state(&mut self) {
        if let Some(w) = self.window_state_mut() {
            w.poison();
        }
    }
}

/// Detector algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DetectorKind {
    Loda,
    RsHash,
    XStream,
}

impl DetectorKind {
    pub const ALL: [DetectorKind; 3] = [DetectorKind::Loda, DetectorKind::RsHash, DetectorKind::XStream];

    pub fn as_str(&self) -> &'static str {
        match self {
            DetectorKind::Loda => "loda",
            DetectorKind::RsHash => "rshash",
            DetectorKind::XStream => "xstream",
        }
    }

    pub fn parse(s: &str) -> Option<DetectorKind> {
        match s.to_ascii_lowercase().as_str() {
            "loda" | "a" => Some(DetectorKind::Loda),
            "rshash" | "rs-hash" | "b" => Some(DetectorKind::RsHash),
            "xstream" | "c" => Some(DetectorKind::XStream),
            _ => None,
        }
    }

    /// Paper Table 7 per-pblock ensemble size.
    pub fn pblock_r(&self) -> usize {
        match self {
            DetectorKind::Loda => defaults::PBLOCK_R_LODA,
            DetectorKind::RsHash => defaults::PBLOCK_R_RSHASH,
            DetectorKind::XStream => defaults::PBLOCK_R_XSTREAM,
        }
    }
}

/// Hyper-parameters for detector construction (paper Table 4 defaults).
#[derive(Clone, Copy, Debug)]
pub struct DetectorSpec {
    pub kind: DetectorKind,
    pub r: usize,
    pub d: usize,
    pub window: usize,
    pub bins: usize,
    pub w: usize,
    pub modulus: usize,
    pub k: usize,
    pub quantize: bool,
    pub seed: u64,
}

impl DetectorSpec {
    pub fn new(kind: DetectorKind, d: usize, r: usize, seed: u64) -> Self {
        DetectorSpec {
            kind,
            r,
            d,
            window: defaults::WINDOW,
            bins: defaults::LODA_BINS,
            w: defaults::CMS_ROWS,
            modulus: defaults::CMS_MOD,
            k: defaults::XSTREAM_K,
            quantize: false,
            seed,
        }
    }

    /// Build a detector owning only sub-detectors `[r0, r1)` of the full
    /// ensemble — used to partition an ensemble across CPU threads (paper
    /// §4.4) while keeping parameters identical to the unpartitioned build.
    pub fn build_slice(&self, warmup: &[f32], r0: usize, r1: usize) -> Box<dyn Detector> {
        assert!(r0 < r1 && r1 <= self.r);
        match self.kind {
            DetectorKind::Loda => {
                let p = LodaParams::generate(self.seed, self.r, self.d, warmup).slice(r0, r1);
                let mut det = Loda::new(p, self.bins, self.window);
                det.quantize = self.quantize;
                Box::new(det)
            }
            DetectorKind::RsHash => {
                let p = RsHashParams::generate(self.seed, self.r, self.d, self.window, warmup)
                    .slice(r0, r1);
                let mut det = RsHash::new(p, self.w, self.modulus, self.window);
                det.quantize = self.quantize;
                Box::new(det)
            }
            DetectorKind::XStream => {
                let p =
                    XStreamParams::generate(self.seed, self.r, self.d, self.k, self.w, warmup)
                        .slice(r0, r1);
                let mut det = XStream::new(p, self.modulus, self.window);
                det.quantize = self.quantize;
                Box::new(det)
            }
        }
    }

    /// Build the detector, estimating ranges from a warm-up prefix
    /// (row-major `[n, d]`, may be empty).
    pub fn build(&self, warmup: &[f32]) -> Box<dyn Detector> {
        match self.kind {
            DetectorKind::Loda => {
                let p = LodaParams::generate(self.seed, self.r, self.d, warmup);
                let mut det = Loda::new(p, self.bins, self.window);
                det.quantize = self.quantize;
                Box::new(det)
            }
            DetectorKind::RsHash => {
                let p = RsHashParams::generate(self.seed, self.r, self.d, self.window, warmup);
                let mut det = RsHash::new(p, self.w, self.modulus, self.window);
                det.quantize = self.quantize;
                Box::new(det)
            }
            DetectorKind::XStream => {
                let p = XStreamParams::generate(self.seed, self.r, self.d, self.k, self.w, warmup);
                let mut det = XStream::new(p, self.modulus, self.window);
                det.quantize = self.quantize;
                Box::new(det)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use prng::Prng;

    #[test]
    fn spec_builds_all_kinds() {
        let mut p = Prng::new(0);
        let warmup: Vec<f32> = (0..32 * 3).map(|_| p.gaussian() as f32).collect();
        for kind in DetectorKind::ALL {
            let mut det = DetectorSpec::new(kind, 3, 4, 1).build(&warmup);
            assert_eq!(det.r(), 4);
            assert_eq!(det.d(), 3);
            let scores = det.run_stream(&warmup);
            assert_eq!(scores.len(), 32);
            assert!(scores.iter().all(|s| s.is_finite()));
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in DetectorKind::ALL {
            assert_eq!(DetectorKind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(DetectorKind::parse("A"), Some(DetectorKind::Loda));
        assert_eq!(DetectorKind::parse("nope"), None);
    }

    #[test]
    fn update_batch_is_bit_identical_to_update_loop() {
        let mut p = Prng::new(11);
        let data: Vec<f32> = (0..60 * 4).map(|_| p.gaussian() as f32).collect();
        for kind in DetectorKind::ALL {
            let mut spec = DetectorSpec::new(kind, 4, 5, 13);
            spec.window = 16;
            let mut a = spec.build(&data[..16 * 4]);
            let mut b = spec.build(&data[..16 * 4]);
            let single: Vec<f32> = data.chunks_exact(4).map(|x| a.update(x)).collect();
            let mut batched = vec![0f32; 60];
            // Uneven batch splits so mid-stream state hand-off is covered.
            for (lo, hi) in [(0usize, 1usize), (1, 16), (16, 47), (47, 60)] {
                let (xs, out) = (&data[lo * 4..hi * 4], &mut batched[lo..hi]);
                b.update_batch(xs, out);
            }
            assert_eq!(single, batched, "{kind:?} batch path diverged");
        }
    }

    #[test]
    fn run_stream_equals_update_loop() {
        let mut p = Prng::new(3);
        let data: Vec<f32> = (0..20 * 3).map(|_| p.gaussian() as f32).collect();
        let spec = DetectorSpec::new(DetectorKind::RsHash, 3, 3, 7);
        let mut a = spec.build(&data);
        let mut b = spec.build(&data);
        let batch = a.run_stream(&data);
        let single: Vec<f32> = data.chunks_exact(3).map(|x| b.update(x)).collect();
        assert_eq!(batch, single);
    }
}
