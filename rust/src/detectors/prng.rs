//! Deterministic PRNG substrate: SplitMix64 seeding + xoshiro256\*\*.
//!
//! Detector parameters (projection vectors, α/f, bin shifts…) are generated
//! here and fed both to the CPU baseline and — as runtime inputs — to the
//! PJRT artifacts, so both paths see *identical* parameters (the FPGA paper
//! stores them in on-chip memory; we pass them as arguments).

/// SplitMix64 — used to expand a user seed into xoshiro state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256\*\* — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second Box-Muller deviate.
    gauss_spare: Option<f64>,
}

impl Prng {
    /// Seeded construction; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Prng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], gauss_spare: None }
    }

    /// Derive an independent child stream (used to give each sub-detector
    /// its own reproducible parameter stream regardless of generation order).
    pub fn child(&self, stream: u64) -> Prng {
        let mut sm = SplitMix64(self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407));
        Prng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (polar-free, cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle prefix: pick `k` distinct indices out of `n`.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_give_distinct_streams() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn child_streams_independent_of_call_order() {
        let root = Prng::new(7);
        let mut c1 = root.child(3);
        let mut c2 = root.child(3);
        assert_eq!(c1.next_u64(), c2.next_u64());
        let mut c3 = root.child(4);
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut p = Prng::new(9);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments_sane() {
        let mut p = Prng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut p = Prng::new(5);
        for _ in 0..50 {
            let k = p.below(10) + 1;
            let picks = p.choose_k(20, k);
            assert_eq!(picks.len(), k);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k);
            assert!(picks.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut p = Prng::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[p.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
