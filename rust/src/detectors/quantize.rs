//! Q16.16 fixed point — the software analogue of the paper's
//! `ap_fixed<32,16,AP_TRN,AP_WRAP>` (§4.4). Matches `model._q16` in the
//! JAX model: round-to-nearest into a 32-bit integer with 16 fraction bits.

/// Quantise an f32 to the Q16.16 grid (round-to-nearest-even like jnp.round).
#[inline]
pub fn q16(v: f32) -> f32 {
    let scaled = (v as f64) * 65536.0;
    // jnp.round uses banker's rounding; f64::round_ties_even matches.
    let q = scaled.round_ties_even() as i64 as i32; // wraps like AP_WRAP
    q as f32 / 65536.0
}

/// Quantise a slice in place.
pub fn q16_slice(vs: &mut [f32]) {
    for v in vs {
        *v = q16(*v);
    }
}

/// Max representable magnitude before wrap.
pub const Q16_MAX: f32 = 32767.999_98;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_spacing_is_2_pow_minus_16() {
        assert_eq!(q16(1.0 / 65536.0), 1.0 / 65536.0);
        assert_eq!(q16(1.0 / 131072.0 + 1e-9), 1.0 / 65536.0);
        assert_eq!(q16(0.0), 0.0);
    }

    #[test]
    fn idempotent() {
        for v in [-3.75, 0.1, 2.5, 1000.125, -0.000_01] {
            assert_eq!(q16(q16(v)), q16(v));
        }
    }

    #[test]
    fn error_bounded_by_half_ulp() {
        for i in 0..1000 {
            let v = (i as f32) * 0.003_7 - 1.85;
            assert!((q16(v) - v).abs() <= 0.5 / 65536.0 + 1e-9);
        }
    }

    #[test]
    fn negative_values_quantise() {
        assert_eq!(q16(-1.5), -1.5);
        assert!((q16(-0.1) - (-0.1)).abs() < 1.0 / 65536.0);
    }
}
