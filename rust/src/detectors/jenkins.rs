//! Jenkins one-at-a-time hash (paper Algorithm 4).
//!
//! Bit-exact counterpart of `python/compile/kernels/jenkins.py` — the golden
//! vectors below are shared verbatim with `python/tests/test_jenkins.py`.
//! Any divergence here breaks CPU↔FPGA-artifact parity.

/// Hash a key of u32 words with the given seed (the paper seeds with the
/// 1-based CMS row index).
#[inline]
pub fn jenkins_hash(key: &[u32], seed: u32) -> u32 {
    let mut h = seed;
    for &k in key {
        h = h.wrapping_add(k);
        h = h.wrapping_add(h << 10);
        h ^= h >> 6;
    }
    h = h.wrapping_add(h << 3);
    h ^= h >> 11;
    h = h.wrapping_add(h << 15);
    h
}

/// `jenkins_hash % mod` as a table index.
#[inline]
pub fn jenkins_mod(key: &[u32], seed: u32, modulus: u32) -> i32 {
    (jenkins_hash(key, seed) % modulus) as i32
}

/// Hash a key of i32 grid values (two's-complement reinterpretation, matching
/// jnp's `astype(uint32)`).
#[inline]
pub fn jenkins_mod_i32(key: &[i32], seed: u32, modulus: u32) -> i32 {
    let mut h = seed;
    for &k in key {
        h = h.wrapping_add(k as u32);
        h = h.wrapping_add(h << 10);
        h ^= h >> 6;
    }
    h = h.wrapping_add(h << 3);
    h ^= h >> 11;
    h = h.wrapping_add(h << 15);
    (h % modulus) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared with python/tests/test_jenkins.py::GOLDEN.
    const GOLDEN: &[(&[u32], u32, u32)] = &[
        (&[0], 0, 0x0000_0000),
        (&[1, 2, 3], 1, 0x54EE_7BFA),
        (&[0xFFFF_FFFF], 7, 0x6DC7_5B8D),
        (&[42, 0, 42, 0xDEAD_BEEF], 2, 0x1FF9_CDF1),
        (&[5, 4, 3, 2, 1, 0], 123456, 0x1C57_948C),
    ];

    #[test]
    fn golden_vectors_match_python() {
        for &(key, seed, want) in GOLDEN {
            assert_eq!(jenkins_hash(key, seed), want, "key={key:?} seed={seed}");
        }
    }

    #[test]
    fn i32_wraps_like_u32() {
        assert_eq!(jenkins_mod_i32(&[-1], 7, 1 << 31), jenkins_mod(&[0xFFFF_FFFF], 7, 1 << 31));
        assert_eq!(jenkins_mod_i32(&[i32::MIN], 3, 997), jenkins_mod(&[0x8000_0000], 3, 997));
    }

    #[test]
    fn mod_in_range() {
        for m in [2u32, 16, 128, 997] {
            for s in 0..8 {
                let idx = jenkins_mod(&[s * 7919, s], s, m);
                assert!((0..m as i32).contains(&idx));
            }
        }
    }

    #[test]
    fn seed_changes_hash() {
        let key = [10u32, 20, 30];
        assert_ne!(jenkins_hash(&key, 1), jenkins_hash(&key, 2));
    }
}
