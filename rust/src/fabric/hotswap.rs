//! Live DFX: hot-swapping RMs while the fabric is streaming.
//!
//! The offline path ([`super::reconfig::DfxManager::reconfigure`]) swaps an
//! RM between runs. This module makes reconfiguration a first-class
//! in-flight operation, following the paper's §3.2 shell protocol:
//!
//! 1. **Stage** — the replacement RM is built up front
//!    ([`DfxManager::stage`]): parameters generated, artifact compiled and
//!    loaded on the device. This mirrors staging the partial bitstream in
//!    DDR; it happens *outside* the dark window, so staging cost never
//!    interrupts the stream.
//! 2. **Quiesce** — when the pblock's service loop reaches the scheduled
//!    flit, it asserts the region's decoupler. Because the swap executes
//!    *in* the service thread between two flits, the RM is quiescent by
//!    construction: no in-flight flit is ever handed to half-configured
//!    logic, and every other pblock keeps streaming untouched (they share
//!    no state with the target region).
//! 3. **Dark window** — the Table-13-calibrated download latency is charged
//!    in stream terms: `dark_flits = ceil(model_ms × samples_per_sec /
//!    chunk)` flits arriving while the region is dark are either dropped at
//!    the decoupler ([`DarkPolicy::Drop`]) or answered with zero-score
//!    placeholder flits ([`DarkPolicy::Bypass`], the default — it keeps
//!    combo joins and output DMAs sample-aligned across the swap).
//! 4. **Re-enable** — the old RM is dropped, the new RM is reset and the
//!    decoupler releases; the next flit flows through the new detector.
//!
//! Accounting rules: the flit that triggers the swap is the first dark
//! flit; exactly `dark_flits` flits are charged unless TLAST ends the
//! stream early (the event is then recorded with `dark_complete = false`);
//! dropped and bypassed flits are counted per swap in [`SwapEvent`] and
//! dropped ones also increment the decoupler's telemetry counter.
//!
//! On top sits the **adaptive reconfiguration controller**
//! ([`spawn_controller`]): it watches each monitored pblock's score stream
//! through [`ScoreStats`] (baseline mean/std vs a sliding recent window — a
//! drift proxy) and, when the drift z-score crosses the configured
//! threshold, stages a swap to the next detector in the TOML-declared pool
//! (`[fabric.dfx]`). While the controller is watching, burst servicing
//! bounds its backlog drain so scores surface at flit-bounded intervals —
//! otherwise a fast producer's whole stream would be admitted as one burst
//! and the controller could never act within the run (see
//! `Pblock::service_burst`).

use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::decoupler::Decoupler;
use super::faults::{FaultPort, Health};
use super::message::{score_chunk, Flit};
use super::pblock::LoadedRm;
use super::reconfig::DfxManager;
use super::snapshot::CheckpointSlot;
use crate::config::{DarkPolicy, DetectorHyper, DfxCfg, RmKind};
use crate::detectors::DetectorKind;
use crate::runtime::{Registry, RuntimeHandle};

/// Convert the modelled DFX download latency into a dark window measured in
/// flits at the declared stream rate. Always at least one flit: a swap is
/// never free while the stream is live.
pub fn model_dark_flits(model_ms: f64, samples_per_sec: f64, chunk: usize) -> u64 {
    let samples = model_ms / 1e3 * samples_per_sec;
    let flits = (samples / chunk.max(1) as f64).ceil();
    (flits as u64).max(1)
}

/// A staged swap: the replacement RM is already built ("bitstream in DDR");
/// executing it only costs the dark window.
pub struct PendingSwap {
    pub pblock: usize,
    /// Pblock-input flit index (0-based, per run) at which the swap fires.
    /// Fires on the first flit with index >= `at_flit`.
    pub at_flit: u64,
    pub rm: LoadedRm,
    pub to: RmKind,
    pub r: usize,
    pub dark_flits: u64,
    pub model_ms: f64,
    pub policy: DarkPolicy,
    /// Skip the post-swap `rm.reset()`: the staged RM carries restored
    /// checkpoint state (fault supervisor's rung-1 reload) that a reset
    /// would wipe. Plain swaps always reset.
    pub preserve_state: bool,
}

/// Record of one executed in-flight swap.
#[derive(Clone, Debug)]
pub struct SwapEvent {
    pub pblock: usize,
    pub from: String,
    pub to: String,
    pub to_kind: RmKind,
    pub r: usize,
    /// Flit index at which the region went dark.
    pub at_flit: u64,
    /// Scheduled dark-window length.
    pub dark_flits: u64,
    /// Flits dropped at the decoupler during the dark window.
    pub dropped: u64,
    /// Zero-score placeholder flits emitted during the dark window.
    pub bypassed: u64,
    /// Table-13 modelled download latency.
    pub model_ms: f64,
    /// Measured RM replace + reset time inside the service thread.
    pub actual_ms: f64,
    /// False when TLAST truncated the dark window.
    pub dark_complete: bool,
}

impl std::fmt::Display for SwapEvent {
    /// Canonical one-line rendering, shared by the CLI and the examples.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RP-{}: {} -> {} @ flit {} — dark {} flits ({} bypassed, {} dropped{}), \
             model {:.1} ms, swap here {:.2} ms",
            self.pblock,
            self.from,
            self.to,
            self.at_flit,
            self.dark_flits,
            self.bypassed,
            self.dropped,
            if self.dark_complete { "" } else { "; truncated by stream end" },
            self.model_ms,
            self.actual_ms
        )
    }
}

/// Per-pblock swap mailbox, shared between the service thread (executes
/// swaps), the fabric (scripted schedules) and the adaptive controller.
pub struct SwapPort {
    pending: Mutex<Vec<PendingSwap>>,
    /// Earliest pending `at_flit` (u64::MAX when none) — one relaxed load
    /// per flit on the hot path.
    next_at: AtomicU64,
    /// Pblock-input flits seen this run (reset by `begin_run`).
    flits_seen: AtomicU64,
    events: Mutex<Vec<SwapEvent>>,
    /// Cumulative copy of the most recent executed swaps, never drained —
    /// [`SwapPort::take_events`] consumes `events` into run/session results,
    /// so the operator plane reads this bounded ring instead.
    history: Mutex<VecDeque<SwapEvent>>,
    /// Swaps executed since construction (monotone across runs/episodes).
    executed: AtomicU64,
}

/// Executed swaps retained for the operator plane's swap history.
const SWAP_HISTORY_CAP: usize = 64;

impl Default for SwapPort {
    fn default() -> Self {
        SwapPort {
            pending: Mutex::new(Vec::new()),
            next_at: AtomicU64::new(u64::MAX),
            flits_seen: AtomicU64::new(0),
            events: Mutex::new(Vec::new()),
            history: Mutex::new(VecDeque::new()),
            executed: AtomicU64::new(0),
        }
    }
}

impl SwapPort {
    /// Arm a staged swap. Pending swaps persist until their flit index is
    /// reached in some run.
    pub fn schedule(&self, swap: PendingSwap) {
        let mut p = self.pending.lock().unwrap();
        p.push(swap);
        p.sort_by_key(|s| s.at_flit);
        self.next_at.store(p[0].at_flit, Ordering::SeqCst);
    }

    /// Cheap hot-path probe: is a swap due at the current flit?
    pub(crate) fn due_now(&self) -> bool {
        self.next_at.load(Ordering::SeqCst) <= self.flits_seen.load(Ordering::SeqCst)
    }

    pub(crate) fn try_take_due(&self) -> Option<PendingSwap> {
        if !self.due_now() {
            return None;
        }
        let mut p = self.pending.lock().unwrap();
        let idx = self.flits_seen.load(Ordering::SeqCst);
        if !matches!(p.first(), Some(s) if s.at_flit <= idx) {
            return None;
        }
        let swap = p.remove(0);
        self.next_at.store(p.first().map(|s| s.at_flit).unwrap_or(u64::MAX), Ordering::SeqCst);
        Some(swap)
    }

    /// Pblock-input flits seen this run (monotone within a run).
    pub fn flits_seen(&self) -> u64 {
        self.flits_seen.load(Ordering::SeqCst)
    }

    pub(crate) fn advance(&self) {
        self.flits_seen.fetch_add(1, Ordering::SeqCst);
    }

    /// Reset the per-run flit counter (scheduled indices are per run).
    pub(crate) fn begin_run(&self) {
        self.flits_seen.store(0, Ordering::SeqCst);
    }

    pub(crate) fn push_event(&self, ev: SwapEvent) {
        let mut h = self.history.lock().unwrap();
        if h.len() == SWAP_HISTORY_CAP {
            h.pop_front();
        }
        h.push_back(ev.clone());
        drop(h);
        self.executed.fetch_add(1, Ordering::SeqCst);
        self.events.lock().unwrap().push(ev);
    }

    /// Drain the events recorded since the last call.
    pub fn take_events(&self) -> Vec<SwapEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    /// Non-draining copy of the most recent executed swaps (newest last,
    /// bounded) — the operator plane's swap history. Unlike
    /// [`SwapPort::take_events`] this never steals events from the episode
    /// bookkeeping that feeds `RunOutput`/`SessionClose`.
    pub fn history(&self) -> Vec<SwapEvent> {
        self.history.lock().unwrap().iter().cloned().collect()
    }

    /// Swaps executed on this partition since construction.
    pub fn executed_count(&self) -> u64 {
        self.executed.load(Ordering::Relaxed)
    }

    pub fn pending_count(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Drop all armed swaps; returns how many were discarded.
    pub fn clear_pending(&self) -> usize {
        let mut p = self.pending.lock().unwrap();
        let n = p.len();
        p.clear();
        self.next_at.store(u64::MAX, Ordering::SeqCst);
        n
    }
}

/// Snapshot of a pblock's score statistics (drift proxy).
#[derive(Clone, Copy, Debug, Default)]
pub struct StatSnapshot {
    pub total: u64,
    pub window: usize,
    pub baseline: usize,
    pub baseline_n: u64,
    pub baseline_mean: f64,
    pub baseline_std: f64,
    pub window_len: usize,
    pub window_mean: f64,
}

impl StatSnapshot {
    /// Baseline established and the recent window full.
    pub fn ready(&self) -> bool {
        self.baseline > 0
            && self.baseline_n >= self.baseline as u64
            && self.window_len >= self.window
    }

    /// |recent mean − baseline mean| in baseline standard deviations.
    pub fn drift_z(&self) -> f64 {
        (self.window_mean - self.baseline_mean).abs() / self.baseline_std.max(1e-6)
    }
}

#[derive(Default)]
struct StatsInner {
    window: usize,
    baseline: usize,
    total: u64,
    base_n: u64,
    base_mean: f64,
    base_m2: f64,
    ring: VecDeque<f64>,
    ring_sum: f64,
}

/// Sliding score statistics published by the pblock service loop, read by
/// the adaptive controller. Disabled (zero-cost fast path: one relaxed
/// atomic load per output flit) until [`ScoreStats::arm`] is called.
#[derive(Default)]
pub struct ScoreStats {
    enabled: AtomicBool,
    inner: Mutex<StatsInner>,
}

impl ScoreStats {
    /// Enable collection with the given window/baseline sizes (in scores).
    pub fn arm(&self, window: usize, baseline: usize) {
        let mut inner = self.inner.lock().unwrap();
        *inner =
            StatsInner { window: window.max(1), baseline: baseline.max(1), ..Default::default() };
        drop(inner);
        self.enabled.store(true, Ordering::SeqCst);
    }

    /// Push the valid scores of one output flit.
    pub fn push(&self, scores: &[f32], n_valid: usize) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let window = inner.window;
        for &s in scores.iter().take(n_valid) {
            let s = s as f64;
            inner.total += 1;
            if inner.base_n < inner.baseline as u64 {
                // Welford update of the baseline mean/variance.
                inner.base_n += 1;
                let delta = s - inner.base_mean;
                inner.base_mean += delta / inner.base_n as f64;
                inner.base_m2 += delta * (s - inner.base_mean);
            }
            inner.ring.push_back(s);
            inner.ring_sum += s;
            if inner.ring.len() > window {
                let old = inner.ring.pop_front().unwrap_or(0.0);
                inner.ring_sum -= old;
            }
        }
    }

    /// True once [`ScoreStats::arm`] has enabled collection (the adaptive
    /// controller is watching this pblock).
    pub fn is_armed(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Stop collection and drop all accumulated state. The session server
    /// disarms a partition's stats after each adaptive session so an idle
    /// partition publishes nothing and burst servicing returns to the
    /// unbounded drain for non-adaptive successors.
    pub fn disarm(&self) {
        self.enabled.store(false, Ordering::SeqCst);
        *self.inner.lock().unwrap() = StatsInner::default();
    }

    /// Forget the baseline and window — called when a swap lands a new
    /// detector (its score scale is unrelated to the old baseline).
    pub fn rebase(&self) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.base_n = 0;
        inner.base_mean = 0.0;
        inner.base_m2 = 0.0;
        inner.ring.clear();
        inner.ring_sum = 0.0;
    }

    pub fn snapshot(&self) -> StatSnapshot {
        let inner = self.inner.lock().unwrap();
        StatSnapshot {
            total: inner.total,
            window: inner.window,
            baseline: inner.baseline,
            baseline_n: inner.base_n,
            baseline_mean: inner.base_mean,
            baseline_std: if inner.base_n > 1 {
                (inner.base_m2 / inner.base_n as f64).sqrt()
            } else {
                0.0
            },
            window_len: inner.ring.len(),
            window_mean: if inner.ring.is_empty() {
                0.0
            } else {
                inner.ring_sum / inner.ring.len() as f64
            },
        }
    }
}

/// Live-tunable adaptive-controller knobs for one partition. Seeded from
/// `[fabric.dfx]` when the fabric or server is built, re-read by
/// [`spawn_controller`] on every poll tick — so the operator plane's
/// `POST /controller` can retune a running stream without restarting the
/// controller thread. Adjustments persist across episode boundaries: the
/// per-episode controller respawn only seeds knobs that were never set.
pub struct DfxTuning {
    /// Drift z-score that triggers a swap (f64 bits).
    threshold: AtomicU64,
    /// Minimum flits between swaps on one partition.
    cooldown_flits: AtomicU64,
    seeded: AtomicBool,
}

impl Default for DfxTuning {
    fn default() -> Self {
        let d = DfxCfg::default();
        DfxTuning {
            threshold: AtomicU64::new(d.threshold.to_bits()),
            cooldown_flits: AtomicU64::new(d.cooldown_flits),
            seeded: AtomicBool::new(false),
        }
    }
}

impl DfxTuning {
    /// Seed both knobs from the configured `[fabric.dfx]` values.
    pub fn seed(&self, cfg: &DfxCfg) {
        self.set_threshold(cfg.threshold);
        self.set_cooldown_flits(cfg.cooldown_flits);
    }

    /// Seed only if no one (construction site or operator) has set the
    /// knobs yet — keeps direct [`spawn_controller`] users working while
    /// never clobbering a live operator adjustment on episode respawn.
    pub fn seed_if_unset(&self, cfg: &DfxCfg) {
        if !self.seeded.load(Ordering::SeqCst) {
            self.seed(cfg);
        }
    }

    pub fn threshold(&self) -> f64 {
        f64::from_bits(self.threshold.load(Ordering::Relaxed))
    }

    pub fn set_threshold(&self, z: f64) {
        self.threshold.store(z.to_bits(), Ordering::SeqCst);
        self.seeded.store(true, Ordering::SeqCst);
    }

    pub fn cooldown_flits(&self) -> u64 {
        self.cooldown_flits.load(Ordering::Relaxed)
    }

    pub fn set_cooldown_flits(&self, flits: u64) {
        self.cooldown_flits.store(flits, Ordering::SeqCst);
        self.seeded.store(true, Ordering::SeqCst);
    }
}

/// Shared control surface of one pblock: swap mailbox, score statistics,
/// live controller tuning, and (armed only under `[fabric.faults]`) the
/// fault-injection port, health/heartbeat surface and checkpoint slot.
#[derive(Default)]
pub struct PblockCtl {
    pub swap: SwapPort,
    pub stats: ScoreStats,
    pub tuning: DfxTuning,
    pub health: Health,
    pub faults: FaultPort,
    pub checkpoint: CheckpointSlot,
    /// Raised by the session server around fault-supervised episodes: when
    /// the supervisor quarantines the region (rung 2), the service loop
    /// *returns* instead of draining-and-dropping the rest of the stream,
    /// so the worker can evict the session to the store for resume on
    /// another partition. `Fabric::run` never raises it — batch-run
    /// quarantine semantics are unchanged.
    pub evict_on_quarantine: AtomicBool,
}

/// Per-flit verdict of the DFX gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Hand the flit to the RM.
    Process,
    /// Isolated (dark window with Drop policy, or externally decoupled):
    /// the flit vanishes at the decoupler.
    Drop,
    /// Dark window with Bypass policy: emit a zero-score placeholder flit.
    Bypass,
}

struct DarkRun {
    remaining: u64,
    policy: DarkPolicy,
    event: SwapEvent,
}

/// The in-flight swap state machine, driven by the pblock service loop once
/// per flit (both execution modes). Owns no RM — the service thread passes
/// its `&mut LoadedRm` in, which is exactly what makes the swap race-free.
pub struct DfxGate<'a> {
    ctl: &'a PblockCtl,
    decoupler: &'a Decoupler,
    dark: Option<DarkRun>,
}

impl<'a> DfxGate<'a> {
    pub fn new(ctl: &'a PblockCtl, decoupler: &'a Decoupler) -> DfxGate<'a> {
        DfxGate { ctl, decoupler, dark: None }
    }

    /// True when the next call to [`DfxGate::admit`] will execute a swap —
    /// burst servicing uses this to flush the backlog segment scored by the
    /// *old* RM before the replacement happens.
    pub fn swap_imminent(&self) -> bool {
        self.dark.is_none() && self.ctl.swap.due_now()
    }

    /// Admit one flit: maybe execute a due swap (quiesce → replace → reset),
    /// then classify the flit against the dark window / decoupler.
    ///
    /// `may_swap = false` defers a due swap to a later flit — burst
    /// servicing passes `seg.is_empty()` so a swap scheduled concurrently
    /// (adaptive controller) between its `swap_imminent` check and this
    /// call can never replace the RM while unflushed flits still belong to
    /// the old one. The per-flit path always passes `true`.
    pub fn admit(&mut self, rm: &mut LoadedRm, last: bool, may_swap: bool) -> Result<Admit> {
        let idx = self.ctl.swap.flits_seen();
        // A due swap executes only while the region's decoupler is enabled
        // (no isolation → no swap, same refusal as `schedule_swap` /
        // `reconfigure`); a swap armed before the decoupler was disabled
        // stays pending until it is re-enabled.
        let due = if may_swap
            && self.dark.is_none()
            && self.ctl.swap.due_now()
            && self.decoupler.is_enabled()
        {
            self.ctl.swap.try_take_due()
        } else {
            None
        };
        if let Some(swap) = due {
            // Quiesce: the region goes dark. The swap runs here, in the
            // service thread, between flits — the RM is quiescent by
            // construction and no other pblock is touched.
            self.decoupler.decouple();
            let from = rm.describe();
            let t0 = Instant::now();
            let preserve = swap.preserve_state;
            let old = std::mem::replace(rm, swap.rm);
            drop(old);
            if !preserve {
                rm.reset()?;
            }
            let actual_ms = t0.elapsed().as_secs_f64() * 1e3;
            self.ctl.stats.rebase();
            let event = SwapEvent {
                pblock: swap.pblock,
                from,
                to: rm.describe(),
                to_kind: swap.to,
                r: swap.r,
                at_flit: idx,
                dark_flits: swap.dark_flits,
                dropped: 0,
                bypassed: 0,
                model_ms: swap.model_ms,
                actual_ms,
                dark_complete: false,
            };
            self.dark =
                Some(DarkRun { remaining: swap.dark_flits.max(1), policy: swap.policy, event });
        }
        self.ctl.swap.advance();
        if self.dark.is_some() {
            let (admit, finished) = {
                let dark = self.dark.as_mut().unwrap();
                dark.remaining -= 1;
                let admit = match dark.policy {
                    DarkPolicy::Drop => {
                        // Count in the decoupler's telemetry like any
                        // isolated-traffic drop, and in the event.
                        self.decoupler.count_drop();
                        dark.event.dropped += 1;
                        Admit::Drop
                    }
                    DarkPolicy::Bypass => {
                        dark.event.bypassed += 1;
                        Admit::Bypass
                    }
                };
                (admit, dark.remaining == 0)
            };
            if finished || last {
                let mut ev = self.dark.take().unwrap().event;
                ev.dark_complete = finished;
                self.decoupler.recouple();
                self.ctl.swap.push_event(ev);
            }
            return Ok(admit);
        }
        if self.decoupler.is_decoupled() {
            return Ok(Admit::Drop);
        }
        Ok(Admit::Process)
    }

    /// Close out a dark window cut short by the stream ending (channel
    /// closed without TLAST) so the event is still recorded and the region
    /// re-enabled for the next run.
    pub fn finish(&mut self) {
        if let Some(dark) = self.dark.take() {
            let mut ev = dark.event;
            ev.dark_complete = false;
            self.decoupler.recouple();
            self.ctl.swap.push_event(ev);
        }
    }
}

/// Zero-score placeholder emitted while a region is dark under
/// [`DarkPolicy::Bypass`] — same seq/mask/n_valid/TLAST framing as the
/// input flit, so downstream joins stay aligned.
pub fn dark_flit(f: &Flit) -> Flit {
    score_chunk(f.seq, vec![0f32; f.rows()], f.mask.clone(), f.n_valid, f.last)
}

impl DfxManager {
    /// Stage a swap: build the replacement RM now (params, artifact
    /// compile/load — the "bitstream into DDR" step) and price the dark
    /// window from the Table-13 model, so executing the swap later only
    /// costs `dark_flits` of stream time. `lanes` is the target partition's
    /// configured lane count: a multi-lane partition stages a whole
    /// replacement lane array, swapped in atomically between two flits.
    #[allow(clippy::too_many_arguments)]
    pub fn stage(
        &self,
        pblock_id: usize,
        to: RmKind,
        r: usize,
        d: usize,
        seed: u64,
        hyper: &DetectorHyper,
        warmup: &[f32],
        fpga: Option<(&RuntimeHandle, &Registry)>,
        quantize: bool,
        at_flit: u64,
        dark_flits: Option<u64>,
        policy: DarkPolicy,
        chunk: usize,
        samples_per_sec: f64,
        lanes: usize,
    ) -> Result<PendingSwap> {
        let to_function = to != RmKind::Empty && to != RmKind::Bypass;
        let model_ms =
            self.model.time_ms_pblock(pblock_id, to_function).unwrap_or(self.model.base_ms);
        let rm = LoadedRm::build(to, r, d, seed, hyper, warmup, fpga, quantize, lanes)?;
        // At least one dark flit: a swap is never free while streaming.
        let dark = dark_flits
            .unwrap_or_else(|| model_dark_flits(model_ms, samples_per_sec, chunk))
            .max(1);
        Ok(PendingSwap {
            pblock: pblock_id,
            at_flit,
            rm,
            to,
            r,
            dark_flits: dark,
            model_ms,
            policy,
            preserve_state: false,
        })
    }
}

/// One pblock monitored by the adaptive controller.
pub struct ControllerTarget {
    pub pblock: usize,
    pub ctl: Arc<PblockCtl>,
    /// Detector currently loaded (tracked locally as swaps are issued).
    pub kind: DetectorKind,
    pub d: usize,
    pub warmup: Vec<f32>,
    pub seed: u64,
    /// The partition's configured lane count — replacement RMs staged by
    /// the controller keep the partition's lane layout.
    pub lanes: usize,
}

/// Everything the controller thread owns.
pub struct ControllerEnv {
    pub dfx: DfxManager,
    pub cfg: DfxCfg,
    pub hyper: DetectorHyper,
    pub chunk: usize,
    pub quantize: bool,
    pub fpga: Option<(RuntimeHandle, Registry)>,
}

/// Spawn the adaptive reconfiguration controller. It polls each target's
/// [`ScoreStats`] and, when the drift z-score crosses the partition's live
/// [`DfxTuning::threshold`] (seeded from `cfg.threshold`; baseline
/// established, window full, cooldown elapsed), stages a swap to
/// the next pool detector with a different algorithm and arms it at the
/// pblock's current flit. Returns the number of swaps issued when `stop`
/// is raised.
pub fn spawn_controller(
    env: ControllerEnv,
    mut targets: Vec<ControllerTarget>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<u64> {
    std::thread::Builder::new()
        .name("dfx-controller".into())
        .spawn(move || {
            // Give up on a target after this many consecutive staging
            // failures (e.g. a pool detector whose artifact is missing) —
            // never rebuild-and-fail at the poll rate forever.
            const MAX_STAGE_FAILURES: u32 = 3;
            let mut issued = 0u64;
            let mut pool_pos = 0usize;
            let mut last_swap: Vec<Option<u64>> = vec![None; targets.len()];
            let mut stage_failures: Vec<u32> = vec![0; targets.len()];
            if env.cfg.pool.is_empty() {
                return issued;
            }
            // Thresholds live on the shared tuning surface so the operator
            // plane can retune them mid-stream; targets whose knobs were
            // never seeded (direct callers, unit tests) get the configured
            // values here.
            for t in &targets {
                t.ctl.tuning.seed_if_unset(&env.cfg);
            }
            while !stop.load(Ordering::SeqCst) {
                for (ti, t) in targets.iter_mut().enumerate() {
                    if stage_failures[ti] >= MAX_STAGE_FAILURES {
                        continue;
                    }
                    if t.ctl.swap.pending_count() > 0 {
                        continue;
                    }
                    let snap = t.ctl.stats.snapshot();
                    if !snap.ready() || snap.drift_z() < t.ctl.tuning.threshold() {
                        continue;
                    }
                    let seen = t.ctl.swap.flits_seen();
                    if let Some(at) = last_swap[ti] {
                        if seen.saturating_sub(at) < t.ctl.tuning.cooldown_flits() {
                            continue;
                        }
                    }
                    // Next pool entry running a different algorithm (any
                    // entry if the pool is homogeneous).
                    let n = env.cfg.pool.len();
                    let mut chosen = None;
                    for k in 0..n {
                        let pos = (pool_pos + k) % n;
                        let e = env.cfg.pool[pos];
                        if e.kind != t.kind || n == 1 {
                            chosen = Some((pos, e));
                            break;
                        }
                    }
                    let Some((pos, entry)) = chosen else { continue };
                    let r = if entry.r == 0 { entry.kind.pblock_r() } else { entry.r };
                    let staged = env.dfx.stage(
                        t.pblock,
                        RmKind::Detector(entry.kind),
                        r,
                        t.d,
                        t.seed,
                        &env.hyper,
                        &t.warmup,
                        env.fpga.as_ref().map(|(h, reg)| (h, reg)),
                        env.quantize,
                        seen,
                        None,
                        env.cfg.policy,
                        env.chunk,
                        env.cfg.samples_per_sec,
                        t.lanes,
                    );
                    match staged {
                        Ok(swap) => {
                            t.ctl.swap.schedule(swap);
                            t.kind = entry.kind;
                            last_swap[ti] = Some(seen);
                            pool_pos = pos + 1;
                            stage_failures[ti] = 0;
                            issued += 1;
                        }
                        Err(e) => {
                            // Back off by the cooldown and count the strike;
                            // the drift condition would otherwise re-fire a
                            // full detector build every poll tick.
                            stage_failures[ti] += 1;
                            last_swap[ti] = Some(seen);
                            eprintln!(
                                "dfx-controller: staging {} for pblock {} failed \
                                 (strike {}/{MAX_STAGE_FAILURES}): {e:#}",
                                entry.kind.as_str(),
                                t.pblock,
                                stage_failures[ti]
                            );
                        }
                    }
                }
                std::thread::sleep(Duration::from_micros(500));
            }
            issued
        })
        .expect("spawn dfx controller")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper() -> DetectorHyper {
        DetectorHyper { window: 8, bins: 4, w: 2, modulus: 16, k: 3 }
    }

    fn staged(at_flit: u64, dark: u64, policy: DarkPolicy) -> PendingSwap {
        let warmup: Vec<f32> = (0..40).map(|i| (i as f32 * 0.3).sin()).collect();
        DfxManager::default()
            .stage(
                1,
                RmKind::Detector(DetectorKind::Loda),
                2,
                2,
                7,
                &hyper(),
                &warmup,
                None,
                false,
                at_flit,
                Some(dark),
                policy,
                8,
                100_000.0,
                1,
            )
            .unwrap()
    }

    fn input_flit(seq: u64, last: bool) -> Flit {
        score_chunk(seq, vec![0.5f32; 8], vec![1.0f32; 4], 4, last)
    }

    #[test]
    fn model_dark_flits_scales_with_rate() {
        // 600 ms at 100k samples/s, chunk 256 → ceil(60000/256) = 235.
        assert_eq!(model_dark_flits(600.0, 100_000.0, 256), 235);
        // Never zero, even for absurdly slow streams.
        assert_eq!(model_dark_flits(600.0, 0.001, 256), 1);
    }

    #[test]
    fn swap_port_orders_and_drains() {
        let port = SwapPort::default();
        port.schedule(staged(5, 1, DarkPolicy::Drop));
        port.schedule(staged(2, 1, DarkPolicy::Drop));
        assert_eq!(port.pending_count(), 2);
        assert!(!port.due_now()); // flits_seen = 0 < 2
        for _ in 0..2 {
            port.advance();
        }
        assert!(port.due_now());
        let s = port.try_take_due().unwrap();
        assert_eq!(s.at_flit, 2);
        assert!(!port.due_now()); // next is at 5
        assert_eq!(port.clear_pending(), 1);
        assert!(!port.due_now());
    }

    #[test]
    fn gate_executes_swap_with_dark_window() {
        let ctl = PblockCtl::default();
        let dec = Decoupler::new();
        ctl.swap.schedule(staged(2, 2, DarkPolicy::Bypass));
        let mut rm = LoadedRm::BypassNative;
        let mut gate = DfxGate::new(&ctl, &dec);
        // Flits 0,1 process; 2,3 dark; 4 processes through the new RM.
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Process);
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Process);
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Bypass);
        assert!(dec.is_decoupled(), "region must be dark mid-window");
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Bypass);
        assert!(!dec.is_decoupled(), "region must re-enable after the window");
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Process);
        assert!(matches!(rm, LoadedRm::DetectorCpu { .. }), "RM was not replaced");
        let evs = ctl.swap.take_events();
        assert_eq!(evs.len(), 1);
        let ev = &evs[0];
        assert_eq!(ev.at_flit, 2);
        assert_eq!(ev.bypassed, 2);
        assert_eq!(ev.dropped, 0);
        assert!(ev.dark_complete);
        assert_eq!(ev.from, "bypass(native)");
        assert!(ev.to.contains("loda"));
    }

    #[test]
    fn gate_drop_policy_counts_at_decoupler() {
        let ctl = PblockCtl::default();
        let dec = Decoupler::new();
        ctl.swap.schedule(staged(0, 3, DarkPolicy::Drop));
        let mut rm = LoadedRm::BypassNative;
        let mut gate = DfxGate::new(&ctl, &dec);
        for _ in 0..3 {
            assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Drop);
        }
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Process);
        let evs = ctl.swap.take_events();
        assert_eq!(evs[0].dropped, 3);
        assert_eq!(dec.dropped(), 3);
    }

    #[test]
    fn gate_truncates_dark_window_at_tlast() {
        let ctl = PblockCtl::default();
        let dec = Decoupler::new();
        ctl.swap.schedule(staged(1, 10, DarkPolicy::Bypass));
        let mut rm = LoadedRm::BypassNative;
        let mut gate = DfxGate::new(&ctl, &dec);
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Process);
        assert_eq!(gate.admit(&mut rm, true, true).unwrap(), Admit::Bypass);
        let evs = ctl.swap.take_events();
        assert_eq!(evs.len(), 1);
        assert!(!evs[0].dark_complete, "TLAST must truncate the window");
        assert_eq!(evs[0].bypassed, 1);
        assert!(!dec.is_decoupled(), "truncated window must still re-enable");
    }

    #[test]
    fn gate_defers_swap_while_decoupler_disabled() {
        let ctl = PblockCtl::default();
        let dec = Decoupler::new();
        ctl.swap.schedule(staged(0, 1, DarkPolicy::Bypass));
        dec.set_enabled(false);
        let mut rm = LoadedRm::BypassNative;
        let mut gate = DfxGate::new(&ctl, &dec);
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Process);
        assert!(matches!(rm, LoadedRm::BypassNative), "no isolation -> no swap");
        assert_eq!(ctl.swap.pending_count(), 1, "swap stays armed");
        // Re-enabling the decoupler lets the pending swap fire.
        dec.set_enabled(true);
        assert_eq!(gate.admit(&mut rm, false, true).unwrap(), Admit::Bypass);
        assert!(matches!(rm, LoadedRm::DetectorCpu { .. }));
    }

    #[test]
    fn gate_finish_records_interrupted_swap() {
        let ctl = PblockCtl::default();
        let dec = Decoupler::new();
        ctl.swap.schedule(staged(0, 5, DarkPolicy::Drop));
        let mut rm = LoadedRm::BypassNative;
        let mut gate = DfxGate::new(&ctl, &dec);
        let _ = gate.admit(&mut rm, false, true).unwrap();
        gate.finish();
        let evs = ctl.swap.take_events();
        assert_eq!(evs.len(), 1);
        assert!(!evs[0].dark_complete);
        assert!(!dec.is_decoupled());
    }

    #[test]
    fn dark_flit_preserves_framing() {
        let f = input_flit(3, true);
        let d = dark_flit(&f);
        assert_eq!(d.seq, 3);
        assert_eq!(d.n_valid, 4);
        assert!(d.last);
        assert_eq!(d.data.len(), d.mask.len());
        assert!(d.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn score_stats_detect_level_shift() {
        let stats = ScoreStats::default();
        stats.arm(8, 16);
        let flat = [1.0f32; 4];
        for _ in 0..6 {
            stats.push(&flat, 4); // 24 scores ≈ N(1, 0): std clamps at eps
        }
        let snap = stats.snapshot();
        assert!(snap.ready());
        assert!(snap.drift_z() < 1.0, "no drift yet: z={}", snap.drift_z());
        let shifted = [5.0f32; 4];
        for _ in 0..4 {
            stats.push(&shifted, 4);
        }
        let snap = stats.snapshot();
        assert!(snap.drift_z() > 100.0, "level shift must trip: z={}", snap.drift_z());
        stats.rebase();
        let snap = stats.snapshot();
        assert!(!snap.ready(), "rebase must forget the baseline");
    }

    #[test]
    fn controller_schedules_swap_on_drift() {
        use crate::config::PoolEntry;
        let ctl = Arc::new(PblockCtl::default());
        ctl.stats.arm(8, 16);
        // Flat baseline, then a hard level shift — drift z explodes.
        ctl.stats.push(&[1.0f32; 16], 16);
        ctl.stats.push(&[9.0f32; 8], 8);
        for _ in 0..40 {
            ctl.swap.advance(); // pretend 40 flits streamed
        }
        let env = ControllerEnv {
            dfx: DfxManager::default(),
            cfg: DfxCfg {
                adaptive: true,
                threshold: 3.0,
                cooldown_flits: 0,
                pool: vec![PoolEntry { kind: DetectorKind::RsHash, r: 2 }],
                ..Default::default()
            },
            hyper: hyper(),
            chunk: 8,
            quantize: false,
            fpga: None,
        };
        let warmup: Vec<f32> = (0..40).map(|i| (i as f32 * 0.3).sin()).collect();
        let targets = vec![ControllerTarget {
            pblock: 1,
            ctl: Arc::clone(&ctl),
            kind: DetectorKind::Loda,
            d: 2,
            warmup,
            seed: 3,
            lanes: 1,
        }];
        let stop = Arc::new(AtomicBool::new(false));
        let handle = spawn_controller(env, targets, Arc::clone(&stop));
        let t0 = Instant::now();
        while ctl.swap.pending_count() == 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        stop.store(true, Ordering::SeqCst);
        let issued = handle.join().unwrap();
        assert_eq!(issued, 1, "one swap while it stays pending");
        let swap = ctl.swap.try_take_due().expect("armed swap must be due at flit 40");
        assert_eq!(swap.pblock, 1);
        assert_eq!(swap.to, RmKind::Detector(DetectorKind::RsHash));
        assert_eq!(swap.at_flit, 40);
        assert!(swap.dark_flits >= 1);
        assert!(matches!(swap.rm, LoadedRm::DetectorCpu { .. }), "RM staged up front");
    }

    #[test]
    fn score_stats_disabled_is_noop() {
        let stats = ScoreStats::default();
        stats.push(&[1.0, 2.0], 2);
        assert_eq!(stats.snapshot().total, 0);
    }
}
