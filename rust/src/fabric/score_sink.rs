//! Durable score sink: a crash-safe, append-only recorder for session
//! score streams.
//!
//! Long-running serving scenarios need an audit trail that survives a
//! process crash. The sink appends one frame per output flit:
//!
//! ```text
//! [u32 len LE] [payload: u64 session | u64 seq | u32 n | f32×n scores] [u32 crc LE]
//! ```
//!
//! `len` is the payload byte length and `crc` is the IEEE CRC-32 of the
//! payload, so every frame is independently verifiable. Appends are
//! `fsync`ed every `fsync_every` records (a durability/throughput knob) —
//! a crash can therefore leave at most a *tail* of unsynced frames, the
//! last of which may be torn. [`recover`] replays the file from the start,
//! keeps every frame whose length and CRC check out, and truncates the
//! file at the first torn or corrupt frame so the sink can be re-opened
//! for appending with a clean tail. Frames are never rewritten in place:
//! the valid prefix of the file is immutable history.

use anyhow::{bail, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// Payload bytes before the score array: session id + seq + score count.
const PAYLOAD_HEADER: usize = 8 + 8 + 4;
/// Refuse absurd frame lengths when scanning (a torn length word would
/// otherwise make recovery try to allocate gigabytes).
const MAX_FRAME_PAYLOAD: usize = 16 << 20;

/// IEEE CRC-32 lookup table (polynomial 0xEDB88320), built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// IEEE CRC-32 over `bytes` (the variant used by zip/png).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Append-only recorder for score flits. One sink is shared by all
/// partitions of a [`super::server::FabricServer`]; callers serialize
/// through a mutex so frames from concurrent sessions interleave whole,
/// never torn (within one process — torn tails only come from crashes).
pub struct ScoreSink {
    file: File,
    path: PathBuf,
    fsync_every: usize,
    since_sync: usize,
    records: u64,
}

impl ScoreSink {
    /// Open `path` for appending (created if missing). `fsync_every`
    /// bounds the number of records that can be lost to a crash; 1 syncs
    /// after every record.
    pub fn open(path: &Path, fsync_every: usize) -> Result<ScoreSink> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening score sink {}", path.display()))?;
        Ok(ScoreSink {
            file,
            path: path.to_path_buf(),
            fsync_every: fsync_every.max(1),
            since_sync: 0,
            records: 0,
        })
    }

    /// Append one frame; syncs to disk on the configured cadence.
    pub fn append(&mut self, session: u64, seq: u64, scores: &[f32]) -> Result<()> {
        let mut payload = Vec::with_capacity(PAYLOAD_HEADER + scores.len() * 4);
        payload.extend_from_slice(&session.to_le_bytes());
        payload.extend_from_slice(&seq.to_le_bytes());
        payload.extend_from_slice(&(scores.len() as u32).to_le_bytes());
        for &s in scores {
            payload.extend_from_slice(&s.to_le_bytes());
        }
        let mut frame = Vec::with_capacity(payload.len() + 8);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to score sink {}", self.path.display()))?;
        self.records += 1;
        self.since_sync += 1;
        if self.since_sync >= self.fsync_every {
            self.sync()?;
        }
        Ok(())
    }

    /// Force an fsync now (also called on the cadence and on drop).
    pub fn sync(&mut self) -> Result<()> {
        if self.since_sync > 0 {
            self.file
                .sync_data()
                .with_context(|| format!("fsync score sink {}", self.path.display()))?;
            self.since_sync = 0;
        }
        Ok(())
    }

    /// Records appended through this handle.
    pub fn records_written(&self) -> u64 {
        self.records
    }
}

impl Drop for ScoreSink {
    fn drop(&mut self) {
        let _ = self.sync();
    }
}

/// One recovered frame.
#[derive(Clone, Debug, PartialEq)]
pub struct SinkRecord {
    pub session: u64,
    pub seq: u64,
    pub scores: Vec<f32>,
}

/// Read exactly `buf.len()` bytes from `r`. `Ok(true)` when the buffer was
/// filled; `Ok(false)` when EOF arrived first — at a frame boundary that is
/// a clean end, mid-frame it is a torn tail, and the caller treats both as
/// "stop scanning here". I/O errors propagate.
fn fill(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..])?;
        if n == 0 {
            return Ok(false);
        }
        got += n;
    }
    Ok(true)
}

/// Scan a sink file: returns every frame that parses and CRC-checks, plus
/// the byte offset at which scanning stopped (== file length for a clean
/// file; the start of the first torn/corrupt frame otherwise). Never
/// panics on arbitrary bytes.
///
/// The scan streams: frames are read one at a time through a fixed-size
/// buffered reader into a reusable frame buffer bounded by
/// [`MAX_FRAME_PAYLOAD`], so recovering a multi-gigabyte sink holds one
/// frame in memory at a time instead of slurping the whole file.
pub fn scan(path: &Path) -> Result<(Vec<SinkRecord>, u64)> {
    let file =
        File::open(path).with_context(|| format!("reading score sink {}", path.display()))?;
    let mut reader = std::io::BufReader::with_capacity(64 << 10, file);
    let mut records = Vec::new();
    let mut pos = 0u64;
    let mut frame = Vec::new(); // payload + trailing CRC, reused across frames
    loop {
        let mut len_word = [0u8; 4];
        let filled = fill(&mut reader, &mut len_word)
            .with_context(|| format!("reading score sink {}", path.display()))?;
        if !filled {
            break; // clean EOF at a frame boundary, or a torn length word
        }
        let len = u32::from_le_bytes(len_word) as usize;
        if len < PAYLOAD_HEADER || len > MAX_FRAME_PAYLOAD {
            break; // torn or garbage length word
        }
        frame.clear();
        frame.resize(len + 4, 0);
        let filled = fill(&mut reader, &mut frame)
            .with_context(|| format!("reading score sink {}", path.display()))?;
        if !filled {
            break; // torn tail: frame runs past EOF
        }
        let payload = &frame[..len];
        let stored = u32::from_le_bytes(frame[len..].try_into().unwrap());
        if crc32(payload) != stored {
            break; // corrupt frame
        }
        let session = u64::from_le_bytes(payload[0..8].try_into().unwrap());
        let seq = u64::from_le_bytes(payload[8..16].try_into().unwrap());
        let n = u32::from_le_bytes(payload[16..20].try_into().unwrap()) as usize;
        if len != PAYLOAD_HEADER + n * 4 {
            break; // declared score count disagrees with frame length
        }
        let scores = payload[20..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        records.push(SinkRecord { session, seq, scores });
        pos += (4 + len + 4) as u64;
    }
    Ok((records, pos))
}

/// Crash recovery: scan the file and truncate it at the end of its last
/// valid frame, discarding any torn/corrupt tail, so the sink can be
/// re-opened for appending. Returns the surviving records.
pub fn recover(path: &Path) -> Result<Vec<SinkRecord>> {
    let (records, valid) = scan(path)?;
    let file = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("opening score sink {} for recovery", path.display()))?;
    let len = file.metadata()?.len();
    if valid > len {
        bail!("scan offset {valid} beyond file length {len} — concurrent writer?");
    }
    if valid < len {
        file.set_len(valid)
            .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        file.sync_data()?;
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fsead-sink-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn appended_frames_scan_back_verbatim() {
        let path = tmp("roundtrip.fsk");
        let _ = fs::remove_file(&path);
        let mut sink = ScoreSink::open(&path, 2).unwrap();
        sink.append(7, 0, &[1.0, -2.5, 3.25]).unwrap();
        sink.append(7, 1, &[0.0; 4]).unwrap();
        sink.append(9, 0, &[]).unwrap();
        drop(sink);
        let (records, _) = scan(&path).unwrap();
        assert_eq!(
            records,
            vec![
                SinkRecord { session: 7, seq: 0, scores: vec![1.0, -2.5, 3.25] },
                SinkRecord { session: 7, seq: 1, scores: vec![0.0; 4] },
                SinkRecord { session: 9, seq: 0, scores: vec![] },
            ]
        );
    }

    #[test]
    fn torn_tail_is_truncated_and_appending_resumes() {
        let path = tmp("torn.fsk");
        let _ = fs::remove_file(&path);
        let mut sink = ScoreSink::open(&path, 1).unwrap();
        sink.append(1, 0, &[4.0, 5.0]).unwrap();
        sink.append(1, 1, &[6.0]).unwrap();
        drop(sink);
        let clean_len = fs::metadata(&path).unwrap().len();
        // Simulated crash mid-append: a frame header plus half a payload.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[28, 0, 0, 0, 0xAB, 0xCD]).unwrap();
        drop(f);
        let records = recover(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(fs::metadata(&path).unwrap().len(), clean_len, "torn tail must be cut");
        // The recovered file accepts appends again.
        let mut sink = ScoreSink::open(&path, 1).unwrap();
        sink.append(1, 2, &[7.0]).unwrap();
        drop(sink);
        let (records, end) = scan(&path).unwrap();
        assert_eq!(records.len(), 3);
        assert_eq!(records[2], SinkRecord { session: 1, seq: 2, scores: vec![7.0] });
        assert_eq!(end, fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn corrupt_crc_stops_the_scan_at_the_bad_frame() {
        let path = tmp("crc.fsk");
        let _ = fs::remove_file(&path);
        let mut sink = ScoreSink::open(&path, 1).unwrap();
        sink.append(2, 0, &[1.0]).unwrap();
        let first_len = fs::metadata(&path).unwrap().len();
        sink.append(2, 1, &[2.0]).unwrap();
        drop(sink);
        // Flip one payload byte of the second frame.
        let mut bytes = fs::read(&path).unwrap();
        let idx = first_len as usize + 6;
        bytes[idx] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        let records = recover(&path).unwrap();
        assert_eq!(records, vec![SinkRecord { session: 2, seq: 0, scores: vec![1.0] }]);
        assert_eq!(fs::metadata(&path).unwrap().len(), first_len);
    }

    #[test]
    fn every_truncation_point_recovers_a_frame_prefix() {
        let path = tmp("sweep.fsk");
        let _ = fs::remove_file(&path);
        let mut sink = ScoreSink::open(&path, 8).unwrap();
        for i in 0..4u64 {
            sink.append(3, i, &[i as f32, -(i as f32)]).unwrap();
        }
        drop(sink);
        let full = fs::read(&path).unwrap();
        let frame = full.len() / 4;
        for cut in 0..full.len() {
            fs::write(&path, &full[..cut]).unwrap();
            let (records, end) = scan(&path).unwrap();
            assert_eq!(records.len(), cut / frame, "cut at {cut}");
            assert_eq!(end as usize, (cut / frame) * frame, "cut at {cut}");
        }
    }
}
