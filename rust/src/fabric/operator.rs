//! Operator plane: live `/metrics` + run-control API over a running
//! [`FabricServer`].
//!
//! `fsead serve --operator <addr>` (or `[fabric.operator]` in the config)
//! starts a small HTTP/1.1 listener — hand-rolled over `std::net`, like
//! every other dependency-free subsystem in this crate — that exposes the
//! server's unified telemetry surface and the run-control verbs:
//!
//! | Endpoint           | Method | Body (JSON)                                        | Returns |
//! |--------------------|--------|----------------------------------------------------|---------|
//! | `/metrics`         | GET    | —                                                  | Prometheus text exposition of the [`FabricSnapshot`] |
//! | `/state`           | GET    | —                                                  | The full [`FabricSnapshot`] as JSON |
//! | `/swap`            | POST   | `{pblock, at_flit, rm, r, dark_flits?}`            | `{model_ms, dark_flits}` — stages an in-flight RM swap through [`FabricServer::schedule_swap`] |
//! | `/drain`           | POST   | `{pblock}`                                         | `{draining: [ids]}` — suspends every session on the partition via [`FabricServer::drain`] |
//! | `/controller`      | POST   | `{pblock?, threshold?, cooldown_flits?}`           | `{ok: true}` — adjusts the adaptive controller live via [`FabricServer::tune_controller`] |
//!
//! # Telemetry surface
//!
//! Everything both exporters serialize comes from one typed view,
//! [`FabricSnapshot`], assembled by [`FabricServer::snapshot`]: a
//! server-wide section ([`ServerTelemetry`]), one row per partition
//! ([`PartitionTelemetry`]) and one row per live or parked session
//! ([`SessionTelemetry`]). [`super::topology::RunOutput::snapshot`] bridges
//! the one-shot batch pass onto the same view, so a `Fabric::run` result
//! renders with the identical exporters.
//!
//! Snapshot assembly never blocks a partition's service loop: admission
//! state is read under one brief lock that workers only take at episode
//! boundaries, and every per-partition counter is a lock-free atomic or a
//! short mutex (swap history). With the plane disabled the server is
//! bit-transparent; with it enabled, scores are unchanged — the plane only
//! ever *reads* the data path, and the control verbs go through the same
//! public [`FabricServer`] methods a host program would call.
//!
//! # Metric naming
//!
//! Metrics follow `fsead_<subsystem>_<name>{partition="<id>"}`:
//! subsystem `server` for server-wide gauges/counters (no labels), and
//! `partition`, `swap`, `controller`, `drift`, `decoupler`, `faults`,
//! `health` for per-partition families labelled with the pblock id.
//! Counters end in `_total`; durations are `_ms`; flit cadences are
//! `_flits` — the same unit-suffix convention as the config surface.
//!
//! # Security
//!
//! The listener binds a plain socket (no TLS) and is meant for loopback /
//! trusted-network scrapes. An optional bearer token (`[fabric.operator]
//! auth_token`) gates every endpoint; with it set, requests must carry
//! `Authorization: Bearer <token>`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::hotswap::SwapEvent;
use super::server::FabricServer;
use crate::config::RmKind;

// ---------------------------------------------------------------------------
// The unified telemetry view
// ---------------------------------------------------------------------------

/// One consistent view of a running fabric — the single source both the
/// Prometheus text exporter and the JSON API serialize from. Built by
/// [`FabricServer::snapshot`] (live server) or
/// [`super::topology::RunOutput::snapshot`] (one-shot batch pass).
#[derive(Clone, Debug, Default)]
pub struct FabricSnapshot {
    pub server: ServerTelemetry,
    /// Per-partition rows, in pblock-id order.
    pub partitions: Vec<PartitionTelemetry>,
    /// Per-session rows (live and parked), in session-id order.
    pub sessions: Vec<SessionTelemetry>,
}

/// Server-wide telemetry section.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerTelemetry {
    /// Sessions fully served over the server's lifetime (counter).
    pub sessions_served: u64,
    /// Live sessions (doored, not parked).
    pub sessions_active: usize,
    /// Sessions parked in the session store.
    pub sessions_parked: usize,
    /// Clients queued in the admission wait loop.
    pub admission_waiters: usize,
    /// Finished-session outcomes not yet collected by their client.
    pub retained_results: usize,
    pub shutting_down: bool,
    /// True when partitions run the multiplexing worker.
    pub mux: bool,
}

/// One partition's telemetry row.
#[derive(Clone, Debug)]
pub struct PartitionTelemetry {
    pub id: usize,
    /// RM kind name (`loda`, `rshash`, `xstream`, `bypass`, `empty`).
    pub rm: &'static str,
    pub r: usize,
    pub lanes: usize,
    /// Session slots this partition offers (`sessions_per_partition`).
    pub capacity: usize,
    /// Sessions currently charged against those slots.
    pub admitted: usize,
    /// Pblock-input flits seen this episode (resets per episode, like the
    /// swap gate's flit cursor it mirrors).
    pub flits_seen: u64,
    /// Swaps staged but not yet executed.
    pub swaps_pending: usize,
    /// Swaps executed over the partition's lifetime (counter).
    pub swaps_executed: u64,
    /// Most recent executed swaps (bounded ring, newest last).
    pub swap_history: Vec<SwapEvent>,
    /// Live adaptive-controller drift threshold (z-score).
    pub controller_threshold: f64,
    /// Live adaptive-controller cooldown, in flits.
    pub controller_cooldown_flits: u64,
    /// Drift statistics armed (an adaptive episode is running).
    pub drift_armed: bool,
    /// Baseline established and the recent window full.
    pub drift_ready: bool,
    /// |recent mean − baseline mean| in baseline standard deviations
    /// (0 until `drift_ready`).
    pub drift_z: f64,
    pub decoupler_enabled: bool,
    /// DECOUPLE currently asserted (dark window in progress).
    pub isolated: bool,
    /// Latched by the fault ladder's last rung.
    pub quarantined: bool,
    /// Flits dropped at the decoupler while isolated (counter).
    pub dropped_flits: u64,
    /// Fault events recorded over the partition's lifetime (counter).
    pub fault_events: u64,
    /// Rung-1 RM reloads (counter).
    pub fault_reloads: u64,
    /// Rung-2 quarantines (counter).
    pub fault_quarantines: u64,
    /// Service-loop heartbeat (stall detection cursor).
    pub health_beat: u64,
}

/// One session's telemetry row.
#[derive(Clone, Copy, Debug)]
pub struct SessionTelemetry {
    pub id: u64,
    /// `active`, `parked-idle`, `parked-suspend` or `parked-quarantine`.
    pub state: &'static str,
    /// Partition the session is placed on (`None` while parked).
    pub partition: Option<usize>,
    /// Flits queued behind the session's inbox.
    pub queued_flits: usize,
    /// Input flits processed before a park (0 for live sessions — their
    /// cursor lives in the partition row).
    pub flits: u64,
    /// Valid samples scored before a park.
    pub samples: u64,
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

/// Append one metric family: `# HELP` / `# TYPE` then each sample line.
fn family(out: &mut String, name: &str, help: &str, typ: &str, samples: &[(String, String)]) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push('\n');
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(typ);
    out.push('\n');
    for (labels, value) in samples {
        out.push_str(name);
        out.push_str(labels);
        out.push(' ');
        out.push_str(value);
        out.push('\n');
    }
}

fn num_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".into()
    }
}

fn flag(v: bool) -> String {
    if v { "1".into() } else { "0".into() }
}

impl FabricSnapshot {
    /// Render the snapshot in the Prometheus text exposition format
    /// (`text/plain; version=0.0.4`). Metric names follow
    /// `fsead_<subsystem>_<name>{partition="<id>"}` — see the module docs.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let s = &self.server;
        let one = |v: String| vec![(String::new(), v)];
        family(
            &mut out,
            "fsead_server_sessions_served_total",
            "Sessions fully served over the server's lifetime.",
            "counter",
            &one(s.sessions_served.to_string()),
        );
        family(
            &mut out,
            "fsead_server_sessions_active",
            "Live sessions (admitted, not parked).",
            "gauge",
            &one(s.sessions_active.to_string()),
        );
        family(
            &mut out,
            "fsead_server_sessions_parked",
            "Sessions parked in the session store.",
            "gauge",
            &one(s.sessions_parked.to_string()),
        );
        family(
            &mut out,
            "fsead_server_admission_waiters",
            "Clients queued in the admission wait loop.",
            "gauge",
            &one(s.admission_waiters.to_string()),
        );
        family(
            &mut out,
            "fsead_server_retained_results",
            "Finished-session outcomes not yet collected by their client.",
            "gauge",
            &one(s.retained_results.to_string()),
        );
        family(
            &mut out,
            "fsead_server_shutting_down",
            "1 while the server is shutting down.",
            "gauge",
            &one(flag(s.shutting_down)),
        );
        family(
            &mut out,
            "fsead_server_multiplexing",
            "1 when partitions run the multiplexing worker.",
            "gauge",
            &one(flag(s.mux)),
        );
        // Per-partition families, labelled with the pblock id.
        let rows = |f: &dyn Fn(&PartitionTelemetry) -> String| -> Vec<(String, String)> {
            self.partitions
                .iter()
                .map(|p| (format!("{{partition=\"{}\"}}", p.id), f(p)))
                .collect()
        };
        family(
            &mut out,
            "fsead_partition_sessions_admitted",
            "Sessions charged against the partition's slots.",
            "gauge",
            &rows(&|p| p.admitted.to_string()),
        );
        family(
            &mut out,
            "fsead_partition_session_capacity",
            "Session slots the partition offers.",
            "gauge",
            &rows(&|p| p.capacity.to_string()),
        );
        family(
            &mut out,
            "fsead_partition_flits_seen",
            "Pblock-input flits seen this episode.",
            "gauge",
            &rows(&|p| p.flits_seen.to_string()),
        );
        family(
            &mut out,
            "fsead_swap_pending",
            "RM swaps staged but not yet executed.",
            "gauge",
            &rows(&|p| p.swaps_pending.to_string()),
        );
        family(
            &mut out,
            "fsead_swap_executed_total",
            "RM swaps executed over the partition's lifetime.",
            "counter",
            &rows(&|p| p.swaps_executed.to_string()),
        );
        family(
            &mut out,
            "fsead_controller_threshold",
            "Live adaptive-controller drift threshold (z-score).",
            "gauge",
            &rows(&|p| num_f(p.controller_threshold)),
        );
        family(
            &mut out,
            "fsead_controller_cooldown_flits",
            "Live adaptive-controller cooldown between swaps, in flits.",
            "gauge",
            &rows(&|p| p.controller_cooldown_flits.to_string()),
        );
        family(
            &mut out,
            "fsead_drift_armed",
            "1 while drift statistics are armed (adaptive episode running).",
            "gauge",
            &rows(&|p| flag(p.drift_armed)),
        );
        family(
            &mut out,
            "fsead_drift_ready",
            "1 once the drift baseline is established and the window full.",
            "gauge",
            &rows(&|p| flag(p.drift_ready)),
        );
        family(
            &mut out,
            "fsead_drift_z",
            "Score drift in baseline standard deviations.",
            "gauge",
            &rows(&|p| num_f(p.drift_z)),
        );
        family(
            &mut out,
            "fsead_decoupler_enabled",
            "1 when the partition's shell has decoupling IP enabled.",
            "gauge",
            &rows(&|p| flag(p.decoupler_enabled)),
        );
        family(
            &mut out,
            "fsead_decoupler_isolated",
            "1 while DECOUPLE is asserted (dark window in progress).",
            "gauge",
            &rows(&|p| flag(p.isolated)),
        );
        family(
            &mut out,
            "fsead_decoupler_quarantined",
            "1 while the fault ladder holds the partition quarantined.",
            "gauge",
            &rows(&|p| flag(p.quarantined)),
        );
        family(
            &mut out,
            "fsead_decoupler_dropped_flits_total",
            "Flits dropped at the decoupler while isolated.",
            "counter",
            &rows(&|p| p.dropped_flits.to_string()),
        );
        family(
            &mut out,
            "fsead_faults_events_total",
            "Fault events recorded over the partition's lifetime.",
            "counter",
            &rows(&|p| p.fault_events.to_string()),
        );
        family(
            &mut out,
            "fsead_faults_reloads_total",
            "Rung-1 RM reloads performed by the fault supervisor.",
            "counter",
            &rows(&|p| p.fault_reloads.to_string()),
        );
        family(
            &mut out,
            "fsead_faults_quarantines_total",
            "Rung-2 quarantines latched by the fault supervisor.",
            "counter",
            &rows(&|p| p.fault_quarantines.to_string()),
        );
        family(
            &mut out,
            "fsead_health_beat",
            "Service-loop heartbeat (stall-detection cursor).",
            "gauge",
            &rows(&|p| p.health_beat.to_string()),
        );
        out
    }

    /// Render the snapshot as JSON (the `/state` body).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"server\":");
        let s = &self.server;
        out.push_str(&format!(
            "{{\"sessions_served\":{},\"sessions_active\":{},\"sessions_parked\":{},\
             \"admission_waiters\":{},\"retained_results\":{},\"shutting_down\":{},\
             \"mux\":{}}}",
            s.sessions_served,
            s.sessions_active,
            s.sessions_parked,
            s.admission_waiters,
            s.retained_results,
            s.shutting_down,
            s.mux
        ));
        out.push_str(",\"partitions\":[");
        for (i, p) in self.partitions.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"id\":{},\"rm\":\"{}\",\"r\":{},\"lanes\":{},\"capacity\":{},\
                 \"admitted\":{},\"flits_seen\":{},\"swaps_pending\":{},\
                 \"swaps_executed\":{},\"controller_threshold\":{},\
                 \"controller_cooldown_flits\":{},\"drift_armed\":{},\"drift_ready\":{},\
                 \"drift_z\":{},\"decoupler_enabled\":{},\"isolated\":{},\
                 \"quarantined\":{},\"dropped_flits\":{},\"fault_events\":{},\
                 \"fault_reloads\":{},\"fault_quarantines\":{},\"health_beat\":{},\
                 \"swap_history\":[",
                p.id,
                p.rm,
                p.r,
                p.lanes,
                p.capacity,
                p.admitted,
                p.flits_seen,
                p.swaps_pending,
                p.swaps_executed,
                num_f(p.controller_threshold),
                p.controller_cooldown_flits,
                p.drift_armed,
                p.drift_ready,
                num_f(p.drift_z),
                p.decoupler_enabled,
                p.isolated,
                p.quarantined,
                p.dropped_flits,
                p.fault_events,
                p.fault_reloads,
                p.fault_quarantines,
                p.health_beat,
            ));
            for (j, ev) in p.swap_history.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"pblock\":{},\"from\":{},\"to\":{},\"at_flit\":{},\
                     \"dark_flits\":{},\"dropped\":{},\"bypassed\":{},\"model_ms\":{},\
                     \"actual_ms\":{},\"dark_complete\":{}}}",
                    ev.pblock,
                    json_string(&ev.from),
                    json_string(&ev.to),
                    ev.at_flit,
                    ev.dark_flits,
                    ev.dropped,
                    ev.bypassed,
                    num_f(ev.model_ms),
                    num_f(ev.actual_ms),
                    ev.dark_complete,
                ));
            }
            out.push_str("]}");
        }
        out.push_str("],\"sessions\":[");
        for (i, s) in self.sessions.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let partition = match s.partition {
                Some(p) => p.to_string(),
                None => "null".into(),
            };
            out.push_str(&format!(
                "{{\"id\":{},\"state\":\"{}\",\"partition\":{},\"queued_flits\":{},\
                 \"flits\":{},\"samples\":{}}}",
                s.id, s.state, partition, s.queued_flits, s.flits, s.samples
            ));
        }
        out.push_str("]}");
        out
    }
}

/// JSON-escape and quote a string.
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

// ---------------------------------------------------------------------------
// Operator errors
// ---------------------------------------------------------------------------

/// Typed operator-plane failures, each with an HTTP status mapping —
/// the [`super::server::AdmitError`] pattern applied to the control plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OperatorError {
    /// Malformed request (bad JSON, missing field, bad value).
    BadRequest(String),
    /// Bearer-token auth configured and the request failed it.
    Unauthorized,
    /// Unknown path or partition.
    NotFound(String),
    /// Known path, wrong method.
    MethodNotAllowed,
    /// The fabric declined the action (e.g. swap on a mux partition).
    Refused(String),
    /// Request body over the size cap.
    PayloadTooLarge,
    /// Concurrent-connection cap reached; the request was shed before a
    /// handler thread was spawned.
    Overloaded,
}

impl OperatorError {
    pub fn status(&self) -> (u16, &'static str) {
        match self {
            OperatorError::BadRequest(_) => (400, "Bad Request"),
            OperatorError::Unauthorized => (401, "Unauthorized"),
            OperatorError::NotFound(_) => (404, "Not Found"),
            OperatorError::MethodNotAllowed => (405, "Method Not Allowed"),
            OperatorError::Refused(_) => (409, "Conflict"),
            OperatorError::PayloadTooLarge => (413, "Payload Too Large"),
            OperatorError::Overloaded => (503, "Service Unavailable"),
        }
    }
}

impl std::fmt::Display for OperatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OperatorError::BadRequest(m) => write!(f, "bad request: {m}"),
            OperatorError::Unauthorized => write!(f, "unauthorized"),
            OperatorError::NotFound(m) => write!(f, "not found: {m}"),
            OperatorError::MethodNotAllowed => write!(f, "method not allowed"),
            OperatorError::Refused(m) => write!(f, "refused: {m}"),
            OperatorError::PayloadTooLarge => write!(f, "payload too large"),
            OperatorError::Overloaded => {
                write!(f, "too many concurrent operator connections — retry")
            }
        }
    }
}

impl std::error::Error for OperatorError {}

// ---------------------------------------------------------------------------
// Minimal JSON body parser
// ---------------------------------------------------------------------------

/// A flat JSON value — all the operator verbs take flat objects.
#[derive(Clone, Debug, PartialEq)]
enum Json {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
}

impl Json {
    fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JsonParser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.b.get(self.i).copied()
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("truncated \\u escape")?;
                            self.i += 4;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape '\\{}'", e as char)),
                    }
                }
                c => {
                    // Re-assemble UTF-8 sequences byte-for-byte.
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    let chunk = self.b.get(start..start + len).ok_or("truncated UTF-8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| "bad UTF-8")?);
                }
            }
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of body")? {
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'{' | b'[' => Err("nested objects/arrays are not accepted here".into()),
            _ => {
                let start = self.i;
                while self.i < self.b.len()
                    && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    self.i += 1;
                }
                std::str::from_utf8(&self.b[start..self.i])
                    .ok()
                    .and_then(|s| s.parse::<f64>().ok())
                    .map(Json::Num)
                    .ok_or_else(|| format!("bad number at byte {start}"))
            }
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        self.ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
}

/// Parse a flat JSON object (`{"key": scalar, ...}`). An empty body parses
/// as an empty object so optional-field verbs accept `curl -X POST` as-is.
fn parse_body(body: &str) -> Result<BTreeMap<String, Json>, OperatorError> {
    let mut map = BTreeMap::new();
    if body.trim().is_empty() {
        return Ok(map);
    }
    let mut p = JsonParser { b: body.as_bytes(), i: 0 };
    p.eat(b'{').map_err(OperatorError::BadRequest)?;
    if p.peek() == Some(b'}') {
        p.i += 1;
        return Ok(map);
    }
    loop {
        let key = p.string().map_err(OperatorError::BadRequest)?;
        p.eat(b':').map_err(OperatorError::BadRequest)?;
        let val = p.value().map_err(OperatorError::BadRequest)?;
        map.insert(key, val);
        match p.peek() {
            Some(b',') => {
                p.i += 1;
            }
            Some(b'}') => {
                p.i += 1;
                return Ok(map);
            }
            _ => {
                return Err(OperatorError::BadRequest(format!(
                    "expected ',' or '}}' at byte {}",
                    p.i
                )))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// HTTP listener
// ---------------------------------------------------------------------------

/// Request/header size cap (8 KiB) — the operator verbs are tiny.
const MAX_HEAD: usize = 8 * 1024;
/// Body size cap (64 KiB).
const MAX_BODY: usize = 64 * 1024;
/// Concurrent-connection cap. Connections past the cap are shed on the
/// accept thread with a `503` instead of spawning a handler — a flood
/// can no longer exhaust threads or memory.
const MAX_CONNECTIONS: usize = 64;

/// Decrements the live-connection gauge when a handler thread ends, by
/// any path (response written, I/O error, panic unwind).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The operator plane's HTTP listener. One accept thread; each connection
/// is served on its own short-lived thread (scrapes and control verbs are
/// rare and tiny — simplicity over throughput, matching the crate's
/// hand-rolled, dependency-free style), with the concurrent-thread count
/// bounded by [`MAX_CONNECTIONS`].
pub struct OperatorServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl OperatorServer {
    /// Bind `addr` (e.g. `127.0.0.1:9091`; port 0 picks a free port) and
    /// start serving the operator endpoints over `fabric`.
    pub fn start(
        addr: &str,
        auth_token: Option<String>,
        fabric: Arc<FabricServer>,
    ) -> Result<OperatorServer> {
        Self::start_with_limit(addr, auth_token, fabric, MAX_CONNECTIONS)
    }

    /// [`OperatorServer::start`] with an explicit concurrent-connection
    /// cap — the flood regression test runs with a tiny one.
    pub fn start_with_limit(
        addr: &str,
        auth_token: Option<String>,
        fabric: Arc<FabricServer>,
        max_connections: usize,
    ) -> Result<OperatorServer> {
        let limit = max_connections.max(1);
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding the operator listener on {addr}"))?;
        let local = listener.local_addr().context("resolving the operator listener address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let live = Arc::new(AtomicUsize::new(0));
        let accept = std::thread::Builder::new()
            .name("operator".into())
            .spawn(move || loop {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        if stop2.load(Ordering::SeqCst) {
                            break;
                        }
                        if live.load(Ordering::SeqCst) >= limit {
                            // Shed on the accept thread: one short write,
                            // no handler spawned, the listener stays
                            // responsive for the connections under the cap.
                            let e = OperatorError::Overloaded;
                            let (status, reason) = e.status();
                            write_response(
                                &mut stream,
                                status,
                                reason,
                                "application/json",
                                &error_json(&e),
                            );
                            continue;
                        }
                        live.fetch_add(1, Ordering::SeqCst);
                        let guard = ConnGuard(Arc::clone(&live));
                        let fabric = Arc::clone(&fabric);
                        let token = auth_token.clone();
                        // If the spawn itself fails, the closure (and the
                        // guard in it) is dropped, keeping the gauge honest
                        // under thread exhaustion.
                        let _ = std::thread::Builder::new().name("operator-conn".into()).spawn(
                            move || {
                                let _guard = guard;
                                let _ = serve_connection(stream, &fabric, token.as_deref());
                            },
                        );
                    }
                    Err(e) => {
                        if stop2.load(Ordering::SeqCst) {
                            break;
                        }
                        // Transient accept failures (fd exhaustion, aborted
                        // handshakes, EINTR) must not kill the listener.
                        std::thread::sleep(super::net::accept_retry_delay(&e));
                    }
                }
            })
            .expect("spawn operator accept thread");
        Ok(OperatorServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. In-flight connection
    /// threads finish their one response on their own.
    pub fn stop(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for OperatorServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// One parsed HTTP/1.1 request.
struct Request {
    method: String,
    path: String,
    /// Header names lowercased, values trimmed.
    headers: Vec<(String, String)>,
    body: String,
}

/// Read one HTTP/1.1 request head + body off `stream`.
fn read_request(stream: &mut TcpStream) -> Result<Request, OperatorError> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return Err(OperatorError::PayloadTooLarge);
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| OperatorError::BadRequest(format!("reading request: {e}")))?;
        if n == 0 {
            return Err(OperatorError::BadRequest("connection closed mid-request".into()));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let k = k.trim().to_ascii_lowercase();
            let v = v.trim().to_string();
            if k == "content-length" {
                content_length = v
                    .parse()
                    .map_err(|_| OperatorError::BadRequest("bad Content-Length".into()))?;
            }
            headers.push((k, v));
        }
    }
    if content_length > MAX_BODY {
        return Err(OperatorError::PayloadTooLarge);
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream
            .read(&mut chunk)
            .map_err(|e| OperatorError::BadRequest(format!("reading body: {e}")))?;
        if n == 0 {
            return Err(OperatorError::BadRequest("connection closed mid-body".into()));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let body = String::from_utf8_lossy(&body).into_owned();
    Ok(Request { method, path, headers, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
) {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

fn serve_connection(
    mut stream: TcpStream,
    fabric: &FabricServer,
    token: Option<&str>,
) -> std::io::Result<()> {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let req = match read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => {
            let (status, reason) = e.status();
            write_response(&mut stream, status, reason, "application/json", &error_json(&e));
            return Ok(());
        }
    };
    if let Some(expect) = token {
        let expect = format!("Bearer {expect}");
        let authed = req
            .headers
            .iter()
            .any(|(k, v)| k == "authorization" && v == &expect);
        if !authed {
            let e = OperatorError::Unauthorized;
            let (status, reason) = e.status();
            let head = format!(
                "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
                 WWW-Authenticate: Bearer\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
                error_json(&e).len()
            );
            let _ = stream.write_all(head.as_bytes());
            let _ = stream.write_all(error_json(&e).as_bytes());
            return Ok(());
        }
    }
    match route(&req.method, &req.path, &req.body, fabric) {
        Ok((content_type, body)) => write_response(&mut stream, 200, "OK", content_type, &body),
        Err(e) => {
            let (status, reason) = e.status();
            write_response(&mut stream, status, reason, "application/json", &error_json(&e));
        }
    }
    Ok(())
}

fn error_json(e: &OperatorError) -> String {
    let (status, _) = e.status();
    format!("{{\"error\":{},\"status\":{}}}", json_string(&e.to_string()), status)
}

/// Dispatch one request to the fabric. Returns `(content-type, body)`.
fn route(
    method: &str,
    path: &str,
    body: &str,
    fabric: &FabricServer,
) -> Result<(&'static str, String), OperatorError> {
    let path = path.split('?').next().unwrap_or(path);
    match (method, path) {
        ("GET", "/metrics") => {
            Ok(("text/plain; version=0.0.4", fabric.snapshot().to_prometheus()))
        }
        ("GET", "/state") => Ok(("application/json", fabric.snapshot().to_json())),
        ("POST", "/swap") => {
            let req = parse_body(body)?;
            let pblock = field_usize(&req, "pblock")?;
            let at_flit = field_u64(&req, "at_flit")?;
            let rm = req
                .get("rm")
                .and_then(Json::as_str)
                .ok_or_else(|| OperatorError::BadRequest("missing string field \"rm\"".into()))?;
            let rm = RmKind::parse(rm)
                .ok_or_else(|| OperatorError::BadRequest(format!("unknown RM kind \"{rm}\"")))?;
            let r = field_usize(&req, "r")?;
            let dark_flits = match req.get("dark_flits") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_u64().ok_or_else(|| {
                    OperatorError::BadRequest("\"dark_flits\" must be a non-negative integer".into())
                })?),
            };
            let (model_ms, dark) = fabric
                .schedule_swap(pblock, at_flit, rm, r, dark_flits)
                .map_err(refusal)?;
            Ok((
                "application/json",
                format!("{{\"model_ms\":{},\"dark_flits\":{}}}", num_f(model_ms), dark),
            ))
        }
        ("POST", "/drain") => {
            let req = parse_body(body)?;
            let pblock = field_usize(&req, "pblock")?;
            let draining = fabric.drain(pblock).map_err(refusal)?;
            let ids: Vec<String> = draining.iter().map(|id| id.to_string()).collect();
            Ok(("application/json", format!("{{\"draining\":[{}]}}", ids.join(","))))
        }
        ("POST", "/controller") => {
            let req = parse_body(body)?;
            let pblock = match req.get("pblock") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_usize().ok_or_else(|| {
                    OperatorError::BadRequest("\"pblock\" must be a non-negative integer".into())
                })?),
            };
            let threshold = match req.get("threshold") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().ok_or_else(|| {
                    OperatorError::BadRequest("\"threshold\" must be a number".into())
                })?),
            };
            let cooldown = match req.get("cooldown_flits") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_u64().ok_or_else(|| {
                    OperatorError::BadRequest(
                        "\"cooldown_flits\" must be a non-negative integer".into(),
                    )
                })?),
            };
            fabric.tune_controller(pblock, threshold, cooldown).map_err(refusal)?;
            Ok(("application/json", "{\"ok\":true}".into()))
        }
        ("GET", "/swap") | ("GET", "/drain") | ("GET", "/controller")
        | ("POST", "/metrics") | ("POST", "/state") => Err(OperatorError::MethodNotAllowed),
        _ => Err(OperatorError::NotFound(format!("{method} {path}"))),
    }
}

fn field_usize(req: &BTreeMap<String, Json>, key: &str) -> Result<usize, OperatorError> {
    req.get(key).and_then(Json::as_usize).ok_or_else(|| {
        OperatorError::BadRequest(format!("missing non-negative integer field \"{key}\""))
    })
}

fn field_u64(req: &BTreeMap<String, Json>, key: &str) -> Result<u64, OperatorError> {
    req.get(key).and_then(Json::as_u64).ok_or_else(|| {
        OperatorError::BadRequest(format!("missing non-negative integer field \"{key}\""))
    })
}

/// Map a fabric refusal onto an HTTP status: unknown partitions are 404,
/// everything else the fabric declines is 409.
fn refusal(e: anyhow::Error) -> OperatorError {
    let msg = format!("{e:#}");
    if msg.contains("no served partition") {
        OperatorError::NotFound(msg)
    } else {
        OperatorError::Refused(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_body_flat_object() {
        let m = parse_body(r#"{"pblock": 2, "rm": "loda", "r": 4, "flag": true, "x": null}"#)
            .unwrap();
        assert_eq!(m.get("pblock").unwrap().as_usize(), Some(2));
        assert_eq!(m.get("rm").unwrap().as_str(), Some("loda"));
        assert_eq!(m.get("r").unwrap().as_u64(), Some(4));
        assert_eq!(m.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(m.get("x"), Some(&Json::Null));
    }

    #[test]
    fn parse_body_empty_and_errors() {
        assert!(parse_body("").unwrap().is_empty());
        assert!(parse_body("  {} ").unwrap().is_empty());
        assert!(parse_body("[1]").is_err());
        assert!(parse_body(r#"{"a": {"nested": 1}}"#).is_err());
        assert!(parse_body(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn parse_body_string_escapes() {
        let m = parse_body(r#"{"s": "a\"b\\c\ndA"}"#).unwrap();
        assert_eq!(m.get("s").unwrap().as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn json_string_escapes() {
        assert_eq!(json_string("a\"b\\c\n"), r#""a\"b\\c\n""#);
    }

    #[test]
    fn prometheus_text_shape() {
        let snap = FabricSnapshot {
            server: ServerTelemetry { sessions_served: 3, ..Default::default() },
            partitions: vec![PartitionTelemetry {
                id: 1,
                rm: "loda",
                r: 4,
                lanes: 1,
                capacity: 1,
                admitted: 0,
                flits_seen: 10,
                swaps_pending: 0,
                swaps_executed: 2,
                swap_history: Vec::new(),
                controller_threshold: 4.0,
                controller_cooldown_flits: 256,
                drift_armed: false,
                drift_ready: false,
                drift_z: 0.0,
                decoupler_enabled: true,
                isolated: false,
                quarantined: false,
                dropped_flits: 0,
                fault_events: 0,
                fault_reloads: 0,
                fault_quarantines: 0,
                health_beat: 0,
            }],
            sessions: Vec::new(),
        };
        let text = snap.to_prometheus();
        assert!(text.contains("# TYPE fsead_server_sessions_served_total counter"));
        assert!(text.contains("fsead_server_sessions_served_total 3"));
        assert!(text.contains("fsead_swap_executed_total{partition=\"1\"} 2"));
        assert!(text.contains("fsead_controller_threshold{partition=\"1\"} 4"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(name.starts_with("fsead_"), "bad metric name: {name}");
            assert!(value.parse::<f64>().is_ok(), "bad value: {value}");
        }
    }

    #[test]
    fn state_json_shape() {
        let snap = FabricSnapshot::default();
        let json = snap.to_json();
        assert!(json.starts_with("{\"server\":"));
        assert!(json.contains("\"partitions\":[]"));
        assert!(json.contains("\"sessions\":[]"));
        // Round-trip sanity through the module's own parser idiom: the
        // server section is a flat object.
        let inner = json
            .strip_prefix("{\"server\":")
            .and_then(|s| s.split_once('}'))
            .map(|(head, _)| format!("{head}}}"))
            .unwrap();
        let m = parse_body(&inner).unwrap();
        assert_eq!(m.get("sessions_served").unwrap().as_u64(), Some(0));
    }
}
