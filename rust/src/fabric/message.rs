//! Fabric message types: AXI4-Stream transfers at chunk granularity.
//!
//! A [`Flit`] is one chunked transfer (the paper streams one f32 per beat;
//! we batch `chunk` samples per transfer to amortise channel overhead — the
//! chunk size is the artifact chunk size, so one flit = one executable
//! invocation). `Chunk.last` models the AXI TLAST sideband.

use std::sync::mpsc::{channel, Receiver, Sender};

pub use crate::data::stream::Chunk;

/// One AXI-stream transfer.
pub type Flit = Chunk;

/// A point-to-point stream link (master → slave).
pub struct Port;

impl Port {
    /// Create a stream link. Unbounded like a register-sliced AXI channel;
    /// backpressure is applied by the consumer's service rate.
    pub fn link() -> (Sender<Flit>, Receiver<Flit>) {
        channel()
    }
}

/// Score flits have d = 1: length of data == length of mask.
pub fn score_chunk(seq: u64, scores: Vec<f32>, mask: Vec<f32>, n_valid: usize, last: bool) -> Flit {
    debug_assert_eq!(scores.len(), mask.len());
    Chunk { seq, data: scores, mask, n_valid, last }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_moves_flits() {
        let (tx, rx) = Port::link();
        tx.send(score_chunk(0, vec![1.0, 0.0], vec![1.0, 0.0], 1, true)).unwrap();
        let f = rx.recv().unwrap();
        assert_eq!(f.n_valid, 1);
        assert!(f.last);
    }

    #[test]
    fn dropped_sender_closes_stream() {
        let (tx, rx) = Port::link();
        drop(tx);
        assert!(rx.recv().is_err());
    }
}
