//! Fabric message types: AXI4-Stream transfers at chunk granularity.
//!
//! A [`Flit`] is one chunked transfer (the paper streams one f32 per beat;
//! we batch `chunk` samples per transfer to amortise channel overhead — the
//! chunk size is the artifact chunk size, so one flit = one executable
//! invocation). `Chunk.last` models the AXI TLAST sideband.
//!
//! # Zero-copy data plane
//!
//! Flit payloads (`data`, `mask`) are shared immutable `Arc<[f32]>`
//! buffers. Moving a flit through a channel moves two pointers; fanning a
//! flit out to several consumers (switch pumps, a bypass RM, the FPGA
//! submission queue, the combiner) clones pointers. The samples themselves
//! are written exactly once, when the input DMA cuts the stream into
//! chunks — every later hop shares that allocation, mirroring how the
//! board's DMA engines hand the same DDR buffer to each pblock channel.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

pub use crate::data::stream::Chunk;

/// One AXI-stream transfer.
pub type Flit = Chunk;

/// A point-to-point stream link (master → slave).
pub struct Port;

impl Port {
    /// Create a stream link. Unbounded like a register-sliced AXI channel;
    /// backpressure is applied by the consumer's service rate.
    pub fn link() -> (Sender<Flit>, Receiver<Flit>) {
        channel()
    }
}

/// Upstream end of a flit stream, as seen by a pblock service loop.
///
/// The fabric's one-shot data plane feeds pblocks through plain mpsc
/// receivers; the session server feeds them through bounded session inboxes
/// ([`crate::fabric::server::SessionInbox`]) that apply backpressure to the
/// client and can be force-closed at shutdown. Both drain identically
/// through this trait, so [`crate::fabric::pblock::Pblock::service_mode`]
/// is byte-for-byte the same loop in either deployment.
pub trait FlitSource {
    /// Block for the next flit; `None` once the stream is closed.
    fn recv_flit(&mut self) -> Option<Flit>;
    /// Non-blocking probe; `None` when the inbox is empty or closed.
    fn try_recv_flit(&mut self) -> Option<Flit>;
}

impl FlitSource for Receiver<Flit> {
    fn recv_flit(&mut self) -> Option<Flit> {
        self.recv().ok()
    }

    fn try_recv_flit(&mut self) -> Option<Flit> {
        self.try_recv().ok()
    }
}

/// Decode little-endian f32 wire bytes into `out` — the network front
/// end's half of the zero-copy contract. `bytes` must be a whole number
/// of 4-byte values. Each value is written exactly once, directly into
/// the destination buffer (a flit allocation or a staged tail), so a
/// `Push` frame's sample block crosses the socket boundary with the same
/// single copy the input DMA pays when it cuts a stream into chunks.
pub fn decode_f32_le(bytes: &[u8], out: &mut Vec<f32>) {
    debug_assert_eq!(bytes.len() % 4, 0, "callers validate framing before decoding");
    out.reserve(bytes.len() / 4);
    for b in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
    }
}

/// Encode f32 values as little-endian wire bytes — the inverse of
/// [`decode_f32_le`], used for `Push` bodies and `Scores` frames.
pub fn encode_f32_le(values: &[f32], out: &mut Vec<u8>) {
    out.reserve(values.len() * 4);
    for &v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Score flits have d = 1: length of data == length of mask. Accepts either
/// freshly-computed `Vec<f32>` buffers or already-shared `Arc<[f32]>`
/// payloads (e.g. a mask forwarded from the input flit).
pub fn score_chunk(
    seq: u64,
    scores: impl Into<Arc<[f32]>>,
    mask: impl Into<Arc<[f32]>>,
    n_valid: usize,
    last: bool,
) -> Flit {
    let (scores, mask) = (scores.into(), mask.into());
    debug_assert_eq!(scores.len(), mask.len());
    Chunk { seq, data: scores, mask, n_valid, last }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_moves_flits() {
        let (tx, rx) = Port::link();
        tx.send(score_chunk(0, vec![1.0, 0.0], vec![1.0, 0.0], 1, true)).unwrap();
        let f = rx.recv().unwrap();
        assert_eq!(f.n_valid, 1);
        assert!(f.last);
    }

    #[test]
    fn dropped_sender_closes_stream() {
        let (tx, rx) = Port::link();
        drop(tx);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn score_chunk_shares_forwarded_masks() {
        let mask: Arc<[f32]> = vec![1.0, 1.0].into();
        let f = score_chunk(3, vec![0.5, 0.7], mask.clone(), 2, false);
        assert!(Arc::ptr_eq(&f.mask, &mask));
    }
}
