//! Persistent streaming session server over the fabric.
//!
//! [`Fabric::run`](super::Fabric::run) is a one-shot batch pass: it wires
//! the topology, streams pre-loaded datasets through and tears everything
//! down. This module keeps the fabric *resident*: a [`FabricServer`] starts
//! one service worker per configured pblock partition and the workers stay
//! alive between requests, serving an open-ended sequence of client
//! sessions — the paper's Fig 7(a) multi-stream configuration (seven
//! independent AD applications, one per pblock, each on its own DMA
//! channel) turned into a long-running service.
//!
//! # Session lifecycle
//!
//! 1. **Open** — [`FabricServer::open`] admits a [`Session`] onto a free
//!    pblock partition (a specific one via [`SessionSpec::pblock`], or any).
//!    When every partition is busy the caller queues on the admission
//!    condvar (bounded by `[fabric.server] max_waiters`) until a partition
//!    frees. The partition's resident worker builds a fresh RM from the
//!    session's dimensionality and warm-up prefix with the same
//!    [`pblock_seed`] the one-shot fabric uses, so a session's scores are
//!    **bit-identical** to a `Fabric::run` over the same concatenated data.
//! 2. **Push** — [`Session::push`] appends samples; full chunks are cut
//!    into flits exactly like the input DMA's `ChunkStream` (shared
//!    all-ones mask, zero-padded tail) and sent through the session's
//!    **bounded inbox**: a full inbox blocks the producer — AXI-style
//!    backpressure — and never drops or reorders flits.
//! 3. **Score** — the partition worker drains the inbox through the
//!    ordinary [`Pblock::service_mode`] loop (both [`ExecMode`]s, the DFX
//!    gate consulted per flit), so live reconfiguration — scripted
//!    schedules via [`FabricServer::schedule_swap`] / `[fabric.dfx.swap.N]`
//!    and the adaptive controller via `[fabric.dfx]` — keeps working
//!    mid-session. Score flits flow back asynchronously per chunk
//!    ([`Session::recv_scores`] / [`Session::poll_scores`]).
//! 4. **Close** — [`Session::close`] flushes with TLAST semantics: a
//!    partial trailing chunk is zero-padded into the final flit and
//!    **reported** ([`SessionClose::padded_tail`], never silent), the
//!    remaining scores are drained, and the partition returns to the free
//!    pool for the next queued session. Dropping a session without closing
//!    abandons it: the worker finishes, the partition frees, nothing leaks.
//!
//! [`FabricServer::shutdown`] force-closes the inboxes of sessions still
//! open (their next `push` fails fast), lets every worker finish its
//! current episode, and joins them — shutdown never deadlocks on an idle
//! client.
//!
//! # Lifecycle resilience
//!
//! On top of the base lifecycle, sessions survive events that used to end
//! them:
//!
//! - **Suspend / resume** — [`Session::suspend`] checkpoints the session
//!   (RM window snapshot + worker cursor + the client-side pending tail)
//!   into a serializable [`SessionTicket`]; [`FabricServer::resume`]
//!   continues it on any partition with the same layout — on this server
//!   or, via `SessionTicket::to_bytes`/`from_bytes` (or a `spill_dir`
//!   file), on a **fresh process** over the same config. Resumed scores
//!   are bit-identical to an uninterrupted session because the resume
//!   rebuilds the RM with the *origin* partition's seed and restores the
//!   exact window state.
//! - **Idle eviction & multiplexing** — with `[fabric.server]`
//!   `sessions_per_partition = K` (and/or `idle_evict_flits = N`) a
//!   partition worker runs a round-robin multiplexer instead of the
//!   one-session episode loop: up to `K` sessions share the partition,
//!   their window state swapped through the snapshot codec as the
//!   multiplexer switches between inboxes. Sessions idle for `N`
//!   multiplexer ticks (processed flits or idle sweeps) are parked into
//!   the session store — transparently: the client's `push` simply
//!   re-attaches the session when its inbox stirs. With both knobs at
//!   their defaults the server is bit-transparent to the dedicated
//!   episode path.
//! - **Admission deadlines & shedding** — `open_timeout_ms` bounds how
//!   long `open`/`resume` may wait for a slot, and `overload = "shed"`
//!   fails immediately instead of queueing. Both return the typed
//!   [`AdmitError`] (downcastable from the `anyhow` error), so callers
//!   can tell overload from shutdown.
//! - **Quarantine eviction** — with `evict_quarantined = true` and faults
//!   armed, a partition quarantined by the recovery ladder (rung 2) does
//!   not drag its session down: the service loop stops, the session is
//!   parked from its last healthy checkpoint and resumes on a compatible
//!   partition (PR-6 reload semantics: state rolls back to the
//!   checkpoint; scores already emitted are not recalled).
//! - **Durable score sink** — `sink_path` appends every score chunk as a
//!   length-prefixed, CRC-framed record
//!   (`[u32 len][u64 session | u64 seq | u32 n | f32×n][u32 crc]`,
//!   fsync'd every `sink_fsync_records` records). After a crash,
//!   [`super::score_sink::recover`] truncates the torn tail and replays
//!   every intact record.

use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::decoupler::Decoupler;
use super::dma::unpad_into;
use super::faults::{FaultEvent, FaultInjector};
use super::hotswap::{self, ControllerEnv, ControllerTarget, PblockCtl, SwapEvent};
use super::message::{decode_f32_le, Flit, FlitSource, Port};
use super::operator::{FabricSnapshot, PartitionTelemetry, ServerTelemetry, SessionTelemetry};
use super::pblock::{LoadedRm, Pblock, PblockReport};
use super::reconfig::DfxManager;
use super::score_sink::ScoreSink;
use super::session_store::{ParkReason, ParkedSession, SessionStore, SessionTicket};
use super::snapshot::{restore_rm, snapshot_rm};
use super::supervisor::{self, SupervisorEnv, SupervisorTarget};
use super::topology::{kind_of, pblock_seed};
use crate::config::{
    DetectorHyper, DfxCfg, FaultsCfg, FseadConfig, OverloadPolicy, RmKind, ScriptedSwap,
};
use crate::data::Dataset;
use crate::ensemble::{ExecMode, LanePool};
use crate::runtime::{Registry, Runtime, RuntimeHandle};

/// Completed-session outcomes retained for clients that have not yet
/// collected them (bounds memory under open/close churn with misbehaving
/// clients that neither close nor drop promptly).
const MAX_RETAINED_OUTCOMES: usize = 512;

// ---------------------------------------------------------------------------
// Bounded session inbox
// ---------------------------------------------------------------------------

#[derive(Default)]
struct InboxQueue {
    buf: VecDeque<Flit>,
    /// Producer hung up (client closed or dropped the session).
    producer_done: bool,
    /// Server force-closed the stream (shutdown): pending flits are
    /// discarded and the producer's next send fails fast.
    force_closed: bool,
    /// Client asked to suspend: remaining queued flits are still
    /// delivered, then `recv_flit` reports end-of-stream so the worker
    /// can checkpoint the session instead of tearing it down.
    suspended: bool,
}

struct InboxShared {
    cap: usize,
    q: Mutex<InboxQueue>,
    /// Signalled when space frees up (consumer popped / force-close).
    space: Condvar,
    /// Signalled when a flit arrives or the stream ends.
    ready: Condvar,
    /// Latched once any lock acquisition observed a poisoned mutex — a
    /// thread panicked inside an inbox critical section. The queue is
    /// force-closed at recovery, so the failure stays confined to this
    /// one session: its producer errors fast, its service loop sees
    /// end-of-stream, and the partition worker survives to serve the
    /// next session. The episode boundary reads this flag to report a
    /// typed [`ServeError::Poisoned`] instead of cascading the panic.
    poisoned: AtomicBool,
}

impl InboxShared {
    /// Recover a poisoned guard: latch the flag and force-close the
    /// queue so every other party backs out instead of re-panicking.
    fn recover<'a>(
        &self,
        p: std::sync::PoisonError<MutexGuard<'a, InboxQueue>>,
    ) -> MutexGuard<'a, InboxQueue> {
        self.poisoned.store(true, Ordering::SeqCst);
        let mut q = p.into_inner();
        q.force_closed = true;
        q.buf.clear();
        self.space.notify_all();
        self.ready.notify_all();
        q
    }

    /// Lock the queue, surviving poison (see [`InboxShared::recover`]).
    fn lock_q(&self) -> MutexGuard<'_, InboxQueue> {
        self.q.lock().unwrap_or_else(|p| self.recover(p))
    }

    /// Wait on `space`, surviving poison.
    fn wait_space<'a>(&self, q: MutexGuard<'a, InboxQueue>) -> MutexGuard<'a, InboxQueue> {
        self.space.wait(q).unwrap_or_else(|p| self.recover(p))
    }

    /// Wait on `ready`, surviving poison.
    fn wait_ready<'a>(&self, q: MutexGuard<'a, InboxQueue>) -> MutexGuard<'a, InboxQueue> {
        self.ready.wait(q).unwrap_or_else(|p| self.recover(p))
    }
}

/// Error returned by [`InboxSender::send`] once the server has force-closed
/// the session (shutdown or partition failure).
#[derive(Debug)]
pub struct InboxClosed;

impl std::fmt::Display for InboxClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "session inbox closed by the server")
    }
}

impl std::error::Error for InboxClosed {}

/// Producer half of a session's bounded inbox. A full inbox **blocks** the
/// sender until the partition's service loop drains a flit — backpressure,
/// never drops, never reorders.
pub struct InboxSender {
    inner: Arc<InboxShared>,
}

impl InboxSender {
    pub fn send(&self, flit: Flit) -> Result<(), InboxClosed> {
        let mut q = self.inner.lock_q();
        loop {
            if q.force_closed {
                return Err(InboxClosed);
            }
            if q.buf.len() < self.inner.cap {
                break;
            }
            q = self.inner.wait_space(q);
        }
        q.buf.push_back(flit);
        drop(q);
        self.inner.ready.notify_one();
        Ok(())
    }

    /// Flits currently queued (telemetry / tests).
    pub fn len(&self) -> usize {
        self.inner.lock_q().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ask the service loop to stop at the current drain point: queued
    /// flits are still delivered, then the stream reports its end without
    /// the producer hanging up — the suspend half of
    /// [`Session::suspend`].
    pub fn request_suspend(&self) {
        let mut q = self.inner.lock_q();
        q.suspended = true;
        drop(q);
        self.inner.ready.notify_all();
    }
}

impl Drop for InboxSender {
    fn drop(&mut self) {
        self.inner.lock_q().producer_done = true;
        self.inner.ready.notify_all();
    }
}

/// Server-side control over a session inbox: force-close at shutdown.
#[derive(Clone)]
pub(crate) struct InboxCtl {
    inner: Arc<InboxShared>,
}

impl InboxCtl {
    fn force_close(&self) {
        let mut q = self.inner.lock_q();
        q.force_closed = true;
        q.buf.clear();
        drop(q);
        self.inner.space.notify_all();
        self.inner.ready.notify_all();
    }

    /// True once any thread panicked inside this inbox's critical
    /// section — the episode boundary maps this to
    /// [`ServeError::Poisoned`].
    pub(crate) fn poisoned(&self) -> bool {
        self.inner.poisoned.load(Ordering::SeqCst)
    }

    /// True once the client requested a suspend on this inbox.
    fn suspend_requested(&self) -> bool {
        self.inner.lock_q().suspended
    }

    /// Server-side suspend request — the operator plane's drain path.
    /// Identical semantics to [`InboxSender::request_suspend`]: queued
    /// flits are still delivered, then the stream ends so the worker
    /// parks the session instead of tearing it down.
    pub(crate) fn request_suspend(&self) {
        let mut q = self.inner.lock_q();
        q.suspended = true;
        drop(q);
        self.inner.ready.notify_all();
    }

    /// Flits currently queued behind this door (telemetry).
    pub(crate) fn queued(&self) -> usize {
        self.inner.lock_q().buf.len()
    }

    /// Mint a fresh consumer half over the same shared queue — used when
    /// a quarantine eviction parks a live session whose [`SessionInbox`]
    /// was consumed by the service loop that just ended.
    fn reopen(&self) -> SessionInbox {
        SessionInbox { inner: Arc::clone(&self.inner) }
    }
}

/// Consumer half of a session's bounded inbox — the [`FlitSource`] a
/// partition worker drains through [`Pblock::service_mode`].
pub struct SessionInbox {
    inner: Arc<InboxShared>,
}

impl SessionInbox {
    /// Create a bounded inbox of `cap` flits.
    pub fn bounded(cap: usize) -> (InboxSender, SessionInbox) {
        assert!(cap > 0, "a zero-depth inbox deadlocks");
        let inner = Arc::new(InboxShared {
            cap,
            q: Mutex::new(InboxQueue::default()),
            space: Condvar::new(),
            ready: Condvar::new(),
            poisoned: AtomicBool::new(false),
        });
        (InboxSender { inner: Arc::clone(&inner) }, SessionInbox { inner })
    }

    pub(crate) fn ctl(&self) -> InboxCtl {
        InboxCtl { inner: Arc::clone(&self.inner) }
    }

    /// True once any thread panicked inside this inbox's critical section.
    pub(crate) fn poisoned(&self) -> bool {
        self.inner.poisoned.load(Ordering::SeqCst)
    }

    /// One consistent view of the inbox's flags — what the multiplexer
    /// uses to decide between draining, parking and finishing a slot.
    pub(crate) fn probe(&self) -> InboxProbe {
        let q = self.inner.lock_q();
        InboxProbe {
            queued: q.buf.len(),
            producer_done: q.producer_done,
            force_closed: q.force_closed,
            suspended: q.suspended,
        }
    }
}

/// Snapshot of a [`SessionInbox`]'s state flags.
#[derive(Clone, Copy)]
pub(crate) struct InboxProbe {
    pub queued: usize,
    pub producer_done: bool,
    pub force_closed: bool,
    pub suspended: bool,
}

impl InboxProbe {
    /// Anything a parked session's partition should react to?
    fn stirring(&self) -> bool {
        self.queued > 0 || self.producer_done || self.force_closed || self.suspended
    }
}

impl FlitSource for SessionInbox {
    fn recv_flit(&mut self) -> Option<Flit> {
        let mut q = self.inner.lock_q();
        loop {
            if q.force_closed {
                return None;
            }
            if let Some(f) = q.buf.pop_front() {
                drop(q);
                self.inner.space.notify_one();
                return Some(f);
            }
            if q.producer_done || q.suspended {
                return None;
            }
            q = self.inner.wait_ready(q);
        }
    }

    fn try_recv_flit(&mut self) -> Option<Flit> {
        let mut q = self.inner.lock_q();
        if q.force_closed {
            return None;
        }
        let f = q.buf.pop_front();
        if f.is_some() {
            drop(q);
            self.inner.space.notify_one();
        }
        f
    }
}

// ---------------------------------------------------------------------------
// Admission errors
// ---------------------------------------------------------------------------

/// Typed admission failures from [`FabricServer::open`] /
/// [`FabricServer::resume`]. Downcast the `anyhow` error to tell overload
/// shedding apart from a timeout, a full queue or shutdown:
/// `err.downcast_ref::<AdmitError>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// `overload = "shed"`: every eligible partition slot was busy and
    /// the server sheds instead of queueing the caller.
    Saturated,
    /// `open_timeout_ms` elapsed while waiting for a slot.
    Timeout {
        waited_ms: u64,
    },
    /// `max_waiters` clients were already queued.
    QueueFull {
        waiters: usize,
    },
    ShuttingDown,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Saturated => {
                write!(f, "admission shed: every eligible partition slot is busy (overload = \"shed\")")
            }
            AdmitError::Timeout { waited_ms } => {
                write!(f, "admission timed out after {waited_ms} ms waiting for a partition slot")
            }
            AdmitError::QueueFull { waiters } => {
                write!(f, "admission queue is full ({waiters} session(s) already waiting)")
            }
            AdmitError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for AdmitError {}

// ---------------------------------------------------------------------------
// Service errors
// ---------------------------------------------------------------------------

/// Which lifecycle operation needed a window snapshot the detector does not
/// expose (see [`ServeError::NoSnapshot`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotOp {
    /// [`Session::suspend`] — checkpoint for a ticket.
    Suspend,
    /// Multiplexer park (idle eviction / suspend on a shared partition).
    Park,
    /// Multiplexer switching the resident RM between tenants.
    Switch,
}

/// Typed session-service failures, the episode-side counterpart of
/// [`AdmitError`]: everything a partition worker can report through
/// [`Session::close`] / [`Session::suspend`] instead of a bare string.
/// Downcast the `anyhow` error (`err.downcast_ref::<ServeError>()`) or match
/// [`ServeError::code`] to map failures onto protocol status codes — the
/// operator plane and the `serve --stdin` JSONL driver both do.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Building the session's fresh RM failed.
    BuildRm { detail: String },
    /// Resetting the freshly built RM failed.
    ResetRm { detail: String },
    /// Restoring a resumed session's checkpoint failed.
    RestoreCheckpoint { detail: String },
    /// Restoring a multiplexed tenant's swapped-out window state failed.
    RestoreState { detail: String },
    /// A scripted `[fabric.dfx.swap.N]` entry could not be staged.
    ArmScriptedSwap { pblock: usize, detail: String },
    /// `[fabric.faults]` injection planning failed.
    PlanFaults { detail: String },
    /// The detector exposes no window snapshot, so the session state
    /// cannot be checkpointed / swapped for `op`.
    NoSnapshot { op: SnapshotOp },
    /// A thread panicked inside the session's inbox critical section,
    /// poisoning its lock. The inbox was force-closed at recovery, so
    /// the damage is confined: this session dies with this error while
    /// the partition worker survives to serve the next one.
    Poisoned,
    /// The service loop itself failed mid-stream.
    Service { detail: String },
}

impl ServeError {
    /// Stable machine-readable code (JSONL `code` field, HTTP mapping).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::BuildRm { .. } => "build_rm",
            ServeError::ResetRm { .. } => "reset_rm",
            ServeError::RestoreCheckpoint { .. } => "restore_checkpoint",
            ServeError::RestoreState { .. } => "restore_state",
            ServeError::ArmScriptedSwap { .. } => "arm_scripted_swap",
            ServeError::PlanFaults { .. } => "plan_faults",
            ServeError::NoSnapshot { .. } => "no_snapshot",
            ServeError::Poisoned => "poisoned",
            ServeError::Service { .. } => "service",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BuildRm { detail } => write!(f, "building RM: {detail}"),
            ServeError::ResetRm { detail } => write!(f, "resetting RM: {detail}"),
            ServeError::RestoreCheckpoint { detail } => {
                write!(f, "restoring the session checkpoint: {detail}")
            }
            ServeError::RestoreState { detail } => {
                write!(f, "restoring session state: {detail}")
            }
            ServeError::ArmScriptedSwap { pblock, detail } => {
                write!(f, "arming scripted swap for pblock {pblock}: {detail}")
            }
            ServeError::PlanFaults { detail } => {
                write!(f, "planning fault injections: {detail}")
            }
            ServeError::NoSnapshot { op: SnapshotOp::Suspend } => {
                write!(f, "suspending: detector exposes no window snapshot to checkpoint")
            }
            ServeError::NoSnapshot { op: SnapshotOp::Park } => {
                write!(f, "parking: detector exposes no window snapshot to checkpoint")
            }
            ServeError::NoSnapshot { op: SnapshotOp::Switch } => {
                write!(
                    f,
                    "multiplexing: detector exposes no window snapshot — cannot swap \
                     session state"
                )
            }
            ServeError::Poisoned => {
                write!(
                    f,
                    "a client thread panicked inside the session inbox — the session \
                     was terminated; the partition survives"
                )
            }
            ServeError::Service { detail } => write!(f, "{detail}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// [`FabricServer::resume`] refused a ticket because no served partition
/// matches its layout (RM kind / ensemble size / lanes) — the server is
/// mis-provisioned for the session, which is a deployment fault, not a
/// corrupt ticket. Typed so the network plane can surface it as its own
/// `config_mismatch` status code; downcast with
/// `err.downcast_ref::<ConfigMismatch>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigMismatch(pub String);

impl std::fmt::Display for ConfigMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ConfigMismatch {}

// ---------------------------------------------------------------------------
// Admission state
// ---------------------------------------------------------------------------

struct ActiveSession {
    session: u64,
    d: usize,
    /// Warm-up prefix of the session's stream — kept so in-flight swaps can
    /// be staged against the live stream's parameter ranges.
    warmup: Arc<Vec<f32>>,
    door: InboxCtl,
}

/// What a finished session left behind for its client.
struct SessionOutcome {
    report: Option<PblockReport>,
    swap_events: Vec<SwapEvent>,
    adaptive_swaps: u64,
    discarded_swaps: u64,
    fault_events: Vec<FaultEvent>,
    error: Option<ServeError>,
}

#[derive(Default)]
struct AdmissionState {
    /// Partitions with at least one free session slot.
    free: BTreeSet<usize>,
    /// Sessions currently charged against each partition's capacity
    /// (`sessions_per_partition`); a parked session gives its slot back.
    admitted: BTreeMap<usize, usize>,
    /// Dedicated mode only: the one live session per partition, kept for
    /// `schedule_swap` and the end-of-episode force-close.
    active: BTreeMap<usize, ActiveSession>,
    /// Inbox doors of every live or transparently-parked session, keyed
    /// by session id — shutdown force-closes them all.
    doors: BTreeMap<u64, InboxCtl>,
    /// Partition each doored session was last dispatched to — the
    /// operator plane's session→partition view and the target set for
    /// [`FabricServer::drain`]. A session sitting in the store keeps its
    /// last placement until a partition claims it again, so readers
    /// cross-check [`SessionStore::contains`] before trusting an entry.
    placed: BTreeMap<u64, usize>,
    results: BTreeMap<u64, SessionOutcome>,
    /// Sessions dropped by their client before the worker stored a result.
    abandoned: BTreeSet<u64>,
    waiters: usize,
    shutting_down: bool,
    next_session: u64,
    served: u64,
}

/// A partition's job queue plus the layout resumes must match.
struct PartitionSender {
    rm: RmKind,
    r: usize,
    lanes: usize,
    jobs: Sender<SessionWork>,
}

struct Shared {
    state: Mutex<AdmissionState>,
    /// Signalled when a partition frees (or at shutdown) — admission queue.
    freed: Condvar,
    /// Checkpointed sessions between partitions (idle-evicted, suspended,
    /// quarantine-evicted).
    store: SessionStore,
    /// Durable score sink (`[fabric.server] sink_path`), shared by every
    /// partition worker.
    sink: Option<Mutex<ScoreSink>>,
    /// Where suspend tickets spill (`[fabric.server] spill_dir`).
    spill_dir: Option<PathBuf>,
    /// Job senders by partition — lets a worker redispatch an evicted
    /// session to a free sibling. Cleared at shutdown so workers see
    /// their queues disconnect.
    senders: Mutex<BTreeMap<usize, PartitionSender>>,
}

// ---------------------------------------------------------------------------
// Partition workers
// ---------------------------------------------------------------------------

/// Continuation state for a resumed session: the worker rebuilds the RM
/// with the *origin* partition's seed and restores the checkpointed
/// window, so scores continue bit-identically.
struct ResumeState {
    seed: u64,
    snapshot: Option<Vec<u8>>,
    /// Input flits already processed before the resume.
    base_flits: u64,
    /// Valid samples already scored before the resume.
    base_samples: u64,
}

struct SessionWork {
    session: u64,
    d: usize,
    warmup: Arc<Vec<f32>>,
    inbox: SessionInbox,
    scores: Sender<Flit>,
    resume: Option<ResumeState>,
}

/// Rebuild a worker job from a parked live session (quarantine eviction /
/// idle-evict re-attach on the dedicated path).
fn work_from_parked(p: ParkedSession) -> SessionWork {
    SessionWork {
        session: p.id,
        d: p.d,
        warmup: p.warmup,
        inbox: p.inbox.expect("live parked session keeps its inbox"),
        scores: p.scores.expect("live parked session keeps its score channel"),
        resume: Some(ResumeState {
            seed: p.seed,
            snapshot: p.snapshot,
            base_flits: p.flits,
            base_samples: p.samples,
        }),
    }
}

/// Inverse of [`work_from_parked`] — re-park a job whose target worker
/// turned out to be gone.
fn park_from_work(w: SessionWork, kind: RmKind, r: usize, lanes: usize) -> ParkedSession {
    let SessionWork { session, d, warmup, inbox, scores, resume } = w;
    let resume = resume.expect("re-parked work carries resume state");
    ParkedSession {
        id: session,
        kind,
        r,
        lanes,
        d,
        seed: resume.seed,
        warmup,
        snapshot: resume.snapshot,
        flits: resume.base_flits,
        samples: resume.base_samples,
        inbox: Some(inbox),
        scores: Some(scores),
        reason: ParkReason::Quarantine,
    }
}

/// Everything a resident partition worker owns for its lifetime.
struct WorkerEnv {
    id: usize,
    rm: RmKind,
    r: usize,
    seed: u64,
    hyper: DetectorHyper,
    chunk: usize,
    exec: ExecMode,
    quantize: bool,
    /// Configured lane count: each session episode rebuilds the RM with
    /// this many sub-detector lanes (clamped to the RM's ensemble size).
    lanes: usize,
    /// Resident lane workers, spawned once at server start and shared by
    /// every session episode this partition serves — lane threads live as
    /// long as the partition worker itself, never per session or burst.
    pool: Option<LanePool>,
    fpga: Option<(RuntimeHandle, Registry)>,
    dfx: DfxManager,
    dfx_cfg: DfxCfg,
    ctl: Arc<PblockCtl>,
    decoupler: Arc<Decoupler>,
    shared: Arc<Shared>,
    /// Fault-injection + recovery config; `enabled = false` keeps every
    /// fault hook out of the episode's service loop.
    faults: FaultsCfg,
    /// `sessions_per_partition` — slots this partition offers.
    capacity: usize,
    /// `idle_evict_flits` — 0 disables idle eviction.
    idle_evict: u64,
    /// `evict_quarantined` — park the session from its last checkpoint
    /// when the fault ladder quarantines this partition.
    evict_quarantined: bool,
}

fn worker_loop(env: WorkerEnv, mut scripted: Vec<ScriptedSwap>, jobs: Receiver<SessionWork>) {
    let mut next: Option<SessionWork> = None;
    loop {
        let work = match next.take() {
            Some(w) => w,
            None => match jobs.recv() {
                Ok(w) => w,
                Err(_) => break,
            },
        };
        let SessionWork { session, d, warmup, inbox, scores, resume } = work;
        let (mut outcome, parked) =
            serve_episode(&env, &mut scripted, session, d, &warmup, inbox, scores.clone(), resume);
        let live_park = parked.as_ref().map_or(false, |p| p.inbox.is_some());
        {
            let mut st = env.shared.state.lock().unwrap();
            // End-of-session boundary, atomic with the admission state:
            // once `active` is gone, `schedule_swap` refuses (it re-checks
            // under this lock), and any swap armed before that is cleared
            // here — a replacement RM staged against this session's stream
            // can never fire on the next one. Force-closing the inbox
            // unblocks a producer stuck in backpressure after the service
            // loop already ended (e.g. it failed mid-session): its next
            // send fails fast instead of waiting on a drain that will
            // never come. A live park (quarantine eviction) keeps the
            // door open — the stream continues elsewhere.
            if let Some(a) = st.active.remove(&env.id) {
                // A panic inside the inbox critical section poisoned its
                // lock; the recovery path force-closed the queue, so the
                // episode above ended with a truncated stream. Surface
                // that as a typed error on this session's outcome — the
                // partition itself carries on.
                if a.door.poisoned() && outcome.error.is_none() {
                    outcome.error = Some(ServeError::Poisoned);
                }
                if !live_park {
                    a.door.force_close();
                }
            }
            outcome.discarded_swaps += env.ctl.swap.clear_pending() as u64;
            match parked {
                Some(p) => {
                    // Not finished: no result, not counted as served.
                    if p.reason == ParkReason::Suspend {
                        st.doors.remove(&session);
                        st.placed.remove(&session);
                    }
                    env.shared.store.park(p);
                }
                None => {
                    st.doors.remove(&session);
                    st.placed.remove(&session);
                    if !st.abandoned.remove(&session) {
                        st.results.insert(session, outcome);
                        while st.results.len() > MAX_RETAINED_OUTCOMES {
                            st.results.pop_first();
                        }
                    }
                    st.served += 1;
                }
            }
            // Prefer handing a just-evicted live session to a free sibling
            // partition — "resume elsewhere".
            if live_park && !st.shutting_down {
                let target = {
                    let senders = env.shared.senders.lock().unwrap();
                    st.free
                        .iter()
                        .copied()
                        .filter(|tid| *tid != env.id)
                        .find_map(|tid| {
                            senders
                                .get(&tid)
                                .filter(|s| {
                                    s.rm == env.rm && s.r == env.r && s.lanes == env.lanes
                                })
                                .map(|s| (tid, s.jobs.clone()))
                        })
                };
                if let Some((tid, jobs_tx)) = target {
                    if let Some(p) = env.shared.store.take(session) {
                        let door = p.inbox.as_ref().expect("live park").ctl();
                        *st.admitted.entry(tid).or_insert(0) += 1;
                        st.free.remove(&tid);
                        st.placed.insert(session, tid);
                        st.active.insert(
                            tid,
                            ActiveSession {
                                session,
                                d: p.d,
                                warmup: Arc::clone(&p.warmup),
                                door,
                            },
                        );
                        if let Err(std::sync::mpsc::SendError(w)) = jobs_tx.send(work_from_parked(p))
                        {
                            // The sibling's worker died since it freed:
                            // undo the charge and leave the session parked
                            // for the next episode boundary to claim.
                            st.active.remove(&tid);
                            let n = st.admitted.entry(tid).or_insert(1);
                            *n = n.saturating_sub(1);
                            env.shared.store.park(park_from_work(w, env.rm, env.r, env.lanes));
                        }
                    }
                }
            }
            // Free this partition's slot — or claim a parked live session
            // that fits it and serve that next, skipping admission.
            let claimed = if st.shutting_down {
                None
            } else {
                env.shared
                    .store
                    .claim_where(|p| p.inbox.is_some() && p.fits(env.rm, env.r, env.lanes))
            };
            match claimed {
                Some(p) => {
                    st.placed.insert(p.id, env.id);
                    st.active.insert(
                        env.id,
                        ActiveSession {
                            session: p.id,
                            d: p.d,
                            warmup: Arc::clone(&p.warmup),
                            door: p.inbox.as_ref().expect("live park").ctl(),
                        },
                    );
                    next = Some(work_from_parked(p));
                }
                None => {
                    let n = st.admitted.entry(env.id).or_insert(1);
                    *n = n.saturating_sub(1);
                    if !st.shutting_down && *n < env.capacity {
                        st.free.insert(env.id);
                    }
                }
            }
        }
        env.shared.freed.notify_all();
        // Dropping the worker's score sender last closes the session's
        // score channel — by then the outcome is already visible, so a
        // client draining in `close()` never races the bookkeeping.
        drop(scores);
    }
}

/// Serve one session episode on this partition: fresh RM (same seed/warmup
/// recipe as the one-shot fabric) or a checkpoint restore for a resumed
/// session, scripted swaps armed, adaptive controller watching if
/// configured, then the ordinary pblock service loop until TLAST /
/// hang-up / force-close / suspend. Returns the outcome plus the parked
/// continuation when the session did not finish (suspend or quarantine
/// eviction) — the caller stores that instead of the outcome.
#[allow(clippy::too_many_arguments)]
fn serve_episode(
    env: &WorkerEnv,
    scripted: &mut Vec<ScriptedSwap>,
    session: u64,
    d: usize,
    warmup: &Arc<Vec<f32>>,
    inbox: SessionInbox,
    tx: Sender<Flit>,
    resume: Option<ResumeState>,
) -> (SessionOutcome, Option<ParkedSession>) {
    let failed = |error: ServeError| {
        (
            SessionOutcome {
                report: None,
                swap_events: Vec::new(),
                adaptive_swaps: 0,
                discarded_swaps: 0,
                fault_events: Vec::new(),
                error: Some(error),
            },
            None,
        )
    };
    let door = inbox.ctl();
    let w: &[f32] = warmup.as_slice();
    // A resumed session keeps the RM seed of the partition it started on
    // and restores its checkpointed window — that is what makes the
    // continuation bit-identical wherever it lands.
    let (seed, base_flits, base_samples, resumed_snapshot, resumed) = match resume {
        Some(r) => (r.seed, r.base_flits, r.base_samples, r.snapshot, true),
        None => (env.seed, 0, 0, None, false),
    };
    let fpga = env.fpga.as_ref().map(|(h, r)| (h, r));
    let mut rm = match LoadedRm::build(
        env.rm,
        env.r,
        d,
        seed,
        &env.hyper,
        w,
        fpga,
        env.quantize,
        env.lanes,
    ) {
        Ok(rm) => rm,
        Err(e) => return failed(ServeError::BuildRm { detail: format!("{e:#}") }),
    };
    if let Err(e) = rm.reset() {
        return failed(ServeError::ResetRm { detail: format!("{e:#}") });
    }
    if let Some(bytes) = &resumed_snapshot {
        if let Err(e) = restore_rm(&mut rm, bytes) {
            return failed(ServeError::RestoreCheckpoint { detail: format!("{e:#}") });
        }
    }
    env.ctl.swap.begin_run();
    // Scripted schedule ([fabric.dfx.swap.N]): consumed by the partition's
    // first *fresh* session, mirroring how `Fabric::new` arms it for the
    // first run — never re-armed against a resumed stream.
    if !resumed {
        for s in scripted.drain(..) {
            let staged = env.dfx.stage(
                env.id,
                s.rm,
                s.r,
                d,
                seed,
                &env.hyper,
                w,
                fpga,
                env.quantize,
                s.at_flit,
                s.dark_flits,
                env.dfx_cfg.policy,
                env.chunk,
                env.dfx_cfg.samples_per_sec,
                env.lanes,
            );
            match staged {
                Ok(swap) => env.ctl.swap.schedule(swap),
                // Mirror `Fabric::new`, which hard-fails when a scripted swap
                // cannot be staged: serving the session without it would
                // silently break the advertised Fabric::run parity. The
                // client sees the error from `close()`.
                Err(e) => {
                    return failed(ServeError::ArmScriptedSwap {
                        pblock: env.id,
                        detail: format!("{e:#}"),
                    })
                }
            }
        }
    }
    // Adaptive live DFX: one controller per adaptive session, watching this
    // partition only — it shares the same drift machinery as `Fabric::run`.
    let controller = match (env.dfx_cfg.adaptive && env.decoupler.is_enabled(), kind_of(env.rm)) {
        (true, Some(kind)) => {
            env.ctl.stats.arm(env.dfx_cfg.window, env.dfx_cfg.baseline);
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let cenv = ControllerEnv {
                dfx: env.dfx.clone(),
                cfg: env.dfx_cfg.clone(),
                hyper: env.hyper,
                chunk: env.chunk,
                quantize: env.quantize,
                fpga: env.fpga.clone(),
            };
            let targets = vec![ControllerTarget {
                pblock: env.id,
                ctl: Arc::clone(&env.ctl),
                kind,
                d,
                warmup: w.to_vec(),
                seed,
                lanes: env.lanes,
            }];
            let handle = hotswap::spawn_controller(cenv, targets, Arc::clone(&stop));
            Some((stop, handle))
        }
        _ => None,
    };
    // Fault campaign, per session: arm the per-flit hooks, schedule this
    // partition's scripted injections (an open-ended session has no flit
    // horizon, so rate-based injections only apply to `Fabric::run`), and
    // watch the episode with a single-target supervisor running the same
    // retry → reload → quarantine ladder as the one-shot fabric. Spawned
    // after every early return above so the thread can never leak.
    let fault_supervisor = if env.faults.enabled {
        env.ctl.health.arm(env.faults.checkpoint_every_flits, env.faults.reload_wait_ms);
        env.ctl.faults.bind(env.id);
        env.ctl.faults.clear_pending();
        env.ctl.checkpoint.clear();
        match FaultInjector::plan(&env.faults, env.seed, &[env.id], 0) {
            Ok(plan) => env
                .ctl
                .faults
                .schedule(plan.into_iter().filter(|f| f.pblock == env.id).collect()),
            Err(e) => {
                env.ctl.health.disarm();
                // Stop the adaptive controller before bailing so the
                // thread never outlives its episode.
                if let Some((stop, handle)) = controller {
                    stop.store(true, std::sync::atomic::Ordering::SeqCst);
                    let _ = handle.join();
                }
                return failed(ServeError::PlanFaults { detail: format!("{e:#}") });
            }
        }
        if let Some(pool) = env.pool.as_ref() {
            pool.arm_faults();
        }
        kind_of(env.rm).map(|kind| {
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let senv = SupervisorEnv {
                dfx: env.dfx.clone(),
                faults: env.faults.clone(),
                hyper: env.hyper,
                chunk: env.chunk,
                samples_per_sec: env.dfx_cfg.samples_per_sec,
                policy: env.dfx_cfg.policy,
            };
            let targets = vec![SupervisorTarget {
                pblock: env.id,
                ctl: Arc::clone(&env.ctl),
                decoupler: Arc::clone(&env.decoupler),
                kind,
                r: env.r,
                d,
                seed,
                warmup: w.to_vec(),
                lanes: env.lanes,
                quantize: env.quantize,
            }];
            let handle = supervisor::spawn_supervisor(senv, targets, Arc::clone(&stop));
            (stop, handle)
        })
    } else {
        None
    };
    // Quarantine eviction: let the service loop *return* on rung 2
    // instead of draining the rest of the stream, so this episode can
    // park the session from its last healthy checkpoint.
    let evictable = env.evict_quarantined
        && env.faults.enabled
        && env.fpga.is_none()
        && matches!(env.rm, RmKind::Detector(_));
    if evictable {
        env.ctl.evict_on_quarantine.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    // Durable score sink: a relay thread appends each score chunk before
    // forwarding it to the client, so a record is on its way to disk no
    // later than the client can observe the score. Zero cost when no sink
    // is configured — the service loop keeps the direct sender.
    let (service_tx, relay) = if env.shared.sink.is_some() {
        let (mid_tx, mid_rx) = Port::link();
        let shared = Arc::clone(&env.shared);
        let client = tx.clone();
        let relay = std::thread::spawn(move || {
            let mut vals = Vec::new();
            for flit in mid_rx {
                vals.clear();
                unpad_into(&flit, &mut vals);
                if let Some(sink) = shared.sink.as_ref() {
                    // A sink write failure must not kill the stream; the
                    // recovery scan simply ends at the last good frame.
                    let _ = sink.lock().unwrap().append(session, flit.seq, &vals);
                }
                let _ = client.send(flit);
            }
        });
        (mid_tx, Some(relay))
    } else {
        (tx.clone(), None)
    };
    let served = Pblock::service_mode(
        &mut rm,
        &env.decoupler,
        &env.ctl,
        inbox,
        service_tx,
        env.exec,
        env.pool.as_ref(),
    );
    if let Some(h) = relay {
        // The service loop dropped its sender; join so every score of this
        // episode is appended before the outcome becomes visible.
        let _ = h.join();
    }
    if evictable {
        env.ctl.evict_on_quarantine.store(false, std::sync::atomic::Ordering::SeqCst);
    }
    // Captured before the fault teardown below lifts the quarantine and
    // clears the checkpoint slot.
    let was_quarantined = env.decoupler.is_quarantined();
    let last_checkpoint =
        if evictable && was_quarantined { env.ctl.checkpoint.latest() } else { None };
    let adaptive_swaps = match controller {
        Some((stop, handle)) => {
            stop.store(true, std::sync::atomic::Ordering::SeqCst);
            handle.join().unwrap_or(0)
        }
        None => 0,
    };
    if let Some((stop, handle)) = fault_supervisor {
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = handle.join();
    }
    let mut fault_events = Vec::new();
    if env.faults.enabled {
        // Session boundary: collect the fault log, disarm the hooks and
        // drop the episode's checkpoints. A quarantine is lifted here —
        // the next session builds a fresh RM, so the region is trusted
        // again (mirroring a full reconfiguration of the partition).
        fault_events = env.ctl.faults.take_events();
        fault_events.sort_by_key(|e| e.at_flit);
        env.ctl.health.disarm();
        env.ctl.checkpoint.clear();
        if env.decoupler.is_quarantined() {
            env.decoupler.lift_quarantine();
        }
    }
    if env.ctl.stats.is_armed() {
        env.ctl.stats.disarm();
    }
    // Swaps still pending are cleared by the caller inside the admission
    // lock (atomic with removing the active-session entry), so a racing
    // `schedule_swap` can never leak a stale RM into the next session.
    let swap_events = env.ctl.swap.take_events();
    // Park the continuation when the session did not finish here.
    let mut parked: Option<ParkedSession> = None;
    if let Ok(report) = served.as_ref() {
        if let Some(cp) = last_checkpoint {
            // Quarantine eviction: resume elsewhere from the last healthy
            // checkpoint (PR-6 reload semantics — state rolls back to the
            // checkpoint; scores already emitted are not recalled). The
            // inbox stays live, so the client's push never notices.
            parked = Some(ParkedSession {
                id: session,
                kind: env.rm,
                r: env.r,
                lanes: env.lanes,
                d,
                seed,
                warmup: Arc::clone(warmup),
                snapshot: Some(cp.bytes),
                flits: base_flits + cp.flit,
                samples: base_samples + cp.samples,
                inbox: Some(door.reopen()),
                scores: Some(tx.clone()),
                reason: ParkReason::Quarantine,
            });
        } else if door.suspend_requested() {
            let snapshot = snapshot_rm(&rm);
            if snapshot.is_none() && matches!(env.rm, RmKind::Detector(_)) {
                return failed(ServeError::NoSnapshot { op: SnapshotOp::Suspend });
            }
            parked = Some(ParkedSession {
                id: session,
                kind: env.rm,
                r: env.r,
                lanes: env.lanes,
                d,
                seed,
                warmup: Arc::clone(warmup),
                snapshot,
                flits: base_flits + report.flits_in,
                samples: base_samples + report.samples,
                inbox: None,
                scores: None,
                reason: ParkReason::Suspend,
            });
        }
    }
    let outcome = match served {
        Ok(mut report) => {
            // Whole-session cursor for resumed streams.
            report.flits_in += base_flits;
            report.samples += base_samples;
            SessionOutcome {
                report: Some(report),
                swap_events,
                adaptive_swaps,
                discarded_swaps: 0,
                fault_events,
                error: None,
            }
        }
        Err(e) => SessionOutcome {
            report: None,
            swap_events,
            adaptive_swaps,
            discarded_swaps: 0,
            fault_events,
            error: Some(ServeError::Service { detail: format!("{e:#}") }),
        },
    };
    (outcome, parked)
}

// ---------------------------------------------------------------------------
// Partition multiplexer
// ---------------------------------------------------------------------------

/// One tenant of a multiplexed partition.
struct MuxSlot {
    session: u64,
    d: usize,
    warmup: Arc<Vec<f32>>,
    inbox: SessionInbox,
    scores: Sender<Flit>,
    /// RM seed the session started under (its origin partition).
    seed: u64,
    /// Window snapshot while this slot's state is swapped out of the
    /// resident RM.
    state: Option<Vec<u8>>,
    flits: u64,
    samples: u64,
    flits_out: u64,
    busy_secs: f64,
    /// Multiplexer tick of the slot's last processed flit (LRU key).
    last_active: u64,
}

fn slot_from_work(w: SessionWork, env_seed: u64, tick: u64) -> MuxSlot {
    let SessionWork { session, d, warmup, inbox, scores, resume } = w;
    let (seed, state, flits, samples) = match resume {
        Some(r) => (r.seed, r.snapshot, r.base_flits, r.base_samples),
        None => (env_seed, None, 0, 0),
    };
    MuxSlot {
        session,
        d,
        warmup,
        inbox,
        scores,
        seed,
        state,
        flits,
        samples,
        flits_out: 0,
        busy_secs: 0.0,
        last_active: tick,
    }
}

fn slot_from_parked(p: ParkedSession, tick: u64) -> MuxSlot {
    MuxSlot {
        session: p.id,
        d: p.d,
        warmup: p.warmup,
        inbox: p.inbox.expect("re-attached session keeps its inbox"),
        scores: p.scores.expect("re-attached session keeps its score channel"),
        seed: p.seed,
        state: p.snapshot,
        flits: p.flits,
        samples: p.samples,
        flits_out: 0,
        busy_secs: 0.0,
        last_active: tick,
    }
}

/// Swap the resident RM over to `slots[idx]`'s session: snapshot the
/// currently loaded session's window state into its slot, rebuild the RM
/// with the target session's (d, seed, warmup) and restore its state.
fn mux_switch(
    env: &WorkerEnv,
    rm: &mut Option<LoadedRm>,
    loaded: &mut Option<u64>,
    slots: &mut [MuxSlot],
    idx: usize,
) -> Result<(), ServeError> {
    if let (Some(pid), Some(prm)) = (loaded.as_ref(), rm.as_ref()) {
        if let Some(prev) = slots.iter_mut().find(|s| s.session == *pid) {
            match snapshot_rm(prm) {
                Some(bytes) => prev.state = Some(bytes),
                None => return Err(ServeError::NoSnapshot { op: SnapshotOp::Switch }),
            }
        }
    }
    *rm = None;
    *loaded = None;
    let (d, seed, warmup) = {
        let s = &slots[idx];
        (s.d, s.seed, Arc::clone(&s.warmup))
    };
    let mut built = match LoadedRm::build(
        env.rm,
        env.r,
        d,
        seed,
        &env.hyper,
        warmup.as_slice(),
        None,
        env.quantize,
        env.lanes,
    ) {
        Ok(b) => b,
        Err(e) => return Err(ServeError::BuildRm { detail: format!("{e:#}") }),
    };
    if let Err(e) = built.reset() {
        return Err(ServeError::ResetRm { detail: format!("{e:#}") });
    }
    if let Some(bytes) = slots[idx].state.take() {
        if let Err(e) = restore_rm(&mut built, &bytes) {
            return Err(ServeError::RestoreState { detail: format!("{e:#}") });
        }
    }
    *rm = Some(built);
    *loaded = Some(slots[idx].session);
    Ok(())
}

/// Retire a multiplexed session: store its outcome, give the slot back.
fn mux_finish(env: &WorkerEnv, slot: MuxSlot, error: Option<ServeError>) {
    let MuxSlot { session, flits, samples, flits_out, busy_secs, scores, inbox, .. } = slot;
    // Same poison boundary as the dedicated path in `worker_loop`: a
    // panic inside this tenant's inbox becomes a typed error on this
    // session only; the multiplexer keeps serving its other tenants.
    let error = match error {
        None if inbox.poisoned() => Some(ServeError::Poisoned),
        e => e,
    };
    drop(inbox);
    let outcome = SessionOutcome {
        report: if error.is_none() {
            Some(PblockReport { flits_in: flits, flits_out, samples, busy_secs })
        } else {
            None
        },
        swap_events: Vec::new(),
        adaptive_swaps: 0,
        discarded_swaps: 0,
        fault_events: Vec::new(),
        error,
    };
    {
        let mut st = env.shared.state.lock().unwrap();
        st.doors.remove(&session);
        st.placed.remove(&session);
        if !st.abandoned.remove(&session) {
            st.results.insert(session, outcome);
            while st.results.len() > MAX_RETAINED_OUTCOMES {
                st.results.pop_first();
            }
        }
        let n = st.admitted.entry(env.id).or_insert(1);
        *n = n.saturating_sub(1);
        if !st.shutting_down && *n < env.capacity {
            st.free.insert(env.id);
        }
        st.served += 1;
    }
    env.shared.freed.notify_all();
    // Senders drop after the outcome is visible — a client draining in
    // `close()` never races the bookkeeping.
    drop(scores);
}

/// Park a multiplexed session into the store. Idle parks are transparent
/// (the live channels ride along); a suspend park leaves only the
/// checkpoint for the client's ticket.
fn mux_park(env: &WorkerEnv, slot: MuxSlot, state: Option<Vec<u8>>, reason: ParkReason) {
    let MuxSlot { session, d, warmup, inbox, scores, seed, flits, samples, .. } = slot;
    let transparent = reason == ParkReason::Idle;
    let (park_inbox, park_scores, held) = if transparent {
        (Some(inbox), Some(scores), None)
    } else {
        (None, None, Some((inbox, scores)))
    };
    let parked = ParkedSession {
        id: session,
        kind: env.rm,
        r: env.r,
        lanes: env.lanes,
        d,
        seed,
        warmup,
        snapshot: state,
        flits,
        samples,
        inbox: park_inbox,
        scores: park_scores,
        reason,
    };
    {
        let mut st = env.shared.state.lock().unwrap();
        env.shared.store.park(parked);
        if !transparent {
            st.doors.remove(&session);
            st.placed.remove(&session);
        }
        let n = st.admitted.entry(env.id).or_insert(1);
        *n = n.saturating_sub(1);
        if !st.shutting_down && *n < env.capacity {
            st.free.insert(env.id);
        }
    }
    env.shared.freed.notify_all();
    // For a suspend park the dead channels drop only now, after the store
    // entry is visible — `Session::suspend` keys off one or the other.
    drop(held);
}

/// Resident worker for a multiplexed partition: up to `capacity` sessions
/// share the one RM, their window state swapped through the snapshot
/// codec between inbox drains. Idle sessions are parked into the session
/// store after `idle_evict` ticks and re-attached when their inbox stirs.
fn mux_loop(env: WorkerEnv, jobs: Receiver<SessionWork>) {
    use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
    let cap = env.capacity.max(1);
    let mut slots: Vec<MuxSlot> = Vec::new();
    let mut rm: Option<LoadedRm> = None;
    let mut loaded: Option<u64> = None;
    let mut tick: u64 = 0;
    let mut disconnected = false;
    loop {
        // Fresh admissions (already charged against this partition's
        // capacity by the admission path).
        while slots.len() < cap && !disconnected {
            match jobs.try_recv() {
                Ok(w) => slots.push(slot_from_work(w, env.seed, tick)),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => disconnected = true,
            }
        }
        // Re-attach parked sessions whose inbox has stirred and that fit
        // this partition's layout.
        while slots.len() < cap {
            let claimed = {
                let mut st = env.shared.state.lock().unwrap();
                if st.shutting_down
                    || st.admitted.get(&env.id).copied().unwrap_or(0) >= cap
                {
                    None
                } else {
                    let p = env.shared.store.claim_where(|p| {
                        p.inbox.is_some()
                            && p.fits(env.rm, env.r, env.lanes)
                            && p.inbox.as_ref().unwrap().probe().stirring()
                    });
                    if let Some(p) = p.as_ref() {
                        let n = st.admitted.entry(env.id).or_insert(0);
                        *n += 1;
                        if *n >= cap {
                            st.free.remove(&env.id);
                        }
                        st.placed.insert(p.id, env.id);
                    }
                    p
                }
            };
            match claimed {
                Some(p) => slots.push(slot_from_parked(p, tick)),
                None => break,
            }
        }
        if slots.is_empty() {
            if disconnected {
                break;
            }
            match jobs.recv_timeout(Duration::from_millis(2)) {
                Ok(w) => slots.push(slot_from_work(w, env.seed, tick)),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
            continue;
        }
        // One sweep: drain each slot's queued flits through the resident
        // RM; then decide whether the slot finishes, parks or stays.
        enum End {
            Finish(Option<ServeError>),
            Park(ParkReason),
        }
        let mut progress = false;
        let mut idx = 0;
        while idx < slots.len() {
            let mut end: Option<End> = None;
            while let Some(f) = slots[idx].inbox.try_recv_flit() {
                progress = true;
                tick += 1;
                slots[idx].last_active = tick;
                slots[idx].flits += 1;
                if loaded != Some(slots[idx].session) {
                    if let Err(e) = mux_switch(&env, &mut rm, &mut loaded, &mut slots, idx) {
                        end = Some(End::Finish(Some(e)));
                        break;
                    }
                }
                let last = f.last;
                let n_valid = f.n_valid as u64;
                let t0 = Instant::now();
                let out = rm.as_mut().expect("state just switched in").process(&f, env.pool.as_ref());
                slots[idx].busy_secs += t0.elapsed().as_secs_f64();
                match out {
                    Ok(Some(out)) => {
                        slots[idx].samples += n_valid;
                        if let Some(sink) = env.shared.sink.as_ref() {
                            let mut vals = Vec::new();
                            unpad_into(&out, &mut vals);
                            let _ =
                                sink.lock().unwrap().append(slots[idx].session, out.seq, &vals);
                        }
                        slots[idx].flits_out += 1;
                        let _ = slots[idx].scores.send(out);
                    }
                    Ok(None) => {
                        slots[idx].samples += n_valid;
                    }
                    Err(e) => {
                        end = Some(End::Finish(Some(ServeError::Service {
                            detail: format!("{e:#}"),
                        })));
                        break;
                    }
                }
                if last {
                    end = Some(End::Finish(None));
                    break;
                }
            }
            if end.is_none() {
                let pr = slots[idx].inbox.probe();
                if pr.force_closed {
                    end = Some(End::Finish(None));
                } else if pr.queued == 0 && pr.suspended {
                    end = Some(End::Park(ParkReason::Suspend));
                } else if pr.queued == 0 && pr.producer_done {
                    end = Some(End::Finish(None));
                } else if env.idle_evict > 0
                    && pr.queued == 0
                    && tick.saturating_sub(slots[idx].last_active) >= env.idle_evict
                {
                    end = Some(End::Park(ParkReason::Idle));
                }
            }
            match end {
                Some(End::Finish(error)) => {
                    let slot = slots.remove(idx);
                    if loaded == Some(slot.session) {
                        loaded = None;
                        rm = None;
                    }
                    mux_finish(&env, slot, error);
                }
                Some(End::Park(reason)) => {
                    let mut slot = slots.remove(idx);
                    let state = if loaded == Some(slot.session) {
                        let bytes = rm.as_ref().and_then(snapshot_rm);
                        loaded = None;
                        rm = None;
                        bytes
                    } else {
                        slot.state.take()
                    };
                    if state.is_none() && slot.flits > 0 && matches!(env.rm, RmKind::Detector(_))
                    {
                        // A detector that has scored flits but exposes no
                        // window snapshot cannot be parked losslessly.
                        mux_finish(
                            &env,
                            slot,
                            Some(ServeError::NoSnapshot { op: SnapshotOp::Park }),
                        );
                    } else {
                        mux_park(&env, slot, state, reason);
                    }
                }
                None => idx += 1,
            }
        }
        // The tick also advances on idle sweeps, so idle eviction fires
        // for a silent fleet too (time-like, not only traffic-like).
        if !progress {
            tick += 1;
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    // Shutdown teardown: remaining slots were force-closed.
    for slot in slots.drain(..) {
        mux_finish(&env, slot, None);
    }
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

struct PartitionHandle {
    rm: RmKind,
    /// Ensemble size the partition was configured with (resume eligibility
    /// is keyed on the full (rm, r, lanes) layout).
    r: usize,
    /// Configured lane count (replacement RMs staged by `schedule_swap`
    /// keep the partition's lane layout).
    lanes: usize,
    /// Job queue into the resident worker; mutexed because `std` senders
    /// are not `Sync` and `open` is called from many client threads.
    jobs: Mutex<Sender<SessionWork>>,
    ctl: Arc<PblockCtl>,
    decoupler: Arc<Decoupler>,
}

/// Summary returned by [`FabricServer::shutdown`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerReport {
    /// Sessions fully served over the server's lifetime.
    pub sessions_served: u64,
}

/// A persistent, multi-session streaming service over the fabric's pblock
/// partitions. See the module docs for the session lifecycle.
pub struct FabricServer {
    cfg: FseadConfig,
    runtime: Option<Mutex<Runtime>>,
    shared: Arc<Shared>,
    partitions: BTreeMap<usize, PartitionHandle>,
    workers: Vec<JoinHandle<()>>,
}

/// What a client wants from [`FabricServer::open`].
#[derive(Clone, Debug)]
pub struct SessionSpec {
    /// Stream dimensionality.
    pub d: usize,
    /// Row-major `[n, d]` warm-up prefix for detector parameter ranges —
    /// pass the same prefix `Fabric::new` would see (`Dataset::warmup`) for
    /// bit-identical scores.
    pub warmup: Vec<f32>,
    /// Pin the session to one partition (1-based pblock id); `None` takes
    /// any free partition.
    pub pblock: Option<usize>,
}

impl SessionSpec {
    pub fn new(d: usize, warmup: Vec<f32>) -> SessionSpec {
        SessionSpec { d, warmup, pblock: None }
    }

    /// Spec for streaming `ds` — warm-up mirrors what `Fabric::new` uses.
    pub fn for_dataset(ds: &Dataset, window: usize) -> SessionSpec {
        SessionSpec::new(ds.d, ds.warmup(window).to_vec())
    }

    pub fn on_pblock(mut self, id: usize) -> SessionSpec {
        self.pblock = Some(id);
        self
    }
}

impl FabricServer {
    /// Start the server: one resident service worker per configured
    /// (non-empty) pblock. The fabric stays up until [`FabricServer::shutdown`]
    /// or drop.
    pub fn start(cfg: FseadConfig) -> Result<FabricServer> {
        cfg.validate()?;
        if !cfg.combos.is_empty() {
            bail!(
                "fabric::server serves the Fig 7(a) multi-stream pattern (direct pblock→host \
                 routes); combo joins are not supported — drop the [combo.N] sections"
            );
        }
        let active: Vec<_> = cfg.pblocks.iter().filter(|p| p.rm != RmKind::Empty).collect();
        if active.is_empty() {
            bail!("no pblocks configured — nothing to serve");
        }
        let runtime = if cfg.use_fpga {
            Some(Runtime::start(&cfg.artifact_dir).context("starting PJRT runtime")?)
        } else {
            None
        };
        let sink = match cfg.server.sink_path.as_deref() {
            Some(path) => Some(Mutex::new(
                ScoreSink::open(std::path::Path::new(path), cfg.server.sink_fsync_records)
                    .context("opening the score sink")?,
            )),
            None => None,
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(AdmissionState {
                free: active.iter().map(|p| p.id).collect(),
                // Distinct bases keep session ids globally unique across a
                // router's worker fleet, so consistent hashing and resume
                // duplicate detection never collide between processes.
                next_session: cfg.server.session_id_base,
                ..Default::default()
            }),
            freed: Condvar::new(),
            store: SessionStore::default(),
            sink,
            spill_dir: cfg.server.spill_dir.clone().map(PathBuf::from),
            senders: Mutex::new(BTreeMap::new()),
        });
        // Lifecycle mode (multiplexing and/or idle eviction) swaps in the
        // snapshot-switching worker; otherwise partitions run the dedicated
        // per-session episode loop, bit-transparent to earlier releases.
        let mux = cfg.server.sessions_per_partition > 1 || cfg.server.idle_evict_flits > 0;
        let mut partitions = BTreeMap::new();
        let mut workers = Vec::new();
        for p in &active {
            let ctl = Arc::new(PblockCtl::default());
            // Seed the live-tuning cell from the config so the operator
            // plane reads (and adjusts) the real thresholds from the start.
            ctl.tuning.seed(&cfg.dfx);
            let decoupler = Arc::new(Decoupler::new());
            let (jobs_tx, jobs_rx) = std::sync::mpsc::channel::<SessionWork>();
            let scripted: Vec<ScriptedSwap> =
                cfg.dfx.swaps.iter().filter(|s| s.pblock == p.id).copied().collect();
            // The configured lane count is staged as-is (each RM build
            // clamps to its own ensemble size — identical to the one-shot
            // fabric, keeping server-vs-fabric swaps bit-identical); only
            // the pool is sized by the partition's initial r.
            let lanes = cfg.lanes_for(p);
            let pool_size = lanes.min(p.r.max(1));
            // Lane workers are resident: spawned here, once per partition,
            // before the first session, and reused by every episode.
            let pool = (!cfg.use_fpga && pool_size > 1 && matches!(p.rm, RmKind::Detector(_)))
                .then(|| LanePool::new(pool_size));
            let env = WorkerEnv {
                id: p.id,
                rm: p.rm,
                r: p.r,
                seed: pblock_seed(cfg.seed, p.id),
                hyper: cfg.hyper,
                chunk: cfg.chunk,
                exec: cfg.exec,
                quantize: cfg.use_fpga,
                lanes,
                pool,
                fpga: runtime.as_ref().map(|rt| (rt.handle(), rt.registry().clone())),
                dfx: DfxManager::default(),
                dfx_cfg: cfg.dfx.clone(),
                ctl: Arc::clone(&ctl),
                decoupler: Arc::clone(&decoupler),
                shared: Arc::clone(&shared),
                faults: cfg.faults.clone(),
                capacity: cfg.server.sessions_per_partition.max(1),
                idle_evict: cfg.server.idle_evict_flits,
                evict_quarantined: cfg.server.evict_quarantined,
            };
            shared.senders.lock().unwrap().insert(
                p.id,
                PartitionSender { rm: p.rm, r: p.r, lanes, jobs: jobs_tx.clone() },
            );
            let handle = std::thread::Builder::new()
                .name(format!("serve-p{}", p.id))
                .spawn(move || {
                    if mux {
                        mux_loop(env, jobs_rx)
                    } else {
                        worker_loop(env, scripted, jobs_rx)
                    }
                })
                .expect("spawn partition worker");
            partitions.insert(
                p.id,
                PartitionHandle {
                    rm: p.rm,
                    r: p.r,
                    lanes,
                    jobs: Mutex::new(jobs_tx),
                    ctl,
                    decoupler: Arc::clone(&decoupler),
                },
            );
            workers.push(handle);
        }
        Ok(FabricServer { cfg, runtime: runtime.map(Mutex::new), shared, partitions, workers })
    }

    pub fn config(&self) -> &FseadConfig {
        &self.cfg
    }

    /// Served partition ids, in pblock order.
    pub fn partitions(&self) -> Vec<usize> {
        self.partitions.keys().copied().collect()
    }

    /// RM kind configured for partition `id`.
    pub fn partition_rm(&self, id: usize) -> Option<RmKind> {
        self.partitions.get(&id).map(|p| p.rm)
    }

    /// The partition's decoupler (isolation control, as on [`super::Fabric`]).
    pub fn decoupler(&self, id: usize) -> Option<&Arc<Decoupler>> {
        self.partitions.get(&id).map(|p| &p.decoupler)
    }

    /// True when partitions run the multiplexing worker (multiple sessions
    /// per partition and/or idle eviction configured).
    fn mux(&self) -> bool {
        self.cfg.server.sessions_per_partition > 1 || self.cfg.server.idle_evict_flits > 0
    }

    /// Open a session, blocking in the admission queue while every eligible
    /// partition slot is busy. Fails once `max_waiters` clients are already
    /// queued, after `open_timeout_ms` (when set), immediately under
    /// `overload = "shed"`, or at shutdown — all as a typed [`AdmitError`].
    pub fn open(&self, spec: SessionSpec) -> Result<Session> {
        Ok(self.open_inner(spec, true)?.expect("blocking open returns a session"))
    }

    /// Non-blocking open: `Ok(None)` when no eligible partition slot is free.
    pub fn try_open(&self, spec: SessionSpec) -> Result<Option<Session>> {
        self.open_inner(spec, false)
    }

    fn open_inner(&self, spec: SessionSpec, block: bool) -> Result<Option<Session>> {
        if spec.d == 0 {
            bail!("session dimensionality must be > 0");
        }
        if spec.warmup.len() % spec.d != 0 {
            bail!(
                "warmup length {} is not a whole number of samples (d = {})",
                spec.warmup.len(),
                spec.d
            );
        }
        if let Some(id) = spec.pblock {
            if !self.partitions.contains_key(&id) {
                bail!("no served partition {id}");
            }
        }
        let (st, id) = match self.admit(spec.pblock, block)? {
            Some(granted) => granted,
            None => return Ok(None),
        };
        Ok(Some(self.install(st, id, spec.d, Arc::new(spec.warmup), None)?))
    }

    /// Claim a slot on an eligible partition: the admission wait loop with
    /// queue bound, deadline and overload shedding. On success the slot is
    /// already charged against the partition's capacity; the state guard is
    /// returned so the caller installs the session in the same critical
    /// section.
    fn admit(
        &self,
        pblock: Option<usize>,
        block: bool,
    ) -> Result<Option<(std::sync::MutexGuard<'_, AdmissionState>, usize)>> {
        let capacity = self.cfg.server.sessions_per_partition.max(1);
        let deadline = (self.cfg.server.open_timeout_ms > 0)
            .then(|| Instant::now() + Duration::from_millis(self.cfg.server.open_timeout_ms));
        let t0 = Instant::now();
        let mut st = self.shared.state.lock().unwrap();
        let mut waiting = false;
        let id = loop {
            if st.shutting_down {
                if waiting {
                    st.waiters -= 1;
                }
                return Err(AdmitError::ShuttingDown.into());
            }
            let pick = match pblock {
                Some(id) => st.free.contains(&id).then_some(id),
                None => st.free.first().copied(),
            };
            if let Some(id) = pick {
                if waiting {
                    st.waiters -= 1;
                }
                let n = st.admitted.entry(id).or_insert(0);
                *n += 1;
                if *n >= capacity {
                    st.free.remove(&id);
                }
                break id;
            }
            if !block {
                return Ok(None);
            }
            if self.cfg.server.overload == OverloadPolicy::Shed {
                if waiting {
                    st.waiters -= 1;
                }
                return Err(AdmitError::Saturated.into());
            }
            if !waiting {
                if st.waiters >= self.cfg.server.max_waiters {
                    return Err(AdmitError::QueueFull { waiters: st.waiters }.into());
                }
                st.waiters += 1;
                waiting = true;
            }
            st = match deadline {
                None => self.shared.freed.wait(st).unwrap(),
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        st.waiters -= 1;
                        return Err(AdmitError::Timeout {
                            waited_ms: t0.elapsed().as_millis() as u64,
                        }
                        .into());
                    }
                    self.shared.freed.wait_timeout(st, deadline - now).unwrap().0
                }
            };
        };
        Ok(Some((st, id)))
    }

    /// Wire a session onto an already-charged partition slot: inbox, score
    /// channel and worker job, with rollback when the worker is gone.
    fn install(
        &self,
        mut st: std::sync::MutexGuard<'_, AdmissionState>,
        id: usize,
        d: usize,
        warmup: Arc<Vec<f32>>,
        resume: Option<(u64, ResumeState)>,
    ) -> Result<Session> {
        let session = match resume.as_ref() {
            Some((sid, _)) => {
                st.next_session = st.next_session.max(sid + 1);
                *sid
            }
            None => {
                let s = st.next_session;
                st.next_session += 1;
                s
            }
        };
        let (inbox_tx, inbox_rx) = SessionInbox::bounded(self.cfg.server.inbox_flits);
        let door = inbox_rx.ctl();
        // The dedicated worker serves one session per partition and keys
        // `schedule_swap` off `active`; multiplexed partitions track their
        // tenants through `doors` + per-partition admitted counts instead.
        if !self.mux() {
            st.active.insert(
                id,
                ActiveSession { session, d, warmup: Arc::clone(&warmup), door: door.clone() },
            );
        }
        st.doors.insert(session, door.clone());
        st.placed.insert(session, id);
        drop(st);
        let (score_tx, score_rx) = Port::link();
        let work = SessionWork {
            session,
            d,
            warmup,
            inbox: inbox_rx,
            scores: score_tx,
            resume: resume.map(|(_, r)| r),
        };
        let sent = self.partitions[&id].jobs.lock().unwrap().send(work).is_ok();
        if !sent {
            // Worker is gone (panicked): the partition is out of service.
            let mut st = self.shared.state.lock().unwrap();
            st.active.remove(&id);
            st.doors.remove(&session);
            st.placed.remove(&session);
            let n = st.admitted.entry(id).or_insert(1);
            *n = n.saturating_sub(1);
            bail!("partition {id}: service worker has exited");
        }
        Ok(Session {
            id: session,
            pblock: id,
            d,
            chunk: self.cfg.chunk,
            tx: Some(inbox_tx),
            rx: score_rx,
            door,
            seq: 0,
            pushed: 0,
            staged: Vec::new(),
            full_mask: vec![1.0f32; self.cfg.chunk].into(),
            shared: Arc::clone(&self.shared),
            finished: false,
        })
    }

    /// Resume a suspended session from its [`SessionTicket`] — on this
    /// server or a fresh one built from the same config. The session keeps
    /// its id, stream cursor (flit/sample counts, staged tail) and detector
    /// window state; subsequent scores are bit-identical to a session that
    /// was never suspended.
    pub fn resume(&self, ticket: SessionTicket) -> Result<Session> {
        if ticket.d == 0 {
            bail!("resume: ticket dimensionality must be > 0");
        }
        if ticket.staged.len() % ticket.d != 0 {
            bail!(
                "resume: staged tail of {} values is not a whole number of samples (d = {})",
                ticket.staged.len(),
                ticket.d
            );
        }
        // The ticket must land on a partition with the exact layout it was
        // checkpointed under — the snapshot codec restores state, not shape.
        let eligible: BTreeSet<usize> = self
            .partitions
            .iter()
            .filter(|(_, p)| p.rm == ticket.kind && p.r == ticket.r && p.lanes == ticket.lanes)
            .map(|(id, _)| *id)
            .collect();
        if eligible.is_empty() {
            return Err(ConfigMismatch(format!(
                "resume: no served partition matches the ticket's layout \
                 (rm {:?}, r {}, lanes {})",
                ticket.kind, ticket.r, ticket.lanes
            ))
            .into());
        }
        let pick = {
            let st = self.shared.state.lock().unwrap();
            st.free.iter().find(|id| eligible.contains(*id)).copied()
        };
        if pick.is_none() {
            bail!("resume: every eligible partition slot is busy — retry once one frees");
        }
        // Re-admit through the normal path pinned to the picked partition so
        // capacity charging stays in one place.
        let (st, id) = match self.admit(pick, false)? {
            Some(granted) => granted,
            None => bail!("resume: every eligible partition slot is busy — retry once one frees"),
        };
        if st.doors.contains_key(&ticket.id) || self.shared.store.contains(ticket.id) {
            // Roll the slot charge back before refusing the duplicate.
            let mut st = st;
            let capacity = self.cfg.server.sessions_per_partition.max(1);
            let n = st.admitted.entry(id).or_insert(1);
            *n = n.saturating_sub(1);
            if !st.shutting_down && *n < capacity {
                st.free.insert(id);
            }
            drop(st);
            self.shared.freed.notify_all();
            bail!("resume: session {} is already live on this server", ticket.id);
        }
        let resume = ResumeState {
            seed: ticket.seed,
            snapshot: ticket.snapshot.clone(),
            base_flits: ticket.flits,
            base_samples: ticket.samples,
        };
        let mut session = self.install(
            st,
            id,
            ticket.d,
            Arc::new(ticket.warmup.clone()),
            Some((ticket.id, resume)),
        )?;
        session.seq = ticket.seq;
        session.pushed = ticket.pushed;
        session.staged = ticket.staged;
        Ok(session)
    }

    /// Resume a session whose ticket was spilled to `[fabric.server]`
    /// `spill_dir` (by `Session::suspend`). The spill file is removed once
    /// the session is live again.
    pub fn resume_spilled(&self, session: u64) -> Result<Session> {
        let dir = self
            .shared
            .spill_dir
            .as_deref()
            .context("resume_spilled: no [fabric.server] spill_dir configured")?;
        let ticket = SessionTicket::load(dir, session)?;
        let path = SessionTicket::spill_path(dir, session);
        let live = self.resume(ticket)?;
        let _ = std::fs::remove_file(path);
        Ok(live)
    }

    /// Arm an in-flight RM swap on partition `id` at session-input flit
    /// `at_flit` of its **active** session — the server-side counterpart of
    /// [`super::Fabric::schedule_swap`], staged against the live session's
    /// stream. Returns (modelled download ms, dark-window flits).
    pub fn schedule_swap(
        &self,
        id: usize,
        at_flit: u64,
        rm: RmKind,
        r: usize,
        dark_flits: Option<u64>,
    ) -> Result<(f64, u64)> {
        let part = self
            .partitions
            .get(&id)
            .with_context(|| format!("no served partition {id}"))?;
        if self.mux() {
            bail!(
                "pblock {id}: in-flight swaps need a dedicated partition — disable \
                 [fabric.server] sessions_per_partition / idle_evict_flits"
            );
        }
        if !part.decoupler.is_enabled() {
            bail!("pblock {id}: decoupler is disabled — cannot hot-swap without isolation");
        }
        let (session, d, warmup) = {
            let st = self.shared.state.lock().unwrap();
            let a = st.active.get(&id).with_context(|| {
                format!("pblock {id} has no active session — swaps are staged against a live stream")
            })?;
            (a.session, a.d, Arc::clone(&a.warmup))
        };
        let fpga = self.runtime.as_ref().map(|rt| {
            let rt = rt.lock().unwrap();
            (rt.handle(), rt.registry().clone())
        });
        let swap = DfxManager::default().stage(
            id,
            rm,
            r,
            d,
            pblock_seed(self.cfg.seed, id),
            &self.cfg.hyper,
            &warmup,
            fpga.as_ref().map(|(h, reg)| (h, reg)),
            self.cfg.use_fpga,
            at_flit,
            dark_flits,
            self.cfg.dfx.policy,
            self.cfg.chunk,
            self.cfg.dfx.samples_per_sec,
            part.lanes,
        )?;
        let info = (swap.model_ms, swap.dark_flits);
        // Arm under the admission lock: the worker clears pending swaps in
        // the same critical section that retires the active session, so a
        // swap staged against a session that ended (or was replaced by a
        // newer one) is refused here instead of leaking into the wrong
        // episode.
        let st = self.shared.state.lock().unwrap();
        if st.active.get(&id).map(|a| a.session) != Some(session) {
            bail!("pblock {id}: the session ended while the swap was being staged");
        }
        part.ctl.swap.schedule(swap);
        Ok(info)
    }

    /// Sessions fully served so far.
    pub fn sessions_served(&self) -> u64 {
        self.shared.state.lock().unwrap().served
    }

    /// One consistent, non-blocking telemetry view of the whole server —
    /// the unified surface the operator plane's `/metrics` and `/state`
    /// endpoints serialize from. Admission state is read under one brief
    /// lock (the lock workers only take at episode boundaries, never
    /// per flit); per-partition counters are lock-free atomics or short
    /// mutexes — snapshotting never stalls a partition's service loop.
    pub fn snapshot(&self) -> FabricSnapshot {
        let capacity = self.cfg.server.sessions_per_partition.max(1);
        let parked = self.shared.store.summaries();
        let (server, admitted, mut sessions) = {
            let st = self.shared.state.lock().unwrap();
            let parked_ids: BTreeSet<u64> = parked.iter().map(|p| p.id).collect();
            // Transparently parked sessions keep their door; they are
            // reported from the store below, not double-counted here.
            let sessions: Vec<SessionTelemetry> = st
                .doors
                .iter()
                .filter(|(sid, _)| !parked_ids.contains(sid))
                .map(|(&sid, door)| SessionTelemetry {
                    id: sid,
                    state: "active",
                    partition: st.placed.get(&sid).copied(),
                    queued_flits: door.queued(),
                    flits: 0,
                    samples: 0,
                })
                .collect();
            let server = ServerTelemetry {
                sessions_served: st.served,
                sessions_active: sessions.len(),
                sessions_parked: parked.len(),
                admission_waiters: st.waiters,
                retained_results: st.results.len(),
                shutting_down: st.shutting_down,
                mux: self.mux(),
            };
            (server, st.admitted.clone(), sessions)
        };
        for p in &parked {
            sessions.push(SessionTelemetry {
                id: p.id,
                state: match p.reason {
                    ParkReason::Idle => "parked-idle",
                    ParkReason::Suspend => "parked-suspend",
                    ParkReason::Quarantine => "parked-quarantine",
                },
                partition: None,
                queued_flits: p.queued_flits,
                flits: p.flits,
                samples: p.samples,
            });
        }
        sessions.sort_by_key(|s| s.id);
        let partitions = self
            .partitions
            .iter()
            .map(|(&id, p)| {
                let drift = p.ctl.stats.snapshot();
                let ready = drift.ready();
                PartitionTelemetry {
                    id,
                    rm: p.rm.as_str(),
                    r: p.r,
                    lanes: p.lanes,
                    capacity,
                    admitted: admitted.get(&id).copied().unwrap_or(0),
                    flits_seen: p.ctl.swap.flits_seen(),
                    swaps_pending: p.ctl.swap.pending_count(),
                    swaps_executed: p.ctl.swap.executed_count(),
                    swap_history: p.ctl.swap.history(),
                    controller_threshold: p.ctl.tuning.threshold(),
                    controller_cooldown_flits: p.ctl.tuning.cooldown_flits(),
                    drift_armed: p.ctl.stats.is_armed(),
                    drift_ready: ready,
                    drift_z: if ready { drift.drift_z() } else { 0.0 },
                    decoupler_enabled: p.decoupler.is_enabled(),
                    isolated: p.decoupler.is_isolated(),
                    quarantined: p.decoupler.is_quarantined(),
                    dropped_flits: p.decoupler.dropped(),
                    fault_events: p.ctl.faults.events_recorded(),
                    fault_reloads: p.ctl.faults.reloads(),
                    fault_quarantines: p.ctl.faults.quarantines(),
                    health_beat: p.ctl.health.beat(),
                }
            })
            .collect();
        FabricSnapshot { server, partitions, sessions }
    }

    /// Operator-plane drain: ask every live session placed on partition
    /// `id` to suspend at its current drain point (the same machinery as
    /// [`Session::suspend`], initiated server-side). Each session's
    /// checkpoint parks into the session store; the client's handle
    /// observes the drain on its next `push` (fails fast) and collects
    /// the [`SessionTicket`] via [`Session::suspend`], which finds the
    /// parked checkpoint. Returns the ids of the sessions asked to
    /// suspend — empty when the partition was idle.
    pub fn drain(&self, id: usize) -> Result<Vec<u64>> {
        if !self.partitions.contains_key(&id) {
            bail!("no served partition {id}");
        }
        let doors: Vec<(u64, InboxCtl)> = {
            let st = self.shared.state.lock().unwrap();
            st.placed
                .iter()
                .filter(|(sid, pid)| **pid == id && !self.shared.store.contains(**sid))
                .filter_map(|(sid, _)| st.doors.get(sid).map(|d| (*sid, d.clone())))
                .collect()
        };
        for (_, door) in &doors {
            door.request_suspend();
        }
        Ok(doors.into_iter().map(|(sid, _)| sid).collect())
    }

    /// Adjust the adaptive controller's live thresholds — `POST
    /// /controller` on the operator plane. `pblock = None` applies to
    /// every partition. The controller reads the tuning cell on its next
    /// poll tick; the values persist across session episodes (they are
    /// partition state, not episode state).
    pub fn tune_controller(
        &self,
        pblock: Option<usize>,
        threshold: Option<f64>,
        cooldown_flits: Option<u64>,
    ) -> Result<()> {
        if threshold.is_none() && cooldown_flits.is_none() {
            bail!("controller tuning: nothing to set (give threshold and/or cooldown_flits)");
        }
        if let Some(t) = threshold {
            if !t.is_finite() || t <= 0.0 {
                bail!("controller tuning: threshold must be finite and > 0 (got {t})");
            }
        }
        if let Some(id) = pblock {
            if !self.partitions.contains_key(&id) {
                bail!("no served partition {id}");
            }
        }
        for (id, p) in &self.partitions {
            if pblock.map_or(true, |t| t == *id) {
                if let Some(t) = threshold {
                    p.ctl.tuning.set_threshold(t);
                }
                if let Some(c) = cooldown_flits {
                    p.ctl.tuning.set_cooldown_flits(c);
                }
            }
        }
        Ok(())
    }

    /// Stop admitting, force-close the inboxes of sessions still open, let
    /// every resident worker finish its current episode and join them.
    pub fn shutdown(mut self) -> Result<ServerReport> {
        self.shutdown_impl()
    }

    fn shutdown_impl(&mut self) -> Result<ServerReport> {
        let doors: Vec<InboxCtl> = {
            let mut st = self.shared.state.lock().unwrap();
            st.shutting_down = true;
            st.active
                .values()
                .map(|a| a.door.clone())
                .chain(st.doors.values().cloned())
                .collect()
        };
        self.shared.freed.notify_all();
        for door in doors {
            door.force_close();
        }
        // Closing the job queues ends the resident workers after their
        // current episode: both the handles here and the sibling-dispatch
        // clones in `Shared.senders` must drop.
        self.shared.senders.lock().unwrap().clear();
        self.partitions.clear();
        let mut panicked = 0usize;
        for w in self.workers.drain(..) {
            if w.join().is_err() {
                panicked += 1;
            }
        }
        // Parked sessions hold score senders; dropping them ends the score
        // streams of clients still draining `close()` on another thread.
        self.shared.store.clear();
        if let Some(sink) = self.shared.sink.as_ref() {
            let _ = sink.lock().unwrap().sync();
        }
        if panicked > 0 {
            bail!("{panicked} partition worker(s) panicked");
        }
        Ok(ServerReport { sessions_served: self.shared.state.lock().unwrap().served })
    }
}

impl Drop for FabricServer {
    fn drop(&mut self) {
        let _ = self.shutdown_impl();
    }
}

// ---------------------------------------------------------------------------
// Client session
// ---------------------------------------------------------------------------

/// Result of [`Session::close`].
#[derive(Clone, Debug)]
pub struct SessionClose {
    /// Scores not yet collected via `recv_scores`/`poll_scores`, in stream
    /// order.
    pub scores: Vec<f32>,
    /// Samples pushed over the session (including the padded tail's valid
    /// rows).
    pub samples: u64,
    /// Flits sent, including the TLAST flit.
    pub flits: u64,
    /// True when `close()` cut the stream mid-chunk: the final flit carries
    /// `tail_valid` valid rows zero-padded to the chunk size. Reported here
    /// — padding is never silent.
    pub padded_tail: bool,
    pub tail_valid: usize,
    /// The partition's service report for this session.
    pub report: PblockReport,
    /// In-flight RM swaps executed during the session.
    pub swap_events: Vec<SwapEvent>,
    /// Swaps issued by the adaptive controller during the session.
    pub adaptive_swaps: u64,
    /// Swaps armed but never executed — discarded at episode boundaries so
    /// a stale replacement RM (staged for another stream) can never fire.
    pub discarded_swaps: u64,
    /// Fault injections, detections and recovery-ladder transitions
    /// recorded during the session (empty unless `[fabric.faults]`
    /// `enabled = true`), in flit order.
    pub fault_events: Vec<FaultEvent>,
}

/// A client's handle on one streaming session. Push sample chunks, receive
/// score chunks asynchronously, close to flush with TLAST semantics.
pub struct Session {
    id: u64,
    pblock: usize,
    d: usize,
    chunk: usize,
    tx: Option<InboxSender>,
    rx: Receiver<Flit>,
    /// Server-side control of this session's inbox (suspend / force-close).
    door: InboxCtl,
    seq: u64,
    pushed: u64,
    /// Samples staged toward the next full chunk (`< chunk × d` values).
    staged: Vec<f32>,
    /// All-ones mask shared by every full flit of this session (one
    /// allocation, like `ChunkStream`).
    full_mask: Arc<[f32]>,
    shared: Arc<Shared>,
    finished: bool,
}

impl Session {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The pblock partition serving this session.
    pub fn pblock(&self) -> usize {
        self.pblock
    }

    /// Samples pushed so far (staged samples included).
    pub fn samples_pushed(&self) -> u64 {
        self.pushed + (self.staged.len() / self.d) as u64
    }

    /// The session's sample dimensionality — the network front end
    /// validates a `Push` body is a whole number of rows before decoding.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Push `samples` (row-major, a whole number of rows). Full chunks are
    /// cut into flits exactly like the input DMA and sent through the
    /// bounded inbox — this call **blocks** while the inbox is full.
    /// Each sample is copied exactly once (into its flit buffer), so a
    /// large push is O(n) regardless of the chunk size.
    pub fn push(&mut self, samples: &[f32]) -> Result<()> {
        if samples.len() % self.d != 0 {
            bail!(
                "push of {} values is not a whole number of samples (d = {})",
                samples.len(),
                self.d
            );
        }
        let flit_len = self.chunk * self.d;
        let mut rest = samples;
        // Complete a partially staged chunk first.
        if !self.staged.is_empty() {
            let take = (flit_len - self.staged.len()).min(rest.len());
            self.staged.extend_from_slice(&rest[..take]);
            rest = &rest[take..];
            if self.staged.len() == flit_len {
                let full = std::mem::take(&mut self.staged);
                self.emit_full(full)?;
            }
        }
        // Cut whole flits straight from the input slice.
        while rest.len() >= flit_len {
            self.emit_full(rest[..flit_len].to_vec())?;
            rest = &rest[flit_len..];
        }
        self.staged.extend_from_slice(rest);
        Ok(())
    }

    /// Push a raw little-endian f32 wire body — the network front end's
    /// half of the zero-copy contract. Each value is decoded from the
    /// socket buffer directly into its flit allocation (or the staged
    /// tail), so a `Push` frame pays the same single copy as
    /// [`Session::push`] pays from a caller's slice; there is no
    /// intermediate `Vec<f32>`. The byte length must be a whole number
    /// of rows (`4 * d` bytes per sample); semantics are otherwise
    /// identical to [`Session::push`], including inbox backpressure.
    pub fn push_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        let row = 4 * self.d;
        if bytes.len() % row != 0 {
            bail!(
                "push of {} bytes is not a whole number of samples (d = {}, 4 bytes per value)",
                bytes.len(),
                self.d
            );
        }
        let flit_len = self.chunk * self.d;
        let mut rest = bytes;
        // Complete a partially staged chunk first.
        if !self.staged.is_empty() {
            let take = ((flit_len - self.staged.len()) * 4).min(rest.len());
            decode_f32_le(&rest[..take], &mut self.staged);
            rest = &rest[take..];
            if self.staged.len() == flit_len {
                let full = std::mem::take(&mut self.staged);
                self.emit_full(full)?;
            }
        }
        // Cut whole flits straight from the wire bytes.
        while rest.len() >= flit_len * 4 {
            let mut data = Vec::with_capacity(flit_len);
            decode_f32_le(&rest[..flit_len * 4], &mut data);
            self.emit_full(data)?;
            rest = &rest[flit_len * 4..];
        }
        decode_f32_le(rest, &mut self.staged);
        Ok(())
    }

    /// Flits emitted into the inbox so far (the staged partial chunk is
    /// not counted). Cumulative across suspend/resume — a resumed
    /// session continues from its ticket's sequence number — which is
    /// what lets the network front end pair every `Push` with exactly
    /// the score flits it is owed.
    pub fn flits_sent(&self) -> u64 {
        self.seq
    }

    fn emit_full(&mut self, data: Vec<f32>) -> Result<()> {
        let flit = Flit {
            seq: self.seq,
            data: data.into(),
            mask: self.full_mask.clone(),
            n_valid: self.chunk,
            last: false,
        };
        self.seq += 1;
        self.pushed += self.chunk as u64;
        self.send(flit)
    }

    fn send(&self, flit: Flit) -> Result<()> {
        match self.tx.as_ref().expect("session already closed").send(flit) {
            Ok(()) => Ok(()),
            Err(InboxClosed) => {
                bail!("session closed by the server (shutdown or partition failure)")
            }
        }
    }

    /// Non-blocking: drain the score flits that have already arrived,
    /// unpadded into plain per-sample scores.
    pub fn poll_scores(&mut self) -> Vec<f32> {
        let mut out = Vec::new();
        while let Ok(flit) = self.rx.try_recv() {
            unpad_into(&flit, &mut out);
        }
        out
    }

    /// Block for the next score flit; `None` once the session's score
    /// stream has ended.
    pub fn recv_scores(&mut self) -> Option<Vec<f32>> {
        let flit = self.rx.recv().ok()?;
        let mut out = Vec::new();
        unpad_into(&flit, &mut out);
        Some(out)
    }

    /// Flush with TLAST semantics and tear the session down: a partial
    /// trailing chunk is zero-padded into the final flit (**reported** via
    /// [`SessionClose::padded_tail`]), remaining scores are drained, and
    /// the partition returns to the free pool.
    pub fn close(mut self) -> Result<SessionClose> {
        let tail_valid = self.staged.len() / self.d;
        let rows = self.chunk;
        let mut data = vec![0f32; rows * self.d];
        data[..self.staged.len()].copy_from_slice(&self.staged);
        let mut mask = vec![0f32; rows];
        mask[..tail_valid].fill(1.0);
        let last = Flit {
            seq: self.seq,
            data: data.into(),
            mask: mask.into(),
            n_valid: tail_valid,
            last: true,
        };
        self.seq += 1;
        self.pushed += tail_valid as u64;
        self.staged.clear();
        // Best effort: at shutdown the inbox is already force-closed and
        // the flush is lost — the drain below still terminates because the
        // worker ends the episode either way.
        let flushed = self.send(last).is_ok();
        drop(self.tx.take());
        let mut scores = Vec::new();
        while let Ok(flit) = self.rx.recv() {
            unpad_into(&flit, &mut scores);
        }
        self.finished = true;
        let outcome = self
            .shared
            .state
            .lock()
            .unwrap()
            .results
            .remove(&self.id)
            .context("session outcome missing — partition worker terminated abnormally")?;
        if let Some(err) = outcome.error {
            // Typed: `e.downcast_ref::<ServeError>()` recovers the variant.
            return Err(anyhow::Error::new(err)
                .context(format!("partition {} service failed", self.pblock)));
        }
        if !flushed {
            bail!("session was force-closed by the server before the TLAST flush");
        }
        Ok(SessionClose {
            scores,
            samples: self.pushed,
            flits: self.seq,
            padded_tail: tail_valid > 0,
            tail_valid,
            report: outcome.report.unwrap_or_default(),
            swap_events: outcome.swap_events,
            adaptive_swaps: outcome.adaptive_swaps,
            discarded_swaps: outcome.discarded_swaps,
            fault_events: outcome.fault_events,
        })
    }

    /// Checkpoint the session and release its partition slot, returning a
    /// [`SessionTicket`] that [`FabricServer::resume`] — on this server or a
    /// fresh one built from the same config — turns back into a live
    /// session with bit-identical scores, plus any scores that were still
    /// in flight. Works in both service modes (dedicated and multiplexed
    /// partitions). When `[fabric.server] spill_dir` is set the ticket is
    /// also spilled to disk for [`FabricServer::resume_spilled`].
    pub fn suspend(mut self) -> Result<(SessionTicket, Vec<f32>)> {
        self.door.request_suspend();
        drop(self.tx.take());
        let mut scores = Vec::new();
        let mut hung_up = false;
        let parked = loop {
            // Drain scores opportunistically so the worker never stalls on
            // a full score channel while finishing the park.
            while let Ok(flit) = self.rx.try_recv() {
                unpad_into(&flit, &mut scores);
            }
            // Workers park (or publish an outcome) under the state lock and
            // drop their channels only afterwards, so "neither in the store
            // nor in results" while the channel lives means "still parking".
            {
                let mut st = self.shared.state.lock().unwrap();
                if let Some(p) = self.shared.store.take(self.id) {
                    break p;
                }
                if let Some(outcome) = st.results.remove(&self.id) {
                    drop(st);
                    self.finished = true;
                    match outcome.error {
                        Some(err) => {
                            return Err(anyhow::Error::new(err).context(format!(
                                "partition {} service failed",
                                self.pblock
                            )))
                        }
                        None => bail!("session ended before it could be suspended"),
                    }
                }
            }
            match self.rx.try_recv() {
                Ok(flit) => unpad_into(&flit, &mut scores),
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    // The hang-up happens-after the park/publish, so one
                    // more sweep over store + results settles it; a second
                    // disconnected pass means the worker really died in
                    // between.
                    if hung_up {
                        self.finished = true;
                        bail!("partition worker terminated abnormally during suspend");
                    }
                    hung_up = true;
                }
            }
        };
        // The worker dropped its score sender after parking — drain the tail.
        while let Ok(flit) = self.rx.recv() {
            unpad_into(&flit, &mut scores);
        }
        self.finished = true;
        let ticket = SessionTicket {
            id: parked.id,
            kind: parked.kind,
            r: parked.r,
            lanes: parked.lanes,
            d: parked.d,
            seed: parked.seed,
            flits: parked.flits,
            samples: parked.samples,
            seq: self.seq,
            pushed: self.pushed,
            staged: std::mem::take(&mut self.staged),
            warmup: parked.warmup.as_ref().clone(),
            snapshot: parked.snapshot,
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.doors.remove(&self.id);
            st.placed.remove(&self.id);
        }
        if let Some(dir) = self.shared.spill_dir.as_deref() {
            ticket.spill(dir).context("spilling the suspend ticket")?;
        }
        Ok((ticket, scores))
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if self.finished {
            return;
        }
        // Abandoned without close(): hang up the inbox, force-close it so a
        // multiplexed worker retires the session promptly (queued flits of
        // an abandoned session are discarded, like any force-close), evict
        // any parked copy from the store and disown the outcome.
        drop(self.tx.take());
        self.door.force_close();
        let mut st = self.shared.state.lock().unwrap();
        let discarded = self.shared.store.discard(self.id);
        if st.results.remove(&self.id).is_none() && !discarded {
            st.abandoned.insert(self.id);
        }
        st.doors.remove(&self.id);
        st.placed.remove(&self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PblockCfg;
    use crate::detectors::prng::Prng;
    use crate::detectors::{DetectorKind, DetectorSpec};
    use crate::fabric::message::score_chunk;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    fn flit(seq: u64) -> Flit {
        score_chunk(seq, vec![seq as f32], vec![1.0], 1, false)
    }

    fn tiny_cfg(chunk: usize, kind: DetectorKind, r: usize) -> FseadConfig {
        let mut cfg = FseadConfig::default();
        cfg.use_fpga = false;
        cfg.chunk = chunk;
        cfg.hyper.window = 16;
        cfg.hyper.bins = 8;
        cfg.hyper.modulus = 32;
        cfg.hyper.k = 4;
        cfg.pblocks.push(PblockCfg { id: 1, rm: RmKind::Detector(kind), r, stream: 0, lanes: 0 });
        cfg
    }

    fn gaussian_data(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    #[test]
    fn inbox_blocks_producer_at_capacity() {
        let (tx, mut rx) = SessionInbox::bounded(2);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = Arc::clone(&sent);
        let producer = std::thread::spawn(move || {
            for seq in 0..5u64 {
                tx.send(flit(seq)).unwrap();
                sent2.fetch_add(1, Ordering::SeqCst);
            }
        });
        // The producer fills the inbox and then blocks on the third send.
        let t0 = Instant::now();
        while sent.load(Ordering::SeqCst) < 2 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(sent.load(Ordering::SeqCst), 2, "producer must block at capacity");
        // Draining one flit unblocks exactly one more send.
        assert_eq!(rx.recv_flit().unwrap().seq, 0);
        let t0 = Instant::now();
        while sent.load(Ordering::SeqCst) < 3 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(sent.load(Ordering::SeqCst), 3);
        // Drain the rest: order is FIFO, nothing dropped, nothing reordered.
        let mut seqs = vec![];
        while let Some(f) = rx.recv_flit() {
            seqs.push(f.seq);
        }
        producer.join().unwrap();
        assert_eq!(seqs, vec![1, 2, 3, 4]);
    }

    #[test]
    fn inbox_force_close_unblocks_producer_and_fails_fast() {
        let (tx, mut rx) = SessionInbox::bounded(1);
        tx.send(flit(0)).unwrap();
        let ctl = rx.ctl();
        let blocked = std::thread::spawn(move || tx.send(flit(1)));
        std::thread::sleep(Duration::from_millis(20));
        ctl.force_close();
        assert!(blocked.join().unwrap().is_err(), "blocked send must fail fast");
        assert!(rx.recv_flit().is_none(), "force-close discards queued flits");
    }

    #[test]
    fn inbox_try_recv_is_nonblocking() {
        let (tx, mut rx) = SessionInbox::bounded(4);
        assert!(rx.try_recv_flit().is_none());
        tx.send(flit(7)).unwrap();
        assert_eq!(rx.try_recv_flit().unwrap().seq, 7);
        drop(tx);
        assert!(rx.recv_flit().is_none(), "producer hang-up ends the stream");
    }

    /// Panic inside `shared`'s inbox critical section from a throwaway
    /// thread, poisoning the queue mutex the way a dying producer would.
    fn poison_inbox(shared: &Arc<InboxShared>) {
        let inner = Arc::clone(shared);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _q = inner.q.lock().unwrap();
            panic!("injected: thread dies while holding the inbox lock");
        }));
    }

    #[test]
    fn poisoned_inbox_degrades_to_typed_closure_not_cascading_panics() {
        let (tx, mut rx) = SessionInbox::bounded(4);
        tx.send(flit(0)).unwrap();
        poison_inbox(&tx.inner);
        // Neither side panics: the consumer sees a clean end-of-stream
        // (recovery force-closed the queue), the producer fails fast,
        // and both observe the latched poison flag.
        assert!(rx.recv_flit().is_none(), "poison recovery must end the stream, not panic");
        assert!(tx.send(flit(1)).is_err(), "sends after poisoning must fail fast");
        assert!(rx.poisoned());
        assert!(rx.ctl().poisoned());
    }

    #[test]
    fn poisoned_session_dies_typed_and_partition_survives() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(16, 2, 9);
        let server = FabricServer::start(cfg).unwrap();
        let session = server.open(SessionSpec::new(2, data.clone())).unwrap();
        poison_inbox(&session.tx.as_ref().unwrap().inner);
        let err = session.close().expect_err("a poisoned session must die");
        assert_eq!(
            err.downcast_ref::<ServeError>(),
            Some(&ServeError::Poisoned),
            "the closure must carry the typed poison error: {err:#}"
        );
        // The partition survives the poisoned tenant: a fresh session on
        // the same (sole) pblock still serves end to end.
        let mut s = server.open(SessionSpec::new(2, data.clone())).unwrap();
        s.push(&data).unwrap();
        let closed = s.close().unwrap();
        assert_eq!(closed.scores.len(), 16);
    }

    #[test]
    fn push_bytes_matches_push_bit_for_bit() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 3);
        let data = gaussian_data(40, 3, 11);
        let server = FabricServer::start(cfg).unwrap();
        let warmup = data[..16 * 3].to_vec();
        let mut by_slice = server.open(SessionSpec::new(3, warmup.clone()).on_pblock(1)).unwrap();
        by_slice.push(&data[..7 * 3]).unwrap();
        by_slice.push(&data[7 * 3..]).unwrap();
        let expect = by_slice.close().unwrap().scores;
        // Same stream as raw little-endian wire bytes, same odd split.
        let mut wire = Vec::new();
        crate::fabric::message::encode_f32_le(&data, &mut wire);
        let mut by_bytes = server.open(SessionSpec::new(3, warmup).on_pblock(1)).unwrap();
        by_bytes.push_bytes(&wire[..7 * 3 * 4]).unwrap();
        by_bytes.push_bytes(&wire[7 * 3 * 4..]).unwrap();
        assert_eq!(by_bytes.flits_sent(), 5, "40 samples / chunk 8 = 5 whole flits");
        let closed = by_bytes.close().unwrap();
        assert_eq!(closed.scores, expect, "wire-byte pushes must be bit-identical");
    }

    #[test]
    fn session_scores_match_standalone_detector() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 3);
        let data = gaussian_data(40, 3, 11);
        let server = FabricServer::start(cfg.clone()).unwrap();
        let mut session =
            server.open(SessionSpec::new(3, data[..16 * 3].to_vec()).on_pblock(1)).unwrap();
        // Irregular pushes: re-chunking must not change the arithmetic.
        session.push(&data[..7 * 3]).unwrap();
        session.push(&data[7 * 3..29 * 3]).unwrap();
        session.push(&data[29 * 3..]).unwrap();
        let closed = session.close().unwrap();
        assert_eq!(closed.samples, 40);
        assert_eq!(closed.scores.len(), 40);
        let mut spec = DetectorSpec::new(DetectorKind::Loda, 3, 3, pblock_seed(cfg.seed, 1));
        spec.window = cfg.hyper.window;
        spec.bins = cfg.hyper.bins;
        let mut det = spec.build(&data[..16 * 3]);
        let expect = det.run_stream(&data);
        assert_eq!(closed.scores, expect, "session scores must be bit-identical");
    }

    #[test]
    fn close_mid_chunk_reports_padded_tail() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(13, 2, 3);
        let server = FabricServer::start(cfg).unwrap();
        let mut s = server.open(SessionSpec::new(2, data[..8 * 2].to_vec())).unwrap();
        s.push(&data).unwrap(); // 13 samples, chunk 8 → 1 full flit + 5 staged
        let closed = s.close().unwrap();
        assert!(closed.padded_tail, "mid-chunk close must be reported");
        assert_eq!(closed.tail_valid, 5);
        assert_eq!(closed.samples, 13);
        assert_eq!(closed.scores.len(), 13, "padding rows never score");
        assert_eq!(closed.flits, 2);
    }

    #[test]
    fn close_on_chunk_boundary_has_no_padding() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(16, 2, 4);
        let server = FabricServer::start(cfg).unwrap();
        let mut s = server.open(SessionSpec::new(2, data.clone())).unwrap();
        s.push(&data).unwrap();
        let closed = s.close().unwrap();
        assert!(!closed.padded_tail);
        assert_eq!(closed.tail_valid, 0);
        assert_eq!(closed.scores.len(), 16);
    }

    #[test]
    fn admission_refuses_when_queue_is_full() {
        let mut cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        cfg.server.max_waiters = 0;
        let data = gaussian_data(8, 2, 5);
        let server = FabricServer::start(cfg).unwrap();
        let _busy = server.open(SessionSpec::new(2, data.clone())).unwrap();
        // The one partition is busy and nobody may queue.
        let err = server.open(SessionSpec::new(2, data.clone())).unwrap_err();
        assert!(err.to_string().contains("admission queue"), "{err}");
        assert!(server.try_open(SessionSpec::new(2, data)).unwrap().is_none());
    }

    #[test]
    fn shutdown_with_open_session_does_not_deadlock() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(24, 2, 6);
        let server = FabricServer::start(cfg).unwrap();
        let mut s = server.open(SessionSpec::new(2, data[..16].to_vec())).unwrap();
        s.push(&data[..16 * 2]).unwrap();
        let report = server.shutdown().unwrap();
        assert_eq!(report.sessions_served, 1, "forced episode still completes");
        // The abandoned client fails fast instead of hanging: the next full
        // chunk hits the force-closed inbox.
        assert!(s.push(&data[..8 * 2]).is_err());
    }

    #[test]
    fn dropped_session_frees_the_partition() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(8, 2, 7);
        let server = FabricServer::start(cfg).unwrap();
        {
            let mut s = server.open(SessionSpec::new(2, data.clone())).unwrap();
            s.push(&data).unwrap();
            // Dropped without close(): the worker finishes and frees RP-1.
        }
        let mut s = server.open(SessionSpec::new(2, data.clone())).unwrap();
        s.push(&data).unwrap();
        let closed = s.close().unwrap();
        assert_eq!(closed.scores.len(), 8);
        assert_eq!(server.sessions_served(), 2);
    }

    #[test]
    fn swap_needs_an_active_session() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let server = FabricServer::start(cfg).unwrap();
        let err = server
            .schedule_swap(1, 2, RmKind::Detector(DetectorKind::RsHash), 2, Some(1))
            .unwrap_err();
        assert!(err.to_string().contains("no active session"), "{err}");
        server.decoupler(1).unwrap().set_enabled(false);
        let err = server
            .schedule_swap(1, 2, RmKind::Detector(DetectorKind::RsHash), 2, Some(1))
            .unwrap_err();
        assert!(err.to_string().contains("decoupler is disabled"), "{err}");
    }

    #[test]
    fn overload_shed_returns_typed_error() {
        let mut cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        cfg.server.overload = OverloadPolicy::Shed;
        let data = gaussian_data(8, 2, 9);
        let server = FabricServer::start(cfg).unwrap();
        let _busy = server.open(SessionSpec::new(2, data.clone())).unwrap();
        // Shedding: a blocking open fails immediately instead of queueing.
        let t0 = Instant::now();
        let err = server.open(SessionSpec::new(2, data)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert_eq!(err.downcast_ref::<AdmitError>(), Some(&AdmitError::Saturated), "{err}");
    }

    #[test]
    fn open_timeout_returns_typed_error() {
        let mut cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        cfg.server.open_timeout_ms = 50;
        let data = gaussian_data(8, 2, 10);
        let server = FabricServer::start(cfg).unwrap();
        let _busy = server.open(SessionSpec::new(2, data.clone())).unwrap();
        let err = server.open(SessionSpec::new(2, data)).unwrap_err();
        match err.downcast_ref::<AdmitError>() {
            Some(AdmitError::Timeout { waited_ms }) => assert!(*waited_ms >= 50, "{waited_ms}"),
            other => panic!("expected a typed timeout, got {other:?} ({err})"),
        }
    }

    /// Two sessions multiplexed through one partition score bit-identically
    /// to each stream served alone on a dedicated partition — the snapshot
    /// swap between tenants is lossless.
    #[test]
    fn multiplexed_sessions_score_bit_identical_to_dedicated() {
        let d = 2;
        let data_a = gaussian_data(32, d, 11);
        let data_b = gaussian_data(32, d, 12);
        let dedicated = |data: &[f32]| -> Vec<f32> {
            let server = FabricServer::start(tiny_cfg(8, DetectorKind::Loda, 2)).unwrap();
            let mut s = server.open(SessionSpec::new(d, data[..16 * d].to_vec())).unwrap();
            s.push(data).unwrap();
            s.close().unwrap().scores
        };
        let (want_a, want_b) = (dedicated(&data_a), dedicated(&data_b));
        let mut cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        cfg.server.sessions_per_partition = 2;
        let server = FabricServer::start(cfg).unwrap();
        let mut a = server.open(SessionSpec::new(d, data_a[..16 * d].to_vec())).unwrap();
        let mut b = server.open(SessionSpec::new(d, data_b[..16 * d].to_vec())).unwrap();
        assert_eq!(a.pblock(), b.pblock(), "both tenants share the one partition");
        // Interleave pushes chunk by chunk to force state swaps.
        for i in 0..4 {
            a.push(&data_a[i * 8 * d..(i + 1) * 8 * d]).unwrap();
            b.push(&data_b[i * 8 * d..(i + 1) * 8 * d]).unwrap();
        }
        let got_a = a.close().unwrap().scores;
        let got_b = b.close().unwrap().scores;
        assert_eq!(got_a, want_a, "session A diverged under multiplexing");
        assert_eq!(got_b, want_b, "session B diverged under multiplexing");
    }

    /// Suspend → resume on the same server continues the score stream
    /// bit-identically to an uninterrupted session.
    #[test]
    fn suspend_resume_is_bit_identical() {
        let d = 2;
        let data = gaussian_data(48, d, 13);
        let want = {
            let server = FabricServer::start(tiny_cfg(8, DetectorKind::Loda, 2)).unwrap();
            let mut s = server.open(SessionSpec::new(d, data[..16 * d].to_vec())).unwrap();
            s.push(&data).unwrap();
            s.close().unwrap().scores
        };
        let server = FabricServer::start(tiny_cfg(8, DetectorKind::Loda, 2)).unwrap();
        let mut s = server.open(SessionSpec::new(d, data[..16 * d].to_vec())).unwrap();
        // An uneven split: the pending tail (4 samples short of a chunk)
        // rides the ticket, not the wire.
        s.push(&data[..20 * d]).unwrap();
        let (ticket, mut scores) = s.suspend().unwrap();
        assert_eq!(ticket.pushed, 16, "two full chunks crossed the wire");
        assert_eq!(ticket.staged.len(), 4 * d, "tail staged client-side");
        let roundtripped = SessionTicket::from_bytes(&ticket.to_bytes()).unwrap();
        assert_eq!(roundtripped, ticket, "ticket survives serialization");
        let mut s = server.resume(roundtripped).unwrap();
        s.push(&data[20 * d..]).unwrap();
        let tail = s.close().unwrap();
        scores.extend_from_slice(&tail.scores);
        assert_eq!(scores, want, "resumed stream diverged");
        // 6 full chunks + the TLAST flit, split 2 / 5 across the episodes.
        assert_eq!(tail.report.flits_in, 7, "cursor spans both episodes");
        assert_eq!(tail.report.samples, 48);
    }

    /// A resume may not collide with the same session still live.
    #[test]
    fn resume_refuses_duplicate_session() {
        let d = 2;
        let data = gaussian_data(16, d, 14);
        let server = FabricServer::start(tiny_cfg(8, DetectorKind::Loda, 2)).unwrap();
        let mut s = server.open(SessionSpec::new(d, data.clone())).unwrap();
        s.push(&data).unwrap();
        let (ticket, _) = s.suspend().unwrap();
        let live = server.resume(ticket.clone()).unwrap();
        let err = server.resume(ticket).unwrap_err();
        assert!(err.to_string().contains("already live"), "{err}");
        drop(live);
    }

    /// Idle-evicted sessions re-attach transparently on the next push and
    /// the stream stays bit-identical.
    #[test]
    fn idle_eviction_is_transparent_to_the_client() {
        let d = 2;
        let data = gaussian_data(48, d, 15);
        let want = {
            let server = FabricServer::start(tiny_cfg(8, DetectorKind::Loda, 2)).unwrap();
            let mut s = server.open(SessionSpec::new(d, data[..16 * d].to_vec())).unwrap();
            s.push(&data).unwrap();
            s.close().unwrap().scores
        };
        let mut cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        cfg.server.idle_evict_flits = 3;
        let server = FabricServer::start(cfg).unwrap();
        let mut s = server.open(SessionSpec::new(d, data[..16 * d].to_vec())).unwrap();
        s.push(&data[..24 * d]).unwrap();
        // Wait until the worker parks the idle session into the store.
        let t0 = Instant::now();
        while server.shared.store.is_empty() && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!server.shared.store.is_empty(), "session was never idle-evicted");
        // The next push stirs the inbox and the session re-attaches.
        s.push(&data[24 * d..]).unwrap();
        let closed = s.close().unwrap();
        assert_eq!(closed.scores, want, "evict → resume diverged");
    }
}
