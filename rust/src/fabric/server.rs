//! Persistent streaming session server over the fabric.
//!
//! [`Fabric::run`](super::Fabric::run) is a one-shot batch pass: it wires
//! the topology, streams pre-loaded datasets through and tears everything
//! down. This module keeps the fabric *resident*: a [`FabricServer`] starts
//! one service worker per configured pblock partition and the workers stay
//! alive between requests, serving an open-ended sequence of client
//! sessions — the paper's Fig 7(a) multi-stream configuration (seven
//! independent AD applications, one per pblock, each on its own DMA
//! channel) turned into a long-running service.
//!
//! # Session lifecycle
//!
//! 1. **Open** — [`FabricServer::open`] admits a [`Session`] onto a free
//!    pblock partition (a specific one via [`SessionSpec::pblock`], or any).
//!    When every partition is busy the caller queues on the admission
//!    condvar (bounded by `[fabric.server] max_waiters`) until a partition
//!    frees. The partition's resident worker builds a fresh RM from the
//!    session's dimensionality and warm-up prefix with the same
//!    [`pblock_seed`] the one-shot fabric uses, so a session's scores are
//!    **bit-identical** to a `Fabric::run` over the same concatenated data.
//! 2. **Push** — [`Session::push`] appends samples; full chunks are cut
//!    into flits exactly like the input DMA's `ChunkStream` (shared
//!    all-ones mask, zero-padded tail) and sent through the session's
//!    **bounded inbox**: a full inbox blocks the producer — AXI-style
//!    backpressure — and never drops or reorders flits.
//! 3. **Score** — the partition worker drains the inbox through the
//!    ordinary [`Pblock::service_mode`] loop (both [`ExecMode`]s, the DFX
//!    gate consulted per flit), so live reconfiguration — scripted
//!    schedules via [`FabricServer::schedule_swap`] / `[fabric.dfx.swap.N]`
//!    and the adaptive controller via `[fabric.dfx]` — keeps working
//!    mid-session. Score flits flow back asynchronously per chunk
//!    ([`Session::recv_scores`] / [`Session::poll_scores`]).
//! 4. **Close** — [`Session::close`] flushes with TLAST semantics: a
//!    partial trailing chunk is zero-padded into the final flit and
//!    **reported** ([`SessionClose::padded_tail`], never silent), the
//!    remaining scores are drained, and the partition returns to the free
//!    pool for the next queued session. Dropping a session without closing
//!    abandons it: the worker finishes, the partition frees, nothing leaks.
//!
//! [`FabricServer::shutdown`] force-closes the inboxes of sessions still
//! open (their next `push` fails fast), lets every worker finish its
//! current episode, and joins them — shutdown never deadlocks on an idle
//! client.

use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::decoupler::Decoupler;
use super::dma::unpad_into;
use super::faults::{FaultEvent, FaultInjector};
use super::hotswap::{self, ControllerEnv, ControllerTarget, PblockCtl, SwapEvent};
use super::message::{Flit, FlitSource, Port};
use super::pblock::{LoadedRm, Pblock, PblockReport};
use super::reconfig::DfxManager;
use super::supervisor::{self, SupervisorEnv, SupervisorTarget};
use super::topology::{kind_of, pblock_seed};
use crate::config::{DetectorHyper, DfxCfg, FaultsCfg, FseadConfig, RmKind, ScriptedSwap};
use crate::data::Dataset;
use crate::ensemble::{ExecMode, LanePool};
use crate::runtime::{Registry, Runtime, RuntimeHandle};

/// Completed-session outcomes retained for clients that have not yet
/// collected them (bounds memory under open/close churn with misbehaving
/// clients that neither close nor drop promptly).
const MAX_RETAINED_OUTCOMES: usize = 512;

// ---------------------------------------------------------------------------
// Bounded session inbox
// ---------------------------------------------------------------------------

#[derive(Default)]
struct InboxQueue {
    buf: VecDeque<Flit>,
    /// Producer hung up (client closed or dropped the session).
    producer_done: bool,
    /// Server force-closed the stream (shutdown): pending flits are
    /// discarded and the producer's next send fails fast.
    force_closed: bool,
}

struct InboxShared {
    cap: usize,
    q: Mutex<InboxQueue>,
    /// Signalled when space frees up (consumer popped / force-close).
    space: Condvar,
    /// Signalled when a flit arrives or the stream ends.
    ready: Condvar,
}

/// Error returned by [`InboxSender::send`] once the server has force-closed
/// the session (shutdown or partition failure).
#[derive(Debug)]
pub struct InboxClosed;

impl std::fmt::Display for InboxClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "session inbox closed by the server")
    }
}

impl std::error::Error for InboxClosed {}

/// Producer half of a session's bounded inbox. A full inbox **blocks** the
/// sender until the partition's service loop drains a flit — backpressure,
/// never drops, never reorders.
pub struct InboxSender {
    inner: Arc<InboxShared>,
}

impl InboxSender {
    pub fn send(&self, flit: Flit) -> Result<(), InboxClosed> {
        let mut q = self.inner.q.lock().unwrap();
        loop {
            if q.force_closed {
                return Err(InboxClosed);
            }
            if q.buf.len() < self.inner.cap {
                break;
            }
            q = self.inner.space.wait(q).unwrap();
        }
        q.buf.push_back(flit);
        drop(q);
        self.inner.ready.notify_one();
        Ok(())
    }

    /// Flits currently queued (telemetry / tests).
    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for InboxSender {
    fn drop(&mut self) {
        self.inner.q.lock().unwrap().producer_done = true;
        self.inner.ready.notify_all();
    }
}

/// Server-side control over a session inbox: force-close at shutdown.
#[derive(Clone)]
pub(crate) struct InboxCtl {
    inner: Arc<InboxShared>,
}

impl InboxCtl {
    fn force_close(&self) {
        let mut q = self.inner.q.lock().unwrap();
        q.force_closed = true;
        q.buf.clear();
        drop(q);
        self.inner.space.notify_all();
        self.inner.ready.notify_all();
    }
}

/// Consumer half of a session's bounded inbox — the [`FlitSource`] a
/// partition worker drains through [`Pblock::service_mode`].
pub struct SessionInbox {
    inner: Arc<InboxShared>,
}

impl SessionInbox {
    /// Create a bounded inbox of `cap` flits.
    pub fn bounded(cap: usize) -> (InboxSender, SessionInbox) {
        assert!(cap > 0, "a zero-depth inbox deadlocks");
        let inner = Arc::new(InboxShared {
            cap,
            q: Mutex::new(InboxQueue::default()),
            space: Condvar::new(),
            ready: Condvar::new(),
        });
        (InboxSender { inner: Arc::clone(&inner) }, SessionInbox { inner })
    }

    pub(crate) fn ctl(&self) -> InboxCtl {
        InboxCtl { inner: Arc::clone(&self.inner) }
    }
}

impl FlitSource for SessionInbox {
    fn recv_flit(&mut self) -> Option<Flit> {
        let mut q = self.inner.q.lock().unwrap();
        loop {
            if q.force_closed {
                return None;
            }
            if let Some(f) = q.buf.pop_front() {
                drop(q);
                self.inner.space.notify_one();
                return Some(f);
            }
            if q.producer_done {
                return None;
            }
            q = self.inner.ready.wait(q).unwrap();
        }
    }

    fn try_recv_flit(&mut self) -> Option<Flit> {
        let mut q = self.inner.q.lock().unwrap();
        if q.force_closed {
            return None;
        }
        let f = q.buf.pop_front();
        if f.is_some() {
            drop(q);
            self.inner.space.notify_one();
        }
        f
    }
}

// ---------------------------------------------------------------------------
// Admission state
// ---------------------------------------------------------------------------

struct ActiveSession {
    session: u64,
    d: usize,
    /// Warm-up prefix of the session's stream — kept so in-flight swaps can
    /// be staged against the live stream's parameter ranges.
    warmup: Arc<Vec<f32>>,
    door: InboxCtl,
}

/// What a finished session left behind for its client.
struct SessionOutcome {
    report: Option<PblockReport>,
    swap_events: Vec<SwapEvent>,
    adaptive_swaps: u64,
    discarded_swaps: u64,
    fault_events: Vec<FaultEvent>,
    error: Option<String>,
}

#[derive(Default)]
struct AdmissionState {
    free: BTreeSet<usize>,
    active: BTreeMap<usize, ActiveSession>,
    results: BTreeMap<u64, SessionOutcome>,
    /// Sessions dropped by their client before the worker stored a result.
    abandoned: BTreeSet<u64>,
    waiters: usize,
    shutting_down: bool,
    next_session: u64,
    served: u64,
}

struct Shared {
    state: Mutex<AdmissionState>,
    /// Signalled when a partition frees (or at shutdown) — admission queue.
    freed: Condvar,
}

// ---------------------------------------------------------------------------
// Partition workers
// ---------------------------------------------------------------------------

struct SessionWork {
    session: u64,
    d: usize,
    warmup: Arc<Vec<f32>>,
    inbox: SessionInbox,
    scores: Sender<Flit>,
}

/// Everything a resident partition worker owns for its lifetime.
struct WorkerEnv {
    id: usize,
    rm: RmKind,
    r: usize,
    seed: u64,
    hyper: DetectorHyper,
    chunk: usize,
    exec: ExecMode,
    quantize: bool,
    /// Configured lane count: each session episode rebuilds the RM with
    /// this many sub-detector lanes (clamped to the RM's ensemble size).
    lanes: usize,
    /// Resident lane workers, spawned once at server start and shared by
    /// every session episode this partition serves — lane threads live as
    /// long as the partition worker itself, never per session or burst.
    pool: Option<LanePool>,
    fpga: Option<(RuntimeHandle, Registry)>,
    dfx: DfxManager,
    dfx_cfg: DfxCfg,
    ctl: Arc<PblockCtl>,
    decoupler: Arc<Decoupler>,
    shared: Arc<Shared>,
    /// Fault-injection + recovery config; `enabled = false` keeps every
    /// fault hook out of the episode's service loop.
    faults: FaultsCfg,
}

fn worker_loop(env: WorkerEnv, mut scripted: Vec<ScriptedSwap>, jobs: Receiver<SessionWork>) {
    while let Ok(work) = jobs.recv() {
        let SessionWork { session, d, warmup, inbox, scores } = work;
        let mut outcome = serve_episode(&env, &mut scripted, d, &warmup, inbox, scores.clone());
        {
            let mut st = env.shared.state.lock().unwrap();
            // End-of-session boundary, atomic with the admission state:
            // once `active` is gone, `schedule_swap` refuses (it re-checks
            // under this lock), and any swap armed before that is cleared
            // here — a replacement RM staged against this session's stream
            // can never fire on the next one. Force-closing the inbox
            // unblocks a producer stuck in backpressure after the service
            // loop already ended (e.g. it failed mid-session): its next
            // send fails fast instead of waiting on a drain that will
            // never come.
            if let Some(a) = st.active.remove(&env.id) {
                a.door.force_close();
            }
            outcome.discarded_swaps += env.ctl.swap.clear_pending() as u64;
            if !st.abandoned.remove(&session) {
                st.results.insert(session, outcome);
                while st.results.len() > MAX_RETAINED_OUTCOMES {
                    st.results.pop_first();
                }
            }
            if !st.shutting_down {
                st.free.insert(env.id);
            }
            st.served += 1;
        }
        env.shared.freed.notify_all();
        // Dropping the worker's score sender last closes the session's
        // score channel — by then the outcome is already visible, so a
        // client draining in `close()` never races the bookkeeping.
        drop(scores);
    }
}

/// Serve exactly one session on this partition: fresh RM (same seed/warmup
/// recipe as the one-shot fabric), scripted swaps armed, adaptive controller
/// watching if configured, then the ordinary pblock service loop until
/// TLAST / hang-up / force-close.
fn serve_episode(
    env: &WorkerEnv,
    scripted: &mut Vec<ScriptedSwap>,
    d: usize,
    warmup: &[f32],
    inbox: SessionInbox,
    tx: Sender<Flit>,
) -> SessionOutcome {
    let failed = |error: String| SessionOutcome {
        report: None,
        swap_events: Vec::new(),
        adaptive_swaps: 0,
        discarded_swaps: 0,
        fault_events: Vec::new(),
        error: Some(error),
    };
    let fpga = env.fpga.as_ref().map(|(h, r)| (h, r));
    let mut rm = match LoadedRm::build(
        env.rm,
        env.r,
        d,
        env.seed,
        &env.hyper,
        warmup,
        fpga,
        env.quantize,
        env.lanes,
    ) {
        Ok(rm) => rm,
        Err(e) => return failed(format!("building RM: {e:#}")),
    };
    if let Err(e) = rm.reset() {
        return failed(format!("resetting RM: {e:#}"));
    }
    env.ctl.swap.begin_run();
    // Scripted schedule ([fabric.dfx.swap.N]): consumed by the partition's
    // first session, mirroring how `Fabric::new` arms it for the first run.
    for s in scripted.drain(..) {
        let staged = env.dfx.stage(
            env.id,
            s.rm,
            s.r,
            d,
            env.seed,
            &env.hyper,
            warmup,
            fpga,
            env.quantize,
            s.at_flit,
            s.dark_flits,
            env.dfx_cfg.policy,
            env.chunk,
            env.dfx_cfg.samples_per_sec,
            env.lanes,
        );
        match staged {
            Ok(swap) => env.ctl.swap.schedule(swap),
            // Mirror `Fabric::new`, which hard-fails when a scripted swap
            // cannot be staged: serving the session without it would
            // silently break the advertised Fabric::run parity. The
            // client sees the error from `close()`.
            Err(e) => {
                return failed(format!("arming scripted swap for pblock {}: {e:#}", env.id))
            }
        }
    }
    // Adaptive live DFX: one controller per adaptive session, watching this
    // partition only — it shares the same drift machinery as `Fabric::run`.
    let controller = match (env.dfx_cfg.adaptive && env.decoupler.is_enabled(), kind_of(env.rm)) {
        (true, Some(kind)) => {
            env.ctl.stats.arm(env.dfx_cfg.window, env.dfx_cfg.baseline);
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let cenv = ControllerEnv {
                dfx: env.dfx.clone(),
                cfg: env.dfx_cfg.clone(),
                hyper: env.hyper,
                chunk: env.chunk,
                quantize: env.quantize,
                fpga: env.fpga.clone(),
            };
            let targets = vec![ControllerTarget {
                pblock: env.id,
                ctl: Arc::clone(&env.ctl),
                kind,
                d,
                warmup: warmup.to_vec(),
                seed: env.seed,
                lanes: env.lanes,
            }];
            let handle = hotswap::spawn_controller(cenv, targets, Arc::clone(&stop));
            Some((stop, handle))
        }
        _ => None,
    };
    // Fault campaign, per session: arm the per-flit hooks, schedule this
    // partition's scripted injections (an open-ended session has no flit
    // horizon, so rate-based injections only apply to `Fabric::run`), and
    // watch the episode with a single-target supervisor running the same
    // retry → reload → quarantine ladder as the one-shot fabric. Spawned
    // after every early return above so the thread can never leak.
    let fault_supervisor = if env.faults.enabled {
        env.ctl.health.arm(env.faults.checkpoint_every_flits, env.faults.reload_wait_ms);
        env.ctl.faults.bind(env.id);
        env.ctl.faults.clear_pending();
        env.ctl.checkpoint.clear();
        match FaultInjector::plan(&env.faults, env.seed, &[env.id], 0) {
            Ok(plan) => env
                .ctl
                .faults
                .schedule(plan.into_iter().filter(|f| f.pblock == env.id).collect()),
            Err(e) => {
                env.ctl.health.disarm();
                // Stop the adaptive controller before bailing so the
                // thread never outlives its episode.
                if let Some((stop, handle)) = controller {
                    stop.store(true, std::sync::atomic::Ordering::SeqCst);
                    let _ = handle.join();
                }
                return failed(format!("planning fault injections: {e:#}"));
            }
        }
        if let Some(pool) = env.pool.as_ref() {
            pool.arm_faults();
        }
        kind_of(env.rm).map(|kind| {
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let senv = SupervisorEnv {
                dfx: env.dfx.clone(),
                faults: env.faults.clone(),
                hyper: env.hyper,
                chunk: env.chunk,
                samples_per_sec: env.dfx_cfg.samples_per_sec,
                policy: env.dfx_cfg.policy,
            };
            let targets = vec![SupervisorTarget {
                pblock: env.id,
                ctl: Arc::clone(&env.ctl),
                decoupler: Arc::clone(&env.decoupler),
                kind,
                r: env.r,
                d,
                seed: env.seed,
                warmup: warmup.to_vec(),
                lanes: env.lanes,
                quantize: env.quantize,
            }];
            let handle = supervisor::spawn_supervisor(senv, targets, Arc::clone(&stop));
            (stop, handle)
        })
    } else {
        None
    };
    let served = Pblock::service_mode(
        &mut rm,
        &env.decoupler,
        &env.ctl,
        inbox,
        tx,
        env.exec,
        env.pool.as_ref(),
    );
    let adaptive_swaps = match controller {
        Some((stop, handle)) => {
            stop.store(true, std::sync::atomic::Ordering::SeqCst);
            handle.join().unwrap_or(0)
        }
        None => 0,
    };
    if let Some((stop, handle)) = fault_supervisor {
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = handle.join();
    }
    let mut fault_events = Vec::new();
    if env.faults.enabled {
        // Session boundary: collect the fault log, disarm the hooks and
        // drop the episode's checkpoints. A quarantine is lifted here —
        // the next session builds a fresh RM, so the region is trusted
        // again (mirroring a full reconfiguration of the partition).
        fault_events = env.ctl.faults.take_events();
        fault_events.sort_by_key(|e| e.at_flit);
        env.ctl.health.disarm();
        env.ctl.checkpoint.clear();
        if env.decoupler.is_quarantined() {
            env.decoupler.lift_quarantine();
        }
    }
    if env.ctl.stats.is_armed() {
        env.ctl.stats.disarm();
    }
    // Swaps still pending are cleared by the caller inside the admission
    // lock (atomic with removing the active-session entry), so a racing
    // `schedule_swap` can never leak a stale RM into the next session.
    let swap_events = env.ctl.swap.take_events();
    match served {
        Ok(report) => SessionOutcome {
            report: Some(report),
            swap_events,
            adaptive_swaps,
            discarded_swaps: 0,
            fault_events,
            error: None,
        },
        Err(e) => SessionOutcome {
            report: None,
            swap_events,
            adaptive_swaps,
            discarded_swaps: 0,
            fault_events,
            error: Some(format!("{e:#}")),
        },
    }
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

struct PartitionHandle {
    rm: RmKind,
    /// Configured lane count (replacement RMs staged by `schedule_swap`
    /// keep the partition's lane layout).
    lanes: usize,
    /// Job queue into the resident worker; mutexed because `std` senders
    /// are not `Sync` and `open` is called from many client threads.
    jobs: Mutex<Sender<SessionWork>>,
    ctl: Arc<PblockCtl>,
    decoupler: Arc<Decoupler>,
}

/// Summary returned by [`FabricServer::shutdown`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerReport {
    /// Sessions fully served over the server's lifetime.
    pub sessions_served: u64,
}

/// A persistent, multi-session streaming service over the fabric's pblock
/// partitions. See the module docs for the session lifecycle.
pub struct FabricServer {
    cfg: FseadConfig,
    runtime: Option<Mutex<Runtime>>,
    shared: Arc<Shared>,
    partitions: BTreeMap<usize, PartitionHandle>,
    workers: Vec<JoinHandle<()>>,
}

/// What a client wants from [`FabricServer::open`].
#[derive(Clone, Debug)]
pub struct SessionSpec {
    /// Stream dimensionality.
    pub d: usize,
    /// Row-major `[n, d]` warm-up prefix for detector parameter ranges —
    /// pass the same prefix `Fabric::new` would see (`Dataset::warmup`) for
    /// bit-identical scores.
    pub warmup: Vec<f32>,
    /// Pin the session to one partition (1-based pblock id); `None` takes
    /// any free partition.
    pub pblock: Option<usize>,
}

impl SessionSpec {
    pub fn new(d: usize, warmup: Vec<f32>) -> SessionSpec {
        SessionSpec { d, warmup, pblock: None }
    }

    /// Spec for streaming `ds` — warm-up mirrors what `Fabric::new` uses.
    pub fn for_dataset(ds: &Dataset, window: usize) -> SessionSpec {
        SessionSpec::new(ds.d, ds.warmup(window).to_vec())
    }

    pub fn on_pblock(mut self, id: usize) -> SessionSpec {
        self.pblock = Some(id);
        self
    }
}

impl FabricServer {
    /// Start the server: one resident service worker per configured
    /// (non-empty) pblock. The fabric stays up until [`FabricServer::shutdown`]
    /// or drop.
    pub fn start(cfg: FseadConfig) -> Result<FabricServer> {
        cfg.validate()?;
        if !cfg.combos.is_empty() {
            bail!(
                "fabric::server serves the Fig 7(a) multi-stream pattern (direct pblock→host \
                 routes); combo joins are not supported — drop the [combo.N] sections"
            );
        }
        let active: Vec<_> = cfg.pblocks.iter().filter(|p| p.rm != RmKind::Empty).collect();
        if active.is_empty() {
            bail!("no pblocks configured — nothing to serve");
        }
        let runtime = if cfg.use_fpga {
            Some(Runtime::start(&cfg.artifact_dir).context("starting PJRT runtime")?)
        } else {
            None
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(AdmissionState {
                free: active.iter().map(|p| p.id).collect(),
                ..Default::default()
            }),
            freed: Condvar::new(),
        });
        let mut partitions = BTreeMap::new();
        let mut workers = Vec::new();
        for p in &active {
            let ctl = Arc::new(PblockCtl::default());
            let decoupler = Arc::new(Decoupler::new());
            let (jobs_tx, jobs_rx) = std::sync::mpsc::channel::<SessionWork>();
            let scripted: Vec<ScriptedSwap> =
                cfg.dfx.swaps.iter().filter(|s| s.pblock == p.id).copied().collect();
            // The configured lane count is staged as-is (each RM build
            // clamps to its own ensemble size — identical to the one-shot
            // fabric, keeping server-vs-fabric swaps bit-identical); only
            // the pool is sized by the partition's initial r.
            let lanes = cfg.lanes_for(p);
            let pool_size = lanes.min(p.r.max(1));
            // Lane workers are resident: spawned here, once per partition,
            // before the first session, and reused by every episode.
            let pool = (!cfg.use_fpga && pool_size > 1 && matches!(p.rm, RmKind::Detector(_)))
                .then(|| LanePool::new(pool_size));
            let env = WorkerEnv {
                id: p.id,
                rm: p.rm,
                r: p.r,
                seed: pblock_seed(cfg.seed, p.id),
                hyper: cfg.hyper,
                chunk: cfg.chunk,
                exec: cfg.exec,
                quantize: cfg.use_fpga,
                lanes,
                pool,
                fpga: runtime.as_ref().map(|rt| (rt.handle(), rt.registry().clone())),
                dfx: DfxManager::default(),
                dfx_cfg: cfg.dfx.clone(),
                ctl: Arc::clone(&ctl),
                decoupler: Arc::clone(&decoupler),
                shared: Arc::clone(&shared),
                faults: cfg.faults.clone(),
            };
            let handle = std::thread::Builder::new()
                .name(format!("serve-p{}", p.id))
                .spawn(move || worker_loop(env, scripted, jobs_rx))
                .expect("spawn partition worker");
            partitions.insert(
                p.id,
                PartitionHandle {
                    rm: p.rm,
                    lanes,
                    jobs: Mutex::new(jobs_tx),
                    ctl,
                    decoupler: Arc::clone(&decoupler),
                },
            );
            workers.push(handle);
        }
        Ok(FabricServer { cfg, runtime: runtime.map(Mutex::new), shared, partitions, workers })
    }

    pub fn config(&self) -> &FseadConfig {
        &self.cfg
    }

    /// Served partition ids, in pblock order.
    pub fn partitions(&self) -> Vec<usize> {
        self.partitions.keys().copied().collect()
    }

    /// RM kind configured for partition `id`.
    pub fn partition_rm(&self, id: usize) -> Option<RmKind> {
        self.partitions.get(&id).map(|p| p.rm)
    }

    /// The partition's decoupler (isolation control, as on [`super::Fabric`]).
    pub fn decoupler(&self, id: usize) -> Option<&Arc<Decoupler>> {
        self.partitions.get(&id).map(|p| &p.decoupler)
    }

    /// Open a session, blocking in the admission queue while every eligible
    /// partition is busy. Fails once `max_waiters` clients are already
    /// queued, or at shutdown.
    pub fn open(&self, spec: SessionSpec) -> Result<Session> {
        Ok(self.open_inner(spec, true)?.expect("blocking open returns a session"))
    }

    /// Non-blocking open: `Ok(None)` when no eligible partition is free.
    pub fn try_open(&self, spec: SessionSpec) -> Result<Option<Session>> {
        self.open_inner(spec, false)
    }

    fn open_inner(&self, spec: SessionSpec, block: bool) -> Result<Option<Session>> {
        if spec.d == 0 {
            bail!("session dimensionality must be > 0");
        }
        if spec.warmup.len() % spec.d != 0 {
            bail!(
                "warmup length {} is not a whole number of samples (d = {})",
                spec.warmup.len(),
                spec.d
            );
        }
        if let Some(id) = spec.pblock {
            if !self.partitions.contains_key(&id) {
                bail!("no served partition {id}");
            }
        }
        let mut st = self.shared.state.lock().unwrap();
        let mut waiting = false;
        let id = loop {
            if st.shutting_down {
                if waiting {
                    st.waiters -= 1;
                }
                bail!("server is shutting down");
            }
            let pick = match spec.pblock {
                Some(id) => st.free.contains(&id).then_some(id),
                None => st.free.first().copied(),
            };
            if let Some(id) = pick {
                if waiting {
                    st.waiters -= 1;
                }
                st.free.remove(&id);
                break id;
            }
            if !block {
                return Ok(None);
            }
            if !waiting {
                if st.waiters >= self.cfg.server.max_waiters {
                    bail!(
                        "admission queue is full ({} session(s) already waiting)",
                        st.waiters
                    );
                }
                st.waiters += 1;
                waiting = true;
            }
            st = self.shared.freed.wait(st).unwrap();
        };
        let session = st.next_session;
        st.next_session += 1;
        let warmup = Arc::new(spec.warmup);
        let (inbox_tx, inbox_rx) = SessionInbox::bounded(self.cfg.server.inbox_flits);
        st.active.insert(
            id,
            ActiveSession { session, d: spec.d, warmup: Arc::clone(&warmup), door: inbox_rx.ctl() },
        );
        drop(st);
        let (score_tx, score_rx) = Port::link();
        let work =
            SessionWork { session, d: spec.d, warmup, inbox: inbox_rx, scores: score_tx };
        let sent = self.partitions[&id].jobs.lock().unwrap().send(work).is_ok();
        if !sent {
            // Worker is gone (panicked): the partition is out of service.
            self.shared.state.lock().unwrap().active.remove(&id);
            bail!("partition {id}: service worker has exited");
        }
        Ok(Some(Session {
            id: session,
            pblock: id,
            d: spec.d,
            chunk: self.cfg.chunk,
            tx: Some(inbox_tx),
            rx: score_rx,
            seq: 0,
            pushed: 0,
            staged: Vec::new(),
            full_mask: vec![1.0f32; self.cfg.chunk].into(),
            shared: Arc::clone(&self.shared),
            finished: false,
        }))
    }

    /// Arm an in-flight RM swap on partition `id` at session-input flit
    /// `at_flit` of its **active** session — the server-side counterpart of
    /// [`super::Fabric::schedule_swap`], staged against the live session's
    /// stream. Returns (modelled download ms, dark-window flits).
    pub fn schedule_swap(
        &self,
        id: usize,
        at_flit: u64,
        rm: RmKind,
        r: usize,
        dark_flits: Option<u64>,
    ) -> Result<(f64, u64)> {
        let part = self
            .partitions
            .get(&id)
            .with_context(|| format!("no served partition {id}"))?;
        if !part.decoupler.is_enabled() {
            bail!("pblock {id}: decoupler is disabled — cannot hot-swap without isolation");
        }
        let (session, d, warmup) = {
            let st = self.shared.state.lock().unwrap();
            let a = st.active.get(&id).with_context(|| {
                format!("pblock {id} has no active session — swaps are staged against a live stream")
            })?;
            (a.session, a.d, Arc::clone(&a.warmup))
        };
        let fpga = self.runtime.as_ref().map(|rt| {
            let rt = rt.lock().unwrap();
            (rt.handle(), rt.registry().clone())
        });
        let swap = DfxManager::default().stage(
            id,
            rm,
            r,
            d,
            pblock_seed(self.cfg.seed, id),
            &self.cfg.hyper,
            &warmup,
            fpga.as_ref().map(|(h, reg)| (h, reg)),
            self.cfg.use_fpga,
            at_flit,
            dark_flits,
            self.cfg.dfx.policy,
            self.cfg.chunk,
            self.cfg.dfx.samples_per_sec,
            part.lanes,
        )?;
        let info = (swap.model_ms, swap.dark_flits);
        // Arm under the admission lock: the worker clears pending swaps in
        // the same critical section that retires the active session, so a
        // swap staged against a session that ended (or was replaced by a
        // newer one) is refused here instead of leaking into the wrong
        // episode.
        let st = self.shared.state.lock().unwrap();
        if st.active.get(&id).map(|a| a.session) != Some(session) {
            bail!("pblock {id}: the session ended while the swap was being staged");
        }
        part.ctl.swap.schedule(swap);
        Ok(info)
    }

    /// Sessions fully served so far.
    pub fn sessions_served(&self) -> u64 {
        self.shared.state.lock().unwrap().served
    }

    /// Stop admitting, force-close the inboxes of sessions still open, let
    /// every resident worker finish its current episode and join them.
    pub fn shutdown(mut self) -> Result<ServerReport> {
        self.shutdown_impl()
    }

    fn shutdown_impl(&mut self) -> Result<ServerReport> {
        let doors: Vec<InboxCtl> = {
            let mut st = self.shared.state.lock().unwrap();
            st.shutting_down = true;
            st.active.values().map(|a| a.door.clone()).collect()
        };
        self.shared.freed.notify_all();
        for door in doors {
            door.force_close();
        }
        // Closing the job queues ends the resident workers after their
        // current episode.
        self.partitions.clear();
        let mut panicked = 0usize;
        for w in self.workers.drain(..) {
            if w.join().is_err() {
                panicked += 1;
            }
        }
        if panicked > 0 {
            bail!("{panicked} partition worker(s) panicked");
        }
        Ok(ServerReport { sessions_served: self.shared.state.lock().unwrap().served })
    }
}

impl Drop for FabricServer {
    fn drop(&mut self) {
        let _ = self.shutdown_impl();
    }
}

// ---------------------------------------------------------------------------
// Client session
// ---------------------------------------------------------------------------

/// Result of [`Session::close`].
#[derive(Clone, Debug)]
pub struct SessionClose {
    /// Scores not yet collected via `recv_scores`/`poll_scores`, in stream
    /// order.
    pub scores: Vec<f32>,
    /// Samples pushed over the session (including the padded tail's valid
    /// rows).
    pub samples: u64,
    /// Flits sent, including the TLAST flit.
    pub flits: u64,
    /// True when `close()` cut the stream mid-chunk: the final flit carries
    /// `tail_valid` valid rows zero-padded to the chunk size. Reported here
    /// — padding is never silent.
    pub padded_tail: bool,
    pub tail_valid: usize,
    /// The partition's service report for this session.
    pub report: PblockReport,
    /// In-flight RM swaps executed during the session.
    pub swap_events: Vec<SwapEvent>,
    /// Swaps issued by the adaptive controller during the session.
    pub adaptive_swaps: u64,
    /// Swaps armed but never executed — discarded at episode boundaries so
    /// a stale replacement RM (staged for another stream) can never fire.
    pub discarded_swaps: u64,
    /// Fault injections, detections and recovery-ladder transitions
    /// recorded during the session (empty unless `[fabric.faults]`
    /// `enabled = true`), in flit order.
    pub fault_events: Vec<FaultEvent>,
}

/// A client's handle on one streaming session. Push sample chunks, receive
/// score chunks asynchronously, close to flush with TLAST semantics.
pub struct Session {
    id: u64,
    pblock: usize,
    d: usize,
    chunk: usize,
    tx: Option<InboxSender>,
    rx: Receiver<Flit>,
    seq: u64,
    pushed: u64,
    /// Samples staged toward the next full chunk (`< chunk × d` values).
    staged: Vec<f32>,
    /// All-ones mask shared by every full flit of this session (one
    /// allocation, like `ChunkStream`).
    full_mask: Arc<[f32]>,
    shared: Arc<Shared>,
    finished: bool,
}

impl Session {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The pblock partition serving this session.
    pub fn pblock(&self) -> usize {
        self.pblock
    }

    /// Samples pushed so far (staged samples included).
    pub fn samples_pushed(&self) -> u64 {
        self.pushed + (self.staged.len() / self.d) as u64
    }

    /// Push `samples` (row-major, a whole number of rows). Full chunks are
    /// cut into flits exactly like the input DMA and sent through the
    /// bounded inbox — this call **blocks** while the inbox is full.
    /// Each sample is copied exactly once (into its flit buffer), so a
    /// large push is O(n) regardless of the chunk size.
    pub fn push(&mut self, samples: &[f32]) -> Result<()> {
        if samples.len() % self.d != 0 {
            bail!(
                "push of {} values is not a whole number of samples (d = {})",
                samples.len(),
                self.d
            );
        }
        let flit_len = self.chunk * self.d;
        let mut rest = samples;
        // Complete a partially staged chunk first.
        if !self.staged.is_empty() {
            let take = (flit_len - self.staged.len()).min(rest.len());
            self.staged.extend_from_slice(&rest[..take]);
            rest = &rest[take..];
            if self.staged.len() == flit_len {
                let full = std::mem::take(&mut self.staged);
                self.emit_full(full)?;
            }
        }
        // Cut whole flits straight from the input slice.
        while rest.len() >= flit_len {
            self.emit_full(rest[..flit_len].to_vec())?;
            rest = &rest[flit_len..];
        }
        self.staged.extend_from_slice(rest);
        Ok(())
    }

    fn emit_full(&mut self, data: Vec<f32>) -> Result<()> {
        let flit = Flit {
            seq: self.seq,
            data: data.into(),
            mask: self.full_mask.clone(),
            n_valid: self.chunk,
            last: false,
        };
        self.seq += 1;
        self.pushed += self.chunk as u64;
        self.send(flit)
    }

    fn send(&self, flit: Flit) -> Result<()> {
        match self.tx.as_ref().expect("session already closed").send(flit) {
            Ok(()) => Ok(()),
            Err(InboxClosed) => {
                bail!("session closed by the server (shutdown or partition failure)")
            }
        }
    }

    /// Non-blocking: drain the score flits that have already arrived,
    /// unpadded into plain per-sample scores.
    pub fn poll_scores(&mut self) -> Vec<f32> {
        let mut out = Vec::new();
        while let Ok(flit) = self.rx.try_recv() {
            unpad_into(&flit, &mut out);
        }
        out
    }

    /// Block for the next score flit; `None` once the session's score
    /// stream has ended.
    pub fn recv_scores(&mut self) -> Option<Vec<f32>> {
        let flit = self.rx.recv().ok()?;
        let mut out = Vec::new();
        unpad_into(&flit, &mut out);
        Some(out)
    }

    /// Flush with TLAST semantics and tear the session down: a partial
    /// trailing chunk is zero-padded into the final flit (**reported** via
    /// [`SessionClose::padded_tail`]), remaining scores are drained, and
    /// the partition returns to the free pool.
    pub fn close(mut self) -> Result<SessionClose> {
        let tail_valid = self.staged.len() / self.d;
        let rows = self.chunk;
        let mut data = vec![0f32; rows * self.d];
        data[..self.staged.len()].copy_from_slice(&self.staged);
        let mut mask = vec![0f32; rows];
        mask[..tail_valid].fill(1.0);
        let last = Flit {
            seq: self.seq,
            data: data.into(),
            mask: mask.into(),
            n_valid: tail_valid,
            last: true,
        };
        self.seq += 1;
        self.pushed += tail_valid as u64;
        self.staged.clear();
        // Best effort: at shutdown the inbox is already force-closed and
        // the flush is lost — the drain below still terminates because the
        // worker ends the episode either way.
        let flushed = self.send(last).is_ok();
        drop(self.tx.take());
        let mut scores = Vec::new();
        while let Ok(flit) = self.rx.recv() {
            unpad_into(&flit, &mut scores);
        }
        self.finished = true;
        let outcome = self
            .shared
            .state
            .lock()
            .unwrap()
            .results
            .remove(&self.id)
            .context("session outcome missing — partition worker terminated abnormally")?;
        if let Some(err) = outcome.error {
            bail!("partition {} service failed: {err}", self.pblock);
        }
        if !flushed {
            bail!("session was force-closed by the server before the TLAST flush");
        }
        Ok(SessionClose {
            scores,
            samples: self.pushed,
            flits: self.seq,
            padded_tail: tail_valid > 0,
            tail_valid,
            report: outcome.report.unwrap_or_default(),
            swap_events: outcome.swap_events,
            adaptive_swaps: outcome.adaptive_swaps,
            discarded_swaps: outcome.discarded_swaps,
            fault_events: outcome.fault_events,
        })
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if self.finished {
            return;
        }
        // Abandoned without close(): hang up the inbox (the worker finishes
        // the episode and frees the partition) and disown the outcome.
        drop(self.tx.take());
        let mut st = self.shared.state.lock().unwrap();
        if st.results.remove(&self.id).is_none() {
            st.abandoned.insert(self.id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PblockCfg;
    use crate::detectors::prng::Prng;
    use crate::detectors::{DetectorKind, DetectorSpec};
    use crate::fabric::message::score_chunk;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    fn flit(seq: u64) -> Flit {
        score_chunk(seq, vec![seq as f32], vec![1.0], 1, false)
    }

    fn tiny_cfg(chunk: usize, kind: DetectorKind, r: usize) -> FseadConfig {
        let mut cfg = FseadConfig::default();
        cfg.use_fpga = false;
        cfg.chunk = chunk;
        cfg.hyper.window = 16;
        cfg.hyper.bins = 8;
        cfg.hyper.modulus = 32;
        cfg.hyper.k = 4;
        cfg.pblocks.push(PblockCfg { id: 1, rm: RmKind::Detector(kind), r, stream: 0, lanes: 0 });
        cfg
    }

    fn gaussian_data(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    #[test]
    fn inbox_blocks_producer_at_capacity() {
        let (tx, mut rx) = SessionInbox::bounded(2);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = Arc::clone(&sent);
        let producer = std::thread::spawn(move || {
            for seq in 0..5u64 {
                tx.send(flit(seq)).unwrap();
                sent2.fetch_add(1, Ordering::SeqCst);
            }
        });
        // The producer fills the inbox and then blocks on the third send.
        let t0 = Instant::now();
        while sent.load(Ordering::SeqCst) < 2 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(sent.load(Ordering::SeqCst), 2, "producer must block at capacity");
        // Draining one flit unblocks exactly one more send.
        assert_eq!(rx.recv_flit().unwrap().seq, 0);
        let t0 = Instant::now();
        while sent.load(Ordering::SeqCst) < 3 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(sent.load(Ordering::SeqCst), 3);
        // Drain the rest: order is FIFO, nothing dropped, nothing reordered.
        let mut seqs = vec![];
        while let Some(f) = rx.recv_flit() {
            seqs.push(f.seq);
        }
        producer.join().unwrap();
        assert_eq!(seqs, vec![1, 2, 3, 4]);
    }

    #[test]
    fn inbox_force_close_unblocks_producer_and_fails_fast() {
        let (tx, mut rx) = SessionInbox::bounded(1);
        tx.send(flit(0)).unwrap();
        let ctl = rx.ctl();
        let blocked = std::thread::spawn(move || tx.send(flit(1)));
        std::thread::sleep(Duration::from_millis(20));
        ctl.force_close();
        assert!(blocked.join().unwrap().is_err(), "blocked send must fail fast");
        assert!(rx.recv_flit().is_none(), "force-close discards queued flits");
    }

    #[test]
    fn inbox_try_recv_is_nonblocking() {
        let (tx, mut rx) = SessionInbox::bounded(4);
        assert!(rx.try_recv_flit().is_none());
        tx.send(flit(7)).unwrap();
        assert_eq!(rx.try_recv_flit().unwrap().seq, 7);
        drop(tx);
        assert!(rx.recv_flit().is_none(), "producer hang-up ends the stream");
    }

    #[test]
    fn session_scores_match_standalone_detector() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 3);
        let data = gaussian_data(40, 3, 11);
        let server = FabricServer::start(cfg.clone()).unwrap();
        let mut session =
            server.open(SessionSpec::new(3, data[..16 * 3].to_vec()).on_pblock(1)).unwrap();
        // Irregular pushes: re-chunking must not change the arithmetic.
        session.push(&data[..7 * 3]).unwrap();
        session.push(&data[7 * 3..29 * 3]).unwrap();
        session.push(&data[29 * 3..]).unwrap();
        let closed = session.close().unwrap();
        assert_eq!(closed.samples, 40);
        assert_eq!(closed.scores.len(), 40);
        let mut spec = DetectorSpec::new(DetectorKind::Loda, 3, 3, pblock_seed(cfg.seed, 1));
        spec.window = cfg.hyper.window;
        spec.bins = cfg.hyper.bins;
        let mut det = spec.build(&data[..16 * 3]);
        let expect = det.run_stream(&data);
        assert_eq!(closed.scores, expect, "session scores must be bit-identical");
    }

    #[test]
    fn close_mid_chunk_reports_padded_tail() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(13, 2, 3);
        let server = FabricServer::start(cfg).unwrap();
        let mut s = server.open(SessionSpec::new(2, data[..8 * 2].to_vec())).unwrap();
        s.push(&data).unwrap(); // 13 samples, chunk 8 → 1 full flit + 5 staged
        let closed = s.close().unwrap();
        assert!(closed.padded_tail, "mid-chunk close must be reported");
        assert_eq!(closed.tail_valid, 5);
        assert_eq!(closed.samples, 13);
        assert_eq!(closed.scores.len(), 13, "padding rows never score");
        assert_eq!(closed.flits, 2);
    }

    #[test]
    fn close_on_chunk_boundary_has_no_padding() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(16, 2, 4);
        let server = FabricServer::start(cfg).unwrap();
        let mut s = server.open(SessionSpec::new(2, data.clone())).unwrap();
        s.push(&data).unwrap();
        let closed = s.close().unwrap();
        assert!(!closed.padded_tail);
        assert_eq!(closed.tail_valid, 0);
        assert_eq!(closed.scores.len(), 16);
    }

    #[test]
    fn admission_refuses_when_queue_is_full() {
        let mut cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        cfg.server.max_waiters = 0;
        let data = gaussian_data(8, 2, 5);
        let server = FabricServer::start(cfg).unwrap();
        let _busy = server.open(SessionSpec::new(2, data.clone())).unwrap();
        // The one partition is busy and nobody may queue.
        let err = server.open(SessionSpec::new(2, data.clone())).unwrap_err();
        assert!(err.to_string().contains("admission queue"), "{err}");
        assert!(server.try_open(SessionSpec::new(2, data)).unwrap().is_none());
    }

    #[test]
    fn shutdown_with_open_session_does_not_deadlock() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(24, 2, 6);
        let server = FabricServer::start(cfg).unwrap();
        let mut s = server.open(SessionSpec::new(2, data[..16].to_vec())).unwrap();
        s.push(&data[..16 * 2]).unwrap();
        let report = server.shutdown().unwrap();
        assert_eq!(report.sessions_served, 1, "forced episode still completes");
        // The abandoned client fails fast instead of hanging: the next full
        // chunk hits the force-closed inbox.
        assert!(s.push(&data[..8 * 2]).is_err());
    }

    #[test]
    fn dropped_session_frees_the_partition() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let data = gaussian_data(8, 2, 7);
        let server = FabricServer::start(cfg).unwrap();
        {
            let mut s = server.open(SessionSpec::new(2, data.clone())).unwrap();
            s.push(&data).unwrap();
            // Dropped without close(): the worker finishes and frees RP-1.
        }
        let mut s = server.open(SessionSpec::new(2, data.clone())).unwrap();
        s.push(&data).unwrap();
        let closed = s.close().unwrap();
        assert_eq!(closed.scores.len(), 8);
        assert_eq!(server.sessions_served(), 2);
    }

    #[test]
    fn swap_needs_an_active_session() {
        let cfg = tiny_cfg(8, DetectorKind::Loda, 2);
        let server = FabricServer::start(cfg).unwrap();
        let err = server
            .schedule_swap(1, 2, RmKind::Detector(DetectorKind::RsHash), 2, Some(1))
            .unwrap_err();
        assert!(err.to_string().contains("no active session"), "{err}");
        server.decoupler(1).unwrap().set_enabled(false);
        let err = server
            .schedule_swap(1, 2, RmKind::Detector(DetectorKind::RsHash), 2, Some(1))
            .unwrap_err();
        assert!(err.to_string().contains("decoupler is disabled"), "{err}");
    }
}
