//! AXI4-Stream switch model (paper §3.3, Xilinx PG085 semantics).
//!
//! Register-programmed crossbar: one register per master selects the slave
//! it listens to. Arbitration follows the paper exactly: "when a slave
//! interface is connected to multiple masters, only the lowest numbered one
//! is used … the other is disabled", so each (master, slave) pair resolves
//! to at most one point-to-point connection. Routing is configured over the
//! AXI-Lite analogue ([`AxiSwitch::set_route`]) while the switch is idle,
//! then [`AxiSwitch::spawn`] instantiates the resolved connections as pump
//! threads. Pumps move flits whose payloads are shared `Arc<[f32]>`
//! buffers, so forwarding a transfer moves two pointers — the crossbar
//! never touches sample data.

use anyhow::{bail, Result};
use std::sync::mpsc::{Receiver, Sender};
use std::thread::JoinHandle;

use super::message::Flit;

/// Maximum ports per switch (Xilinx AXI4-Stream Switch IP limit the paper
/// works around by cascading switches).
pub const MAX_PORTS: usize = 16;

#[derive(Clone, Debug)]
pub struct AxiSwitch {
    name: String,
    n_slaves: usize,
    n_masters: usize,
    /// Routing registers: reg[master] = Some(slave).
    reg: Vec<Option<usize>>,
}

impl AxiSwitch {
    pub fn new(name: &str, n_slaves: usize, n_masters: usize) -> Result<AxiSwitch> {
        if n_slaves > MAX_PORTS || n_masters > MAX_PORTS {
            bail!("switch {name}: at most {MAX_PORTS} slave and master ports (cascade switches instead)");
        }
        Ok(AxiSwitch { name: name.to_string(), n_slaves, n_masters, reg: vec![None; n_masters] })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn n_slaves(&self) -> usize {
        self.n_slaves
    }

    pub fn n_masters(&self) -> usize {
        self.n_masters
    }

    /// Program one routing register (AXI-Lite write).
    pub fn set_route(&mut self, master: usize, slave: usize) -> Result<()> {
        if master >= self.n_masters {
            bail!("{}: master {master} out of range (< {})", self.name, self.n_masters);
        }
        if slave >= self.n_slaves {
            bail!("{}: slave {slave} out of range (< {})", self.name, self.n_slaves);
        }
        self.reg[master] = Some(slave);
        Ok(())
    }

    /// Disable a master interface (AXI-Lite write).
    pub fn disable(&mut self, master: usize) -> Result<()> {
        if master >= self.n_masters {
            bail!("{}: master {master} out of range", self.name);
        }
        self.reg[master] = None;
        Ok(())
    }

    pub fn route_of(&self, master: usize) -> Option<usize> {
        self.reg.get(master).copied().flatten()
    }

    /// Apply the arbitration rule: for each slave, the lowest-numbered
    /// master requesting it wins; higher-numbered requesters are disabled.
    /// Returns the effective master → slave map.
    pub fn resolve(&self) -> Vec<Option<usize>> {
        let mut taken = vec![false; self.n_slaves];
        let mut eff = vec![None; self.n_masters];
        for (m, reg) in self.reg.iter().enumerate() {
            if let Some(s) = *reg {
                if !taken[s] {
                    taken[s] = true;
                    eff[m] = Some(s);
                }
            }
        }
        eff
    }

    /// Instantiate the resolved crossbar over real channels: takes the slave
    /// receivers and master senders, spawns one pump thread per effective
    /// connection. Slots for disabled ports may be `None`.
    pub fn spawn(
        &self,
        mut slave_rx: Vec<Option<Receiver<Flit>>>,
        mut master_tx: Vec<Option<Sender<Flit>>>,
    ) -> Result<SwitchRun> {
        if slave_rx.len() != self.n_slaves || master_tx.len() != self.n_masters {
            bail!(
                "{}: port count mismatch (got {} slaves / {} masters)",
                self.name,
                slave_rx.len(),
                master_tx.len()
            );
        }
        let mut pumps = Vec::new();
        for (m, slave) in self.resolve().into_iter().enumerate() {
            let Some(s) = slave else { continue };
            let Some(rx) = slave_rx[s].take() else {
                bail!("{}: route M{m}←S{s} but slave {s} has no upstream", self.name);
            };
            let Some(tx) = master_tx[m].take() else {
                bail!("{}: route M{m}←S{s} but master {m} has no downstream", self.name);
            };
            let name = format!("{}-m{}", self.name, m);
            pumps.push(
                std::thread::Builder::new()
                    .name(name)
                    .spawn(move || {
                        let mut flits = 0u64;
                        // Forward until the upstream closes (TLAST + close).
                        for flit in rx.iter() {
                            if tx.send(flit).is_err() {
                                break; // downstream gone: disable route
                            }
                            flits += 1;
                        }
                        flits
                    })
                    .expect("spawn switch pump"),
            );
        }
        Ok(SwitchRun { pumps })
    }
}

/// Handle over a running crossbar; join to collect per-connection counters.
pub struct SwitchRun {
    pumps: Vec<JoinHandle<u64>>,
}

impl SwitchRun {
    /// Wait for every connection to drain; returns total flits moved.
    pub fn join(self) -> u64 {
        self.pumps.into_iter().map(|p| p.join().unwrap_or(0)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::message::{score_chunk, Port};

    #[test]
    fn arbitration_lowest_master_wins() {
        let mut sw = AxiSwitch::new("t", 4, 4).unwrap();
        sw.set_route(1, 2).unwrap();
        sw.set_route(3, 2).unwrap(); // loses to master 1
        let eff = sw.resolve();
        assert_eq!(eff, vec![None, Some(2), None, None]);
    }

    #[test]
    fn rejects_out_of_range() {
        let mut sw = AxiSwitch::new("t", 2, 2).unwrap();
        assert!(sw.set_route(2, 0).is_err());
        assert!(sw.set_route(0, 2).is_err());
        assert!(AxiSwitch::new("big", 17, 4).is_err());
    }

    #[test]
    fn disable_clears_route() {
        let mut sw = AxiSwitch::new("t", 2, 2).unwrap();
        sw.set_route(0, 1).unwrap();
        sw.disable(0).unwrap();
        assert_eq!(sw.resolve(), vec![None, None]);
    }

    #[test]
    fn pumps_move_flits_end_to_end() {
        let mut sw = AxiSwitch::new("t", 2, 2).unwrap();
        sw.set_route(0, 1).unwrap(); // M0 ← S1
        sw.set_route(1, 0).unwrap(); // M1 ← S0
        let (s0_tx, s0_rx) = Port::link();
        let (s1_tx, s1_rx) = Port::link();
        let (m0_tx, m0_rx) = Port::link();
        let (m1_tx, m1_rx) = Port::link();
        let run = sw
            .spawn(vec![Some(s0_rx), Some(s1_rx)], vec![Some(m0_tx), Some(m1_tx)])
            .unwrap();
        s0_tx.send(score_chunk(0, vec![1.0], vec![1.0], 1, true)).unwrap();
        s1_tx.send(score_chunk(0, vec![2.0], vec![1.0], 1, true)).unwrap();
        drop((s0_tx, s1_tx));
        assert_eq!(&m0_rx.recv().unwrap().data[..], &[2.0]); // M0 ← S1
        assert_eq!(&m1_rx.recv().unwrap().data[..], &[1.0]); // M1 ← S0
        assert_eq!(run.join(), 2);
    }

    #[test]
    fn unrouted_slave_is_dropped() {
        let sw = AxiSwitch::new("t", 1, 1).unwrap(); // no routes programmed
        let (s_tx, s_rx) = Port::link();
        let (m_tx, m_rx) = Port::link();
        let run = sw.spawn(vec![Some(s_rx)], vec![Some(m_tx)]).unwrap();
        drop(s_tx);
        assert_eq!(run.join(), 0);
        assert!(m_rx.recv().is_err()); // master sender dropped unused
    }

    #[test]
    fn route_to_missing_upstream_errors() {
        let mut sw = AxiSwitch::new("t", 2, 2).unwrap();
        sw.set_route(0, 0).unwrap();
        let (m_tx, _m_rx) = Port::link();
        let res = sw.spawn(vec![None, None], vec![Some(m_tx), None]);
        assert!(res.is_err());
    }
}
