//! DFX manager (paper §3.2, Table 13): swaps RMs in and out of pblocks at
//! run time and models partial-reconfiguration latency.
//!
//! The latency model is calibrated against paper Table 13: PYNQ bitstream
//! download cost is dominated by a fixed overhead (~578 ms) plus a term
//! proportional to the region size (LUT share of the device), reaching
//! ~610 ms for the largest AD pblock. The *actual* swap work here —
//! compiling/instantiating the artifact — is measured and reported
//! separately; `emulate_latency` optionally sleeps out the modelled time to
//! reproduce end-to-end behaviour.

use anyhow::{bail, Result};
use std::time::Instant;

use super::pblock::{LoadedRm, Pblock};
use crate::config::{DetectorHyper, RmKind};
use crate::hw::resources::TABLE6_BLOCKS;
use crate::runtime::{Registry, RuntimeHandle};

/// Latency model parameters (fit to Table 13).
#[derive(Clone, Copy, Debug)]
pub struct ReconfigModel {
    /// Fixed PYNQ DFX download overhead (ms).
    pub base_ms: f64,
    /// ms per √(% of device LUTs) — Table 13's times grow sublinearly with
    /// region size (per-frame transfer amortises against driver overhead).
    pub per_sqrt_lut_pct_ms: f64,
    /// Extra cost when the incoming bitstream is non-trivial logic
    /// (Table 13: Identity→Function is marginally slower on average).
    pub function_bias_ms: f64,
}

impl Default for ReconfigModel {
    fn default() -> Self {
        // Fit over Table 13's clusters: combo blocks (~0.63 % LUT, ~582 ms)
        // and AD pblocks (6.2–8.7 % LUT, 604–610 ms). Max residual ≈ 2.5 ms.
        ReconfigModel { base_ms: 571.8, per_sqrt_lut_pct_ms: 13.3, function_bias_ms: 0.4 }
    }
}

impl ReconfigModel {
    /// Modelled reconfiguration time for a named block (RP-1..7, COMBO1..3).
    pub fn time_ms(&self, block: &str, to_function: bool) -> Option<f64> {
        let b = TABLE6_BLOCKS.iter().find(|b| b.name.eq_ignore_ascii_case(block))?;
        let bias = if to_function { self.function_bias_ms } else { 0.0 };
        Some(self.base_ms + self.per_sqrt_lut_pct_ms * b.lut_pct.sqrt() + bias)
    }

    /// Model time for an AD pblock by 1-based id.
    pub fn time_ms_pblock(&self, id: usize, to_function: bool) -> Option<f64> {
        self.time_ms(&format!("RP-{id}"), to_function)
    }
}

/// Outcome of one partial reconfiguration.
#[derive(Clone, Debug)]
pub struct ReconfigReport {
    pub pblock: usize,
    pub from: String,
    pub to: String,
    /// Modelled DFX bitstream-download latency (Table 13 analogue).
    pub model_ms: f64,
    /// Measured swap time in this system (artifact compile + instantiate).
    pub actual_ms: f64,
}

/// The DFX controller.
#[derive(Clone)]
pub struct DfxManager {
    pub model: ReconfigModel,
    /// Sleep out the modelled latency (off by default: experiments report
    /// the model without paying 600 ms per swap).
    pub emulate_latency: bool,
}

impl Default for DfxManager {
    fn default() -> Self {
        DfxManager { model: ReconfigModel::default(), emulate_latency: false }
    }
}

impl DfxManager {
    /// Swap the RM in `pblock`: decouple → build/load new RM → reset →
    /// recouple. `warmup` seeds parameter ranges for detector RMs; `lanes`
    /// is the partition's configured lane count (CPU detector RMs load as
    /// a lane array when it is > 1).
    #[allow(clippy::too_many_arguments)]
    pub fn reconfigure(
        &self,
        pblock: &mut Pblock,
        rm: RmKind,
        r: usize,
        d: usize,
        seed: u64,
        hyper: &DetectorHyper,
        warmup: &[f32],
        fpga: Option<(&RuntimeHandle, &Registry)>,
        quantize: bool,
        lanes: usize,
    ) -> Result<ReconfigReport> {
        if !pblock.decoupler.is_enabled() {
            bail!(
                "pblock {}: decoupler is disabled — refusing to reconfigure a region that \
                 cannot be isolated from its stream",
                pblock.id
            );
        }
        let from = pblock.rm.describe();
        let t0 = Instant::now();
        pblock.decoupler.decouple();
        let new_rm = LoadedRm::build(rm, r, d, seed, hyper, warmup, fpga, quantize, lanes)?;
        let old = std::mem::replace(&mut pblock.rm, new_rm);
        drop(old);
        pblock.rm.reset()?;
        pblock.decoupler.recouple();
        let actual_ms = t0.elapsed().as_secs_f64() * 1e3;
        let to_function = rm != RmKind::Empty && rm != RmKind::Bypass;
        let model_ms =
            self.model.time_ms_pblock(pblock.id, to_function).unwrap_or(self.model.base_ms);
        if self.emulate_latency {
            let remaining = model_ms - actual_ms;
            if remaining > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(remaining / 1e3));
            }
        }
        Ok(ReconfigReport { pblock: pblock.id, from, to: pblock.rm.describe(), model_ms, actual_ms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::DetectorKind;

    #[test]
    fn model_tracks_paper_table13() {
        let m = ReconfigModel::default();
        // Paper: RP-6 ≈ 609.6 ms (largest), COMBO3 ≈ 579.8 ms (smallest).
        let rp6 = m.time_ms("RP-6", false).unwrap();
        assert!((rp6 - 609.6).abs() < 3.0, "rp6={rp6}");
        let combo3 = m.time_ms("COMBO3", false).unwrap();
        assert!((combo3 - 579.8).abs() < 3.0, "combo3={combo3}");
        // Bigger region ⇒ longer download.
        assert!(rp6 > m.time_ms("RP-3", false).unwrap());
    }

    #[test]
    fn unknown_block_is_none() {
        assert!(ReconfigModel::default().time_ms("RP-9", true).is_none());
    }

    #[test]
    fn reconfigure_swaps_cpu_rms() {
        let hyper = DetectorHyper { window: 8, bins: 4, w: 2, modulus: 16, k: 3 };
        let mut pb = Pblock::new(3);
        let mgr = DfxManager::default();
        let warmup: Vec<f32> = (0..60).map(|i| (i as f32).sin()).collect();
        let rep = mgr
            .reconfigure(
                &mut pb,
                RmKind::Detector(DetectorKind::Loda),
                2,
                3,
                1,
                &hyper,
                &warmup,
                None,
                false,
                1,
            )
            .unwrap();
        assert_eq!(rep.from, "empty");
        assert!(rep.to.contains("loda"));
        assert!(rep.model_ms > 595.0);
        assert!(!pb.decoupler.is_decoupled());
        // Swap back to bypass.
        let rep2 = mgr
            .reconfigure(&mut pb, RmKind::Bypass, 0, 3, 1, &hyper, &[], None, false, 1)
            .unwrap();
        assert!(rep2.from.contains("loda"));
        assert_eq!(rep2.to, "bypass(native)");
    }

    #[test]
    fn reconfigure_refuses_disabled_decoupler() {
        // A region whose decoupler IP is absent cannot be isolated; swapping
        // it would expose half-configured logic to live traffic.
        let hyper = DetectorHyper { window: 8, bins: 4, w: 2, modulus: 16, k: 3 };
        let mut pb = Pblock::new(2);
        pb.decoupler.set_enabled(false);
        let mgr = DfxManager::default();
        let warmup: Vec<f32> = (0..30).map(|i| (i as f32).cos()).collect();
        let err = mgr
            .reconfigure(
                &mut pb,
                RmKind::Detector(DetectorKind::Loda),
                2,
                3,
                1,
                &hyper,
                &warmup,
                None,
                false,
                1,
            )
            .unwrap_err();
        assert!(err.to_string().contains("decoupler is disabled"), "{err}");
        assert!(matches!(pb.rm, LoadedRm::Empty), "RM must be untouched after refusal");
        // Re-enabling the decoupler unblocks the swap.
        pb.decoupler.set_enabled(true);
        mgr.reconfigure(
            &mut pb,
            RmKind::Detector(DetectorKind::Loda),
            2,
            3,
            1,
            &hyper,
            &warmup,
            None,
            false,
            1,
        )
        .unwrap();
        assert!(!pb.decoupler.is_decoupled());
    }

    #[test]
    fn function_bias_orders_directions() {
        let m = ReconfigModel::default();
        let to_fn = m.time_ms("RP-1", true).unwrap();
        let to_id = m.time_ms("RP-1", false).unwrap();
        assert!(to_fn > to_id);
    }
}
