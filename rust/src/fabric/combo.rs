//! Combo pblocks (paper §3.3, Table 2): aggregate up to four score streams
//! into one. Inputs are joined in seq lock-step (the four AXI inputs of a
//! combo pblock advance together); the combination itself runs either
//! through the combo artifact on the device or natively. Stream-invariant
//! state (wavg weights) is prepared once per stream and shared per flit.

use anyhow::{bail, Context, Result};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use super::decoupler::Decoupler;
use super::message::{score_chunk, Flit};
use crate::combine::ScoreCombiner;
use crate::runtime::RuntimeHandle;

/// How the combination is computed.
pub enum ComboEngine {
    Native(ScoreCombiner),
    /// Through the `combo_<method>` artifact on the PJRT device. `weights`
    /// is pre-padded to the device shape `[4]` at construction
    /// ([`ComboEngine::fpga`]) and shared — per flit the engine clones the
    /// pointer, never the buffer.
    Fpga { handle: RuntimeHandle, method: String, weights: Arc<[f32]>, chunk: usize },
}

impl ComboEngine {
    /// Build the device engine, padding `weights` to the artifact's fixed
    /// `[4]` input once so the per-flit path never copies or resizes.
    pub fn fpga(handle: RuntimeHandle, method: String, weights: Vec<f32>, chunk: usize) -> Self {
        let mut w4 = weights;
        w4.resize(4, 0.0);
        ComboEngine::Fpga { handle, method, weights: w4.into(), chunk }
    }
}

/// Per-run combo statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ComboReport {
    pub flits_out: u64,
    pub samples: u64,
    /// Inputs dropped from the join because their partition was
    /// quarantined by the fault supervisor (the combine renormalized over
    /// the survivors from that flit on).
    pub quarantined_inputs: u64,
}

/// Join `inputs` (1..=4 score streams) and emit the combined stream.
pub fn service(
    engine: &ComboEngine,
    inputs: Vec<Receiver<Flit>>,
    tx: Sender<Flit>,
) -> Result<ComboReport> {
    let guards = vec![None; inputs.len()];
    service_guarded(engine, inputs, guards, tx)
}

/// [`service`] with per-input quarantine guards. When input `i`'s channel
/// closes and `guards[i]` reports a quarantined partition, the input is
/// deactivated instead of failing the join: the remaining streams keep
/// advancing in lock-step and the combine renormalizes over the survivors
/// (weighted-average weights are re-filtered to the active slots; the
/// device engine zeroes the slot's lane of the active mask, keeping slot
/// positions stable). A closed input with no guard — or a quarantined
/// *sole* survivor — still ends or fails the stream exactly as before, so
/// the guarded path is bit-transparent while every input is healthy.
pub fn service_guarded(
    engine: &ComboEngine,
    inputs: Vec<Receiver<Flit>>,
    guards: Vec<Option<Arc<Decoupler>>>,
    tx: Sender<Flit>,
) -> Result<ComboReport> {
    if inputs.is_empty() || inputs.len() > 4 {
        bail!("combo pblocks have 1..=4 input ports (got {})", inputs.len());
    }
    if guards.len() != inputs.len() {
        bail!("combo guards ({}) must match inputs ({})", guards.len(), inputs.len());
    }
    let n_ports = inputs.len();
    let mut active = vec![true; n_ports];
    let mut report = ComboReport::default();
    // Flits tagged with their input slot, so a degraded join keeps the
    // slot-positional semantics (wavg weights, device active mask).
    let mut flits: Vec<(usize, Flit)> = Vec::with_capacity(n_ports);
    'stream: loop {
        // Lock-step join: one flit from every still-active input.
        flits.clear();
        for (i, rx) in inputs.iter().enumerate() {
            if !active[i] {
                continue;
            }
            match rx.recv() {
                Ok(f) => flits.push((i, f)),
                Err(_) => {
                    let quarantined =
                        guards[i].as_ref().map_or(false, |g| g.is_quarantined());
                    let survivors = active.iter().filter(|a| **a).count();
                    if quarantined && survivors > 1 {
                        // The partition was isolated by the fault ladder
                        // and has drained: drop it from the join and keep
                        // going on the survivors.
                        active[i] = false;
                        report.quarantined_inputs += 1;
                        continue;
                    }
                    if flits.is_empty() {
                        break 'stream; // clean end of stream
                    }
                    bail!("combo input {i} closed mid-join");
                }
            }
        }
        if flits.is_empty() {
            break; // every input quarantined-drained this round
        }
        let first = &flits[0].1;
        for (i, f) in &flits {
            if f.seq != first.seq || f.n_valid != first.n_valid || f.mask.len() != first.mask.len()
            {
                bail!(
                    "combo misalignment: input {i} at seq {} ({} valid), input 0 at seq {} ({} valid)",
                    f.seq,
                    f.n_valid,
                    first.seq,
                    first.n_valid
                );
            }
        }
        let rows = first.mask.len();
        let degraded = flits.len() < n_ports;
        let combined: Vec<f32> = match engine {
            ComboEngine::Native(c) => {
                let views: Vec<&[f32]> = flits.iter().map(|(_, f)| &f.data[..]).collect();
                if !degraded {
                    // All inputs healthy: the original combiner, bit-identical.
                    c.combine(&views)
                } else {
                    match c {
                        // Positional wavg weights must follow the surviving
                        // slots, then the combine renormalizes over them.
                        ScoreCombiner::WeightedAverage(w) => {
                            let w2: Vec<f32> = flits
                                .iter()
                                .map(|(i, _)| w.get(*i).copied().unwrap_or(0.0))
                                .collect();
                            ScoreCombiner::WeightedAverage(w2).combine(&views)
                        }
                        other => other.combine(&views),
                    }
                }
            }
            ComboEngine::Fpga { handle, method, weights, chunk } => {
                if rows != *chunk {
                    bail!("combo artifact chunk {} != flit rows {rows}", chunk);
                }
                // Interleave into [C,4] with an active mask over inputs.
                // Slot positions are stable: a quarantined input keeps its
                // lane zeroed with active[slot] = 0, mirroring a combo
                // pblock whose upstream port is decoupled.
                let mut scores = vec![0f32; rows * 4];
                let mut active_mask = [0f32; 4];
                for (k, f) in &flits {
                    active_mask[*k] = 1.0;
                    for (i, &v) in f.data.iter().enumerate() {
                        scores[i * 4 + k] = v;
                    }
                }
                handle
                    .run_combo(method, scores, active_mask.to_vec(), weights.clone())
                    .context("combo artifact execution")?
            }
        };
        let last = flits.iter().any(|(_, f)| f.last);
        report.flits_out += 1;
        report.samples += first.n_valid as u64;
        let out = score_chunk(first.seq, combined, first.mask.clone(), first.n_valid, last);
        if tx.send(out).is_err() || last {
            break;
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::message::Port;

    fn feed(vals: Vec<Vec<f32>>, last_at: usize) -> Receiver<Flit> {
        let (tx, rx) = Port::link();
        for (seq, data) in vals.into_iter().enumerate() {
            let n = data.len();
            tx.send(score_chunk(seq as u64, data, vec![1.0; n], n, seq == last_at)).unwrap();
        }
        rx
    }

    #[test]
    fn averages_two_streams_in_lockstep() {
        let a = feed(vec![vec![1.0, 3.0], vec![5.0, 7.0]], 1);
        let b = feed(vec![vec![3.0, 5.0], vec![7.0, 9.0]], 1);
        let (tx, rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Averaging);
        let report = service(&engine, vec![a, b], tx).unwrap();
        assert_eq!(report.flits_out, 2);
        let f0 = rx.recv().unwrap();
        assert_eq!(&f0.data[..], &[2.0, 4.0]);
        let f1 = rx.recv().unwrap();
        assert_eq!(&f1.data[..], &[6.0, 8.0]);
        assert!(f1.last);
    }

    #[test]
    fn detects_misaligned_sequences() {
        let (tx_a, rx_a) = Port::link();
        tx_a.send(score_chunk(0, vec![1.0], vec![1.0], 1, true)).unwrap();
        let (tx_b, rx_b) = Port::link();
        tx_b.send(score_chunk(3, vec![1.0], vec![1.0], 1, true)).unwrap();
        let (tx, _rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Averaging);
        assert!(service(&engine, vec![rx_a, rx_b], tx).is_err());
    }

    #[test]
    fn rejects_more_than_four_inputs() {
        let rxs: Vec<Receiver<Flit>> = (0..5).map(|_| Port::link().1).collect();
        let (tx, _rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Averaging);
        assert!(service(&engine, rxs, tx).is_err());
    }

    #[test]
    fn maximization_native() {
        let a = feed(vec![vec![1.0, 9.0]], 0);
        let b = feed(vec![vec![5.0, 2.0]], 0);
        let (tx, rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Maximization);
        service(&engine, vec![a, b], tx).unwrap();
        assert_eq!(&rx.recv().unwrap().data[..], &[5.0, 9.0]);
    }

    #[test]
    fn single_input_passthrough() {
        let a = feed(vec![vec![1.5, 2.5]], 0);
        let (tx, rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Averaging);
        service(&engine, vec![a], tx).unwrap();
        assert_eq!(&rx.recv().unwrap().data[..], &[1.5, 2.5]);
    }

    #[test]
    fn combined_flit_shares_the_input_mask() {
        let a = feed(vec![vec![1.0, 3.0]], 0);
        let (tx, rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Averaging);
        service(&engine, vec![a], tx).unwrap();
        let f = rx.recv().unwrap();
        assert_eq!(&f.mask[..], &[1.0, 1.0]);
    }

    #[test]
    fn quarantined_input_renormalizes_weighted_average() {
        // Input 1 delivers one flit, then its partition is quarantined and
        // the stream drains. The join must renormalize over input 0 from
        // the next flit on, using input 0's *positional* weight.
        let a = feed(vec![vec![1.0, 3.0], vec![5.0, 7.0], vec![9.0, 11.0]], 2);
        let b = feed(vec![vec![3.0, 5.0]], 99);
        let guard = Arc::new(Decoupler::new());
        guard.quarantine();
        let (tx, rx) = Port::link();
        let w = vec![0.75f32, 0.25];
        let engine = ComboEngine::Native(ScoreCombiner::WeightedAverage(w.clone()));
        let report =
            service_guarded(&engine, vec![a, b], vec![None, Some(guard)], tx).unwrap();
        assert_eq!(report.flits_out, 3);
        assert_eq!(report.quarantined_inputs, 1);
        // Round 1: both inputs present — the plain weighted average.
        let f0 = rx.recv().unwrap();
        let tot = w[0] + w[1];
        assert_eq!(&f0.data[..], &[(0.75 * 1.0 + 0.25 * 3.0) / tot, (0.75 * 3.0 + 0.25 * 5.0) / tot]);
        // Rounds 2-3: survivor only — w = [0.75], tot = 0.75, so the
        // renormalized combine must return input 0's scores exactly.
        assert_eq!(&rx.recv().unwrap().data[..], &[5.0, 7.0]);
        let f2 = rx.recv().unwrap();
        assert_eq!(&f2.data[..], &[9.0, 11.0]);
        assert!(f2.last);
    }

    #[test]
    fn unguarded_mid_close_still_fails_the_join() {
        let a = feed(vec![vec![1.0], vec![2.0]], 1);
        let b = feed(vec![vec![3.0]], 99); // closes after one flit, no guard
        let (tx, _rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Averaging);
        let err = service_guarded(&engine, vec![a, b], vec![None, None], tx).unwrap_err();
        assert!(err.to_string().contains("closed mid-join"), "{err:#}");
    }

    #[test]
    fn quarantined_sole_survivor_ends_stream_cleanly() {
        let a = feed(vec![vec![1.5, 2.5]], 99);
        let guard = Arc::new(Decoupler::new());
        guard.quarantine();
        let (tx, rx) = Port::link();
        let engine = ComboEngine::Native(ScoreCombiner::Averaging);
        let report = service_guarded(&engine, vec![a], vec![Some(guard)], tx).unwrap();
        assert_eq!(report.flits_out, 1);
        assert_eq!(report.quarantined_inputs, 0, "a sole survivor is never dropped");
        assert_eq!(&rx.recv().unwrap().data[..], &[1.5, 2.5]);
    }

    #[test]
    fn fpga_engine_pads_weights_once() {
        let handle = crate::runtime::RuntimeHandle::disconnected();
        let engine = ComboEngine::fpga(handle, "wavg".into(), vec![0.5, 0.5], 8);
        match engine {
            ComboEngine::Fpga { weights, .. } => assert_eq!(&weights[..], &[0.5, 0.5, 0.0, 0.0]),
            _ => unreachable!(),
        }
    }
}
