//! Reconfigurable partitions (pblocks): each holds one loaded RM —
//! a detector ensemble (CPU-native or a PJRT artifact, the "bitstream"),
//! a bypass, or the default empty logic (paper §3.2–3.3). The RM persists
//! across stream runs (sliding-window state is streaming state), and is
//! swapped at run time by the DFX manager.
//!
//! # Burst servicing
//!
//! A pblock drains its inbox in one of two modes, selected per run by
//! [`ExecMode`]:
//!
//! - **[`ExecMode::LockStep`]** ([`Pblock::service`]) — the paper-faithful
//!   per-flit loop: one receive, one RM invocation, one send per transfer.
//! - **[`ExecMode::Batched`]** ([`Pblock::service_burst`]) — the production
//!   fast path: block for the head flit, then drain everything already
//!   queued and score the whole backlog as **one burst** — CPU RMs through
//!   a single `update_batch` call over the concatenated valid rows, FPGA
//!   RMs through a single [`RuntimeHandle::run_chunks`] round-trip instead
//!   of one hop per flit.
//!
//! The two modes are bit-identical on CPU RMs (chunk boundaries never
//! change `update_batch` arithmetic — property-tested in
//! `ensemble::batched`) and preserve flit order, per-flit TLAST and
//! decoupler semantics exactly; only the per-transfer overhead is
//! amortised. Flit payloads are shared `Arc` buffers throughout, so
//! neither mode copies sample data when forwarding, bypassing or
//! submitting to the device. Burst scoring reuses one per-partition
//! [`BurstScratch`] (concatenated rows + merged scores) across backlog
//! drains instead of allocating per burst.
//!
//! # Multi-lane partitions
//!
//! The paper's intra-pblock scalability axis — "multiple instances can be
//! placed within a pblock to improve performance" (§4, Fig 9) — is the
//! lane model: with `lanes = N` (per `[pblock.N]` in TOML, `[fabric]
//! lanes` default, `fsead --lanes`) a CPU detector RM loads as
//! [`LoadedRm::DetectorCpuLanes`] — `N` sub-detector slices built with the
//! same `DetectorSpec::build_slice` partition the CPU ensemble runners
//! use. Each burst (or flit) is scored by all lanes concurrently through
//! the partition's resident [`LanePool`] (spawned once per partition,
//! alive across bursts and across server sessions) into per-lane partial
//! vectors, merged with `run_batched`'s weighted arithmetic. The thread /
//! parity contract:
//!
//! - **`lanes = 1`** keeps the single-detector RM and the exact service
//!   loops above — bit-identical to the pre-lane data plane (golden
//!   vectors and server bit-identity suites run unchanged).
//! - **`lanes > 1`** changes only the f32 summation order of the ensemble
//!   mean (the established 1e-5 partition tolerance vs `lanes = 1`), and
//!   is itself bit-identical across [`ExecMode`]s, pool sizes and pooled
//!   vs inline execution.
//! - DFX hot-swaps replace the **whole lane array** between two flits
//!   (staged like any RM); [`super::hotswap::ScoreStats`] observe the
//!   merged stream, never per-lane partials.

use anyhow::{bail, Context, Result};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::decoupler::Decoupler;
use super::faults::{FaultEvent, FaultKind, ReloadRequest};
use super::hotswap::{self, Admit, DfxGate, PblockCtl};
use super::message::{score_chunk, Flit, FlitSource};
use super::snapshot::{snapshot_rm, Checkpoint};
use crate::config::{DetectorHyper, RmKind};
use crate::detectors::{Detector, DetectorSpec};
use crate::ensemble::lanes::{build_lanes, merge_lanes_into, score_inline, Lane, LaneInput};
use crate::ensemble::{ExecMode, LanePool};
use crate::runtime::{generate_params, InstanceId, Registry, RuntimeHandle};

/// Reusable burst-scoring buffers, owned by the service loop and reused
/// across backlog drains: `rows` holds the concatenated valid samples of a
/// burst, `scores` the merged per-sample scores. One per partition stream —
/// burst servicing allocates nothing per drain beyond the output flits.
#[derive(Default)]
pub struct BurstScratch {
    rows: Vec<f32>,
    scores: Vec<f32>,
}

/// Score `n` rows of `input` through a lane array: concurrently on the
/// partition's resident [`LanePool`] when one is attached, inline on the
/// calling thread otherwise — bit-identical either way (same per-lane job,
/// same merge order).
fn score_lanes(
    pool: Option<&LanePool>,
    lanes: &mut [Lane],
    input: &LaneInput,
    n: usize,
) -> Result<()> {
    match pool {
        Some(pool) => pool.score(lanes, input, n, usize::MAX),
        None => score_inline(lanes, input, n, usize::MAX),
    }
}

/// A loaded reconfigurable module.
pub enum LoadedRm {
    /// Default RM: consumes nothing, produces nothing (power-save).
    Empty,
    /// Identity logic, native implementation.
    BypassNative,
    /// Identity logic executed through the bypass artifact on the device.
    BypassFpga { handle: RuntimeHandle, d: usize },
    /// Detector ensemble on the CPU (baseline / fast tests).
    DetectorCpu { det: Box<dyn Detector> },
    /// Detector ensemble partitioned into lane slices for intra-partition
    /// instance parallelism (`lanes >= 2`); scored through the partition's
    /// resident [`LanePool`] and merged with `run_batched`'s weighted
    /// arithmetic.
    DetectorCpuLanes { lanes: Vec<Lane>, name: &'static str, r: usize, d: usize },
    /// Detector ensemble as a compiled artifact on the PJRT device.
    DetectorFpga { handle: RuntimeHandle, inst: InstanceId, chunk: usize, d: usize },
}

impl LoadedRm {
    pub fn describe(&self) -> String {
        match self {
            LoadedRm::Empty => "empty".into(),
            LoadedRm::BypassNative => "bypass(native)".into(),
            LoadedRm::BypassFpga { d, .. } => format!("bypass(fpga,d={d})"),
            LoadedRm::DetectorCpu { det } => format!("{}(cpu,r={})", det.name(), det.r()),
            LoadedRm::DetectorCpuLanes { lanes, name, r, .. } => {
                format!("{name}(cpu,r={r},lanes={})", lanes.len())
            }
            LoadedRm::DetectorFpga { d, .. } => format!("detector(fpga,d={d})"),
        }
    }

    /// Build an RM from its config description. `lanes` requests
    /// intra-partition instance parallelism for CPU-native detector RMs:
    /// the effective count is clamped to `[1, r]`, `1` keeps the
    /// single-detector RM (bit-identical to the pre-lane data plane), and
    /// the FPGA/bypass/empty variants ignore it (the modelled FPGA path
    /// already executes as one artifact invocation).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        rm: RmKind,
        r: usize,
        d: usize,
        seed: u64,
        hyper: &DetectorHyper,
        warmup: &[f32],
        fpga: Option<(&RuntimeHandle, &Registry)>,
        quantize: bool,
        lanes: usize,
    ) -> Result<LoadedRm> {
        match rm {
            RmKind::Empty => Ok(LoadedRm::Empty),
            RmKind::Bypass => match fpga {
                Some((handle, reg)) if reg.find_bypass(d).is_ok() => {
                    Ok(LoadedRm::BypassFpga { handle: handle.clone(), d })
                }
                _ => Ok(LoadedRm::BypassNative),
            },
            RmKind::Detector(kind) => match fpga {
                Some((handle, reg)) => {
                    let meta = reg.find_detector(kind, d, r, quantize)?;
                    let params = generate_params(kind, seed, r, d, hyper, warmup);
                    let inst = handle
                        .load_detector(meta, params)
                        .with_context(|| format!("loading {}", meta.name))?;
                    Ok(LoadedRm::DetectorFpga { handle: handle.clone(), inst, chunk: meta.chunk, d })
                }
                None => {
                    let mut spec = DetectorSpec::new(kind, d, r, seed);
                    spec.window = hyper.window;
                    spec.bins = hyper.bins;
                    spec.w = hyper.w;
                    spec.modulus = hyper.modulus;
                    spec.k = hyper.k;
                    spec.quantize = quantize;
                    let lanes = lanes.clamp(1, r.max(1));
                    if lanes > 1 {
                        Ok(LoadedRm::DetectorCpuLanes {
                            lanes: build_lanes(&spec, warmup, lanes),
                            name: kind.as_str(),
                            r,
                            d,
                        })
                    } else {
                        Ok(LoadedRm::DetectorCpu { det: spec.build(warmup) })
                    }
                }
            },
        }
    }

    /// Process one flit; returns the output flit (None for Empty logic).
    /// Payloads are shared: bypass outputs and forwarded masks clone the
    /// input `Arc`s instead of copying buffers. Multi-lane RMs score the
    /// flit through `pool` when one is attached (the partition's resident
    /// lane workers), inline otherwise — bit-identical either way.
    pub fn process(&mut self, flit: &Flit, pool: Option<&LanePool>) -> Result<Option<Flit>> {
        match self {
            LoadedRm::Empty => Ok(None),
            LoadedRm::BypassNative => Ok(Some(flit.clone())),
            LoadedRm::BypassFpga { handle, d } => {
                let out = handle.run_bypass(*d, flit.data.clone())?;
                Ok(Some(Flit {
                    seq: flit.seq,
                    data: out.into(),
                    mask: flit.mask.clone(),
                    n_valid: flit.n_valid,
                    last: flit.last,
                }))
            }
            LoadedRm::DetectorCpu { det } => {
                let d = det.d();
                let rows = flit.mask.len();
                let mut scores = vec![0f32; rows];
                // Batch fast path over the whole flit (bit-identical to the
                // per-sample update loop); padding rows stay zero-scored.
                let n = flit.n_valid;
                det.update_batch(&flit.data[..n * d], &mut scores[..n]);
                Ok(Some(score_chunk(flit.seq, scores, flit.mask.clone(), flit.n_valid, flit.last)))
            }
            LoadedRm::DetectorCpuLanes { lanes, .. } => {
                // Zero-copy lane fan-out: every lane shares the flit payload.
                let n = flit.n_valid;
                let input = LaneInput::Flit(flit.data.clone());
                score_lanes(pool, lanes, &input, n)?;
                let mut scores = vec![0f32; flit.rows()];
                merge_lanes_into(lanes, &mut scores[..n]);
                Ok(Some(score_chunk(flit.seq, scores, flit.mask.clone(), flit.n_valid, flit.last)))
            }
            LoadedRm::DetectorFpga { handle, inst, chunk, d } => {
                if flit.data.len() != *chunk * *d {
                    bail!(
                        "pblock chunk mismatch: flit has {} values, artifact expects [{},{}]",
                        flit.data.len(),
                        chunk,
                        d
                    );
                }
                let scores = handle.run_chunk(*inst, flit.data.clone(), flit.mask.clone())?;
                Ok(Some(score_chunk(flit.seq, scores, flit.mask.clone(), flit.n_valid, flit.last)))
            }
        }
    }

    /// Score a backlog of flits in stream order as one burst, appending the
    /// output flits to `out`. Results are bit-identical to calling
    /// [`LoadedRm::process`] once per flit:
    ///
    /// - CPU RMs concatenate the valid rows of the backlog (into the
    ///   reusable `scratch.rows` buffer) and score them through a
    ///   **single** `update_batch` call — same rows, same order, same
    ///   arithmetic (chunk boundaries never change scores; see the
    ///   `chunk_size_does_not_change_scores` proptest in
    ///   `ensemble::batched`);
    /// - multi-lane RMs score the same concatenated backlog through every
    ///   lane concurrently on `pool` and merge the weighted partials — the
    ///   rows allocation round-trips through an `Arc` and is reclaimed into
    ///   the scratch afterwards;
    /// - FPGA RMs submit the whole backlog through **one**
    ///   [`RuntimeHandle::run_chunks`] round-trip, with state threading
    ///   chunk-to-chunk exactly as repeated `run_chunk` calls would;
    /// - bypass/empty logic degenerate to pointer clones / nothing.
    pub fn process_burst(
        &mut self,
        flits: &[Flit],
        out: &mut Vec<Flit>,
        scratch: &mut BurstScratch,
        pool: Option<&LanePool>,
    ) -> Result<()> {
        match self {
            LoadedRm::Empty => Ok(()),
            LoadedRm::BypassNative => {
                // Identity: share the payloads, copy nothing.
                out.extend(flits.iter().cloned());
                Ok(())
            }
            LoadedRm::BypassFpga { handle, d } => {
                // No burst artifact API for the bypass; per-flit device
                // hops, but submission still shares the payload pointers.
                for f in flits {
                    let o = handle.run_bypass(*d, f.data.clone())?;
                    out.push(Flit {
                        seq: f.seq,
                        data: o.into(),
                        mask: f.mask.clone(),
                        n_valid: f.n_valid,
                        last: f.last,
                    });
                }
                Ok(())
            }
            LoadedRm::DetectorCpu { det } => {
                let d = det.d();
                let total: usize = flits.iter().map(|f| f.n_valid).sum();
                scratch.rows.clear();
                scratch.rows.reserve(total * d);
                for f in flits {
                    scratch.rows.extend_from_slice(&f.data[..f.n_valid * d]);
                }
                scratch.scores.clear();
                scratch.scores.resize(total, 0.0);
                det.update_batch(&scratch.rows, &mut scratch.scores);
                Self::emit_burst(flits, &scratch.scores, out);
                Ok(())
            }
            LoadedRm::DetectorCpuLanes { lanes, d, .. } => {
                let d = *d;
                let total: usize = flits.iter().map(|f| f.n_valid).sum();
                scratch.rows.clear();
                scratch.rows.reserve(total * d);
                for f in flits {
                    scratch.rows.extend_from_slice(&f.data[..f.n_valid * d]);
                }
                // Share the concatenated rows with every lane worker, then
                // reclaim the allocation into the scratch: by the time
                // `score_lanes` returns all lane clones are dropped.
                let rows = Arc::new(std::mem::take(&mut scratch.rows));
                let res = score_lanes(pool, lanes, &LaneInput::Rows(Arc::clone(&rows)), total);
                scratch.rows = Arc::try_unwrap(rows).unwrap_or_default();
                res?;
                scratch.scores.clear();
                scratch.scores.resize(total, 0.0);
                merge_lanes_into(lanes, &mut scratch.scores);
                Self::emit_burst(flits, &scratch.scores, out);
                Ok(())
            }
            LoadedRm::DetectorFpga { handle, inst, chunk, d } => {
                for f in flits {
                    if f.data.len() != *chunk * *d {
                        bail!(
                            "pblock chunk mismatch: flit has {} values, artifact expects [{},{}]",
                            f.data.len(),
                            chunk,
                            d
                        );
                    }
                }
                let burst: Vec<(Arc<[f32]>, Arc<[f32]>)> =
                    flits.iter().map(|f| (f.data.clone(), f.mask.clone())).collect();
                let scores = handle.run_chunks(*inst, burst)?;
                for (f, s) in flits.iter().zip(scores) {
                    out.push(score_chunk(f.seq, s, f.mask.clone(), f.n_valid, f.last));
                }
                Ok(())
            }
        }
    }

    /// Cut the merged burst scores back into per-flit output flits
    /// (padding rows stay zero-scored), preserving seq/mask/TLAST framing.
    fn emit_burst(flits: &[Flit], scores: &[f32], out: &mut Vec<Flit>) {
        let mut off = 0;
        for f in flits {
            let mut s = vec![0f32; f.rows()];
            s[..f.n_valid].copy_from_slice(&scores[off..off + f.n_valid]);
            off += f.n_valid;
            out.push(score_chunk(f.seq, s, f.mask.clone(), f.n_valid, f.last));
        }
    }

    /// Reset streaming state (window contents), keeping parameters.
    pub fn reset(&mut self) -> Result<()> {
        match self {
            LoadedRm::DetectorCpu { det } => {
                det.reset();
                Ok(())
            }
            LoadedRm::DetectorCpuLanes { lanes, .. } => {
                for lane in lanes.iter_mut() {
                    if let Some(det) = lane.det_mut() {
                        det.reset();
                    }
                }
                Ok(())
            }
            LoadedRm::DetectorFpga { handle, inst, .. } => handle.reset_state(*inst),
            _ => Ok(()),
        }
    }

    /// Fault injection: corrupt the RM's detector window state so
    /// subsequent scores go non-finite (a bit-flip in on-chip window
    /// memory). Returns false for RMs with no poisonable state (bypass,
    /// empty, modelled-FPGA — device state is out of reach).
    pub fn poison(&mut self) -> bool {
        match self {
            LoadedRm::DetectorCpu { det } => {
                let has_state = det.window_state().is_some();
                det.poison_state();
                has_state
            }
            LoadedRm::DetectorCpuLanes { lanes, .. } => {
                let mut any = false;
                for lane in lanes.iter_mut() {
                    if let Some(det) = lane.det_mut() {
                        any |= det.window_state().is_some();
                        det.poison_state();
                    }
                }
                any
            }
            _ => false,
        }
    }
}

impl Drop for LoadedRm {
    fn drop(&mut self) {
        // Unloading an RM frees its device-side executable instance —
        // reconfiguration and session-server teardown would otherwise leak
        // one instance per swap/session. Best effort: at process exit the
        // runtime service may already be gone.
        if let LoadedRm::DetectorFpga { handle, inst, .. } = self {
            let _ = handle.drop_instance(*inst);
        }
    }
}

/// Per-run pblock statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PblockReport {
    pub flits_in: u64,
    pub flits_out: u64,
    pub samples: u64,
    /// Seconds spent inside the RM (compute, not waiting).
    pub busy_secs: f64,
}

/// A reconfigurable partition of the fabric.
pub struct Pblock {
    pub id: usize,
    pub rm: LoadedRm,
    pub decoupler: Arc<Decoupler>,
    /// Live-DFX control surface: swap mailbox + score statistics, shared
    /// with the fabric and the adaptive controller while the service
    /// thread owns the RM.
    pub ctl: Arc<PblockCtl>,
    /// Resident lane workers for multi-lane RMs (None when the partition
    /// runs a single lane). Spawned once when the partition is configured
    /// with `lanes > 1` and kept alive across runs, bursts and hot-swaps —
    /// the per-partition counterpart of the server's resident workers.
    pub pool: Option<LanePool>,
}

impl Pblock {
    pub fn new(id: usize) -> Pblock {
        Pblock {
            id,
            rm: LoadedRm::Empty,
            decoupler: Arc::new(Decoupler::new()),
            ctl: Arc::new(PblockCtl::default()),
            pool: None,
        }
    }

    /// Service one stream under the selected execution mode. The stream
    /// source is anything implementing [`FlitSource`]: the fabric's mpsc
    /// receivers or a server session's bounded inbox. `pool` is the
    /// partition's resident lane workers (None for single-lane partitions;
    /// multi-lane RMs then score inline, bit-identically).
    pub fn service_mode<S: FlitSource>(
        rm: &mut LoadedRm,
        decoupler: &Decoupler,
        ctl: &PblockCtl,
        rx: S,
        tx: Sender<Flit>,
        mode: ExecMode,
        pool: Option<&LanePool>,
    ) -> Result<PblockReport> {
        match mode {
            ExecMode::LockStep => Self::service(rm, decoupler, ctl, rx, tx, pool),
            ExecMode::Batched => Self::service_burst(rm, decoupler, ctl, rx, tx, pool),
        }
    }

    /// Service one stream per flit: pull flits from `rx`, run them through
    /// the RM one at a time, push results to `tx`. Returns when the stream
    /// ends (TLAST or closed). The paper-faithful baseline data plane.
    ///
    /// Every flit first passes the DFX gate, which executes scheduled
    /// hot-swaps between flits and classifies dark-window traffic (see
    /// `fabric::hotswap` for the quiesce protocol and accounting rules).
    pub fn service<S: FlitSource>(
        rm: &mut LoadedRm,
        decoupler: &Decoupler,
        ctl: &PblockCtl,
        mut rx: S,
        tx: Sender<Flit>,
        pool: Option<&LanePool>,
    ) -> Result<PblockReport> {
        let mut report = PblockReport::default();
        let mut gate = DfxGate::new(ctl, decoupler);
        // Fault machinery is strictly armed-gated: unarmed (the default),
        // every hook below is skipped and the loop is the pre-fault data
        // plane, byte for byte.
        let armed = ctl.health.is_armed();
        while let Some(flit) = rx.recv_flit() {
            report.flits_in += 1;
            let last = flit.last;
            if armed {
                Self::apply_due_faults(rm, ctl, pool);
                ctl.health.tick();
            }
            match gate.admit(rm, last, true)? {
                Admit::Drop => {
                    // Isolated (reconfiguration dark window, or externally
                    // decoupled): traffic is dropped, never handed to
                    // half-configured logic. A quarantined region normally
                    // drains-and-drops to stream end; the session server
                    // raises `evict_on_quarantine` so the loop returns
                    // instead and the session can be parked for resume on
                    // another partition.
                    if decoupler.is_quarantined()
                        && ctl.evict_on_quarantine.load(std::sync::atomic::Ordering::SeqCst)
                    {
                        break;
                    }
                    if last {
                        break;
                    }
                    continue;
                }
                Admit::Bypass => {
                    // Dark window, bypass policy: keep downstream framing
                    // alive with a zero-score placeholder.
                    report.flits_out += 1;
                    if tx.send(hotswap::dark_flit(&flit)).is_err() || last {
                        break;
                    }
                    continue;
                }
                Admit::Process => {}
            }
            let t0 = Instant::now();
            if armed {
                ctl.health.set_processing(true);
            }
            let mut res = rm.process(&flit, pool);
            if armed {
                ctl.health.set_processing(false);
                if res.is_err() {
                    if let Some(p) = pool {
                        // Rung 0, worker containment: a dead lane worker
                        // loses one burst's lane results; respawn the pool
                        // and retry the flit (lane state rolls back on
                        // every panic, so a retry is state-valid).
                        let err = res.unwrap_err();
                        ctl.faults.record(FaultEvent {
                            id: "-".into(),
                            pblock: ctl.faults.pblock(),
                            at_flit: ctl.swap.flits_seen(),
                            fault: "worker_exit".into(),
                            action: "respawn_retry".into(),
                            rung: 0,
                            latency_us: t0.elapsed().as_micros() as u64,
                            checkpoint_flit: None,
                            detail: format!("{err:#}"),
                        });
                        p.respawn();
                        ctl.health.set_processing(true);
                        res = rm.process(&flit, pool);
                        ctl.health.set_processing(false);
                    }
                }
                if let Some(p) = pool {
                    for note in p.take_fault_notes() {
                        let fault =
                            if note.kind == "worker_exit" { "worker_exit" } else { "lane_panic" };
                        ctl.faults.record(FaultEvent {
                            id: "-".into(),
                            pblock: ctl.faults.pblock(),
                            at_flit: ctl.swap.flits_seen(),
                            fault: fault.into(),
                            action: note.kind.into(),
                            rung: 0,
                            latency_us: note.latency_us,
                            checkpoint_flit: None,
                            detail: note.detail,
                        });
                    }
                }
            }
            let out = res?;
            report.busy_secs += t0.elapsed().as_secs_f64();
            report.samples += flit.n_valid as u64;
            if let Some(mut out) = out {
                let healthy =
                    if armed { Self::screen_output(ctl, decoupler, &mut out) } else { true };
                if healthy {
                    ctl.stats.push(&out.data, out.n_valid);
                    if armed {
                        Self::maybe_checkpoint(rm, ctl, report.samples);
                    }
                }
                report.flits_out += 1;
                if tx.send(out).is_err() {
                    break; // downstream disabled
                }
            }
            if last {
                break;
            }
        }
        gate.finish();
        Ok(report)
    }

    /// Fire the injections scheduled for the current input flit (armed
    /// runs only). Every injection is recorded as a [`FaultEvent`] —
    /// `injected` when it took effect, `skipped` when the partition has no
    /// matching surface (e.g. a lane fault on a single-lane RM).
    fn apply_due_faults(rm: &mut LoadedRm, ctl: &PblockCtl, pool: Option<&LanePool>) {
        let idx = ctl.swap.flits_seen();
        for fault in ctl.faults.take_due(idx) {
            let tag = fault.kind.tag();
            let (action, detail) = match fault.kind {
                FaultKind::LanePanic { lane } => match pool {
                    Some(p) => {
                        p.inject_lane_panic(lane);
                        ("injected", format!("lane {lane} panics on its next scoring job"))
                    }
                    None => ("skipped", "partition has no lane pool".to_string()),
                },
                FaultKind::WorkerExit { worker } => match pool {
                    Some(p) => {
                        p.inject_worker_exit(worker);
                        ("injected", format!("worker {worker} exits after its next job"))
                    }
                    None => ("skipped", "partition has no lane pool".to_string()),
                },
                FaultKind::StateCorrupt => {
                    if rm.poison() {
                        ("injected", "sliding-window denom poisoned (NaN)".to_string())
                    } else {
                        ("skipped", format!("{} holds no poisonable state", rm.describe()))
                    }
                }
                FaultKind::Stall { ms } => {
                    // Wedge *inside* the processing section: the
                    // supervisor's watchdog must flag this.
                    ctl.health.set_processing(true);
                    std::thread::sleep(Duration::from_millis(ms));
                    ctl.health.set_processing(false);
                    ("injected", format!("service loop wedged {ms} ms mid-processing"))
                }
                FaultKind::InboxStall { ms } => {
                    // Starve *outside* processing: indistinguishable from a
                    // slow producer, so the watchdog must stay silent — the
                    // loop records the injection itself.
                    std::thread::sleep(Duration::from_millis(ms));
                    ("injected", format!("starved {ms} ms outside processing (benign)"))
                }
            };
            ctl.faults.record(FaultEvent {
                id: fault.id,
                pblock: fault.pblock,
                at_flit: idx,
                fault: tag.into(),
                action: action.into(),
                rung: 0,
                latency_us: 0,
                checkpoint_flit: None,
                detail,
            });
        }
    }

    /// Screen one output flit for corruption (armed runs only). Non-finite
    /// scores are replaced with a zero-score placeholder (downstream
    /// framing stays aligned, score ordering is preserved), a rung-1
    /// reload is requested, and the loop blocks — bounded by
    /// `reload_wait_ms` — until the supervisor stages the replacement (or
    /// quarantines the partition), so the swap lands deterministically at
    /// the very next flit. Returns false when the flit was screened: the
    /// caller must not feed it to the score stats or checkpoint on it.
    fn screen_output(ctl: &PblockCtl, decoupler: &Decoupler, out: &mut Flit) -> bool {
        let n = out.n_valid;
        if out.data[..n].iter().all(|v| v.is_finite()) {
            return true;
        }
        let at = ctl.swap.flits_seen();
        let bad = out.data[..n].iter().filter(|v| !v.is_finite()).count();
        ctl.faults.record(FaultEvent {
            id: "-".into(),
            pblock: ctl.faults.pblock(),
            at_flit: at,
            fault: "state_corrupt".into(),
            action: "nonfinite_detected".into(),
            rung: 1,
            latency_us: 0,
            checkpoint_flit: None,
            detail: format!("{bad}/{n} scores non-finite; flit zeroed, reload requested"),
        });
        *out = hotswap::dark_flit(out);
        ctl.health.request_reload(ReloadRequest {
            fault_id: "-".into(),
            at_flit: at,
            reason: format!("{bad}/{n} non-finite scores"),
        });
        let wait = Duration::from_millis(ctl.health.reload_wait_ms());
        let t0 = Instant::now();
        while t0.elapsed() < wait {
            if ctl.swap.pending_count() > 0 || decoupler.is_quarantined() {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        false
    }

    /// Store a checkpoint of the RM's detector state every
    /// `checkpoint_every` healthy flits (armed runs only; never called on
    /// a screened flit, so a stored checkpoint is always finite state).
    fn maybe_checkpoint(rm: &LoadedRm, ctl: &PblockCtl, samples: u64) {
        let every = ctl.health.checkpoint_every();
        if every == 0 {
            return;
        }
        // flits_seen was advanced by admit(): it equals the number of
        // flits fully processed once this flit's scores are out.
        let done = ctl.swap.flits_seen();
        if done == 0 || done % every != 0 {
            return;
        }
        if let Some(bytes) = snapshot_rm(rm) {
            ctl.checkpoint.store(Checkpoint { flit: done, samples, bytes });
        }
    }

    /// Service one stream in bursts: block for the head flit, drain the
    /// rest of the inbox without blocking, and score the whole backlog as
    /// one burst through [`LoadedRm::process_burst`]. Flit order, per-flit
    /// TLAST and decoupler drops match [`Pblock::service`] exactly; only
    /// the per-transfer overhead is amortised.
    ///
    /// The DFX gate is consulted per drained flit, so a hot-swap scheduled
    /// mid-backlog splits the burst: flits before the swap are scored by
    /// the old RM (the segment is flushed before the RM is replaced),
    /// dark-window flits are dropped or bypassed, and the tail is scored
    /// by the new RM — identical flit-level semantics to the per-flit
    /// path.
    pub fn service_burst<S: FlitSource>(
        rm: &mut LoadedRm,
        decoupler: &Decoupler,
        ctl: &PblockCtl,
        mut rx: S,
        tx: Sender<Flit>,
        pool: Option<&LanePool>,
    ) -> Result<PblockReport> {
        // A fault campaign needs the per-flit hooks (heartbeat, injection
        // points, output screen, checkpoints); armed partitions fall back
        // to the lock-step loop. Chunk boundaries never change CPU RM
        // arithmetic, so scores are unchanged — only the per-transfer
        // amortisation is given up, and only while faults are armed.
        if ctl.health.is_armed() {
            return Self::service(rm, decoupler, ctl, rx, tx, pool);
        }
        // When the adaptive controller is watching this pblock (stats
        // armed), bound the backlog so scores are published — and newly
        // scheduled swaps consulted — at flit-bounded intervals mid-stream.
        // With an unbounded drain a fast producer's whole stream becomes
        // one burst: every admit() decision would be made before the first
        // score reaches the controller, making adaptive swaps unreachable
        // in this mode. Throughput-only runs keep the unbounded drain.
        const ADAPTIVE_MAX_BURST: usize = 32;
        let max_burst = if ctl.stats.is_armed() { ADAPTIVE_MAX_BURST } else { usize::MAX };
        let mut report = PblockReport::default();
        let mut gate = DfxGate::new(ctl, decoupler);
        let mut outputs: Vec<Flit> = Vec::new();
        let mut seg: Vec<Flit> = Vec::new();
        // Per-partition burst scratch (concatenated rows + merged scores),
        // reused across every backlog drain of this stream.
        let mut scratch = BurstScratch::default();
        loop {
            let Some(first) = rx.recv_flit() else {
                gate.finish();
                return Ok(report);
            };
            let mut done = first.last;
            let mut backlog = vec![first];
            while !done && backlog.len() < max_burst {
                let Some(f) = rx.try_recv_flit() else { break };
                done = f.last;
                backlog.push(f);
            }
            report.flits_in += backlog.len() as u64;
            seg.clear();
            for flit in backlog.drain(..) {
                if gate.swap_imminent() && !seg.is_empty() {
                    // Flush the segment owned by the outgoing RM before the
                    // gate replaces it.
                    if !Self::flush_seg(
                        rm, ctl, &mut seg, &mut outputs, &mut scratch, pool, &tx, &mut report,
                    )? {
                        gate.finish();
                        return Ok(report);
                    }
                }
                let last = flit.last;
                match gate.admit(rm, last, seg.is_empty())? {
                    Admit::Drop => {}
                    Admit::Bypass => {
                        if !seg.is_empty()
                            && !Self::flush_seg(
                                rm, ctl, &mut seg, &mut outputs, &mut scratch, pool, &tx,
                                &mut report,
                            )?
                        {
                            gate.finish();
                            return Ok(report);
                        }
                        report.flits_out += 1;
                        if tx.send(hotswap::dark_flit(&flit)).is_err() {
                            gate.finish();
                            return Ok(report);
                        }
                    }
                    Admit::Process => seg.push(flit),
                }
            }
            if !seg.is_empty()
                && !Self::flush_seg(
                    rm, ctl, &mut seg, &mut outputs, &mut scratch, pool, &tx, &mut report,
                )?
            {
                gate.finish();
                return Ok(report);
            }
            if done {
                gate.finish();
                return Ok(report);
            }
        }
    }

    /// Score one backlog segment through the RM and forward the outputs.
    /// Returns `Ok(false)` when downstream is disabled (send failed).
    #[allow(clippy::too_many_arguments)]
    fn flush_seg(
        rm: &mut LoadedRm,
        ctl: &PblockCtl,
        seg: &mut Vec<Flit>,
        outputs: &mut Vec<Flit>,
        scratch: &mut BurstScratch,
        pool: Option<&LanePool>,
        tx: &Sender<Flit>,
        report: &mut PblockReport,
    ) -> Result<bool> {
        let t0 = Instant::now();
        outputs.clear();
        rm.process_burst(seg, outputs, scratch, pool)?;
        report.busy_secs += t0.elapsed().as_secs_f64();
        report.samples += seg.iter().map(|f| f.n_valid as u64).sum::<u64>();
        seg.clear();
        for out in outputs.drain(..) {
            ctl.stats.push(&out.data, out.n_valid);
            report.flits_out += 1;
            if tx.send(out).is_err() {
                return Ok(false); // downstream disabled
            }
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DetectorHyper;
    use crate::data::stream::ChunkStream;
    use crate::detectors::prng::Prng;
    use crate::detectors::DetectorKind;
    use crate::fabric::message::Port;

    fn hyper() -> DetectorHyper {
        DetectorHyper { window: 16, bins: 8, w: 2, modulus: 32, k: 4 }
    }

    fn stream_data(n: usize, d: usize) -> Vec<f32> {
        let mut p = Prng::new(9);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    fn detector_rm(kind: DetectorKind, r: usize, d: usize, seed: u64, warmup: &[f32]) -> LoadedRm {
        LoadedRm::build(RmKind::Detector(kind), r, d, seed, &hyper(), warmup, None, false, 1)
            .unwrap()
    }

    fn lane_rm(
        kind: DetectorKind,
        r: usize,
        d: usize,
        seed: u64,
        warmup: &[f32],
        lanes: usize,
    ) -> LoadedRm {
        LoadedRm::build(RmKind::Detector(kind), r, d, seed, &hyper(), warmup, None, false, lanes)
            .unwrap()
    }

    #[test]
    fn cpu_detector_rm_scores_stream() {
        let data = stream_data(40, 3);
        let mut rm = detector_rm(DetectorKind::Loda, 4, 3, 1, &data[..30]);
        let (tx_out, rx_out) = Port::link();
        let (tx_in, rx_in) = Port::link();
        for f in ChunkStream::new(&data, 3, 8) {
            tx_in.send(f).unwrap();
        }
        drop(tx_in);
        let dec = Decoupler::new();
        let ctl = PblockCtl::default();
        let report = Pblock::service(&mut rm, &dec, &ctl, rx_in, tx_out, None).unwrap();
        assert_eq!(report.samples, 40);
        assert_eq!(report.flits_in, 5);
        let mut n_scores = 0;
        for f in rx_out.iter() {
            n_scores += f.n_valid;
        }
        assert_eq!(n_scores, 40);
    }

    #[test]
    fn bypass_rm_is_identity_and_zero_copy() {
        let data = stream_data(10, 2);
        let mut rm = LoadedRm::BypassNative;
        let flit = ChunkStream::new(&data, 2, 16).next().unwrap();
        let out = rm.process(&flit, None).unwrap().unwrap();
        assert_eq!(out.data, flit.data);
        // Identity shares the payload allocation, it does not copy it.
        assert!(Arc::ptr_eq(&out.data, &flit.data));
        assert!(Arc::ptr_eq(&out.mask, &flit.mask));
    }

    #[test]
    fn empty_rm_produces_nothing() {
        let mut rm = LoadedRm::Empty;
        let flit = ChunkStream::new(&[1.0, 2.0], 2, 4).next().unwrap();
        assert!(rm.process(&flit, None).unwrap().is_none());
        let mut out = Vec::new();
        let mut scratch = BurstScratch::default();
        rm.process_burst(std::slice::from_ref(&flit), &mut out, &mut scratch, None).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn decoupled_pblock_drops_traffic() {
        let data = stream_data(16, 2);
        let (tx_in, rx_in) = Port::link();
        let (tx_out, rx_out) = Port::link();
        for f in ChunkStream::new(&data, 2, 8) {
            tx_in.send(f).unwrap();
        }
        drop(tx_in);
        let mut rm = LoadedRm::BypassNative;
        let dec = Decoupler::new();
        dec.decouple();
        let ctl = PblockCtl::default();
        let report = Pblock::service(&mut rm, &dec, &ctl, rx_in, tx_out, None).unwrap();
        assert_eq!(report.flits_out, 0);
        assert!(rx_out.recv().is_err());
        assert!(report.flits_in >= 1);
    }

    #[test]
    fn decoupled_pblock_drops_traffic_in_burst_mode() {
        let data = stream_data(16, 2);
        let (tx_in, rx_in) = Port::link();
        let (tx_out, rx_out) = Port::link();
        for f in ChunkStream::new(&data, 2, 8) {
            tx_in.send(f).unwrap();
        }
        drop(tx_in);
        let mut rm = LoadedRm::BypassNative;
        let dec = Decoupler::new();
        dec.decouple();
        let ctl = PblockCtl::default();
        let report = Pblock::service_burst(&mut rm, &dec, &ctl, rx_in, tx_out, None).unwrap();
        assert_eq!(report.flits_out, 0);
        assert_eq!(report.flits_in, 2);
        assert!(rx_out.recv().is_err());
        assert_eq!(dec.dropped(), 2);
    }

    #[test]
    fn cpu_rm_scores_match_plain_detector() {
        let data = stream_data(32, 3);
        let hy = hyper();
        let mut rm = detector_rm(DetectorKind::RsHash, 3, 3, 5, &data[..30]);
        let mut spec = DetectorSpec::new(DetectorKind::RsHash, 3, 3, 5);
        spec.window = hy.window;
        spec.bins = hy.bins;
        spec.w = hy.w;
        spec.modulus = hy.modulus;
        spec.k = hy.k;
        let mut det = spec.build(&data[..30]);
        let expect = det.run_stream(&data);
        let mut got = Vec::new();
        for flit in ChunkStream::new(&data, 3, 8) {
            if let Some(out) = rm.process(&flit, None).unwrap() {
                got.extend_from_slice(&out.data[..out.n_valid]);
            }
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn burst_service_is_bit_identical_to_per_flit() {
        // The whole stream queued up-front forces the burst path to drain
        // it as one backlog — the hardest case for parity.
        let data = stream_data(50, 3);
        for kind in DetectorKind::ALL {
            let mut per_flit: Vec<Flit> = Vec::new();
            {
                let mut rm = detector_rm(kind, 4, 3, 7, &data[..30]);
                let (tx_in, rx_in) = Port::link();
                let (tx_out, rx_out) = Port::link();
                for f in ChunkStream::new(&data, 3, 8) {
                    tx_in.send(f).unwrap();
                }
                drop(tx_in);
                let dec = Decoupler::new();
                let ctl = PblockCtl::default();
                Pblock::service(&mut rm, &dec, &ctl, rx_in, tx_out, None).unwrap();
                per_flit.extend(rx_out.iter());
            }
            let mut burst: Vec<Flit> = Vec::new();
            {
                let mut rm = detector_rm(kind, 4, 3, 7, &data[..30]);
                let (tx_in, rx_in) = Port::link();
                let (tx_out, rx_out) = Port::link();
                for f in ChunkStream::new(&data, 3, 8) {
                    tx_in.send(f).unwrap();
                }
                drop(tx_in);
                let dec = Decoupler::new();
                let ctl = PblockCtl::default();
                let report =
                    Pblock::service_burst(&mut rm, &dec, &ctl, rx_in, tx_out, None).unwrap();
                assert_eq!(report.samples, 50, "{kind:?}");
                burst.extend(rx_out.iter());
            }
            assert_eq!(per_flit.len(), burst.len(), "{kind:?}");
            for (a, b) in per_flit.iter().zip(&burst) {
                assert_eq!(a.seq, b.seq, "{kind:?}");
                assert_eq!(a.n_valid, b.n_valid, "{kind:?}");
                assert_eq!(a.last, b.last, "{kind:?}");
                assert_eq!(&a.data[..], &b.data[..], "{kind:?} seq {}", a.seq);
                assert_eq!(&a.mask[..], &b.mask[..], "{kind:?} seq {}", a.seq);
            }
        }
    }

    #[test]
    fn hot_swap_splits_stream_between_rms() {
        // 40 samples, chunk 8 → 5 flits. A swap Loda → RS-Hash scheduled at
        // flit 2 with a 1-flit dark window must yield: flits 0-1 scored by
        // the old RM, flit 2 bypassed with zeros, flits 3-4 scored by the
        // fresh new RM — identically in both drain modes.
        use crate::config::DarkPolicy;
        use crate::fabric::reconfig::DfxManager;
        let data = stream_data(40, 3);
        // Expected score stream, assembled from standalone RMs.
        let mut expect: Vec<f32> = Vec::new();
        {
            let mut old = detector_rm(DetectorKind::Loda, 4, 3, 1, &data[..30]);
            for flit in ChunkStream::new(&data[..16 * 3], 3, 8) {
                let out = old.process(&flit, None).unwrap().unwrap();
                expect.extend_from_slice(&out.data[..out.n_valid]);
            }
        }
        expect.extend([0f32; 8]);
        {
            let mut new = detector_rm(DetectorKind::RsHash, 3, 3, 5, &data[..30]);
            for flit in ChunkStream::new(&data[24 * 3..], 3, 8) {
                let out = new.process(&flit, None).unwrap().unwrap();
                expect.extend_from_slice(&out.data[..out.n_valid]);
            }
        }
        for mode in ExecMode::ALL {
            let mut rm = detector_rm(DetectorKind::Loda, 4, 3, 1, &data[..30]);
            let (tx_in, rx_in) = Port::link();
            let (tx_out, rx_out) = Port::link();
            for f in ChunkStream::new(&data, 3, 8) {
                tx_in.send(f).unwrap();
            }
            drop(tx_in);
            let dec = Decoupler::new();
            let ctl = PblockCtl::default();
            let swap = DfxManager::default()
                .stage(
                    1,
                    RmKind::Detector(DetectorKind::RsHash),
                    3,
                    3,
                    5,
                    &hyper(),
                    &data[..30],
                    None,
                    false,
                    2,
                    Some(1),
                    DarkPolicy::Bypass,
                    8,
                    1e5,
                    1,
                )
                .unwrap();
            ctl.swap.schedule(swap);
            let report =
                Pblock::service_mode(&mut rm, &dec, &ctl, rx_in, tx_out, mode, None).unwrap();
            let outs: Vec<Flit> = rx_out.iter().collect();
            assert_eq!(outs.len(), 5, "{mode:?}");
            let got: Vec<f32> =
                outs.iter().flat_map(|f| f.data[..f.n_valid].to_vec()).collect();
            assert_eq!(got, expect, "{mode:?}");
            // Dark flit's samples never reached an RM.
            assert_eq!(report.samples, 32, "{mode:?}");
            let evs = ctl.swap.take_events();
            assert_eq!(evs.len(), 1, "{mode:?}");
            assert_eq!(evs[0].at_flit, 2);
            assert_eq!(evs[0].bypassed, 1);
            assert!(evs[0].dark_complete);
            assert!(evs[0].from.contains("loda"), "{}", evs[0].from);
            assert!(evs[0].to.contains("rshash"), "{}", evs[0].to);
            assert!(!dec.is_decoupled(), "{mode:?}");
        }
    }

    #[test]
    fn burst_bypass_shares_payloads() {
        let data = stream_data(12, 2);
        let flits: Vec<Flit> = ChunkStream::new(&data, 2, 4).collect();
        let mut rm = LoadedRm::BypassNative;
        let mut out = Vec::new();
        let mut scratch = BurstScratch::default();
        rm.process_burst(&flits, &mut out, &mut scratch, None).unwrap();
        assert_eq!(out.len(), flits.len());
        for (i, o) in out.iter().enumerate() {
            assert!(Arc::ptr_eq(&o.data, &flits[i].data));
        }
    }

    #[test]
    fn build_selects_lane_variant_and_clamps() {
        let data = stream_data(20, 3);
        let rm = lane_rm(DetectorKind::Loda, 4, 3, 1, &data[..30], 1);
        assert!(matches!(rm, LoadedRm::DetectorCpu { .. }), "lanes=1 keeps the single path");
        let rm = lane_rm(DetectorKind::Loda, 4, 3, 1, &data[..30], 2);
        assert_eq!(rm.describe(), "loda(cpu,r=4,lanes=2)");
        // More lanes than sub-detectors clamp to r.
        let rm = lane_rm(DetectorKind::RsHash, 3, 3, 1, &data[..30], 16);
        match &rm {
            LoadedRm::DetectorCpuLanes { lanes, .. } => assert_eq!(lanes.len(), 3),
            other => panic!("expected lane RM, got {}", other.describe()),
        }
    }

    #[test]
    fn lane_rm_matches_weighted_slice_reference() {
        // A 2-lane RM must equal the manual build_slice + weighted-merge
        // arithmetic of run_batched, bit-for-bit (uneven 5 % 2 partition).
        let data = stream_data(40, 3);
        let hy = hyper();
        for kind in DetectorKind::ALL {
            let mut spec = DetectorSpec::new(kind, 3, 5, 9);
            spec.window = hy.window;
            spec.bins = hy.bins;
            spec.w = hy.w;
            spec.modulus = hy.modulus;
            spec.k = hy.k;
            let mut lo = spec.build_slice(&data[..30], 0, 3);
            let mut hi = spec.build_slice(&data[..30], 3, 5);
            let expect: Vec<f32> = lo
                .run_stream(&data)
                .iter()
                .zip(hi.run_stream(&data))
                .map(|(a, b)| a * (3.0 / 5.0) + b * (2.0 / 5.0))
                .collect();
            let mut rm = lane_rm(kind, 5, 3, 9, &data[..30], 2);
            let mut got = Vec::new();
            for flit in ChunkStream::new(&data, 3, 8) {
                let out = rm.process(&flit, None).unwrap().unwrap();
                got.extend_from_slice(&out.data[..out.n_valid]);
            }
            assert_eq!(got, expect, "{kind:?}");
        }
    }

    #[test]
    fn lane_service_is_bit_identical_across_modes_and_pools() {
        // lanes=2: per-flit vs burst, pooled vs inline — all four streams
        // must agree bit-for-bit.
        let data = stream_data(50, 3);
        let pool = LanePool::new(2);
        let mut streams: Vec<Vec<f32>> = Vec::new();
        for mode in ExecMode::ALL {
            for pooled in [false, true] {
                let mut rm = lane_rm(DetectorKind::XStream, 4, 3, 7, &data[..30], 2);
                let (tx_in, rx_in) = Port::link();
                let (tx_out, rx_out) = Port::link();
                for f in ChunkStream::new(&data, 3, 8) {
                    tx_in.send(f).unwrap();
                }
                drop(tx_in);
                let dec = Decoupler::new();
                let ctl = PblockCtl::default();
                let p = pooled.then_some(&pool);
                let report =
                    Pblock::service_mode(&mut rm, &dec, &ctl, rx_in, tx_out, mode, p).unwrap();
                assert_eq!(report.samples, 50, "{mode:?} pooled={pooled}");
                let scores: Vec<f32> =
                    rx_out.iter().flat_map(|f| f.data[..f.n_valid].to_vec()).collect();
                assert_eq!(scores.len(), 50);
                streams.push(scores);
            }
        }
        for s in &streams[1..] {
            assert_eq!(s, &streams[0], "lane scoring must not depend on mode or pool");
        }
    }

    #[test]
    fn hot_swap_replaces_whole_lane_array() {
        // A swap staged for a 2-lane partition lands a fresh 2-lane array
        // between flits; the stream keeps the bypass framing through the
        // dark window.
        use crate::config::DarkPolicy;
        use crate::fabric::reconfig::DfxManager;
        let data = stream_data(32, 3);
        let pool = LanePool::new(2);
        let mut rm = lane_rm(DetectorKind::Loda, 4, 3, 1, &data[..30], 2);
        let (tx_in, rx_in) = Port::link();
        let (tx_out, rx_out) = Port::link();
        for f in ChunkStream::new(&data, 3, 8) {
            tx_in.send(f).unwrap();
        }
        drop(tx_in);
        let dec = Decoupler::new();
        let ctl = PblockCtl::default();
        let swap = DfxManager::default()
            .stage(
                1,
                RmKind::Detector(DetectorKind::RsHash),
                3,
                3,
                5,
                &hyper(),
                &data[..30],
                None,
                false,
                1,
                Some(1),
                DarkPolicy::Bypass,
                8,
                1e5,
                2,
            )
            .unwrap();
        assert_eq!(swap.rm.describe(), "rshash(cpu,r=3,lanes=2)");
        ctl.swap.schedule(swap);
        Pblock::service_burst(&mut rm, &dec, &ctl, rx_in, tx_out, Some(&pool)).unwrap();
        let outs: Vec<Flit> = rx_out.iter().collect();
        assert_eq!(outs.len(), 4);
        assert_eq!(rm.describe(), "rshash(cpu,r=3,lanes=2)");
        let evs = ctl.swap.take_events();
        assert_eq!(evs.len(), 1);
        assert!(evs[0].from.contains("lanes=2"), "{}", evs[0].from);
        assert!(evs[0].to.contains("lanes=2"), "{}", evs[0].to);
    }
}
