//! Reconfigurable partitions (pblocks): each holds one loaded RM —
//! a detector ensemble (CPU-native or a PJRT artifact, the "bitstream"),
//! a bypass, or the default empty logic (paper §3.2–3.3). The RM persists
//! across stream runs (sliding-window state is streaming state), and is
//! swapped at run time by the DFX manager.

use anyhow::{bail, Context, Result};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use super::decoupler::Decoupler;
use super::message::{score_chunk, Flit};
use crate::config::{DetectorHyper, RmKind};
use crate::detectors::{Detector, DetectorSpec};
use crate::runtime::{generate_params, InstanceId, Registry, RuntimeHandle};

/// A loaded reconfigurable module.
pub enum LoadedRm {
    /// Default RM: consumes nothing, produces nothing (power-save).
    Empty,
    /// Identity logic, native implementation.
    BypassNative,
    /// Identity logic executed through the bypass artifact on the device.
    BypassFpga { handle: RuntimeHandle, d: usize },
    /// Detector ensemble on the CPU (baseline / fast tests).
    DetectorCpu { det: Box<dyn Detector> },
    /// Detector ensemble as a compiled artifact on the PJRT device.
    DetectorFpga { handle: RuntimeHandle, inst: InstanceId, chunk: usize, d: usize },
}

impl LoadedRm {
    pub fn describe(&self) -> String {
        match self {
            LoadedRm::Empty => "empty".into(),
            LoadedRm::BypassNative => "bypass(native)".into(),
            LoadedRm::BypassFpga { d, .. } => format!("bypass(fpga,d={d})"),
            LoadedRm::DetectorCpu { det } => format!("{}(cpu,r={})", det.name(), det.r()),
            LoadedRm::DetectorFpga { d, .. } => format!("detector(fpga,d={d})"),
        }
    }

    /// Build an RM from its config description.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        rm: RmKind,
        r: usize,
        d: usize,
        seed: u64,
        hyper: &DetectorHyper,
        warmup: &[f32],
        fpga: Option<(&RuntimeHandle, &Registry)>,
        quantize: bool,
    ) -> Result<LoadedRm> {
        match rm {
            RmKind::Empty => Ok(LoadedRm::Empty),
            RmKind::Bypass => match fpga {
                Some((handle, reg)) if reg.find_bypass(d).is_ok() => {
                    Ok(LoadedRm::BypassFpga { handle: handle.clone(), d })
                }
                _ => Ok(LoadedRm::BypassNative),
            },
            RmKind::Detector(kind) => match fpga {
                Some((handle, reg)) => {
                    let meta = reg.find_detector(kind, d, r, quantize)?;
                    let params = generate_params(kind, seed, r, d, hyper, warmup);
                    let inst = handle
                        .load_detector(meta, params)
                        .with_context(|| format!("loading {}", meta.name))?;
                    Ok(LoadedRm::DetectorFpga { handle: handle.clone(), inst, chunk: meta.chunk, d })
                }
                None => {
                    let mut spec = DetectorSpec::new(kind, d, r, seed);
                    spec.window = hyper.window;
                    spec.bins = hyper.bins;
                    spec.w = hyper.w;
                    spec.modulus = hyper.modulus;
                    spec.k = hyper.k;
                    spec.quantize = quantize;
                    Ok(LoadedRm::DetectorCpu { det: spec.build(warmup) })
                }
            },
        }
    }

    /// Process one flit; returns the output flit (None for Empty logic).
    pub fn process(&mut self, flit: &Flit) -> Result<Option<Flit>> {
        match self {
            LoadedRm::Empty => Ok(None),
            LoadedRm::BypassNative => Ok(Some(flit.clone())),
            LoadedRm::BypassFpga { handle, d } => {
                let out = handle.run_bypass(*d, flit.data.clone())?;
                Ok(Some(Flit {
                    seq: flit.seq,
                    data: out,
                    mask: flit.mask.clone(),
                    n_valid: flit.n_valid,
                    last: flit.last,
                }))
            }
            LoadedRm::DetectorCpu { det } => {
                let d = det.d();
                let rows = flit.mask.len();
                let mut scores = vec![0f32; rows];
                // Batch fast path over the whole flit (bit-identical to the
                // per-sample update loop); padding rows stay zero-scored.
                let n = flit.n_valid;
                det.update_batch(&flit.data[..n * d], &mut scores[..n]);
                Ok(Some(score_chunk(flit.seq, scores, flit.mask.clone(), flit.n_valid, flit.last)))
            }
            LoadedRm::DetectorFpga { handle, inst, chunk, d } => {
                if flit.data.len() != *chunk * *d {
                    bail!(
                        "pblock chunk mismatch: flit has {} values, artifact expects [{},{}]",
                        flit.data.len(),
                        chunk,
                        d
                    );
                }
                let scores = handle.run_chunk(*inst, flit.data.clone(), flit.mask.clone())?;
                Ok(Some(score_chunk(flit.seq, scores, flit.mask.clone(), flit.n_valid, flit.last)))
            }
        }
    }

    /// Reset streaming state (window contents), keeping parameters.
    pub fn reset(&mut self) -> Result<()> {
        match self {
            LoadedRm::DetectorCpu { det } => {
                det.reset();
                Ok(())
            }
            LoadedRm::DetectorFpga { handle, inst, .. } => handle.reset_state(*inst),
            _ => Ok(()),
        }
    }
}

/// Per-run pblock statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PblockReport {
    pub flits_in: u64,
    pub flits_out: u64,
    pub samples: u64,
    /// Seconds spent inside the RM (compute, not waiting).
    pub busy_secs: f64,
}

/// A reconfigurable partition of the fabric.
pub struct Pblock {
    pub id: usize,
    pub rm: LoadedRm,
    pub decoupler: Arc<Decoupler>,
}

impl Pblock {
    pub fn new(id: usize) -> Pblock {
        Pblock { id, rm: LoadedRm::Empty, decoupler: Arc::new(Decoupler::new()) }
    }

    /// Service one stream: pull flits from `rx`, run them through the RM,
    /// push results to `tx`. Returns when the stream ends (TLAST or closed).
    pub fn service(
        rm: &mut LoadedRm,
        decoupler: &Decoupler,
        rx: Receiver<Flit>,
        tx: Sender<Flit>,
    ) -> Result<PblockReport> {
        let mut report = PblockReport::default();
        for flit in rx.iter() {
            report.flits_in += 1;
            if decoupler.is_decoupled() {
                // DFX decoupler isolates the region during reconfiguration:
                // traffic is dropped, never handed to half-configured logic.
                if flit.last {
                    break;
                }
                continue;
            }
            let last = flit.last;
            let t0 = Instant::now();
            let out = rm.process(&flit)?;
            report.busy_secs += t0.elapsed().as_secs_f64();
            report.samples += flit.n_valid as u64;
            if let Some(out) = out {
                report.flits_out += 1;
                if tx.send(out).is_err() {
                    break; // downstream disabled
                }
            }
            if last {
                break;
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DetectorHyper;
    use crate::data::stream::ChunkStream;
    use crate::detectors::prng::Prng;
    use crate::detectors::DetectorKind;
    use crate::fabric::message::Port;

    fn hyper() -> DetectorHyper {
        DetectorHyper { window: 16, bins: 8, w: 2, modulus: 32, k: 4 }
    }

    fn stream_data(n: usize, d: usize) -> Vec<f32> {
        let mut p = Prng::new(9);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    #[test]
    fn cpu_detector_rm_scores_stream() {
        let data = stream_data(40, 3);
        let mut rm = LoadedRm::build(
            RmKind::Detector(DetectorKind::Loda),
            4,
            3,
            1,
            &hyper(),
            &data[..30],
            None,
            false,
        )
        .unwrap();
        let (tx_out, rx_out) = Port::link();
        let (tx_in, rx_in) = Port::link();
        for f in ChunkStream::new(&data, 3, 8) {
            tx_in.send(f).unwrap();
        }
        drop(tx_in);
        let dec = Decoupler::new();
        let report = Pblock::service(&mut rm, &dec, rx_in, tx_out).unwrap();
        assert_eq!(report.samples, 40);
        assert_eq!(report.flits_in, 5);
        let mut n_scores = 0;
        for f in rx_out.iter() {
            n_scores += f.n_valid;
        }
        assert_eq!(n_scores, 40);
    }

    #[test]
    fn bypass_rm_is_identity() {
        let data = stream_data(10, 2);
        let mut rm = LoadedRm::BypassNative;
        let flit = ChunkStream::new(&data, 2, 16).next().unwrap();
        let out = rm.process(&flit).unwrap().unwrap();
        assert_eq!(out.data, flit.data);
    }

    #[test]
    fn empty_rm_produces_nothing() {
        let mut rm = LoadedRm::Empty;
        let flit = ChunkStream::new(&[1.0, 2.0], 2, 4).next().unwrap();
        assert!(rm.process(&flit).unwrap().is_none());
    }

    #[test]
    fn decoupled_pblock_drops_traffic() {
        let data = stream_data(16, 2);
        let mut rm = LoadedRm::BypassNative;
        let (tx_in, rx_in) = Port::link();
        let (tx_out, rx_out) = Port::link();
        for f in ChunkStream::new(&data, 2, 8) {
            tx_in.send(f).unwrap();
        }
        drop(tx_in);
        let dec = Decoupler::new();
        dec.decouple();
        let report = Pblock::service(&mut rm, &dec, rx_in, tx_out).unwrap();
        assert_eq!(report.flits_out, 0);
        assert!(rx_out.recv().is_err());
        assert!(report.flits_in >= 1);
    }

    #[test]
    fn cpu_rm_scores_match_plain_detector() {
        let data = stream_data(32, 3);
        let hy = hyper();
        let mut rm = LoadedRm::build(
            RmKind::Detector(DetectorKind::RsHash),
            3,
            3,
            5,
            &hy,
            &data[..30],
            None,
            false,
        )
        .unwrap();
        let mut spec = DetectorSpec::new(DetectorKind::RsHash, 3, 3, 5);
        spec.window = hy.window;
        spec.bins = hy.bins;
        spec.w = hy.w;
        spec.modulus = hy.modulus;
        spec.k = hy.k;
        let mut det = spec.build(&data[..30]);
        let expect = det.run_stream(&data);
        let mut got = Vec::new();
        for flit in ChunkStream::new(&data, 3, 8) {
            if let Some(out) = rm.process(&flit).unwrap() {
                got.extend_from_slice(&out.data[..out.n_valid]);
            }
        }
        assert_eq!(got, expect);
    }
}
