//! Seeded fault injection and the per-partition health surface.
//!
//! # Fault taxonomy
//!
//! The injector models the failures a live FPGA fabric actually sees, each
//! with a deterministic software analogue:
//!
//! - **`lane_panic`** — a detector instance dies mid-burst (SEU in region
//!   logic): the lane worker's scoring closure panics once.
//! - **`worker_exit`** — a lane worker thread dies outright (hung kernel):
//!   the worker exits after its next job; the following dispatch fails.
//! - **`state_corrupt`** — detector state corruption (bit-flip in on-chip
//!   window memory): the RM's sliding window is poisoned so subsequent
//!   scores go non-finite — detected at the partition's output screen.
//! - **`stall`** — the partition wedges *while processing* (deadlocked
//!   pipeline): the service loop sleeps inside its processing section, so
//!   the supervisor's heartbeat watchdog must fire.
//! - **`inbox_stall`** — upstream starvation: the service loop sleeps
//!   *outside* its processing section. The watchdog must stay silent (a
//!   partition blocked on its inbox is healthy); the loop records the
//!   injection itself so tests can assert on the non-event.
//!
//! Every injection carries an id, so tests assert on exactly which fault
//! fired, and every detection/recovery step is recorded as a typed
//! [`FaultEvent`] on the partition's [`FaultPort`] — surfaced through
//! `RunOutput::fault_events` and per-session by the fabric server.
//!
//! Injection is **off by default** and the armed/unarmed split is strict:
//! with `[fabric.faults] enabled = false` (or no `--faults`), none of the
//! hooks in the service loops run — the data plane is bit-transparent to
//! this module.
//!
//! The escalation ladder that consumes these signals lives in
//! [`crate::fabric::supervisor`]; checkpoint/restore in
//! [`crate::fabric::snapshot`].

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::{FaultsCfg, InjectSpec};
use crate::detectors::prng::Prng;

/// What a scheduled fault does when it fires.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic lane `lane`'s next scoring job (multi-lane partitions).
    LanePanic { lane: usize },
    /// Kill lane worker `worker` after its next job.
    WorkerExit { worker: usize },
    /// Poison the RM's sliding-window state (scores go non-finite).
    StateCorrupt,
    /// Wedge the service loop mid-processing for `ms` milliseconds.
    Stall { ms: u64 },
    /// Starve the service loop for `ms` milliseconds *outside* processing
    /// (blocked-on-inbox is healthy; the watchdog must not fire).
    InboxStall { ms: u64 },
}

impl FaultKind {
    /// Taxonomy tag (stable strings for events, logs and BENCH output).
    pub fn tag(&self) -> &'static str {
        match self {
            FaultKind::LanePanic { .. } => "lane_panic",
            FaultKind::WorkerExit { .. } => "worker_exit",
            FaultKind::StateCorrupt => "state_corrupt",
            FaultKind::Stall { .. } => "stall",
            FaultKind::InboxStall { .. } => "inbox_stall",
        }
    }
}

/// One scheduled fault: fires on partition `pblock` when its service loop
/// reaches input flit `at_flit`.
#[derive(Clone, Debug)]
pub struct InjectedFault {
    pub id: String,
    pub pblock: usize,
    pub at_flit: u64,
    pub kind: FaultKind,
}

impl InjectedFault {
    /// Convert a parsed `[fabric.faults.inject.N]` section. The kind string
    /// is the taxonomy tag; `lane` selects the lane/worker index, `ms` the
    /// stall duration.
    pub fn from_spec(s: &InjectSpec) -> Result<InjectedFault> {
        let kind = match s.kind.as_str() {
            "lane_panic" => FaultKind::LanePanic { lane: s.lane },
            "worker_exit" => FaultKind::WorkerExit { worker: s.lane },
            "state_corrupt" => FaultKind::StateCorrupt,
            "stall" => FaultKind::Stall { ms: s.ms.max(1) },
            "inbox_stall" => FaultKind::InboxStall { ms: s.ms.max(1) },
            other => bail!(
                "unknown fault kind {other:?} (expected lane_panic | worker_exit | \
                 state_corrupt | stall | inbox_stall)"
            ),
        };
        Ok(InjectedFault { id: s.id.clone(), pblock: s.pblock, at_flit: s.at_flit, kind })
    }
}

/// One recorded fault-handling step: an injection firing, a detection, or a
/// rung of the supervisor's retry → reload → quarantine ladder.
#[derive(Clone, Debug)]
pub struct FaultEvent {
    /// Id of the injected fault this traces back to (`-` when the trigger
    /// was detected rather than matched to a scheduled injection).
    pub id: String,
    pub pblock: usize,
    /// Partition input flit at which this step happened.
    pub at_flit: u64,
    /// Taxonomy tag of the fault ([`FaultKind::tag`]) or detection class.
    pub fault: String,
    /// What was done: `injected`, `skipped`, `lane_panic_retried`,
    /// `respawn_retry`, `nonfinite_detected`, `stall_detected`,
    /// `reloaded`, `reload_failed`, `quarantined`, …
    pub action: String,
    /// Escalation rung that handled it: 0 = in-place worker containment,
    /// 1 = RM reload (+ checkpoint restore), 2 = quarantine.
    pub rung: u8,
    /// Detection-to-action latency where meaningful (0 otherwise).
    pub latency_us: u64,
    /// For `reloaded`: the checkpoint flit the replacement resumed from.
    pub checkpoint_flit: Option<u64>,
    pub detail: String,
}

impl std::fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "p{} flit {}: {} -> {} (rung {}, id {}",
            self.pblock, self.at_flit, self.fault, self.action, self.rung, self.id
        )?;
        if self.latency_us > 0 {
            write!(f, ", {} us", self.latency_us)?;
        }
        if let Some(cp) = self.checkpoint_flit {
            write!(f, ", from checkpoint flit {cp}")?;
        }
        write!(f, ")")?;
        if !self.detail.is_empty() {
            write!(f, " — {}", self.detail)?;
        }
        Ok(())
    }
}

/// Sentinel for "no pending injection" in the cheap due-probe.
const NO_PENDING: u64 = u64::MAX;

/// Per-partition fault mailbox on the control surface: scheduled injections
/// going in (fabric construction), fault events coming out (service loop,
/// lane pool, supervisor). The hot-path probe is one relaxed atomic load —
/// a partition with nothing due never touches a lock per flit.
pub struct FaultPort {
    pending: Mutex<Vec<InjectedFault>>,
    /// Earliest pending `at_flit` (`NO_PENDING` when the queue is empty).
    next_at: AtomicU64,
    events: Mutex<Vec<FaultEvent>>,
    /// Owning partition id, bound when the fabric arms fault handling —
    /// detection events (non-finite screen, respawn retries) are recorded
    /// by code that only sees the control surface, not the pblock.
    pblock: AtomicU64,
    /// Cumulative event count, never reset — [`FaultPort::take_events`]
    /// drains the event list into run/session results, so the operator
    /// plane reads these counters instead.
    recorded: AtomicU64,
    /// Cumulative rung-1 reloads ([`FaultEvent::action`] == `reloaded`).
    reloads: AtomicU64,
    /// Cumulative rung-2 quarantines (`action` == `quarantined`).
    quarantines: AtomicU64,
}

impl Default for FaultPort {
    fn default() -> Self {
        FaultPort {
            pending: Mutex::new(Vec::new()),
            next_at: AtomicU64::new(NO_PENDING),
            events: Mutex::new(Vec::new()),
            pblock: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
        }
    }
}

impl FaultPort {
    /// Bind the owning partition id (done once, while arming).
    pub fn bind(&self, pblock: usize) {
        self.pblock.store(pblock as u64, Ordering::SeqCst);
    }

    /// The bound partition id (0 until [`FaultPort::bind`]).
    pub fn pblock(&self) -> usize {
        self.pblock.load(Ordering::Relaxed) as usize
    }

    /// Queue injections for this partition (sorted by fire flit).
    pub fn schedule(&self, faults: Vec<InjectedFault>) {
        let mut q = self.pending.lock().unwrap();
        q.extend(faults);
        q.sort_by_key(|f| f.at_flit);
        let next = q.first().map_or(NO_PENDING, |f| f.at_flit);
        self.next_at.store(next, Ordering::SeqCst);
    }

    /// Injections due at input flit `flit` (0-based), removed from the
    /// queue. The common no-fault case is a single atomic load.
    pub fn take_due(&self, flit: u64) -> Vec<InjectedFault> {
        if self.next_at.load(Ordering::Relaxed) > flit {
            return Vec::new();
        }
        let mut q = self.pending.lock().unwrap();
        let mut due = Vec::new();
        let mut i = 0;
        while i < q.len() {
            if q[i].at_flit <= flit {
                due.push(q.remove(i));
            } else {
                i += 1;
            }
        }
        let next = q.first().map_or(NO_PENDING, |f| f.at_flit);
        self.next_at.store(next, Ordering::SeqCst);
        due
    }

    /// Record one fault-handling step.
    pub fn record(&self, ev: FaultEvent) {
        self.recorded.fetch_add(1, Ordering::SeqCst);
        match ev.action.as_str() {
            "reloaded" => {
                self.reloads.fetch_add(1, Ordering::SeqCst);
            }
            "quarantined" => {
                self.quarantines.fetch_add(1, Ordering::SeqCst);
            }
            _ => {}
        }
        self.events.lock().unwrap().push(ev);
    }

    /// Drain the recorded events (run teardown / session close).
    pub fn take_events(&self) -> Vec<FaultEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    /// Fault-handling steps recorded since construction (cumulative,
    /// survives [`FaultPort::take_events`] drains).
    pub fn events_recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Cumulative rung-1 RM reloads performed on this partition.
    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Cumulative rung-2 quarantines latched on this partition.
    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Injections not yet fired.
    pub fn pending_count(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Drop pending injections (episode boundary).
    pub fn clear_pending(&self) -> usize {
        let mut q = self.pending.lock().unwrap();
        let n = q.len();
        q.clear();
        self.next_at.store(NO_PENDING, Ordering::SeqCst);
        n
    }
}

/// A reload requested by the service loop after detecting non-finite
/// scores, consumed by the fault supervisor (single-slot: one recovery in
/// flight per partition).
#[derive(Clone, Debug)]
pub struct ReloadRequest {
    /// Injected-fault id that (probably) caused this, `-` when unknown.
    pub fault_id: String,
    /// Input flits fully processed when the corruption was detected.
    pub at_flit: u64,
    pub reason: String,
}

/// Per-partition health surface: heartbeat + processing flag published by
/// the service loop, watched by the supervisor's stall watchdog, plus the
/// reload-request slot and the checkpoint cadence.
///
/// Heartbeat semantics: `beat` ticks once per input flit and `processing`
/// is true strictly while the RM is scoring. The watchdog flags a stall
/// only when `processing` is set **and** the beat has not moved past the
/// timeout — a partition blocked on an empty inbox is healthy, however
/// long it waits.
#[derive(Default)]
pub struct Health {
    armed: AtomicBool,
    beat: AtomicU64,
    processing: AtomicBool,
    /// Store a checkpoint every N healthy flits (0 = never).
    checkpoint_every: AtomicU64,
    /// How long the service loop waits for the supervisor's staged reload
    /// after requesting one, before carrying on degraded.
    reload_wait_ms: AtomicU64,
    reload: Mutex<Option<ReloadRequest>>,
}

impl Health {
    /// Arm the fault machinery for this partition. Unarmed (the default),
    /// every hook in the service loops is skipped — bit-transparent.
    pub fn arm(&self, checkpoint_every: u64, reload_wait_ms: u64) {
        self.checkpoint_every.store(checkpoint_every, Ordering::SeqCst);
        self.reload_wait_ms.store(reload_wait_ms, Ordering::SeqCst);
        self.armed.store(true, Ordering::SeqCst);
    }

    /// Disarm (episode boundary) and drop any un-consumed reload request.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::SeqCst);
        *self.reload.lock().unwrap() = None;
    }

    #[inline]
    pub fn is_armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// One heartbeat per input flit.
    #[inline]
    pub fn tick(&self) {
        self.beat.fetch_add(1, Ordering::SeqCst);
    }

    pub fn beat(&self) -> u64 {
        self.beat.load(Ordering::SeqCst)
    }

    #[inline]
    pub fn set_processing(&self, on: bool) {
        self.processing.store(on, Ordering::SeqCst);
    }

    pub fn is_processing(&self) -> bool {
        self.processing.load(Ordering::SeqCst)
    }

    pub fn checkpoint_every(&self) -> u64 {
        self.checkpoint_every.load(Ordering::Relaxed)
    }

    pub fn reload_wait_ms(&self) -> u64 {
        self.reload_wait_ms.load(Ordering::Relaxed)
    }

    /// File a reload request; refused (false) while one is already pending
    /// — repeated non-finite flits during one recovery collapse into it.
    pub fn request_reload(&self, req: ReloadRequest) -> bool {
        let mut slot = self.reload.lock().unwrap();
        if slot.is_some() {
            return false;
        }
        *slot = Some(req);
        true
    }

    /// Consume the pending reload request (supervisor side).
    pub fn take_reload(&self) -> Option<ReloadRequest> {
        self.reload.lock().unwrap().take()
    }

    pub fn has_reload_request(&self) -> bool {
        self.reload.lock().unwrap().is_some()
    }
}

/// Deterministic fault planner: scripted injections verbatim plus an
/// optional seeded pseudo-random background rate.
pub struct FaultInjector;

impl FaultInjector {
    /// Build the injection plan for one run. `pblocks` are the configured
    /// partition ids, `horizon_flits` bounds the random placement window
    /// (per-pblock input flits). Same config + seed + pblocks + horizon →
    /// same plan, always.
    pub fn plan(
        cfg: &FaultsCfg,
        fabric_seed: u64,
        pblocks: &[usize],
        horizon_flits: u64,
    ) -> Result<Vec<InjectedFault>> {
        let mut out = Vec::new();
        for spec in &cfg.injections {
            out.push(InjectedFault::from_spec(spec)?);
        }
        if cfg.rate_per_kflit > 0.0 && horizon_flits > 0 {
            let seed = if cfg.seed != 0 { cfg.seed } else { fabric_seed };
            for &p in pblocks {
                let mut rng = Prng::new(seed ^ (p as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let expected = cfg.rate_per_kflit * horizon_flits as f64 / 1000.0;
                let mut count = expected.floor() as u64;
                if rng.uniform() < expected.fract() {
                    count += 1;
                }
                for i in 0..count {
                    let at_flit = (rng.uniform() * horizon_flits as f64) as u64;
                    let kind = match i % 3 {
                        0 => FaultKind::StateCorrupt,
                        1 => FaultKind::LanePanic { lane: 0 },
                        _ => FaultKind::Stall { ms: cfg.stall_ms.max(1) },
                    };
                    out.push(InjectedFault { id: format!("r{p}-{i}"), pblock: p, at_flit, kind });
                }
            }
        }
        out.sort_by(|a, b| (a.at_flit, a.pblock).cmp(&(b.at_flit, b.pblock)));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: &str, kind: &str, at_flit: u64) -> InjectSpec {
        InjectSpec {
            id: id.to_string(),
            pblock: 1,
            at_flit,
            kind: kind.to_string(),
            lane: 2,
            ms: 15,
        }
    }

    #[test]
    fn spec_kinds_parse_and_reject() {
        let f = InjectedFault::from_spec(&spec("a", "lane_panic", 3)).unwrap();
        assert_eq!(f.kind, FaultKind::LanePanic { lane: 2 });
        assert_eq!((f.id.as_str(), f.pblock, f.at_flit), ("a", 1, 3));
        let f = InjectedFault::from_spec(&spec("b", "worker_exit", 0)).unwrap();
        assert_eq!(f.kind, FaultKind::WorkerExit { worker: 2 });
        let f = InjectedFault::from_spec(&spec("c", "state_corrupt", 0)).unwrap();
        assert_eq!(f.kind, FaultKind::StateCorrupt);
        let f = InjectedFault::from_spec(&spec("d", "stall", 0)).unwrap();
        assert_eq!(f.kind, FaultKind::Stall { ms: 15 });
        let f = InjectedFault::from_spec(&spec("e", "inbox_stall", 0)).unwrap();
        assert_eq!(f.kind, FaultKind::InboxStall { ms: 15 });
        assert!(InjectedFault::from_spec(&spec("f", "gamma_ray", 0)).is_err());
    }

    #[test]
    fn port_fires_in_flit_order_with_cheap_probe() {
        let port = FaultPort::default();
        assert!(port.take_due(1_000_000).is_empty(), "empty port never fires");
        port.schedule(vec![
            InjectedFault { id: "late".into(), pblock: 1, at_flit: 9, kind: FaultKind::StateCorrupt },
            InjectedFault { id: "early".into(), pblock: 1, at_flit: 2, kind: FaultKind::StateCorrupt },
        ]);
        assert_eq!(port.pending_count(), 2);
        assert!(port.take_due(1).is_empty());
        let due = port.take_due(2);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].id, "early");
        // Overdue injections all fire at once.
        let due = port.take_due(50);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].id, "late");
        assert_eq!(port.pending_count(), 0);
        assert!(port.take_due(u64::MAX - 1).is_empty());
    }

    #[test]
    fn port_clear_drops_pending_and_events_drain_once() {
        let port = FaultPort::default();
        port.schedule(vec![InjectedFault {
            id: "x".into(),
            pblock: 2,
            at_flit: 4,
            kind: FaultKind::Stall { ms: 1 },
        }]);
        assert_eq!(port.clear_pending(), 1);
        assert!(port.take_due(100).is_empty());
        port.record(FaultEvent {
            id: "x".into(),
            pblock: 2,
            at_flit: 4,
            fault: "stall".into(),
            action: "injected".into(),
            rung: 0,
            latency_us: 0,
            checkpoint_flit: None,
            detail: String::new(),
        });
        assert_eq!(port.take_events().len(), 1);
        assert!(port.take_events().is_empty());
    }

    #[test]
    fn health_reload_slot_is_single_occupancy() {
        let h = Health::default();
        assert!(!h.is_armed());
        h.arm(8, 100);
        assert!(h.is_armed());
        assert_eq!((h.checkpoint_every(), h.reload_wait_ms()), (8, 100));
        let req = ReloadRequest { fault_id: "a".into(), at_flit: 5, reason: "nan".into() };
        assert!(h.request_reload(req.clone()));
        assert!(!h.request_reload(req), "second request collapses into the first");
        assert!(h.has_reload_request());
        assert_eq!(h.take_reload().unwrap().fault_id, "a");
        assert!(h.take_reload().is_none());
        h.tick();
        h.tick();
        assert_eq!(h.beat(), 2);
        h.set_processing(true);
        assert!(h.is_processing());
        h.disarm();
        assert!(!h.is_armed());
    }

    #[test]
    fn plan_is_deterministic_and_keeps_scripted_faults() {
        let mut cfg = FaultsCfg::default();
        cfg.injections.push(spec("s1", "state_corrupt", 7));
        cfg.rate_per_kflit = 40.0;
        cfg.stall_ms = 5;
        let a = FaultInjector::plan(&cfg, 42, &[1, 2], 100).unwrap();
        let b = FaultInjector::plan(&cfg, 42, &[1, 2], 100).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.at_flit, x.pblock, &x.id, &x.kind), (y.at_flit, y.pblock, &y.id, &y.kind));
        }
        assert!(a.iter().any(|f| f.id == "s1"), "scripted injection survives planning");
        assert!(a.len() > 1, "rate 40/kflit over 100 flits × 2 pblocks plans random faults");
        assert!(a.windows(2).all(|w| w[0].at_flit <= w[1].at_flit), "sorted by fire flit");
        // Different seed → different placement.
        let c = FaultInjector::plan(&cfg, 43, &[1, 2], 100).unwrap();
        let same = a.iter().zip(&c).filter(|(x, y)| x.at_flit == y.at_flit).count();
        assert!(same < a.len(), "plans must depend on the seed");
        // Disabled rate plans only scripted faults.
        cfg.rate_per_kflit = 0.0;
        let d = FaultInjector::plan(&cfg, 42, &[1, 2], 100).unwrap();
        assert_eq!(d.len(), 1);
    }
}
