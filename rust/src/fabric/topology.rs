//! Fabric topology (paper Fig 6): seven AD pblocks fed by fixed input DMAs,
//! outputs into Switch-1, Switch-1 masters either to output DMAs (direct
//! host routes, Fig 7a) or across to Switch-2, which feeds the combo
//! pblocks; combo outputs return through Switch-2 to output DMAs.
//!
//! `Fabric::new` loads every configured RM (through the DFX manager);
//! `Fabric::run` wires the switches for the current configuration, streams
//! the datasets through, and collects per-pblock / per-combo score streams.
//!
//! The data plane is zero-copy: flit payloads are shared `Arc<[f32]>`
//! buffers, pblocks fed by the same stream share one host buffer, and each
//! pblock drains its inbox in bursts or per flit according to
//! `FseadConfig::exec` (see `fabric::pblock` for the burst design).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use super::combo::{self, ComboEngine};
use super::decoupler::Decoupler;
use super::dma::{DmaReport, InputDma, OutputDma};
use super::faults::{FaultEvent, FaultInjector};
use super::hotswap::{self, ControllerEnv, ControllerTarget, SwapEvent};
use super::operator::{FabricSnapshot, PartitionTelemetry, ServerTelemetry};
use super::supervisor::{self, SupervisorEnv, SupervisorTarget};
use super::message::{Flit, Port};
use super::pblock::{Pblock, PblockReport};
use super::reconfig::{DfxManager, ReconfigReport};
use super::switch::AxiSwitch;
use crate::combine::ScoreCombiner;
use crate::config::{ComboCfg, DarkPolicy, FseadConfig, RmKind};
use crate::data::Dataset;
use crate::defaults;
use crate::detectors::DetectorKind;
use crate::hw::timing::FpgaTimingModel;
use crate::runtime::{Runtime, RuntimeStats};

/// Result of one streaming pass.
#[derive(Clone, Debug, Default)]
pub struct RunOutput {
    /// Scores from pblocks routed directly to the host, by pblock id.
    pub pblock_scores: BTreeMap<usize, Vec<f32>>,
    /// Scores from combo pblocks, by combo id.
    pub combo_scores: BTreeMap<usize, Vec<f32>>,
    /// Wall-clock of the pass.
    pub wall_secs: f64,
    /// Modelled FPGA execution time for this pass (DESIGN.md §6).
    pub modeled_fpga_secs: f64,
    /// Total flits moved by the two switches.
    pub switch_flits: u64,
    /// Per-pblock service reports.
    pub pblock_reports: BTreeMap<usize, PblockReport>,
    /// Input DMA reports by pblock id.
    pub dma_reports: BTreeMap<usize, DmaReport>,
    /// In-flight RM swaps executed during this pass (live DFX), in
    /// (flit, pblock) order.
    pub swap_events: Vec<SwapEvent>,
    /// Swaps issued by the adaptive controller during this pass (some may
    /// still be pending if the stream ended first).
    pub adaptive_swaps_issued: u64,
    /// Fault injections, detections and recovery-ladder transitions
    /// recorded during this pass, in (flit, pblock) order. Empty unless
    /// `[fabric.faults] enabled = true`.
    pub fault_events: Vec<FaultEvent>,
}

impl RunOutput {
    /// Bridge the one-shot batch pass onto the operator plane's unified
    /// telemetry view, so `Fabric::run` results render through the same
    /// Prometheus / JSON exporters as a live `fsead serve`
    /// ([`FabricSnapshot::to_prometheus`], [`FabricSnapshot::to_json`]).
    ///
    /// `cfg` supplies the static placement (RM kind, R, lanes) the pass
    /// itself does not carry. Live-only readings — controller tuning,
    /// drift statistics, decoupler state — report the configured or
    /// resting values: the pass is over, nothing is isolated or pending.
    pub fn snapshot(&self, cfg: &FseadConfig) -> FabricSnapshot {
        let partitions = cfg
            .pblocks
            .iter()
            .map(|p| {
                let report = self.pblock_reports.get(&p.id).copied().unwrap_or_default();
                let history: Vec<SwapEvent> =
                    self.swap_events.iter().filter(|e| e.pblock == p.id).cloned().collect();
                let faults = |action: &str| -> u64 {
                    self.fault_events
                        .iter()
                        .filter(|e| e.pblock == p.id && e.action.as_str() == action)
                        .count() as u64
                };
                let fault_events =
                    self.fault_events.iter().filter(|e| e.pblock == p.id).count() as u64;
                PartitionTelemetry {
                    id: p.id,
                    rm: p.rm.as_str(),
                    r: p.r,
                    lanes: cfg.lanes_for(p),
                    capacity: 1,
                    admitted: 0,
                    flits_seen: report.flits_in,
                    swaps_pending: 0,
                    swaps_executed: history.len() as u64,
                    dropped_flits: history.iter().map(|e| e.dropped).sum(),
                    swap_history: history,
                    controller_threshold: cfg.dfx.threshold,
                    controller_cooldown_flits: cfg.dfx.cooldown_flits,
                    drift_armed: false,
                    drift_ready: false,
                    drift_z: 0.0,
                    decoupler_enabled: true,
                    isolated: false,
                    quarantined: false,
                    fault_events,
                    fault_reloads: faults("reloaded"),
                    fault_quarantines: faults("quarantined"),
                    health_beat: report.flits_in,
                }
            })
            .collect();
        FabricSnapshot {
            server: ServerTelemetry::default(),
            partitions,
            sessions: Vec::new(),
        }
    }
}

/// The composable fabric.
pub struct Fabric {
    cfg: FseadConfig,
    streams: Vec<Dataset>,
    runtime: Option<Runtime>,
    pblocks: Vec<Pblock>,
    dfx: DfxManager,
}

impl Fabric {
    /// Build the fabric: start the PJRT device (if `use_fpga`), then load
    /// every configured RM. `streams[i]` backs DMA channel `i`.
    pub fn new(cfg: FseadConfig, streams: Vec<Dataset>) -> Result<Fabric> {
        cfg.validate()?;
        Self::validate_streams(&cfg, &streams)?;
        let runtime = if cfg.use_fpga {
            Some(Runtime::start(&cfg.artifact_dir).context("starting PJRT runtime")?)
        } else {
            None
        };
        let pblocks: Vec<Pblock> = (1..=defaults::NUM_AD_PBLOCKS).map(Pblock::new).collect();
        let mut fabric = Fabric { cfg, streams, runtime, pblocks, dfx: DfxManager::default() };
        fabric.load_all_rms()?;
        fabric.ensure_lane_pools();
        // Arm the scripted swap schedule (live DFX): the replacement RMs
        // are staged now, each one fires at its flit index during `run()`.
        let scripted = fabric.cfg.dfx.swaps.clone();
        for s in &scripted {
            fabric
                .schedule_swap(s.pblock, s.at_flit, s.rm, s.r, s.dark_flits)
                .with_context(|| format!("arming scripted swap for pblock {}", s.pblock))?;
        }
        Ok(fabric)
    }

    fn validate_streams(cfg: &FseadConfig, streams: &[Dataset]) -> Result<()> {
        for p in &cfg.pblocks {
            if p.rm == RmKind::Empty {
                continue;
            }
            let ds = streams
                .get(p.stream)
                .with_context(|| format!("pblock {} references missing stream {}", p.id, p.stream))?;
            if ds.d == 0 || ds.n() == 0 {
                bail!("stream {} is empty", p.stream);
            }
        }
        for c in &cfg.combos {
            let stream_of = |id: usize| cfg.pblocks.iter().find(|p| p.id == id).map(|p| p.stream);
            let first = stream_of(c.inputs[0]);
            for &i in &c.inputs[1..] {
                if stream_of(i) != first {
                    bail!("combo {} joins pblocks on different streams", c.id);
                }
            }
        }
        Ok(())
    }

    fn load_all_rms(&mut self) -> Result<()> {
        let cfg = self.cfg.clone();
        for pcfg in &cfg.pblocks {
            self.reconfigure(pcfg.id, pcfg.rm, pcfg.r, pcfg.stream)?;
        }
        Ok(())
    }

    /// Spawn (or retire) each partition's resident lane workers to match
    /// its configured lane count. Pools persist across runs, bursts and
    /// hot-swaps; only a lane-count change rebuilds one. CPU-native
    /// detector RMs only — the modelled FPGA path executes as a single
    /// artifact invocation.
    fn ensure_lane_pools(&mut self) {
        for p in &self.cfg.pblocks {
            let want = if !self.cfg.use_fpga && matches!(p.rm, RmKind::Detector(_)) {
                self.cfg.lanes_for(p).min(p.r.max(1))
            } else {
                1
            };
            let pb = &mut self.pblocks[p.id - 1];
            let have = pb.pool.as_ref().map_or(1, |pool| pool.workers());
            if want > 1 && have != want {
                pb.pool = Some(crate::ensemble::LanePool::new(want));
            } else if want <= 1 {
                pb.pool = None;
            }
        }
    }

    /// Swap the RM in pblock `id` (run-time DFX). Returns the report with
    /// modelled and measured latency.
    pub fn reconfigure(
        &mut self,
        id: usize,
        rm: RmKind,
        r: usize,
        stream: usize,
    ) -> Result<ReconfigReport> {
        if !(1..=self.pblocks.len()).contains(&id) {
            bail!("no pblock {id}");
        }
        let ds = self.streams.get(stream);
        let (d, warmup): (usize, &[f32]) = match ds {
            Some(ds) => (ds.d, ds.warmup(self.cfg.hyper.window)),
            None if rm == RmKind::Empty => (0, &[]),
            None => bail!("pblock {id}: stream {stream} does not exist"),
        };
        let fpga = self.runtime.as_ref().map(|rt| (rt.handle(), rt.registry().clone()));
        let seed = pblock_seed(self.cfg.seed, id);
        // The partition keeps its configured lane count across swaps.
        let lanes = self
            .cfg
            .pblocks
            .iter()
            .find(|p| p.id == id)
            .map(|p| self.cfg.lanes_for(p))
            .unwrap_or_else(|| self.cfg.lanes.max(1));
        let report = self.dfx.reconfigure(
            &mut self.pblocks[id - 1],
            rm,
            r,
            d,
            seed,
            &self.cfg.hyper,
            warmup,
            fpga.as_ref().map(|(h, r)| (h, r)),
            self.cfg.use_fpga, // artifacts are the quantized builds
            lanes,
        )?;
        // Track the new assignment in the config (so run() wires it).
        if let Some(pcfg) = self.cfg.pblocks.iter_mut().find(|p| p.id == id) {
            pcfg.rm = rm;
            pcfg.r = r;
            pcfg.stream = stream;
        } else {
            self.cfg.pblocks.push(crate::config::PblockCfg { id, rm, r, stream, lanes: 0 });
            self.cfg.pblocks.sort_by_key(|p| p.id);
        }
        self.ensure_lane_pools();
        Ok(report)
    }

    /// Arm an in-flight swap for pblock `id` at pblock-input flit
    /// `at_flit` of the next `run()` — live DFX, the fabric keeps
    /// streaming (see `fabric::hotswap` for the quiesce protocol). The
    /// replacement RM is staged immediately; the pblock stays on its DMA
    /// channel. `dark_flits = None` derives the dark window from the
    /// Table-13 model at `[fabric.dfx] samples_per_sec`. Returns the
    /// modelled download latency (ms) and the dark-window length (flits).
    pub fn schedule_swap(
        &self,
        id: usize,
        at_flit: u64,
        rm: RmKind,
        r: usize,
        dark_flits: Option<u64>,
    ) -> Result<(f64, u64)> {
        if !(1..=self.pblocks.len()).contains(&id) {
            bail!("no pblock {id}");
        }
        let pb = &self.pblocks[id - 1];
        if !pb.decoupler.is_enabled() {
            bail!("pblock {id}: decoupler is disabled — cannot hot-swap without isolation");
        }
        if self.cfg.dfx.policy == DarkPolicy::Drop
            && self.cfg.combos.iter().any(|c| c.inputs.contains(&id))
        {
            bail!(
                "pblock {id} feeds a combo — a drop-policy dark window would desynchronise \
                 the lock-step join; use DarkPolicy::Bypass"
            );
        }
        let pcfg = self
            .cfg
            .pblocks
            .iter()
            .find(|p| p.id == id)
            .with_context(|| format!("pblock {id} is not configured (no stream to stay on)"))?;
        let ds = self
            .streams
            .get(pcfg.stream)
            .with_context(|| format!("pblock {id}: stream {} does not exist", pcfg.stream))?;
        let fpga = self.runtime.as_ref().map(|rt| (rt.handle(), rt.registry().clone()));
        let seed = pblock_seed(self.cfg.seed, id);
        let swap = self.dfx.stage(
            id,
            rm,
            r,
            ds.d,
            seed,
            &self.cfg.hyper,
            ds.warmup(self.cfg.hyper.window),
            fpga.as_ref().map(|(h, reg)| (h, reg)),
            self.cfg.use_fpga,
            at_flit,
            dark_flits,
            self.cfg.dfx.policy,
            self.cfg.chunk,
            self.cfg.dfx.samples_per_sec,
            self.cfg.lanes_for(pcfg),
        )?;
        let info = (swap.model_ms, swap.dark_flits);
        pb.ctl.swap.schedule(swap);
        Ok(info)
    }

    /// Update combo assignments (run-time switch re-programming).
    pub fn set_combos(&mut self, combos: Vec<ComboCfg>) -> Result<()> {
        let mut cfg = self.cfg.clone();
        cfg.combos = combos;
        cfg.validate()?;
        self.cfg = cfg;
        Ok(())
    }

    pub fn config(&self) -> &FseadConfig {
        &self.cfg
    }

    /// Shared control surfaces of pblock `id` (1-based): decoupler, swap
    /// mailbox, score statistics.
    pub fn pblock(&self, id: usize) -> Option<&Pblock> {
        self.pblocks.get(id.checked_sub(1)?)
    }

    pub fn runtime_stats(&self) -> Option<RuntimeStats> {
        self.runtime.as_ref().and_then(|rt| rt.handle().stats().ok())
    }

    /// Reset all detector sliding-window state.
    pub fn reset_all(&mut self) -> Result<()> {
        for pb in &mut self.pblocks {
            pb.rm.reset()?;
        }
        Ok(())
    }

    fn combo_engine(&self, c: &ComboCfg) -> Result<ComboEngine> {
        if let Some(rt) = &self.runtime {
            if let Ok(meta) = rt.registry().find_combo(&c.method) {
                // Weights are padded to the device shape once here and
                // shared per flit by the combo service.
                return Ok(ComboEngine::fpga(
                    rt.handle(),
                    c.method.clone(),
                    c.weights.clone(),
                    meta.chunk,
                ));
            }
        }
        let combiner = match c.method.as_str() {
            "wavg" => ScoreCombiner::WeightedAverage(c.weights.clone()),
            m => ScoreCombiner::parse(m)
                .with_context(|| format!("combo {}: unknown method {m:?}", c.id))?,
        };
        Ok(ComboEngine::Native(combiner))
    }

    /// Modelled FPGA time of this pass: pblocks run spatially in parallel,
    /// so the fabric finishes with its slowest configured pblock.
    fn model_pass_time(&self) -> f64 {
        let model = FpgaTimingModel::default();
        let mut worst = 0f64;
        for p in &self.cfg.pblocks {
            if let (RmKind::Detector(kind), Some(ds)) = (p.rm, self.streams.get(p.stream)) {
                worst = worst.max(model.exec_time_s(kind, ds.n(), ds.d));
            }
        }
        worst
    }

    /// One streaming pass over all configured streams.
    pub fn run(&mut self) -> Result<RunOutput> {
        let cfg = self.cfg.clone();
        let chunk = cfg.chunk;
        let active: Vec<_> = cfg.pblocks.iter().filter(|p| p.rm != RmKind::Empty).collect();
        if active.is_empty() {
            bail!("no pblocks configured — nothing to run");
        }
        let direct = cfg.direct_outputs();
        let modeled = self.model_pass_time();

        // ---- Live DFX: reset the per-run flit counters (swap schedules
        //      are indexed by pblock-input flit).
        for pb in &self.pblocks {
            pb.ctl.swap.begin_run();
        }

        // ---- Fault campaign: plan this pass's injections (scripted +
        //      seeded random) and arm the per-partition fault hooks. With
        //      faults disabled none of this runs and the data plane stays
        //      bit-transparent to the fault machinery.
        let faults_on = cfg.faults.enabled;
        if faults_on {
            let horizon = active
                .iter()
                .map(|p| {
                    let n = self.streams[p.stream].n();
                    ((n + chunk - 1) / chunk) as u64
                })
                .max()
                .unwrap_or(0);
            let ids: Vec<usize> = active.iter().map(|p| p.id).collect();
            let plan = FaultInjector::plan(&cfg.faults, cfg.seed, &ids, horizon)?;
            for pb in &self.pblocks {
                if !ids.contains(&pb.id) {
                    continue;
                }
                pb.ctl.health.arm(cfg.faults.checkpoint_every_flits, cfg.faults.reload_wait_ms);
                pb.ctl.faults.bind(pb.id);
                pb.ctl.faults.clear_pending();
                pb.ctl
                    .faults
                    .schedule(plan.iter().filter(|f| f.pblock == pb.id).cloned().collect());
                if let Some(pool) = &pb.pool {
                    pool.arm_faults();
                }
            }
        }

        // ---- Switch-1: slaves = pblock outputs; masters = direct-out DMAs
        //      then feeds toward Switch-2 (one per combo input).
        let mut sw1 = AxiSwitch::new("switch1", defaults::NUM_AD_PBLOCKS, 16)?;
        let mut sw1_master = 0usize;
        // master index → role
        enum Sw1Role {
            DirectOut(usize),            // pblock id
            ComboFeed(usize, usize),     // (combo id, input slot)
        }
        let mut sw1_roles: Vec<Sw1Role> = Vec::new();
        for &id in &direct {
            sw1.set_route(sw1_master, id - 1)?;
            sw1_roles.push(Sw1Role::DirectOut(id));
            sw1_master += 1;
        }
        for c in &cfg.combos {
            for (slot, &input) in c.inputs.iter().enumerate() {
                sw1.set_route(sw1_master, input - 1)?;
                sw1_roles.push(Sw1Role::ComboFeed(c.id, slot));
                sw1_master += 1;
            }
        }

        // ---- Switch-2: slaves = combo feeds (from switch-1) + combo
        //      outputs; masters = combo input ports + combo out DMAs.
        let n_feeds = cfg.combos.iter().map(|c| c.inputs.len()).sum::<usize>();
        let n_combos = cfg.combos.len();
        let mut sw2 = AxiSwitch::new("switch2", n_feeds + n_combos, n_feeds + n_combos)
            .context("switch-2 port budget (cascade limit)")?;
        for j in 0..n_feeds {
            sw2.set_route(j, j)?; // feed j → combo input port j
        }
        for ci in 0..n_combos {
            sw2.set_route(n_feeds + ci, n_feeds + ci)?; // combo out → DMA
        }

        // ---- Channels.
        let mut sw1_slave_rx: Vec<Option<Receiver<Flit>>> = (0..7).map(|_| None).collect();
        let mut sw1_master_tx: Vec<Option<Sender<Flit>>> = (0..16).map(|_| None).collect();
        let mut sw2_slave_rx: Vec<Option<Receiver<Flit>>> =
            (0..n_feeds + n_combos).map(|_| None).collect();
        let mut sw2_master_tx: Vec<Option<Sender<Flit>>> =
            (0..n_feeds + n_combos).map(|_| None).collect();

        let mut input_dmas = Vec::new();
        let mut output_dmas: BTreeMap<(bool, usize), std::thread::JoinHandle<(Vec<f32>, DmaReport)>> =
            BTreeMap::new();
        let mut pblock_inputs: BTreeMap<usize, Receiver<Flit>> = BTreeMap::new();

        // Input DMA per active pblock (fixed channel per pblock, Fig 6) and
        // the pblock-output → switch-1-slave links. Pblocks fed by the same
        // stream share one host buffer — the DMA channels read it
        // concurrently, like the board's DMA engines reading one DDR
        // region, instead of each owning a copy.
        let mut stream_bufs: BTreeMap<usize, Arc<Vec<f32>>> = BTreeMap::new();
        for p in &active {
            stream_bufs
                .entry(p.stream)
                .or_insert_with(|| Arc::new(self.streams[p.stream].data.clone()));
        }
        let mut pblock_out_tx: BTreeMap<usize, Sender<Flit>> = BTreeMap::new();
        for p in &active {
            let ds = &self.streams[p.stream];
            let (tx, rx) = Port::link();
            input_dmas.push((
                p.id,
                InputDma::spawn(
                    format!("dma-in-{}", p.id),
                    Arc::clone(&stream_bufs[&p.stream]),
                    ds.d,
                    chunk,
                    cfg.non_finite,
                    tx,
                ),
            ));
            pblock_inputs.insert(p.id, rx);
            let (pb_tx, pb_rx) = Port::link();
            sw1_slave_rx[p.id - 1] = Some(pb_rx);
            pblock_out_tx.insert(p.id, pb_tx);
        }

        // Switch-1 master endpoints.
        let mut combo_feed_rx: BTreeMap<(usize, usize), Receiver<Flit>> = BTreeMap::new();
        for (m, role) in sw1_roles.iter().enumerate() {
            match role {
                Sw1Role::DirectOut(id) => {
                    let (tx, rx) = Port::link();
                    sw1_master_tx[m] = Some(tx);
                    output_dmas
                        .insert((false, *id), OutputDma::spawn(format!("dma-out-p{id}"), rx));
                }
                Sw1Role::ComboFeed(cid, slot) => {
                    let (tx, rx) = Port::link();
                    sw1_master_tx[m] = Some(tx);
                    combo_feed_rx.insert((*cid, *slot), rx);
                }
            }
        }

        // Switch-2 wiring: feeds in config order.
        let mut feed_idx = 0usize;
        let mut combo_input_rx: BTreeMap<usize, Vec<Receiver<Flit>>> = BTreeMap::new();
        for c in &cfg.combos {
            let mut ports = Vec::new();
            for slot in 0..c.inputs.len() {
                // slave side: receiver produced by switch-1 master pump
                let rx = combo_feed_rx.remove(&(c.id, slot)).expect("feed exists");
                sw2_slave_rx[feed_idx] = Some(rx);
                // master side: link to the combo's input port
                let (tx, port_rx) = Port::link();
                sw2_master_tx[feed_idx] = Some(tx);
                ports.push(port_rx);
                feed_idx += 1;
            }
            combo_input_rx.insert(c.id, ports);
        }
        let mut combo_out_tx: BTreeMap<usize, Sender<Flit>> = BTreeMap::new();
        for (ci, c) in cfg.combos.iter().enumerate() {
            let (tx, rx) = Port::link();
            sw2_slave_rx[n_feeds + ci] = Some(rx);
            combo_out_tx.insert(c.id, tx);
            let (out_tx, out_rx) = Port::link();
            sw2_master_tx[n_feeds + ci] = Some(out_tx);
            output_dmas.insert((true, c.id), OutputDma::spawn(format!("dma-out-c{}", c.id), out_rx));
        }

        // ---- Spawn the crossbars.
        let sw1_run = sw1.spawn(sw1_slave_rx, sw1_master_tx)?;
        let sw2_run = if n_feeds + n_combos > 0 {
            Some(sw2.spawn(sw2_slave_rx, sw2_master_tx)?)
        } else {
            None
        };

        // ---- Combo engines (built before the scope so threads can move them).
        let mut combo_threads = Vec::new();
        for c in &cfg.combos {
            let engine = self.combo_engine(c)?;
            let inputs = combo_input_rx.remove(&c.id).unwrap();
            let tx = combo_out_tx.remove(&c.id).unwrap();
            // Quarantine guards: when the fault ladder isolates an input
            // partition, the combo drops it from the lock-step join and
            // renormalizes instead of failing on the closed channel.
            let guards: Vec<Option<Arc<Decoupler>>> = c
                .inputs
                .iter()
                .map(|&id| Some(Arc::clone(&self.pblocks[id - 1].decoupler)))
                .collect();
            let cid = c.id;
            combo_threads.push(
                std::thread::Builder::new()
                    .name(format!("combo-{cid}"))
                    .spawn(move || combo::service_guarded(&engine, inputs, guards, tx))
                    .expect("spawn combo"),
            );
        }

        // ---- Adaptive reconfiguration controller. Spawned last, after
        //      every fallible `?` above, so an early setup error can never
        //      leak the thread: from here the next exit point is the
        //      stop/join right after the service scope.
        let controller = if cfg.dfx.adaptive {
            let mut targets = Vec::new();
            for p in &active {
                let Some(kind) = kind_of(p.rm) else { continue };
                let pb = &self.pblocks[p.id - 1];
                if !pb.decoupler.is_enabled() {
                    continue;
                }
                pb.ctl.stats.arm(cfg.dfx.window, cfg.dfx.baseline);
                let ds = &self.streams[p.stream];
                targets.push(ControllerTarget {
                    pblock: p.id,
                    ctl: Arc::clone(&pb.ctl),
                    kind,
                    d: ds.d,
                    warmup: ds.warmup(cfg.hyper.window).to_vec(),
                    seed: pblock_seed(cfg.seed, p.id),
                    lanes: cfg.lanes_for(p),
                });
            }
            let env = ControllerEnv {
                dfx: self.dfx.clone(),
                cfg: cfg.dfx.clone(),
                hyper: cfg.hyper,
                chunk,
                quantize: cfg.use_fpga,
                fpga: self.runtime.as_ref().map(|rt| (rt.handle(), rt.registry().clone())),
            };
            let stop = Arc::new(AtomicBool::new(false));
            let handle = hotswap::spawn_controller(env, targets, Arc::clone(&stop));
            Some((stop, handle))
        } else {
            None
        };

        // ---- Fault supervisor: watchdog + retry→reload→quarantine ladder.
        //      Same spawn discipline as the controller — after every
        //      fallible `?`, stopped and joined before any early return.
        let fault_supervisor = if faults_on {
            let mut targets = Vec::new();
            for p in &active {
                let Some(kind) = kind_of(p.rm) else { continue };
                let pb = &self.pblocks[p.id - 1];
                let ds = &self.streams[p.stream];
                targets.push(SupervisorTarget {
                    pblock: p.id,
                    ctl: Arc::clone(&pb.ctl),
                    decoupler: Arc::clone(&pb.decoupler),
                    kind,
                    r: p.r,
                    d: ds.d,
                    seed: pblock_seed(cfg.seed, p.id),
                    warmup: ds.warmup(cfg.hyper.window).to_vec(),
                    lanes: cfg.lanes_for(p),
                    quantize: cfg.use_fpga,
                });
            }
            let env = SupervisorEnv {
                dfx: self.dfx.clone(),
                faults: cfg.faults.clone(),
                hyper: cfg.hyper,
                chunk,
                samples_per_sec: cfg.dfx.samples_per_sec,
                policy: cfg.dfx.policy,
            };
            let stop = Arc::new(AtomicBool::new(false));
            let handle = supervisor::spawn_supervisor(env, targets, Arc::clone(&stop));
            Some((stop, handle))
        } else {
            None
        };

        // ---- Pblock service threads (scoped: they borrow the RMs).
        let t0 = Instant::now();
        let mut pblock_reports: BTreeMap<usize, PblockReport> = BTreeMap::new();
        let mut service_err: Option<anyhow::Error> = None;
        {
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for pb in self.pblocks.iter_mut() {
                    let Some(rx) = pblock_inputs.remove(&pb.id) else { continue };
                    let Some(tx) = pblock_out_tx.remove(&pb.id) else { continue };
                    let id = pb.id;
                    let dec = Arc::clone(&pb.decoupler);
                    let ctl = Arc::clone(&pb.ctl);
                    // Disjoint field borrows: the service thread mutates the
                    // RM while sharing the partition's resident lane pool.
                    let pool = pb.pool.as_ref();
                    let rm = &mut pb.rm;
                    let mode = cfg.exec;
                    handles.push((
                        id,
                        s.spawn(move || Pblock::service_mode(rm, &dec, &ctl, rx, tx, mode, pool)),
                    ));
                }
                for (id, h) in handles.drain(..) {
                    match h.join() {
                        Ok(Ok(rep)) => {
                            pblock_reports.insert(id, rep);
                        }
                        Ok(Err(e)) => service_err = Some(e.context(format!("pblock {id}"))),
                        Err(_) => service_err = Some(anyhow::anyhow!("pblock {id} panicked")),
                    }
                }
            });
        }
        // Stop the controller before any early return so its thread never
        // outlives the pass.
        let adaptive_swaps_issued = match controller {
            Some((stop, handle)) => {
                stop.store(true, Ordering::SeqCst);
                handle.join().map_err(|_| anyhow::anyhow!("dfx controller panicked"))?
            }
            None => 0,
        };
        if let Some((stop, handle)) = fault_supervisor {
            stop.store(true, Ordering::SeqCst);
            handle.join().map_err(|_| anyhow::anyhow!("fault supervisor panicked"))?;
        }
        if let Some(e) = service_err {
            return Err(e);
        }

        // ---- Drain and collect.
        let mut out =
            RunOutput { modeled_fpga_secs: modeled, adaptive_swaps_issued, ..Default::default() };
        // Executed swaps: record the events and track the new assignments
        // in the config, so the next run wires (and reports) what is
        // actually loaded.
        for pb in &self.pblocks {
            let evs = pb.ctl.swap.take_events();
            for ev in &evs {
                if let Some(pcfg) = self.cfg.pblocks.iter_mut().find(|p| p.id == ev.pblock) {
                    pcfg.rm = ev.to_kind;
                    pcfg.r = ev.r;
                }
            }
            out.swap_events.extend(evs);
        }
        out.swap_events.sort_by_key(|e| (e.at_flit, e.pblock));
        // Fault campaign epilogue: collect the event log and disarm the
        // per-flit hooks so a later pass without faults runs the plain
        // (bit-transparent) service loop. A rung-2 quarantine stays latched
        // across passes — the region is untrusted until reconfigured.
        if faults_on {
            for pb in &self.pblocks {
                out.fault_events.extend(pb.ctl.faults.take_events());
                pb.ctl.health.disarm();
                pb.ctl.faults.clear_pending();
            }
            out.fault_events.sort_by_key(|e| (e.at_flit, e.pblock));
        }
        // A swap may have put a multi-lane detector into a partition that
        // had no pool (or changed what the pool should serve): re-sync the
        // resident workers so the next run scores with full lane
        // parallelism instead of silently falling back to inline.
        self.ensure_lane_pools();
        // Input DMAs first: an ingress rejection (`non_finite = "error"`)
        // also collapses the downstream joins, and its diagnostic — naming
        // the offending sample — must win over the secondary failures.
        for (id, h) in input_dmas {
            let rep = h
                .join()
                .map_err(|_| anyhow::anyhow!("input dma panicked"))?
                .with_context(|| format!("input dma for pblock {id}"))?;
            out.dma_reports.insert(id, rep);
        }
        for t in combo_threads {
            t.join().map_err(|_| anyhow::anyhow!("combo thread panicked"))??;
        }
        out.switch_flits = sw1_run.join() + sw2_run.map(|r| r.join()).unwrap_or(0);
        for ((is_combo, id), h) in output_dmas {
            let (scores, _rep) = h.join().map_err(|_| anyhow::anyhow!("output dma panicked"))?;
            if is_combo {
                out.combo_scores.insert(id, scores);
            } else {
                out.pblock_scores.insert(id, scores);
            }
        }
        out.pblock_reports = pblock_reports;
        out.wall_secs = t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Detector kinds currently loaded, by pblock id (for reporting).
    pub fn assignments(&self) -> Vec<(usize, String)> {
        self.cfg
            .pblocks
            .iter()
            .map(|p| {
                (
                    p.id,
                    match p.rm {
                        RmKind::Detector(k) => {
                            let lanes = self.cfg.lanes_for(p).min(p.r.max(1));
                            if lanes > 1 && !self.cfg.use_fpga {
                                format!("{}(r={},lanes={lanes})", k.as_str(), p.r)
                            } else {
                                format!("{}(r={})", k.as_str(), p.r)
                            }
                        }
                        other => other.as_str().to_string(),
                    },
                )
            })
            .collect()
    }
}

/// Convenience: detector kind of a pblock config, if any.
pub fn kind_of(rm: RmKind) -> Option<DetectorKind> {
    match rm {
        RmKind::Detector(k) => Some(k),
        _ => None,
    }
}

/// Per-pblock parameter seed. One formula shared by the one-shot fabric and
/// the session server ([`crate::fabric::server`]), so a server session on
/// pblock `id` builds bit-identical detector parameters to a `Fabric::run`
/// pass — the foundation of the server-vs-fabric parity tests.
pub fn pblock_seed(base: u64, id: usize) -> u64 {
    base.wrapping_add(id as u64 * 1009)
}
